"""Mixture-of-Experts layer: top-k routing, capacity-bounded gather dispatch,
expert-parallel over the "experts" logical axis (-> mesh "model" axis).

Dispatch strategy (SPMD- and memory-friendly at 1M-token batches): tokens are
grouped by their batch row (one group per sequence) and each expert gathers
its top-C tokens per group by router score — the standard capacity-factor
dropping formulation, realized with gather/scatter instead of a dense
[tokens, experts, capacity] one-hot, so peak memory is
[groups, experts, capacity, d_model] sharded over both batch (data) and
experts (model). XLA SPMD inserts the all-to-all-equivalent collectives.

DeepSeek-style shared experts are a dense FFN added unconditionally.
A load-balance auxiliary loss (Switch-style) is returned alongside.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import ffn, ffn_defs
from .params import ParamDef


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    activation: str = "silu"
    router_dtype: object = jnp.float32


def moe_defs(cfg: MoEConfig, dtype=jnp.bfloat16) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    defs = {
        "router": ParamDef((d, e), ("embed", None), dtype=jnp.float32,
                           init="scaled"),
        # Expert hidden uses "moe_mlp" (replicated by default): the expert
        # dim already takes the "model" mesh axis (EP) and a PartitionSpec
        # cannot map one mesh axis to two tensor dims.
        "wi": ParamDef((e, d, f), ("experts", "embed", "moe_mlp"),
                       dtype=dtype, init="scaled"),
        "wg": ParamDef((e, d, f), ("experts", "embed", "moe_mlp"),
                       dtype=dtype, init="scaled"),
        "wo": ParamDef((e, f, d), ("experts", "moe_mlp", "embed"),
                       dtype=dtype, init="scaled"),
    }
    if cfg.n_shared_experts:
        defs["shared"] = ffn_defs(
            d, cfg.d_ff_shared or f * cfg.n_shared_experts, gated=True,
            dtype=dtype)
    return defs


def _capacity(cfg: MoEConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            / cfg.n_experts) + 1
    return min(max(cfg.top_k, c), tokens_per_group)


def moe_ffn(p, cfg: MoEConfig, x):
    """x: [B, S, D] -> (y: [B, S, D], aux_loss scalar).

    B is the group axis; capacity is per (group, expert).

    Decode (S == 1): tokens regroup across the batch into one group —
    per-row grouping would clamp capacity to top_k PER EXPERT PER TOKEN
    (64 experts x 6 slots for 1 token x 6 assignments = 64x wasted expert
    compute; measured 11x total flops on deepseek-v2-lite/decode_32k,
    EXPERIMENTS.md §Perf iteration 6).
    """
    b, s, d = x.shape
    if s == 1 and b > 1:
        y, aux = moe_ffn(p, cfg, x.reshape(1, b, d))
        return y.reshape(b, s, d), aux
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, s)

    logits = (x.astype(cfg.router_dtype)
              @ p["router"].astype(cfg.router_dtype))        # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                     # [B,S,k]
    # normalized combine weights over the selected experts
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # per-token-per-expert score (0 if not selected)
    sel = jax.nn.one_hot(topi, e, dtype=probs.dtype)          # [B,S,k,E]
    score = (sel * topv[..., None]).sum(axis=2)               # [B,S,E]

    # each expert takes its top-C tokens per group by score
    score_t = score.swapaxes(1, 2)                            # [B,E,S]
    gate_c, idx_c = jax.lax.top_k(score_t, cap)               # [B,E,C]
    keep = (gate_c > 0).astype(x.dtype)

    xe = jnp.take_along_axis(
        x[:, None], idx_c[..., None], axis=2)                 # [B,E,C,D]
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe,
                               p["wg"].astype(x.dtype)))
    h = h * jnp.einsum("becd,edf->becf", xe, p["wi"].astype(x.dtype))
    ye = jnp.einsum("becf,efd->becd", h, p["wo"].astype(x.dtype))
    ye = ye * (gate_c.astype(x.dtype) * keep)[..., None]

    # scatter-add back to token positions
    y = jnp.zeros_like(x)
    flat_idx = idx_c                                           # [B,E,C]
    y = jax.vmap(lambda yb, ib, vb: yb.at[ib.reshape(-1)].add(
        vb.reshape(-1, d)))(y, flat_idx, ye)

    if cfg.n_shared_experts:
        y = y + ffn(p["shared"], x, cfg.activation)

    # Switch-style load balance aux loss
    frac_tokens = (score > 0).astype(jnp.float32).mean(axis=(0, 1))
    frac_probs = probs.mean(axis=(0, 1)).astype(jnp.float32)
    aux = e * (frac_tokens * frac_probs).sum()
    return y, aux
