"""Decoder-only transformer LM covering the dense / MoE / MLA families.

One config class (`LMConfig`) describes all six dense archs, the two MoE
archs (incl. DeepSeek-MLA) and the text backbones of the VLM.  Layers are
grouped into homogeneous runs (e.g. DeepSeek-V2-Lite = 1 dense + 26 MoE
layers) and each run is a `lax.scan` over stacked parameters with
`jax.checkpoint` on the body — compile time and activation memory stay
bounded at 95-layer scale.

Entry points (the Model protocol used by launch/ and configs/):
  * train_loss(params, batch, rng)      -> (loss, metrics)
  * prefill(params, tokens)             -> (last-position logits, cache)
  * decode_step(params, cache, token, cur_len) -> (logits, cache)
plus param_defs() / cache_defs() metadata for init, sharding and the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .attention import AttnConfig, MLAConfig
from .layers import (chunked_softmax_xent, embed, embed_defs, ffn, ffn_defs,
                     logits_last, rmsnorm, rmsnorm_defs, unembed_defs)
from .moe import MoEConfig, moe_defs, moe_ffn
from .params import ParamDef, stack_defs


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    activation: str = "silu"
    gated_ffn: bool = True
    qk_norm: bool = False
    rope_theta: float = 10000.0
    embed_scale: bool = False            # gemma-style sqrt(d) embed scaling
    zero_centered_norm: bool = False     # gemma-style (1 + scale) RMSNorm
    # attention family
    attention: str = "gqa"               # "gqa" | "mla"
    mla_kv_rank: int = 512
    mla_qk_nope: int = 128
    mla_qk_rope: int = 64
    mla_v_dim: int = 128
    # MoE (None -> dense)
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    # execution
    dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunk: int = 512
    kv_chunk: int = 1024
    aux_loss_weight: float = 0.01

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def attn_config(self) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                          self.hd, self.rope_theta, self.qk_norm,
                          kv_chunk=self.kv_chunk)

    def mla_config(self) -> MLAConfig:
        return MLAConfig(self.d_model, self.n_heads, self.mla_kv_rank,
                         self.mla_qk_nope, self.mla_qk_rope, self.mla_v_dim,
                         self.rope_theta, kv_chunk=self.kv_chunk)

    def moe_config(self) -> MoEConfig:
        return MoEConfig(self.d_model, self.n_experts, self.top_k,
                         self.moe_d_ff or self.d_ff,
                         self.n_shared_experts,
                         self.n_shared_experts * (self.moe_d_ff or self.d_ff),
                         activation=self.activation)

    def groups(self) -> list[tuple[str, int]]:
        """Homogeneous layer runs: [(kind, count)]."""
        if not self.is_moe:
            return [("dense", self.n_layers)]
        out = []
        if self.first_dense_layers:
            out.append(("dense", self.first_dense_layers))
        out.append(("moe", self.n_layers - self.first_dense_layers))
        return out


class TransformerLM:
    def __init__(self, cfg: LMConfig):
        self.cfg = cfg

    # -- parameter / cache metadata -----------------------------------------

    def _layer_defs(self, kind: str) -> dict:
        cfg = self.cfg
        if cfg.attention == "mla":
            attn = attn_mod.mla_defs(cfg.mla_config(), cfg.dtype)
        else:
            attn = attn_mod.gqa_defs(cfg.attn_config(), cfg.dtype)
        if kind == "moe":
            mixer = moe_defs(cfg.moe_config(), cfg.dtype)
        else:
            mixer = ffn_defs(cfg.d_model, cfg.d_ff, cfg.gated_ffn, cfg.dtype)
        return {
            "ln1": rmsnorm_defs(cfg.d_model),
            "attn": attn,
            "ln2": rmsnorm_defs(cfg.d_model),
            "mixer": mixer,
        }

    def param_defs(self):
        cfg = self.cfg
        defs = {
            "embed": embed_defs(cfg.vocab, cfg.d_model, cfg.dtype),
            "final_norm": rmsnorm_defs(cfg.d_model),
            "unembed": unembed_defs(cfg.d_model, cfg.vocab, cfg.dtype),
        }
        for gi, (kind, count) in enumerate(cfg.groups()):
            defs[f"layers_{gi}_{kind}"] = stack_defs(
                self._layer_defs(kind), count)
        return defs

    def cache_defs(self, batch: int, max_len: int):
        """ParamDef pytree for the decode cache (dry-run + serving init)."""
        cfg = self.cfg
        caches = {}
        for gi, (kind, count) in enumerate(cfg.groups()):
            if cfg.attention == "mla":
                caches[f"layers_{gi}_{kind}"] = {
                    "ckv": ParamDef((count, batch, max_len, cfg.mla_kv_rank),
                                    ("stack", "batch", "kv_seq", None),
                                    dtype=cfg.dtype, init="zeros"),
                    "kr": ParamDef((count, batch, max_len, cfg.mla_qk_rope),
                                   ("stack", "batch", "kv_seq", None),
                                   dtype=cfg.dtype, init="zeros"),
                }
            else:
                kv_shape = (count, batch, max_len, cfg.n_kv_heads, cfg.hd)
                axes = ("stack", "batch", "kv_seq", "kv_heads", "head_dim")
                caches[f"layers_{gi}_{kind}"] = {
                    "k": ParamDef(kv_shape, axes, dtype=cfg.dtype,
                                  init="zeros"),
                    "v": ParamDef(kv_shape, axes, dtype=cfg.dtype,
                                  init="zeros"),
                }
        return caches

    # -- forward -------------------------------------------------------------

    def _mix(self, kind, p, h_norm):
        cfg = self.cfg
        if kind == "moe":
            return moe_ffn(p, cfg.moe_config(), h_norm)
        return ffn(p, h_norm, cfg.activation), 0.0

    def _layer_full(self, kind, p, h, positions):
        cfg = self.cfg
        hn = rmsnorm(p["ln1"], h, zero_centered=cfg.zero_centered_norm)
        if cfg.attention == "mla":
            a, kv = attn_mod.mla_attention(p["attn"], cfg.mla_config(), hn,
                                           positions)
        else:
            a, kv = attn_mod.gqa_attention(p["attn"], cfg.attn_config(), hn,
                                           positions)
        h = h + a
        hn = rmsnorm(p["ln2"], h, zero_centered=cfg.zero_centered_norm)
        f, aux = self._mix(kind, p["mixer"], hn)
        return h + f, kv, aux

    def _embed_tokens(self, params, tokens):
        cfg = self.cfg
        h = embed(params["embed"], tokens).astype(cfg.dtype)
        if cfg.embed_scale:
            h = h * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
        return h

    def _backbone(self, params, h, positions, collect_cache=False):
        """Run all layer groups. Returns (h, caches, aux_total)."""
        cfg = self.cfg
        caches, aux_total = {}, 0.0
        for gi, (kind, count) in enumerate(cfg.groups()):
            name = f"layers_{gi}_{kind}"

            def body(carry, lp, kind=kind):
                h, aux = carry
                h, kv, aux_l = self._layer_full(kind, lp, h, positions)
                ys = kv if collect_cache else None
                return (h, aux + aux_l), ys

            scan_body = jax.checkpoint(body) if cfg.remat else body
            (h, aux_total), ys = jax.lax.scan(
                scan_body, (h, aux_total), params[name])
            if collect_cache:
                caches[name] = ys
        return h, caches, aux_total

    def apply_backbone(self, params, h, positions):
        """Expose hidden-state pipeline for wrappers (VLM)."""
        h, _, aux = self._backbone(params, h, positions)
        h = rmsnorm(params["final_norm"], h,
                    zero_centered=self.cfg.zero_centered_norm)
        return h, aux

    def train_loss(self, params, batch, rng=None):
        """batch: {tokens [B,S], labels [B,S], (mask [B,S])}."""
        cfg = self.cfg
        tokens = batch["tokens"]
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]),
                                     tokens.shape)
        h = self._embed_tokens(params, tokens)
        h, _, aux = self._backbone(params, h, positions)
        h = rmsnorm(params["final_norm"], h,
                    zero_centered=cfg.zero_centered_norm)
        loss, _ = chunked_softmax_xent(
            params["unembed"], h, batch["labels"], batch.get("mask"),
            chunk=min(cfg.loss_chunk, tokens.shape[1]))
        metrics = {"xent": loss, "aux": aux}
        return loss + cfg.aux_loss_weight * aux, metrics

    @property
    def supports_chunked_prefill(self) -> bool:
        """Whether bucketed / chunked prefill is bit-exact for this config.

        MoE routing is sequence-global (expert capacity is a function of
        the sequence length and top-C token selection competes across all
        positions), so padded or chunked prefill changes MoE outputs — MoE
        models keep the exact-length whole-prompt path.
        """
        return not self.cfg.is_moe

    def prefill(self, params, tokens, max_len: int | None = None,
                last_idx=None):
        """Process a full prompt; returns (last logits [B,V], cache).

        ``last_idx``: optional (traced) index of the row to read logits
        from — the true last prompt position when ``tokens`` is zero-padded
        to a length bucket.  Defaults to the final row, matching the
        unpadded behaviour.
        """
        cfg = self.cfg
        b, s = tokens.shape
        max_len = max_len or s
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        h = self._embed_tokens(params, tokens)
        h, raw, _ = self._backbone(params, h, positions, collect_cache=True)
        h = rmsnorm(params["final_norm"], h,
                    zero_centered=cfg.zero_centered_norm)
        cache = {}
        for name, kv in raw.items():
            if cfg.attention == "mla":
                ckv, kr = kv
                pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0))
                cache[name] = {"ckv": jnp.pad(ckv, pad),
                               "kr": jnp.pad(kr, pad)}
            else:
                k, v = kv
                pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
                cache[name] = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
        if last_idx is None:
            h_last = h[:, -1]
        else:
            h_last = jax.lax.dynamic_slice_in_dim(
                h, last_idx, 1, axis=1)[:, 0]
        return logits_last(params["unembed"], h_last), cache

    def prefill_chunk(self, params, tokens, cache, start, *, kv_len: int,
                      last_idx=None):
        """Resume a prompt into an existing KV cache: one prefill chunk.

        tokens: [B, C] — prompt positions [start, start+C); ``cache`` is a
        full decode-cache pytree (``cache_defs`` layout, leaves
        [L, B, Smax, ...]) holding earlier chunks at their absolute
        positions.  ``start`` is traced (one jit variant per (C, kv_len),
        not per offset); ``kv_len`` is static — attention reads the first
        ``kv_len`` cache rows, the prompt's pow2 length bucket, so every
        row is bit-identical to a whole-bucket prefill (see
        attention.gqa_prefill_chunk).  ``last_idx``: chunk-local index of
        the final prompt token; when given, returns its logits row
        (otherwise the chunk's last row).

        Returns (logits [B, V], updated cache).  MoE configs are rejected
        — see ``supports_chunked_prefill``.
        """
        cfg = self.cfg
        if not self.supports_chunked_prefill:
            raise NotImplementedError(
                "chunked prefill is not bit-exact for MoE configs "
                "(sequence-global router capacity); use whole-prompt "
                "prefill")
        h = self._embed_tokens(params, tokens)
        new_cache = {}
        for gi, (kind, count) in enumerate(cfg.groups()):
            name = f"layers_{gi}_{kind}"

            def body(h, xs, kind=kind):
                lp, lcache = xs
                hn = rmsnorm(lp["ln1"], h,
                             zero_centered=cfg.zero_centered_norm)
                if cfg.attention == "mla":
                    a, ckv, kr = attn_mod.mla_prefill_chunk(
                        lp["attn"], cfg.mla_config(), hn, lcache["ckv"],
                        lcache["kr"], start, kv_len)
                    upd = {"ckv": ckv, "kr": kr}
                else:
                    a, k, v = attn_mod.gqa_prefill_chunk(
                        lp["attn"], cfg.attn_config(), hn, lcache["k"],
                        lcache["v"], start, kv_len)
                    upd = {"k": k, "v": v}
                h = h + a
                hn = rmsnorm(lp["ln2"], h,
                             zero_centered=cfg.zero_centered_norm)
                f, _ = self._mix(kind, lp["mixer"], hn)
                return h + f, upd

            h, upd = jax.lax.scan(body, h, (params[name], cache[name]))
            new_cache[name] = upd
        h = rmsnorm(params["final_norm"], h,
                    zero_centered=cfg.zero_centered_norm)
        if last_idx is None:
            h_last = h[:, -1]
        else:
            h_last = jax.lax.dynamic_slice_in_dim(
                h, last_idx, 1, axis=1)[:, 0]
        return logits_last(params["unembed"], h_last), new_cache

    def decode_step(self, params, cache, tokens, cur_len):
        """tokens: [B, 1]; cur_len: current cache fill — a scalar int, or a
        [B] int vector of per-slot lengths for continuous batching (each
        batch slot decodes at its own position; see runtime/engine.py).

        Returns (logits [B, V], new cache).
        """
        cfg = self.cfg
        h = self._embed_tokens(params, tokens)
        new_cache = {}
        for gi, (kind, count) in enumerate(cfg.groups()):
            name = f"layers_{gi}_{kind}"

            def body(h, xs, kind=kind):
                lp, lcache = xs
                hn = rmsnorm(lp["ln1"], h,
                             zero_centered=cfg.zero_centered_norm)
                if cfg.attention == "mla":
                    a, ckv, kr = attn_mod.mla_decode(
                        lp["attn"], cfg.mla_config(), hn, lcache["ckv"],
                        lcache["kr"], cur_len)
                    upd = {"ckv": ckv, "kr": kr}
                else:
                    a, k, v = attn_mod.gqa_decode(
                        lp["attn"], cfg.attn_config(), hn, lcache["k"],
                        lcache["v"], cur_len)
                    upd = {"k": k, "v": v}
                h = h + a
                hn = rmsnorm(lp["ln2"], h,
                             zero_centered=cfg.zero_centered_norm)
                f, _ = self._mix(kind, lp["mixer"], hn)
                return h + f, upd

            h, upd = jax.lax.scan(body, h, (params[name], cache[name]))
            new_cache[name] = upd
        h = rmsnorm(params["final_norm"], h,
                    zero_centered=cfg.zero_centered_norm)
        return logits_last(params["unembed"], h[:, -1]), new_cache
