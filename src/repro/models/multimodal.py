"""VLM wrapper (pixtral-12b backbone): text decoder + projected patch prefix.

Per the brief the ViT frontend is a STUB: ``input_specs`` provides precomputed
patch embeddings [B, n_patches, d_vit]; a 2-layer MLP projector maps them into
the text model's embedding space and they are *prepended* to the token
sequence (total sequence budget = n_patches + text tokens = the assigned
seq_len).  Loss is computed on text positions only.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import (chunked_softmax_xent, embed, logits_last, rmsnorm)
from .params import ParamDef
from .transformer import LMConfig, TransformerLM


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    lm: LMConfig
    n_patches: int = 256
    d_vit: int = 1024


class VLM:
    def __init__(self, cfg: VLMConfig):
        self.cfg = cfg
        self.lm = TransformerLM(cfg.lm)

    def param_defs(self):
        c = self.cfg
        defs = self.lm.param_defs()
        defs["projector"] = {
            "w1": ParamDef((c.d_vit, c.lm.d_model), (None, "embed"),
                           dtype=c.lm.dtype, init="scaled"),
            "w2": ParamDef((c.lm.d_model, c.lm.d_model), ("embed", None),
                           dtype=c.lm.dtype, init="scaled"),
        }
        return defs

    def cache_defs(self, batch: int, max_len: int):
        return self.lm.cache_defs(batch, max_len)

    def _prefix(self, params, patches):
        p = params["projector"]
        h = jax.nn.gelu(patches.astype(self.cfg.lm.dtype)
                        @ p["w1"].astype(self.cfg.lm.dtype))
        return h @ p["w2"].astype(self.cfg.lm.dtype)

    def _embed_all(self, params, patches, tokens):
        prefix = self._prefix(params, patches)               # [B,P,D]
        text = self.lm._embed_tokens(params, tokens)         # [B,S,D]
        return jnp.concatenate([prefix, text], axis=1)

    def train_loss(self, params, batch, rng=None):
        """batch: {patches [B,P,dv], tokens [B,St], labels [B,St]}."""
        c = self.cfg
        h = self._embed_all(params, batch["patches"], batch["tokens"])
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        h, aux = self.lm.apply_backbone(params, h, positions)
        # text positions only
        h_text = h[:, c.n_patches:]
        loss, _ = chunked_softmax_xent(
            params["unembed"], h_text, batch["labels"], batch.get("mask"),
            chunk=min(c.lm.loss_chunk, h_text.shape[1]))
        return loss + c.lm.aux_loss_weight * aux, {"xent": loss, "aux": aux}

    def prefill(self, params, tokens, patches, max_len: int | None = None):
        """Returns (last logits, cache). Cache spans patches + text."""
        h = self._embed_all(params, patches, tokens)
        b, s, _ = h.shape
        max_len = max_len or s
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        # run the LM's internals with prefix embeddings
        hh, raw, _ = self.lm._backbone(params, h, positions,
                                       collect_cache=True)
        hh = rmsnorm(params["final_norm"], hh)
        cache = {}
        for name, kv in raw.items():
            k, v = kv
            pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
            cache[name] = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
        return logits_last(params["unembed"], hh[:, -1]), cache

    def decode_step(self, params, cache, tokens, cur_len):
        return self.lm.decode_step(params, cache, tokens, cur_len)
