"""Mamba2 block via the SSD (state-space duality) chunked algorithm.

Training/prefill runs the matmul-friendly chunked form (intra-chunk quadratic
attention-like term + inter-chunk state scan) — this is the MXU-suited
formulation from the Mamba2 paper.  Decode runs the O(1) recurrence with a
(conv window, SSM state) cache.

Sharding: heads / d_inner over the "ssm" logical axis (-> mesh "model");
the SSM state [B, H, P, N] shards batch over data and heads over model.
ngroups = 1 (B/C shared across heads), per-head scalar decay A.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import rmsnorm, rmsnorm_defs
from .params import ParamDef
from .sharding_ctx import hint


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_inner: int
    d_state: int
    head_dim: int = 64
    d_conv: int = 4
    chunk: int = 128

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.d_state


def mamba2_defs(cfg: SSMConfig, dtype=jnp.bfloat16) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    return {
        "wz": ParamDef((d, di), ("embed", "ssm"), dtype=dtype, init="scaled"),
        "wx": ParamDef((d, di), ("embed", "ssm"), dtype=dtype, init="scaled"),
        "wB": ParamDef((d, n), ("embed", None), dtype=dtype, init="scaled"),
        "wC": ParamDef((d, n), ("embed", None), dtype=dtype, init="scaled"),
        "wdt": ParamDef((d, h), ("embed", "ssm"), dtype=dtype, init="scaled"),
        "conv": ParamDef((cfg.d_conv, cfg.conv_channels), (None, "ssm"),
                         dtype=dtype, init="scaled"),
        "a_log": ParamDef((h,), ("ssm",), dtype=jnp.float32, init="zeros"),
        "d_skip": ParamDef((h,), ("ssm",), dtype=jnp.float32, init="ones"),
        "dt_bias": ParamDef((h,), ("ssm",), dtype=jnp.float32, init="zeros"),
        "norm": rmsnorm_defs(di),
        "wo": ParamDef((di, d), ("ssm", "embed"), dtype=dtype, init="scaled"),
    }


def _causal_conv(xbc, kernel):
    """Depthwise causal conv. xbc: [B, L, C]; kernel: [W, C]."""
    w = kernel.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, kernel[:, None, :].astype(xbc.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=kernel.shape[1])
    return out


def _proj_inputs(p, cfg: SSMConfig, x):
    z = x @ p["wz"].astype(x.dtype)
    xs = x @ p["wx"].astype(x.dtype)
    bb = x @ p["wB"].astype(x.dtype)
    cc = x @ p["wC"].astype(x.dtype)
    dt = (x @ p["wdt"].astype(x.dtype)).astype(jnp.float32)
    return z, xs, bb, cc, dt


def mamba2_block(p, cfg: SSMConfig, x):
    """Full-sequence SSD. x: [B, L, D] -> (y [B, L, D], final (conv, ssm) state).

    hint() calls pin (batch -> data, d_inner/heads -> model) through the
    chunked einsums and the inter-chunk scan — without them GSPMD leaves the
    batch dim replicated inside the layer scan (measured on
    mamba2-1.3b/train_4k: conv/elementwise tensors [32, 4096, 272] instead
    of [2, 4096, 272]; EXPERIMENTS.md §Perf iteration 5).
    """
    b, l, _ = x.shape
    q = min(cfg.chunk, l)
    nc, h, pd, n = -(-l // q), cfg.n_heads, cfg.head_dim, cfg.d_state

    x = hint(x, "batch", None, None)
    z, xs, bb, cc, dt = _proj_inputs(p, cfg, x)
    xbc = jnp.concatenate([xs, bb, cc], axis=-1)
    xbc = hint(xbc, "batch", None, "ssm")
    conv_tail = xbc[:, -(cfg.d_conv - 1):, :]        # decode cache seed
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv"]))
    xbc = hint(xbc, "batch", None, "ssm")
    xs, bb, cc = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + n], axis=-1)

    pad = nc * q - l
    if pad:  # no-op padding: dt -> 0 (no decay, no state contribution)
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)),
                     constant_values=-1e9)
    lpad = l + pad

    dt = jax.nn.softplus(dt + p["dt_bias"])                       # [B,L,H]
    a = -jnp.exp(p["a_log"])                                       # [H]
    la = hint((dt * a).reshape(b, nc, q, h),
              "batch", None, None, "ssm")                          # log decay
    xh = hint((xs.reshape(b, lpad, h, pd) * dt[..., None]).reshape(
        b, nc, q, h, pd), "batch", None, None, "ssm", None)        # dt * x
    bc = hint(bb.reshape(b, nc, q, n), "batch", None, None, None)
    cg = hint(cc.reshape(b, nc, q, n), "batch", None, None, None)

    cs = jnp.cumsum(la, axis=2)                                    # [B,C,Q,H]
    # intra-chunk: decay matrix L[t,s] = exp(cs_t - cs_s), t >= s
    dmat = cs[:, :, :, None, :] - cs[:, :, None, :, :]             # [B,C,Q,S,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    dmat = jnp.where(tri[None, None, :, :, None], jnp.exp(dmat), 0.0)
    dmat = hint(dmat, "batch", None, None, None, "ssm")
    g = jnp.einsum("bcqn,bcsn->bcqs", cg, bc,
                   preferred_element_type=jnp.float32)
    y_intra = jnp.einsum("bcqs,bcqsh,bcshp->bcqhp", g, dmat,
                         xh.astype(jnp.float32))
    y_intra = hint(y_intra, "batch", None, None, "ssm", None)

    # chunk states and inter-chunk scan
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)                  # [B,C,Q,H]
    s_chunk = jnp.einsum("bcsn,bcsh,bcshp->bchpn", bc.astype(jnp.float32),
                         decay_to_end, xh.astype(jnp.float32))
    lam = jnp.exp(cs[:, :, -1, :])                                 # [B,C,H]

    def scan_body(hprev, xs_):
        s_c, lam_c = xs_
        s_c = hint(s_c, "batch", "ssm", None, None)
        return hprev * lam_c[..., None, None] + s_c, hprev

    s_cs = s_chunk.swapaxes(0, 1)                                  # [C,B,H,P,N]
    lam_s = lam.swapaxes(0, 1)                                     # [C,B,H]
    h_final, h_prevs = jax.lax.scan(
        scan_body,
        hint(jnp.zeros((b, h, pd, n), jnp.float32),
             "batch", "ssm", None, None), (s_cs, lam_s))
    h_prevs = hint(h_prevs.swapaxes(0, 1),
                   "batch", None, "ssm", None, None)               # [B,C,H,P,N]

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cg.astype(jnp.float32),
                         jnp.exp(cs), h_prevs)
    y = (y_intra + y_inter).reshape(b, lpad, h, pd)[:, :l]
    y = y + p["d_skip"][None, None, :, None] * xs[:, :l].reshape(
        b, l, h, pd).astype(jnp.float32)
    y = y.reshape(b, l, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = y @ p["wo"].astype(x.dtype)
    return out, (conv_tail, h_final)


def mamba2_init_cache(cfg: SSMConfig, batch: int, dtype=jnp.bfloat16):
    conv = jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_channels), dtype)
    state = jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                      jnp.float32)
    return conv, state


def mamba2_decode(p, cfg: SSMConfig, x, cache):
    """One-token recurrence. x: [B, 1, D]; cache = (conv_win, ssm_state)."""
    conv_win, h_state = cache
    b = x.shape[0]
    n, h, pd = cfg.d_state, cfg.n_heads, cfg.head_dim

    z, xs, bb, cc, dt = _proj_inputs(p, cfg, x)
    xbc = jnp.concatenate([xs, bb, cc], axis=-1)                   # [B,1,C]
    window = jnp.concatenate([conv_win, xbc], axis=1)              # [B,W,C]
    conv_out = (window * p["conv"].astype(x.dtype)[None]).sum(axis=1)
    xbc_t = jax.nn.silu(conv_out)                                  # [B,C]
    xs_t, b_t, c_t = jnp.split(xbc_t, [cfg.d_inner, cfg.d_inner + n], axis=-1)

    dt_t = jax.nn.softplus(dt[:, 0] + p["dt_bias"])                # [B,H]
    a_t = jnp.exp(dt_t * (-jnp.exp(p["a_log"])))                   # [B,H]
    xh = (xs_t.reshape(b, h, pd) * dt_t[..., None]).astype(jnp.float32)
    h_state = (h_state * a_t[..., None, None]
               + jnp.einsum("bhp,bn->bhpn", xh, b_t.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", h_state, c_t.astype(jnp.float32))
    y = y + p["d_skip"][None, :, None] * xs_t.reshape(b, h, pd).astype(
        jnp.float32)
    y = y.reshape(b, 1, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = y @ p["wo"].astype(x.dtype)
    return out, (window[:, 1:], h_state)
