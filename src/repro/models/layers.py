"""Shared layer library: norms, projections, embeddings, RoPE, chunked loss.

Pure functions over (params pytree, inputs).  Param structure for each layer
is produced by the matching ``*_defs`` function so init/sharding stay in one
place (see params.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .params import ParamDef

ACT = {
    "silu": jax.nn.silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
}


# --- RMSNorm ---------------------------------------------------------------

def rmsnorm_defs(dim: int) -> dict:
    return {"scale": ParamDef((dim,), ("norm",), init="ones")}


def rmsnorm(p, x, eps: float = 1e-6, zero_centered: bool = False):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = (x * x).mean(axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = p["scale"] + 1.0 if zero_centered else p["scale"]
    return (x * scale).astype(dtype)


# --- Dense -----------------------------------------------------------------

def dense_defs(d_in: int, d_out: int, axes: tuple, dtype=jnp.bfloat16) -> dict:
    return {"w": ParamDef((d_in, d_out), axes, dtype=dtype, init="scaled")}


def dense(p, x):
    return x @ p["w"].astype(x.dtype)


# --- Embedding / unembedding ------------------------------------------------

def embed_defs(vocab: int, d_model: int, dtype=jnp.bfloat16) -> dict:
    # "vocab_in" (replicated) rather than "vocab" (TP): a vocab-sharded
    # lookup table makes GSPMD fully rematerialize the gather (measured:
    # +100 GiB temp on the 152k-vocab train cell). The unembed projection
    # stays vocab-sharded — that one is a matmul and partitions cleanly.
    return {"table": ParamDef((vocab, d_model), ("vocab_in", "embed"),
                              dtype=dtype, init="normal")}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed_defs(d_model: int, vocab: int, dtype=jnp.bfloat16) -> dict:
    return {"w": ParamDef((d_model, vocab), ("embed", "vocab"), dtype=dtype,
                          init="scaled")}


# --- Rotary position embedding ----------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
         rotary_dims: int | None = None) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S]. Rotates first rotary_dims."""
    d = x.shape[-1] if rotary_dims is None else rotary_dims
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # [...,S,half]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x_rot, x_pass = x[..., :d], x[..., d:]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if d < x.shape[-1] else out


# --- FFN (SwiGLU / GeGLU / plain) -------------------------------------------

def ffn_defs(d_model: int, d_ff: int, gated: bool = True,
             dtype=jnp.bfloat16) -> dict:
    defs = {
        "wi": ParamDef((d_model, d_ff), ("embed", "mlp"), dtype=dtype,
                       init="scaled"),
        "wo": ParamDef((d_ff, d_model), ("mlp", "embed"), dtype=dtype,
                       init="scaled"),
    }
    if gated:
        defs["wg"] = ParamDef((d_model, d_ff), ("embed", "mlp"), dtype=dtype,
                              init="scaled")
    return defs


def linear(p, name: str, x):
    """Projection dispatch: PUD bit-plane GeMV when a packed variant exists.

    ``repro.pud.packer.pack_model`` (via ``PUDSession.pack``) replaces
    ``<name>`` with a ``<name>_pud`` ``PackedTensor``; the forward then
    routes through the Pallas bit-plane kernel (the MVDRAM serving path)
    with no model changes.
    """
    packed = p.get(name + "_pud")
    if packed is not None:
        from repro.pud.gemv import pud_linear
        return pud_linear(x, packed).astype(x.dtype)
    return x @ p[name].astype(x.dtype)


def ffn(p, x, activation: str = "silu"):
    act = ACT[activation]
    h = linear(p, "wi", x)
    if "wg" in p or "wg_pud" in p:
        h = act(linear(p, "wg", x)) * h
    else:
        h = act(h)
    return linear(p, "wo", h)


# --- Chunked cross-entropy over a sharded vocabulary ------------------------

def chunked_softmax_xent(unembed_p, h, labels, mask=None,
                         chunk: int = 512):
    """CE loss without materializing [B, S, V] logits.

    Scans over sequence chunks; the [B, chunk, V] logits block stays sharded
    over the vocab (model) axis and is recomputed in backward (checkpoint).
    h: [B, S, D]; labels: [B, S] int32; mask: [B, S] (1 = count).
    Returns (mean loss, total weight).
    """
    b, s, d = h.shape
    w = unembed_p["w"]
    m = (jnp.ones_like(labels, jnp.float32) if mask is None
         else mask.astype(jnp.float32))
    if s % chunk:  # pad to a chunk multiple with zero weight
        pad = chunk - s % chunk
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        m = jnp.pad(m, ((0, 0), (0, pad)))
        s += pad
    n_chunks = s // chunk
    h_c = h.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    y_c = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    m_c = m.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(hc, yc, mc):
        logits = (hc @ w.astype(hc.dtype)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return ((logz - gold) * mc).sum(), mc.sum()

    def body(carry, xs):
        tot, cnt = carry
        l, c = chunk_loss(*xs)
        return (tot + l, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (h_c, y_c, m_c))
    return tot / jnp.maximum(cnt, 1.0), cnt


def logits_last(unembed_p, h_last):
    """Decode-time logits for the last position only. h_last: [B, D]."""
    return linear(unembed_p, "w", h_last).astype(jnp.float32)
