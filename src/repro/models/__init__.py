"""Model families: dense/MoE/MLA transformers, SSM, hybrid, enc-dec, VLM."""
from .transformer import LMConfig, TransformerLM  # noqa: F401
from .ssm_lm import SSMLM, SSMLMConfig  # noqa: F401
from .hybrid import HybridConfig, HybridLM  # noqa: F401
from .encdec import EncDecConfig, EncDecLM  # noqa: F401
from .multimodal import VLM, VLMConfig  # noqa: F401
