"""Parameter definition / init / sharding machinery (functional, no flax).

A model is described by a pytree of ``ParamDef``s (pure metadata: shape,
dtype, init, *logical axes*).  From it we derive
  * concrete parameters        (``init_params`` — real arrays), or
  * abstract parameters        (``abstract_params`` — ShapeDtypeStruct, used
    by the dry-run so nothing is allocated), and
  * PartitionSpecs             (``param_pspecs`` — logical axes mapped to mesh
    axes through a rules table, MaxText-style).

Logical axis vocabulary (see DESIGN.md §5):
  "vocab"    — vocabulary dim           -> TP ("model")
  "heads"    — attention heads / q dim  -> TP
  "kv_heads" — kv heads                 -> TP
  "mlp"      — FFN hidden               -> TP
  "experts"  — MoE expert dim           -> EP ("model")
  "embed"    — d_model                  -> FSDP ("data")
  "ssm"      — SSM inner/head dim       -> TP
  "conv", "stack", "norm", None         -> replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Initializer = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis per dim
    dtype: Any = jnp.float32
    init: str = "normal"                  # normal | zeros | ones | scaled
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_one(defn: ParamDef, key: jax.Array) -> jax.Array:
    if defn.init == "zeros":
        return jnp.zeros(defn.shape, defn.dtype)
    if defn.init == "ones":
        return jnp.ones(defn.shape, defn.dtype)
    if defn.init == "scaled":  # fan-in scaled normal
        fan_in = defn.shape[-2] if len(defn.shape) >= 2 else defn.shape[-1]
        std = defn.scale / math.sqrt(max(1, fan_in))
        return (std * jax.random.normal(key, defn.shape)).astype(defn.dtype)
    return (defn.scale * 0.02 * jax.random.normal(key, defn.shape)).astype(
        defn.dtype)


def init_params(defs, key: jax.Array):
    """Materialize a ParamDef pytree into arrays (unique key per leaf)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs):
    """ShapeDtypeStruct pytree — used by the dry-run, no allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs,
        is_leaf=is_def)


# Default logical->mesh rules: 2-D FSDP("data") x TP("model").
DEFAULT_RULES: dict[str | None, str | None] = {
    "vocab": "model",
    "vocab_in": None,
    "heads": "model",
    "heads_act": "model",   # attention activations (padded to divisibility)
    "kv_heads": "model",
    "head_dim": None,
    "kv_seq": None,
    "mlp": "model",
    "experts": "model",
    "moe_mlp": None,     # expert FFN hidden: EP already takes "model"
    "frames": None,      # enc-dec cross-attn source length (1500, indivisible)
    "ssm": "model",
    "embed": "data",
    "stack": None,
    "conv": None,
    "norm": None,
    None: None,
}


def param_pspecs(defs, rules: dict | None = None):
    """PartitionSpec pytree from logical axes through the rules table."""
    merged = dict(DEFAULT_RULES)
    merged.update(rules or {})
    rules = merged

    def one(d: ParamDef):
        return P(*(rules.get(a, None) for a in d.axes))

    return jax.tree.map(one, defs, is_leaf=is_def)


def param_count(defs) -> int:
    return sum(math.prod(d.shape)
               for d in jax.tree.leaves(defs, is_leaf=is_def))


def stack_defs(defs, n: int):
    """Prepend a scan/stack dim of size n to every ParamDef in the tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("stack",) + d.axes, d.dtype,
                           d.init, d.scale),
        defs, is_leaf=is_def)
