"""Encoder-decoder transformer (whisper-large-v3 backbone).

Per the brief, the conv/mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings [B, n_frames, d_model] (post-conv).  The encoder
adds fixed sinusoidal positions and runs non-causal self-attention; the
decoder runs causal self-attention + cross-attention.  Whisper's learned
absolute positions are replaced by sinusoidal (encoder) / RoPE (decoder) so
the assigned 32k-decode shapes are representable (deviation in DESIGN.md).

Decode cache = per-layer self-attn KV (grows) + cross-attn KV (static,
precomputed from the encoder memory at prefill).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .attention import AttnConfig
from .layers import (chunked_softmax_xent, embed, embed_defs, ffn, ffn_defs,
                     logits_last, rmsnorm, rmsnorm_defs, unembed_defs)
from .params import ParamDef, stack_defs


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    n_frames: int = 1500
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunk: int = 512
    kv_chunk: int = 1024

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    def attn_config(self, causal=True) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                          self.hd, self.rope_theta, causal=causal,
                          kv_chunk=self.kv_chunk)


def _sinusoid(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDecLM:
    def __init__(self, cfg: EncDecConfig):
        self.cfg = cfg

    def param_defs(self):
        c = self.cfg
        enc_layer = {
            "ln1": rmsnorm_defs(c.d_model),
            "attn": attn_mod.gqa_defs(c.attn_config(False), c.dtype),
            "ln2": rmsnorm_defs(c.d_model),
            "ffn": ffn_defs(c.d_model, c.d_ff, gated=False, dtype=c.dtype),
        }
        dec_layer = {
            "ln1": rmsnorm_defs(c.d_model),
            "self_attn": attn_mod.gqa_defs(c.attn_config(True), c.dtype),
            "lnx": rmsnorm_defs(c.d_model),
            "cross_attn": attn_mod.gqa_defs(c.attn_config(False), c.dtype),
            "ln2": rmsnorm_defs(c.d_model),
            "ffn": ffn_defs(c.d_model, c.d_ff, gated=False, dtype=c.dtype),
        }
        return {
            "embed": embed_defs(c.vocab, c.d_model, c.dtype),
            "enc_layers": stack_defs(enc_layer, c.n_enc_layers),
            "enc_norm": rmsnorm_defs(c.d_model),
            "dec_layers": stack_defs(dec_layer, c.n_dec_layers),
            "final_norm": rmsnorm_defs(c.d_model),
            "unembed": unembed_defs(c.d_model, c.vocab, c.dtype),
        }

    def cache_defs(self, batch: int, max_len: int):
        c = self.cfg
        kv = (c.n_dec_layers, batch, max_len, c.n_kv_heads, c.hd)
        xkv = (c.n_dec_layers, batch, c.n_frames, c.n_kv_heads, c.hd)
        axes = ("stack", "batch", "kv_seq", "kv_heads", "head_dim")
        # cross-attention source length (1500 frames) is indivisible by the
        # TP degree -> its own "frames" logical axis (replicated by default).
        xaxes = ("stack", "batch", "frames", "kv_heads", "head_dim")
        return {
            "self_k": ParamDef(kv, axes, dtype=c.dtype, init="zeros"),
            "self_v": ParamDef(kv, axes, dtype=c.dtype, init="zeros"),
            "cross_k": ParamDef(xkv, xaxes, dtype=c.dtype, init="zeros"),
            "cross_v": ParamDef(xkv, xaxes, dtype=c.dtype, init="zeros"),
        }

    # -- encoder -------------------------------------------------------------

    def encode(self, params, frames):
        """frames: [B, n_frames, d_model] (stub frontend output)."""
        c = self.cfg
        h = (frames + _sinusoid(frames.shape[1], c.d_model)[None]).astype(
            c.dtype)
        positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                     frames.shape[:2])

        def body(h, lp):
            hn = rmsnorm(lp["ln1"], h)
            # non-causal self-attention over frames (kv from the same seq)
            kv = attn_mod.encoder_kv(lp["attn"], c.attn_config(False), hn)
            a, _ = attn_mod.gqa_attention(lp["attn"], c.attn_config(False),
                                          hn, positions, kv_override=kv)
            h = h + a
            hn = rmsnorm(lp["ln2"], h)
            return h + ffn(lp["ffn"], hn, "gelu"), None

        body = jax.checkpoint(body) if c.remat else body
        h, _ = jax.lax.scan(body, h, params["enc_layers"])
        return rmsnorm(params["enc_norm"], h)

    # -- decoder -------------------------------------------------------------

    def _decoder_full(self, params, tokens, memory, collect_cache=False):
        c = self.cfg
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        h = embed(params["embed"], tokens).astype(c.dtype)

        def body(h, lp):
            hn = rmsnorm(lp["ln1"], h)
            a, kv = attn_mod.gqa_attention(lp["self_attn"],
                                           c.attn_config(True), hn, positions)
            h = h + a
            hn = rmsnorm(lp["lnx"], h)
            xkv = attn_mod.encoder_kv(lp["cross_attn"], c.attn_config(False),
                                      memory)
            a, _ = attn_mod.gqa_attention(lp["cross_attn"],
                                          c.attn_config(False), hn, positions,
                                          kv_override=xkv)
            h = h + a
            hn = rmsnorm(lp["ln2"], h)
            h = h + ffn(lp["ffn"], hn, "gelu")
            return h, (kv, xkv) if collect_cache else None

        sbody = jax.checkpoint(body) if (c.remat and not collect_cache) \
            else body
        h, caches = jax.lax.scan(sbody, h, params["dec_layers"])
        return rmsnorm(params["final_norm"], h), caches

    def train_loss(self, params, batch, rng=None):
        memory = self.encode(params, batch["frames"])
        h, _ = self._decoder_full(params, batch["tokens"], memory)
        loss, _ = chunked_softmax_xent(
            params["unembed"], h, batch["labels"], batch.get("mask"),
            chunk=min(self.cfg.loss_chunk, batch["tokens"].shape[1]))
        return loss, {"xent": loss}

    def prefill(self, params, tokens, frames, max_len: int | None = None):
        b, s = tokens.shape
        max_len = max_len or s
        memory = self.encode(params, frames)
        h, caches = self._decoder_full(params, tokens, memory,
                                       collect_cache=True)
        (k, v), (xk, xv) = caches
        pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
        cache = {"self_k": jnp.pad(k, pad), "self_v": jnp.pad(v, pad),
                 "cross_k": xk, "cross_v": xv}
        return logits_last(params["unembed"], h[:, -1]), cache

    def decode_step(self, params, cache, tokens, cur_len):
        c = self.cfg
        h = embed(params["embed"], tokens).astype(c.dtype)

        def body(h, xs):
            lp, sk, sv, xk, xv = xs
            hn = rmsnorm(lp["ln1"], h)
            a, sk, sv = attn_mod.gqa_decode(lp["self_attn"],
                                            c.attn_config(True), hn, sk, sv,
                                            cur_len)
            h = h + a
            hn = rmsnorm(lp["lnx"], h)
            a, _, _ = attn_mod.gqa_decode(lp["cross_attn"],
                                          c.attn_config(False), hn, xk, xv,
                                          cur_len, cross=True)
            h = h + a
            hn = rmsnorm(lp["ln2"], h)
            h = h + ffn(lp["ffn"], hn, "gelu")
            return h, (sk, sv)

        h, (sk, sv) = jax.lax.scan(
            body, h, (params["dec_layers"], cache["self_k"], cache["self_v"],
                      cache["cross_k"], cache["cross_v"]))
        h = rmsnorm(params["final_norm"], h)
        new_cache = dict(cache, self_k=sk, self_v=sv)
        return logits_last(params["unembed"], h[:, -1]), new_cache
