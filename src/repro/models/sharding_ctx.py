"""Logical-axis sharding hints inside model code (MaxText-style).

Model forward functions call ``hint(x, "batch", None, "heads", None)`` at
layout-critical points (attention carries, scan bodies).  When a launcher has
installed rules (``set_rules`` — the same logical->mesh table used for
parameter PartitionSpecs) AND a mesh is current, this becomes
``jax.lax.with_sharding_constraint``; otherwise it is a no-op, so model code
stays runnable on bare CPU without any mesh.

Why this exists (EXPERIMENTS.md §Perf iterations 1-3): GSPMD's sharding
propagation resolves conflicting constraints inside ``lax.scan`` bodies by
replication.  Measured on qwen3-1.7b/train_4k: the flash-attention
accumulators came out head-replicated, costing 6.1x model flops per device.
One hint on the q/k/v tensors and the scan carry restores the intended
(batch="data", heads="model") layout.
"""
from __future__ import annotations

import contextvars

import jax
from jax.sharding import PartitionSpec as P

_RULES: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "repro_logical_sharding_rules", default=None)


def set_rules(rules: dict | None) -> None:
    """Install logical->mesh rules (launcher-side). None disables hints."""
    _RULES.set(rules)


def get_rules() -> dict | None:
    return _RULES.get()


def hint(x: jax.Array, *logical_axes) -> jax.Array:
    """Constrain x's dims to the mesh axes the rules map these names to."""
    rules = _RULES.get()
    if rules is None or x.ndim != len(logical_axes):
        return x
    spec = P(*(rules.get(a) if a is not None else None
               for a in logical_axes))
    return jax.lax.with_sharding_constraint(x, spec)


def padded_head_count(n_heads: int) -> int:
    """Activation-level head padding target for TP.

    Archs whose head count does not divide the TP degree (llama4: 40 heads
    on a 16-way "model" axis; whisper: 20) would otherwise run attention
    fully replicated — parameters stay at the true head count (the arch is
    unchanged), but q/k/v activations pad to the next multiple with zero
    heads, shard cleanly, and the pads are trimmed before the output
    projection (numerically exact; +20 % attention flops for llama4 vs 16x
    replication).  Requires ``set_rules`` to include "_mesh_sizes".
    """
    rules = _RULES.get()
    if not rules:
        return n_heads
    sizes = rules.get("_mesh_sizes") or {}
    ax = rules.get("heads_act", rules.get("heads"))
    m = sizes.get(ax) if isinstance(ax, str) else None
    if not m or n_heads % m == 0:
        return n_heads
    return -(-n_heads // m) * m
