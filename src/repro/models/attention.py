"""Attention: GQA (flash-style chunked), decode with KV cache, MLA (DeepSeek),
and cross-attention for the enc-dec architecture.

Training/prefill attention scans over KV chunks with a running
(max, denominator, accumulator) — the flash pattern in pure JAX — so the
[B, H, S, S] score matrix is never materialized (required at seq 32k).
Decode computes one query row against the cache directly.

Sharding: heads ("heads"/"kv_heads" -> model axis), batch -> data axis.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import rmsnorm, rmsnorm_defs, rope
from .params import ParamDef
from .sharding_ctx import hint, padded_head_count


def head_proj(p, name: str, x, heads: int, hdim: int):
    """x [..., D] @ [D, H, Dh] -> [..., H, Dh], PUD-packed aware.

    ``pud.packer.pack_model`` (via ``PUDSession.pack``) with attention
    packing replaces ``<name>`` by a ``<name>_pud`` ``PackedTensor``
    holding bit-planes of the flattened [D, H*Dh] projection; the head
    split is restored by reshape.
    """
    packed = p.get(name + "_pud")
    if packed is not None:
        from repro.pud.gemv import pud_linear
        y = pud_linear(x, packed).astype(x.dtype)
        return y.reshape(y.shape[:-1] + (heads, hdim))
    return jnp.einsum("...d,dhk->...hk", x, p[name].astype(x.dtype))


def merge_proj(p, name: str, x):
    """x [..., H, Dh] @ [H, Dh, D] -> [..., D], PUD-packed aware."""
    packed = p.get(name + "_pud")
    if packed is not None:
        from repro.pud.gemv import pud_linear
        flat = x.reshape(x.shape[:-2] + (x.shape[-2] * x.shape[-1],))
        return pud_linear(flat, packed).astype(x.dtype)
    return jnp.einsum("...hk,hkd->...d", x, p[name].astype(x.dtype))


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    causal: bool = True
    kv_chunk: int = 1024


def gqa_defs(cfg: AttnConfig, dtype=jnp.bfloat16) -> dict:
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    defs = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim"),
                       dtype=dtype, init="scaled"),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim"),
                       dtype=dtype, init="scaled"),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim"),
                       dtype=dtype, init="scaled"),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed"),
                       dtype=dtype, init="scaled"),
    }
    if cfg.qk_norm:
        defs["q_norm"] = rmsnorm_defs(hd)
        defs["k_norm"] = rmsnorm_defs(hd)
    return defs


def _flash(q, k, v, *, causal: bool, kv_chunk: int, q_offset: int = 0,
           bias=None):
    """Chunked softmax attention.

    q: [B, Sq, H, D]; k,v: [B, Skv, KV, D] with H = KV * G.
    Returns [B, Sq, H, D].  q_offset: absolute position of q[0] (causal).

    GQA grouping is realized by REPEATING kv to the full head count rather
    than reshaping q to [B, S, KV, G, D]: a grouped reshape splits the
    "heads"-sharded dim (e.g. 16-way model sharding into KV=8 x G=2), which
    GSPMD cannot partition and resolves by replicating the whole attention
    (measured: 6.1x model flops on qwen3/train_4k — EXPERIMENTS.md §Perf
    iteration 2).  The repeat is a broadcast: with kv replicated and heads
    sharded, each device materializes only its own heads' kv slice.
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = d ** -0.5
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = hint(q, "batch", None, "heads_act", None)
    k = hint(k, "batch", None, "heads_act", None)
    v = hint(v, "batch", None, "heads_act", None)
    n_chunks = max(1, skv // kv_chunk)
    assert skv % n_chunks == 0
    kc = k.reshape(b, n_chunks, skv // n_chunks, h, d).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, skv // n_chunks, h, d).swapaxes(0, 1)

    def body(carry, xs):
        m, l, acc, idx = carry
        kb, vb = xs
        kb = hint(kb, "batch", None, "heads_act", None)
        s = jnp.einsum("bqhd,bphd->bhqp", q, kb,
                       preferred_element_type=jnp.float32) * scale
        s = hint(s, "batch", "heads_act", None, None)
        if causal:
            qpos = q_offset + jnp.arange(sq)
            kpos = idx * (skv // n_chunks) + jnp.arange(skv // n_chunks)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqp,bphd->bhqd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new, idx + 1), None

    m0 = hint(jnp.full((b, h, sq), -jnp.inf, jnp.float32),
              "batch", "heads_act", None)
    l0 = hint(jnp.zeros((b, h, sq), jnp.float32), "batch", "heads_act", None)
    a0 = hint(jnp.zeros((b, h, sq, d), jnp.float32),
              "batch", "heads_act", None, None)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, 0), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def gqa_attention(p, cfg: AttnConfig, x, positions, kv_override=None):
    """Full-sequence attention (train / prefill). x: [B, S, D].

    Returns (out [B,S,D], (k, v) for cache seeding).
    kv_override: (k, v) from an encoder for cross-attention (no causal).
    """
    q = head_proj(p, "wq", x, cfg.n_heads, cfg.head_dim)
    if kv_override is None:
        k = head_proj(p, "wk", x, cfg.n_kv_heads, cfg.head_dim)
        v = head_proj(p, "wv", x, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = rmsnorm(p["q_norm"], q)
            k = rmsnorm(p["k_norm"], k)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        causal = cfg.causal
    else:
        k, v = kv_override
        if cfg.qk_norm:
            q = rmsnorm(p["q_norm"], q)
        causal = False
    # Activation-level head padding: archs whose head count does not divide
    # the TP degree (llama4: 40, whisper: 20 on a 16-way axis) would run the
    # flash loop replicated.  Expand kv to the full head count (the GQA
    # grouping, done eagerly), pad q/k/v with zero heads to the next
    # multiple, shard over "model", trim before wo — numerically exact.
    cache_kv = (k, v)
    h_true = q.shape[2]
    hp = padded_head_count(h_true)
    if hp != h_true:
        g = h_true // k.shape[2]
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        pad = ((0, 0), (0, 0), (0, hp - h_true), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    out = _flash(q, k, v, causal=causal,
                 kv_chunk=min(cfg.kv_chunk, k.shape[1]))
    if hp != h_true:
        out = out[:, :, :h_true]
    out = merge_proj(p, "wo", out)
    return out, cache_kv


def gqa_prefill_chunk(p, cfg: AttnConfig, x, cache_k, cache_v, start,
                      kv_len: int):
    """Chunked-prefill attention: resume a prompt into an existing KV cache.

    x: [B, C, D] — the chunk's hidden states for absolute positions
    [start, start+C); cache_k/v: [B, Smax, KV, Dh] holding every earlier
    chunk's keys/values at their absolute positions.  The chunk's k/v are
    written at ``start`` (traced scalar) and attention runs over the first
    ``kv_len`` cache rows (static: the prompt's pow2 bucket), with the
    causal mask anchored at ``q_offset=start``.  Row ``p`` of the output
    sees exactly the keys ``0..p`` a whole-bucket prefill would show it, so
    chunked prefill is bit-identical to whole prefill row by row (the
    masked-tail length is the same ``kv_len`` in both).

    Returns (out [B,C,D], new_cache_k, new_cache_v).
    """
    b, c, _ = x.shape
    positions = start + jnp.broadcast_to(jnp.arange(c), (b, c))
    q = head_proj(p, "wq", x, cfg.n_heads, cfg.head_dim)
    k_new = head_proj(p, "wk", x, cfg.n_kv_heads, cfg.head_dim)
    v_new = head_proj(p, "wv", x, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k_new = rmsnorm(p["k_norm"], k_new)
    q = rope(q, positions, cfg.rope_theta)
    k_new = rope(k_new, positions, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), start, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), start, axis=1)
    k = cache_k[:, :kv_len].astype(x.dtype)
    v = cache_v[:, :kv_len].astype(x.dtype)
    # Same activation-level head padding as gqa_attention (no-op without a
    # model-sharded mesh context); per-head rows are independent, so padded
    # heads never perturb real heads' values.
    h_true = q.shape[2]
    hp = padded_head_count(h_true)
    if hp != h_true:
        g = h_true // k.shape[2]
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        pad = ((0, 0), (0, 0), (0, hp - h_true), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    out = _flash(q, k, v, causal=True, kv_chunk=min(cfg.kv_chunk, kv_len),
                 q_offset=start)
    if hp != h_true:
        out = out[:, :, :h_true]
    out = merge_proj(p, "wo", out)
    return out, cache_k, cache_v


def encoder_kv(p, cfg: AttnConfig, memory):
    """Precompute cross-attention K/V from encoder output."""
    k = head_proj(p, "wk", memory, cfg.n_kv_heads, cfg.head_dim)
    v = head_proj(p, "wv", memory, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def gqa_decode(p, cfg: AttnConfig, x, cache_k, cache_v, cur_len,
               cross: bool = False):
    """One-token decode. x: [B, 1, D]; cache_k/v: [B, Smax, KV, D].

    ``cur_len`` is the current cache fill: a scalar (all rows at the same
    position — the classic single-sequence/batched-prompt decode) or a [B]
    vector of per-slot lengths (continuous batching: every slot sits at its
    own position; the cache write becomes a per-row scatter and the causal
    mask goes per-row).  Rows are independent either way, so the vector
    path is bit-identical per row to the scalar path at that row's length.

    Returns (out [B,1,D], new_cache_k, new_cache_v).
    For cross-attention the cache holds encoder K/V and is not updated.
    """
    b, smax = cache_k.shape[0], cache_k.shape[1]
    lens = jnp.asarray(cur_len)
    per_slot = lens.ndim == 1
    q = head_proj(p, "wq", x, cfg.n_heads, cfg.head_dim)
    if not cross:
        k_new = head_proj(p, "wk", x, cfg.n_kv_heads, cfg.head_dim)
        v_new = head_proj(p, "wv", x, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = rmsnorm(p["q_norm"], q)
            k_new = rmsnorm(p["k_norm"], k_new)
        pos = lens[:, None] if per_slot else jnp.full((b, 1), lens)
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)
        if per_slot:
            rows = jnp.arange(b)
            cache_k = cache_k.at[rows, lens].set(
                k_new[:, 0].astype(cache_k.dtype), mode="drop")
            cache_v = cache_v.at[rows, lens].set(
                v_new[:, 0].astype(cache_v.dtype), mode="drop")
        else:
            cache_k = jax.lax.dynamic_update_slice_in_dim(
                cache_k, k_new.astype(cache_k.dtype), cur_len, axis=1)
            cache_v = jax.lax.dynamic_update_slice_in_dim(
                cache_v, v_new.astype(cache_v.dtype), cur_len, axis=1)
        valid_len = lens + 1
    else:
        if cfg.qk_norm:
            q = rmsnorm(p["q_norm"], q)
        valid_len = jnp.full(lens.shape, smax)
    h, kvh, d = q.shape[2], cache_k.shape[2], q.shape[3]
    g = h // kvh
    qr = q.reshape(b, kvh, g, d)
    s = jnp.einsum("bkgd,bpkd->bkgp", qr, cache_k,
                   preferred_element_type=jnp.float32) * d ** -0.5
    if per_slot:
        mask = jnp.arange(smax)[None, :] < valid_len[:, None]     # [B, Smax]
        s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    else:
        mask = jnp.arange(smax) < valid_len
        s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgp,bpkd->bkgd", w.astype(cache_v.dtype), cache_v)
    o = o.reshape(b, 1, h, d)
    out = merge_proj(p, "wo", o)
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, DeepSeek-V2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    kv_chunk: int = 1024

    @property
    def qk_dim(self):
        return self.qk_nope_dim + self.qk_rope_dim


def mla_defs(cfg: MLAConfig, dtype=jnp.bfloat16) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    return {
        "wq": ParamDef((d, h, cfg.qk_dim), ("embed", "heads", None),
                       dtype=dtype, init="scaled"),
        "wdkv": ParamDef((d, cfg.kv_lora_rank), ("embed", None), dtype=dtype,
                         init="scaled"),
        "kv_norm": rmsnorm_defs(cfg.kv_lora_rank),
        "wkr": ParamDef((d, cfg.qk_rope_dim), ("embed", None), dtype=dtype,
                        init="scaled"),
        "wuk": ParamDef((cfg.kv_lora_rank, h, cfg.qk_nope_dim),
                        (None, "heads", None), dtype=dtype, init="scaled"),
        "wuv": ParamDef((cfg.kv_lora_rank, h, cfg.v_head_dim),
                        (None, "heads", None), dtype=dtype, init="scaled"),
        "wo": ParamDef((h, cfg.v_head_dim, d), ("heads", None, "embed"),
                       dtype=dtype, init="scaled"),
    }


def mla_attention(p, cfg: MLAConfig, x, positions):
    """Training/prefill MLA. Returns (out, (c_kv, k_rope)) for cache seeding."""
    q = head_proj(p, "wq", x, cfg.n_heads, cfg.qk_dim)
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    c_kv = rmsnorm(p["kv_norm"], x @ p["wdkv"].astype(x.dtype))  # [B,S,R]
    k_rope = rope((x @ p["wkr"].astype(x.dtype))[:, :, None, :], positions,
                  cfg.rope_theta)                                # [B,S,1,dr]
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuv"].astype(x.dtype))

    h = cfg.n_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_rope.shape[:2] + (h,) +
                                  k_rope.shape[3:])], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pad v to qk_dim so _flash can share the accumulator, then trim
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                       (0, cfg.qk_dim - cfg.v_head_dim)))
    out = _flash(q_full, k, vpad, causal=True,
                 kv_chunk=min(cfg.kv_chunk, x.shape[1]))
    out = out[..., : cfg.v_head_dim]
    out = merge_proj(p, "wo", out)
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_prefill_chunk(p, cfg: MLAConfig, x, cache_ckv, cache_kr, start,
                      kv_len: int):
    """Chunked-prefill MLA: resume a prompt into the latent KV cache.

    x: [B, C, D] for absolute positions [start, start+C); cache_ckv:
    [B, Smax, R]; cache_kr: [B, Smax, dr].  The chunk's latents land at
    ``start`` and k_nope/v are re-expanded from the cached latents over the
    first ``kv_len`` rows — the same up-projection a whole-bucket prefill
    applies, so the rows are bit-identical (see gqa_prefill_chunk).

    Returns (out [B,C,D], new_cache_ckv, new_cache_kr).
    """
    b, c, _ = x.shape
    positions = start + jnp.broadcast_to(jnp.arange(c), (b, c))
    q = head_proj(p, "wq", x, cfg.n_heads, cfg.qk_dim)
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    c_new = rmsnorm(p["kv_norm"], x @ p["wdkv"].astype(x.dtype))  # [B,C,R]
    kr_new = rope((x @ p["wkr"].astype(x.dtype))[:, :, None, :], positions,
                  cfg.rope_theta)[:, :, 0, :]                     # [B,C,dr]
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_new.astype(cache_ckv.dtype), start, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, kr_new.astype(cache_kr.dtype), start, axis=1)

    ckv = cache_ckv[:, :kv_len].astype(x.dtype)
    kr = cache_kr[:, :kv_len].astype(x.dtype)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wuv"].astype(x.dtype))
    h = cfg.n_heads
    kr_b = kr[:, :, None, :]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_b, kr_b.shape[:2] + (h,) +
                                  kr_b.shape[3:])], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                       (0, cfg.qk_dim - cfg.v_head_dim)))
    out = _flash(q_full, k, vpad, causal=True,
                 kv_chunk=min(cfg.kv_chunk, kv_len), q_offset=start)
    out = out[..., : cfg.v_head_dim]
    out = merge_proj(p, "wo", out)
    return out, cache_ckv, cache_kr


def mla_decode(p, cfg: MLAConfig, x, cache_ckv, cache_kr, cur_len):
    """Absorbed-matmul MLA decode: attention runs in the latent space, so the
    per-step cost is O(S * (R + dr)) instead of O(S * H * head_dim).

    cache_ckv: [B, Smax, R]; cache_kr: [B, Smax, dr].
    ``cur_len``: scalar or per-slot [B] vector, as in ``gqa_decode``.
    """
    b, smax, r = cache_ckv.shape
    lens = jnp.asarray(cur_len)
    per_slot = lens.ndim == 1
    q = head_proj(p, "wq", x, cfg.n_heads, cfg.qk_dim)[:, 0]
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    pos = lens[:, None] if per_slot else jnp.full((b, 1), lens)
    q_rope = rope(q_rope[:, None], pos, cfg.rope_theta)[:, 0]

    c_new = rmsnorm(p["kv_norm"], x @ p["wdkv"].astype(x.dtype))  # [B,1,R]
    kr_new = rope((x @ p["wkr"].astype(x.dtype))[:, :, None, :], pos,
                  cfg.rope_theta)[:, :, 0, :]
    if per_slot:
        rows = jnp.arange(b)
        cache_ckv = cache_ckv.at[rows, lens].set(
            c_new[:, 0].astype(cache_ckv.dtype), mode="drop")
        cache_kr = cache_kr.at[rows, lens].set(
            kr_new[:, 0].astype(cache_kr.dtype), mode="drop")
    else:
        cache_ckv = jax.lax.dynamic_update_slice_in_dim(
            cache_ckv, c_new.astype(cache_ckv.dtype), cur_len, axis=1)
        cache_kr = jax.lax.dynamic_update_slice_in_dim(
            cache_kr, kr_new.astype(cache_kr.dtype), cur_len, axis=1)

    # absorb W_uk into the query: scores in latent space.  bf16 inputs with
    # f32 accumulation (preferred_element_type) — an .astype(f32) on the
    # score made XLA hoist an f32 convert of the ENTIRE stacked cache out of
    # the layer loop (1.3 GB/step materialization on deepseek-v2-lite
    # decode_32k; EXPERIMENTS.md §Perf iteration 7).
    cache_ckv = hint(cache_ckv, "batch", "kv_seq", None)
    cache_kr = hint(cache_kr, "batch", "kv_seq", None)
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope, p["wuk"].astype(x.dtype))
    s = (jnp.einsum("bhr,bpr->bhp", q_lat, cache_ckv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhk,bpk->bhp", q_rope, cache_kr,
                      preferred_element_type=jnp.float32))
    s = hint(s, "batch", None, "kv_seq")
    s = s * (cfg.qk_dim ** -0.5)
    if per_slot:
        mask = jnp.arange(smax)[None, :] < (lens + 1)[:, None]    # [B, Smax]
        s = jnp.where(mask[:, None, :], s, -jnp.inf)
    else:
        mask = jnp.arange(smax) < lens + 1
        s = jnp.where(mask[None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhp,bpr->bhr", w.astype(cache_ckv.dtype), cache_ckv)
    o = jnp.einsum("bhr,rhk->bhk", o_lat, p["wuv"].astype(x.dtype))
    out = merge_proj(p, "wo", o)[:, None]
    return out, cache_ckv, cache_kr
