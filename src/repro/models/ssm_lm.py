"""Attention-free SSM language model (mamba2-1.3b family).

Stack of Mamba2/SSD blocks with pre-RMSNorm residuals, scanned over stacked
layer parameters.  Decode carries (conv window, SSM state) per layer — O(1)
in sequence length, which is why this family runs the long_500k cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (chunked_softmax_xent, embed, embed_defs, logits_last,
                     rmsnorm, rmsnorm_defs, unembed_defs)
from .params import ParamDef, stack_defs
from .ssm import (SSMConfig, mamba2_block, mamba2_decode, mamba2_defs)


@dataclasses.dataclass(frozen=True)
class SSMLMConfig:
    name: str
    n_layers: int
    d_model: int
    d_state: int
    vocab: int
    d_inner: int | None = None
    head_dim: int = 64
    dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunk: int = 512
    ssd_chunk: int = 128

    def ssm_config(self) -> SSMConfig:
        return SSMConfig(self.d_model, self.d_inner or 2 * self.d_model,
                         self.d_state, self.head_dim, chunk=self.ssd_chunk)


class SSMLM:
    def __init__(self, cfg: SSMLMConfig):
        self.cfg = cfg
        self.ssm = cfg.ssm_config()

    def param_defs(self):
        layer = {"ln": rmsnorm_defs(self.cfg.d_model),
                 "mamba": mamba2_defs(self.ssm, self.cfg.dtype)}
        return {
            "embed": embed_defs(self.cfg.vocab, self.cfg.d_model,
                                self.cfg.dtype),
            "layers": stack_defs(layer, self.cfg.n_layers),
            "final_norm": rmsnorm_defs(self.cfg.d_model),
            "unembed": unembed_defs(self.cfg.d_model, self.cfg.vocab,
                                    self.cfg.dtype),
        }

    def cache_defs(self, batch: int, max_len: int):
        s, l = self.ssm, self.cfg.n_layers
        return {
            "conv": ParamDef((l, batch, s.d_conv - 1, s.conv_channels),
                             ("stack", "batch", None, "ssm"),
                             dtype=self.cfg.dtype, init="zeros"),
            "state": ParamDef((l, batch, s.n_heads, s.head_dim, s.d_state),
                              ("stack", "batch", "ssm", None, None),
                              dtype=jnp.float32, init="zeros"),
        }

    def _backbone(self, params, h, collect_cache=False):
        def body(h, lp):
            hn = rmsnorm(lp["ln"], h)
            out, cache = mamba2_block(lp["mamba"], self.ssm, hn)
            return h + out, cache if collect_cache else None

        scan_body = jax.checkpoint(body) if self.cfg.remat else body
        h, caches = jax.lax.scan(scan_body, h, params["layers"])
        return h, caches

    def train_loss(self, params, batch, rng=None):
        tokens = batch["tokens"]
        h = embed(params["embed"], tokens).astype(self.cfg.dtype)
        h, _ = self._backbone(params, h)
        h = rmsnorm(params["final_norm"], h)
        loss, _ = chunked_softmax_xent(
            params["unembed"], h, batch["labels"], batch.get("mask"),
            chunk=min(self.cfg.loss_chunk, tokens.shape[1]))
        return loss, {"xent": loss}

    def prefill(self, params, tokens, max_len: int | None = None):
        h = embed(params["embed"], tokens).astype(self.cfg.dtype)
        h, caches = self._backbone(params, h, collect_cache=True)
        h = rmsnorm(params["final_norm"], h)
        conv, state = caches
        cache = {"conv": conv, "state": state}
        return logits_last(params["unembed"], h[:, -1]), cache

    def decode_step(self, params, cache, tokens, cur_len=None):
        h = embed(params["embed"], tokens).astype(self.cfg.dtype)

        def body(h, xs):
            lp, conv, state = xs
            hn = rmsnorm(lp["ln"], h)
            out, (conv, state) = mamba2_decode(lp["mamba"], self.ssm, hn,
                                               (conv, state))
            return h + out, (conv, state)

        h, (conv, state) = jax.lax.scan(
            body, h, (params["layers"], cache["conv"], cache["state"]))
        h = rmsnorm(params["final_norm"], h)
        return (logits_last(params["unembed"], h[:, -1]),
                {"conv": conv, "state": state})
