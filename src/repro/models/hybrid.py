"""Zamba2-style hybrid: Mamba2 backbone + a *shared* transformer block.

81 blocks: every 6th position (5, 11, ..., 77 — 13 occurrences) invokes one
shared attention+FFN block (a single parameter set reused at every
occurrence, Zamba-style) specialized per occurrence by LoRA adapters; the
other 68 positions are Mamba2 blocks.  Layout: an outer scan over 13 uniform
segments (5 Mamba2 + shared block), then a 3-block Mamba2 tail — so compile
sees two scan bodies regardless of depth.

Decode: Mamba2 states are O(1); the shared block keeps a KV cache per
occurrence (13 caches over the same weights).  Memory is O(S), per-step work
O(S) — sub-quadratic decode, so this family runs the long_500k cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .attention import AttnConfig
from .layers import (chunked_softmax_xent, embed, embed_defs, ffn, ffn_defs,
                     logits_last, rmsnorm, rmsnorm_defs, unembed_defs)
from .params import ParamDef, stack_defs
from .ssm import SSMConfig, mamba2_block, mamba2_decode, mamba2_defs


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    name: str
    n_blocks: int            # total positions (81)
    shared_every: int        # every Nth position is the shared block (6)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_state: int
    ssm_head_dim: int = 64
    lora_rank: int = 64
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunk: int = 512
    kv_chunk: int = 1024
    ssd_chunk: int = 128

    @property
    def n_shared_uses(self) -> int:
        return self.n_blocks // self.shared_every          # 13

    @property
    def mamba_per_segment(self) -> int:
        return self.shared_every - 1                        # 5

    @property
    def n_tail(self) -> int:
        return (self.n_blocks - self.n_shared_uses
                * self.shared_every)                        # 81 - 78 = 3

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    def ssm_config(self) -> SSMConfig:
        return SSMConfig(self.d_model, 2 * self.d_model, self.d_state,
                         self.ssm_head_dim, chunk=self.ssd_chunk)

    def attn_config(self) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                          self.hd, self.rope_theta, kv_chunk=self.kv_chunk)


class HybridLM:
    def __init__(self, cfg: HybridConfig):
        self.cfg = cfg
        self.ssm = cfg.ssm_config()

    def _mamba_defs(self):
        return {"ln": rmsnorm_defs(self.cfg.d_model),
                "mamba": mamba2_defs(self.ssm, self.cfg.dtype)}

    def _lora_defs(self):
        """Per-occurrence LoRA on the shared block's FFN up-projection
        (Zamba2 specializes the shared block per use; we adapt the FFN path
        — the attention projections stay fully shared, noted in DESIGN.md)."""
        c, r = self.cfg, self.cfg.lora_rank
        return {
            "ffn_a": ParamDef((c.d_model, r), ("embed", None), dtype=c.dtype,
                              init="scaled"),
            "ffn_b": ParamDef((r, c.d_ff), (None, "mlp"), dtype=c.dtype,
                              init="zeros"),
        }

    def param_defs(self):
        c = self.cfg
        shared = {
            "ln1": rmsnorm_defs(c.d_model),
            "attn": attn_mod.gqa_defs(c.attn_config(), c.dtype),
            "ln2": rmsnorm_defs(c.d_model),
            "ffn": ffn_defs(c.d_model, c.d_ff, True, c.dtype),
        }
        return {
            "embed": embed_defs(c.vocab, c.d_model, c.dtype),
            "segments": stack_defs(
                {"mamba": stack_defs(self._mamba_defs(),
                                     c.mamba_per_segment),
                 "lora": self._lora_defs()},
                c.n_shared_uses),
            "shared": shared,
            "tail": stack_defs(self._mamba_defs(), c.n_tail),
            "final_norm": rmsnorm_defs(c.d_model),
            "unembed": unembed_defs(c.d_model, c.vocab, c.dtype),
        }

    def cache_defs(self, batch: int, max_len: int):
        c, s = self.cfg, self.ssm

        def mamba_cache(n):
            return {
                "conv": ParamDef((n, batch, s.d_conv - 1, s.conv_channels),
                                 ("stack", "batch", None, "ssm"),
                                 dtype=c.dtype, init="zeros"),
                "state": ParamDef(
                    (n, batch, s.n_heads, s.head_dim, s.d_state),
                    ("stack", "batch", "ssm", None, None),
                    dtype=jnp.float32, init="zeros"),
            }

        kv_shape = (c.n_shared_uses, batch, max_len, c.n_kv_heads, c.hd)
        return {
            "seg_mamba": {k: ParamDef((c.n_shared_uses,) + d.shape,
                                      ("stack",) + d.axes, d.dtype, d.init)
                          for k, d in mamba_cache(
                              c.mamba_per_segment).items()},
            "shared_kv": {
                "k": ParamDef(kv_shape,
                              ("stack", "batch", "kv_seq", "kv_heads", "head_dim"),
                              dtype=c.dtype, init="zeros"),
                "v": ParamDef(kv_shape,
                              ("stack", "batch", "kv_seq", "kv_heads", "head_dim"),
                              dtype=c.dtype, init="zeros"),
            },
            "tail_mamba": mamba_cache(c.n_tail),
        }

    # -- shared transformer block with per-occurrence LoRA -------------------

    def _ffn_with_lora(self, shared, lora, hn):
        """Shared FFN + per-occurrence rank-r correction on the up-proj."""
        f = ffn(shared["ffn"], hn)
        delta = jnp.einsum("bsd,dr,rf->bsf", hn,
                           lora["ffn_a"].astype(hn.dtype),
                           lora["ffn_b"].astype(hn.dtype))
        return f + delta @ shared["ffn"]["wo"].astype(hn.dtype)

    def _shared_block_full(self, shared, lora, h, positions):
        c = self.cfg
        hn = rmsnorm(shared["ln1"], h)
        a, kv = attn_mod.gqa_attention(shared["attn"], c.attn_config(), hn,
                                       positions)
        h = h + a
        hn = rmsnorm(shared["ln2"], h)
        return h + self._ffn_with_lora(shared, lora, hn), kv

    def _shared_block_decode(self, shared, lora, h, k_cache, v_cache,
                             cur_len):
        c = self.cfg
        hn = rmsnorm(shared["ln1"], h)
        a, k_cache, v_cache = attn_mod.gqa_decode(
            shared["attn"], c.attn_config(), hn, k_cache, v_cache, cur_len)
        h = h + a
        hn = rmsnorm(shared["ln2"], h)
        return h + self._ffn_with_lora(shared, lora, hn), k_cache, v_cache

    # -- forward -------------------------------------------------------------

    def _mamba_scan_full(self, stacked, h, collect):
        def body(h, lp):
            hn = rmsnorm(lp["ln"], h)
            out, cache = mamba2_block(lp["mamba"], self.ssm, hn)
            return h + out, cache if collect else None

        body = jax.checkpoint(body) if self.cfg.remat else body
        return jax.lax.scan(body, h, stacked)

    def _backbone(self, params, h, positions, collect=False):
        shared = params["shared"]

        def seg_body(h, seg):
            h, mcache = self._mamba_scan_full(seg["mamba"], h, collect)
            h, kv = self._shared_block_full(shared, seg["lora"], h,
                                            positions)
            return h, (mcache, kv if collect else None)

        seg_body = jax.checkpoint(seg_body) if self.cfg.remat else seg_body
        h, seg_caches = jax.lax.scan(seg_body, h, params["segments"])
        h, tail_cache = self._mamba_scan_full(params["tail"], h, collect)
        return h, seg_caches, tail_cache

    def train_loss(self, params, batch, rng=None):
        tokens = batch["tokens"]
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]),
                                     tokens.shape)
        h = embed(params["embed"], tokens).astype(self.cfg.dtype)
        h, _, _ = self._backbone(params, h, positions)
        h = rmsnorm(params["final_norm"], h)
        loss, _ = chunked_softmax_xent(
            params["unembed"], h, batch["labels"], batch.get("mask"),
            chunk=min(self.cfg.loss_chunk, tokens.shape[1]))
        return loss, {"xent": loss}

    def prefill(self, params, tokens, max_len: int | None = None):
        c = self.cfg
        b, s = tokens.shape
        max_len = max_len or s
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        h = embed(params["embed"], tokens).astype(c.dtype)
        h, seg_caches, tail_cache = self._backbone(params, h, positions,
                                                   collect=True)
        h = rmsnorm(params["final_norm"], h)
        (mconv, mstate), kvs = seg_caches
        k, v = kvs
        pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
        cache = {
            "seg_mamba": {"conv": mconv, "state": mstate},
            "shared_kv": {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)},
            "tail_mamba": {"conv": tail_cache[0], "state": tail_cache[1]},
        }
        return logits_last(params["unembed"], h[:, -1]), cache

    def decode_step(self, params, cache, tokens, cur_len):
        c = self.cfg
        h = embed(params["embed"], tokens).astype(c.dtype)
        shared = params["shared"]

        def mamba_body(h, xs):
            lp, conv, state = xs
            hn = rmsnorm(lp["ln"], h)
            out, (conv, state) = mamba2_decode(lp["mamba"], self.ssm, hn,
                                               (conv, state))
            return h + out, (conv, state)

        def seg_body(h, xs):
            seg, mconv, mstate, kc, vc = xs
            h, (mconv, mstate) = jax.lax.scan(
                mamba_body, h, (seg["mamba"], mconv, mstate))
            h, kc, vc = self._shared_block_decode(shared, seg["lora"], h,
                                                  kc, vc, cur_len)
            return h, (mconv, mstate, kc, vc)

        sm = cache["seg_mamba"]
        h, (mconv, mstate, kc, vc) = jax.lax.scan(
            seg_body, h, (params["segments"], sm["conv"], sm["state"],
                          cache["shared_kv"]["k"], cache["shared_kv"]["v"]))
        tm = cache["tail_mamba"]
        h, (tconv, tstate) = jax.lax.scan(
            mamba_body, h, (params["tail"], tm["conv"], tm["state"]))
        h = rmsnorm(params["final_norm"], h)
        new_cache = {
            "seg_mamba": {"conv": mconv, "state": mstate},
            "shared_kv": {"k": kc, "v": vc},
            "tail_mamba": {"conv": tconv, "state": tstate},
        }
        return logits_last(params["unembed"], h[:, -1]), new_cache
