"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --preset smoke --steps 60 --save-every 20 --ckpt-dir /tmp/run1
    # kill it mid-run, then:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --preset smoke --steps 60 --save-every 20 --ckpt-dir /tmp/run1 --resume

Wires together every runtime subsystem on whatever devices exist (1 CPU
device here; the same code path jits under the production mesh on TPU —
the dry-run proves those shardings):

  data (deterministic, shard-aware, resumable) -> microbatched train step
  (fp32 grad accumulation, ZeRO AdamW, optional int8 EF grad compression)
  -> atomic async checkpoints (keep-k, LATEST pointer) -> auto-resume
  -> straggler watchdog + heartbeat files.

``--preset smoke`` trains the arch's reduced config; ``--preset paper100m``
scales qwen3-family to ~100M params for the end-to-end loss-drop run;
``--preset full`` builds the full assigned config (cluster use).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get
from repro.launch.mesh import make_mesh_for_devices, use_mesh
from repro.models.params import init_params, param_count, param_pspecs
from repro.runtime import sharding as shd
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.data import DataConfig, DataPipeline
from repro.runtime.optim import OptConfig, init_opt_state, opt_state_pspecs
from repro.runtime.steps import make_train_step
from repro.runtime.watchdog import Heartbeat, StepWatchdog


def build_model(arch: str, preset: str):
    spec = get(arch)
    if preset == "full":
        return spec.make_model()
    if preset == "smoke":
        return spec.make_smoke()
    if preset == "paper100m":
        from repro.models.transformer import LMConfig, TransformerLM
        return TransformerLM(LMConfig(      # ~105M params
            name=f"{arch}-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=6, d_ff=3072, vocab=16384, head_dim=64,
            loss_chunk=128))
    raise ValueError(preset)


def family_extras(spec, model, batch_shape, step: int, seed: int = 0) -> dict:
    """Stub-frontend inputs (brief: precomputed patch/frame embeddings)."""
    b = batch_shape[0]
    # Domain-tag the run seed so the stub-frontend stream never collides
    # with the init/data streams derived from the same --seed.
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(seed), 0xF00D), step)
    c = model.cfg
    if spec.family == "vlm" and hasattr(c, "n_patches"):
        return {"patches": 0.1 * jax.random.normal(
            key, (b, c.n_patches, c.d_vit), jnp.bfloat16)}
    if spec.family == "encdec" and hasattr(c, "n_frames"):
        return {"frames": 0.1 * jax.random.normal(
            key, (b, c.n_frames, c.d_model), jnp.bfloat16)}
    return {}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--preset", default="smoke",
                    choices=("smoke", "paper100m", "full"))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    model = build_model(args.arch, args.preset)
    lm_cfg = getattr(model.cfg, "lm", None) or model.cfg
    vocab = lm_cfg.vocab
    seq = args.seq_len or (128 if args.preset != "full" else 4096)
    gbs = args.global_batch or (8 if args.preset != "full" else 256)

    mesh = make_mesh_for_devices()
    with use_mesh(mesh):
        return _run(args, model, mesh, vocab, seq, gbs)


def _run(args, model, mesh, vocab, seq, gbs) -> int:
    rules = shd.make_rules(mesh)
    from repro.models import sharding_ctx
    sharding_ctx.set_rules({**rules, "_mesh_sizes": dict(mesh.shape)})
    pspecs = param_pspecs(model.param_defs(), rules)
    opt_cfg = OptConfig(total_steps=max(args.steps, 200),
                        warmup_steps=min(20, args.steps // 3 + 1),
                        compress_grads=args.compress_grads)
    opt_ps = opt_state_pspecs(pspecs, opt_cfg)
    spec = get(args.arch)
    batch_ps = {"tokens": P("data"), "labels": P("data"), "mask": P("data")}
    for name in family_extras(spec, model, (1,), 0, seed=args.seed):
        batch_ps[name] = P("data")

    data_cfg = DataConfig(vocab=vocab, seq_len=seq, global_batch=gbs,
                          seed=args.seed)
    pipe = DataPipeline(data_cfg)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start_step = 0
    params = opt_state = None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        tmpl_params = init_params(model.param_defs(),
                                  jax.random.key(args.seed))
        tmpl = {
            "params": tmpl_params,
            "opt": init_opt_state(tmpl_params, opt_cfg),
        }
        tree, step, meta = ckpt.restore(
            tmpl, shardings={
                "params": shd.named(mesh, pspecs),
                "opt": shd.named(mesh, opt_ps)})
        params, opt_state = tree["params"], tree["opt"]
        pipe = DataPipeline.from_state(data_cfg, meta["data"])
        start_step = step
        print(f"[train] resumed from step {step} "
              f"(data stream continues at {pipe.next_step})")
    if params is None:
        params = init_params(model.param_defs(), jax.random.key(args.seed))
        params = jax.device_put(params, shd.named(mesh, pspecs))
        opt_state = init_opt_state(params, opt_cfg)
        opt_state = jax.device_put(opt_state, shd.named(mesh, opt_ps))

    n_params = param_count(model.param_defs())
    print(f"[train] arch={args.arch} preset={args.preset} params={n_params:,}"
          f" devices={mesh.size} seq={seq} batch={gbs}")

    step_fn = jax.jit(
        make_train_step(model, opt_cfg, microbatches=args.microbatches,
                        batch_axes=shd.batch_axes(mesh)),
        in_shardings=(shd.named(mesh, pspecs), shd.named(mesh, opt_ps),
                      shd.named(mesh, batch_ps), shd.named(mesh, P())),
        out_shardings=(shd.named(mesh, pspecs), shd.named(mesh, opt_ps),
                       shd.named(mesh, P())),
        donate_argnums=(0, 1),
    )

    def on_hang(waited):
        raise TimeoutError(f"step hung for {waited:.0f}s")

    dog = StepWatchdog(on_hang=on_hang)
    hb = Heartbeat(args.ckpt_dir or "/tmp/repro_hb", host_id=0)
    losses = []

    for step in range(start_step, args.steps):
        batch = next(pipe)
        batch.update(family_extras(spec, model, batch["tokens"].shape, step,
                                   seed=args.seed))
        dog.start_step(step)
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.uint32(step))
        loss = float(metrics["loss"])
        stats = dog.end_step()
        hb.beat(step, loss=loss)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"  step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({stats['step_time_s']:.2f}s"
                  f"{' STRAGGLER' if stats['straggler'] else ''})",
                  flush=True)
        if ckpt and (step + 1) % args.save_every == 0:
            ckpt.save_async(step + 1,
                            {"params": params, "opt": opt_state},
                            metadata={"data": pipe.state(),
                                      "loss": loss, "arch": args.arch})
    if ckpt:
        ckpt.wait()
        ckpt.save(args.steps, {"params": params, "opt": opt_state},
                  metadata={"data": pipe.state(), "arch": args.arch})
    dog.close()

    k = min(10, max(1, len(losses) // 4))
    first, last = sum(losses[:k]) / k, sum(losses[-k:]) / k
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'}), "
          f"stragglers={len(dog.stragglers)}")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
