import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production mesh, and extract the roofline terms from the compiled
artifact.  MUST be run as a module: PYTHONPATH=src python -m repro.launch.dryrun

The two lines above run before any other import — jax locks the device count
at first init.  Do NOT import this module from tests (it would force 512
devices session-wide).

Per cell this script records to artifacts/dryrun/<mesh>/<arch>__<shape>.json:
  * cost_analysis flops / bytes (per device — the module is SPMD-partitioned)
  * collective bytes by op kind, parsed from the compiled HLO
  * memory_analysis (argument/output/temp/peak bytes per device)
  * lower/compile wall times, microbatch setting, sharding overrides used

Restartable: existing cell files are skipped unless --force.
"""
import argparse
import json
import pathlib
import re
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, all_archs, get
from repro.launch.mesh import make_production_mesh
from repro.models.params import abstract_params, param_pspecs
from repro.runtime import sharding as shd
from repro.runtime.optim import OptConfig, opt_state_pspecs
from repro.runtime.steps import (make_decode_step, make_prefill_step,
                                 make_train_step)

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")
SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(tok: tuple[str, str]) -> int:
    dt, dims = tok
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic by op kind, from post-SPMD HLO.

    Methodology: per op line, take the largest tensor involved (for
    all-gather that's the gathered result ~= bytes received; for
    reduce-scatter the unscattered operand ~= bytes sent); all-reduce counts
    2x (ring reduce-scatter + all-gather).  '-done' lines are skipped so
    async pairs aren't double-counted.
    """
    out = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "-done" in line:
            continue
        kind = m.group(1)
        sizes = [_shape_bytes(t) for t in SHAPE_RE.findall(line)]
        if not sizes:
            continue
        b = max(sizes)
        out[kind] += 2 * b if kind == "all-reduce" else b
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def sharding_overrides(spec, mesh, kind: str) -> dict:
    """Per-arch logical-axis overrides for divisibility on this mesh.

    Policy (hypothesis-tested, see EXPERIMENTS.md §Perf baseline notes):
      * q heads shard over "model" when divisible, else replicate (an
        earlier head_dim-sharding fallback was measured to make GSPMD
        replicate the whole attention through the rope reshapes — 4x flops).
      * kv heads likewise; replicated kv projections are cheap (kv << H).
      * decode caches sequence-shard over "model" when kv heads can't —
        the cache is the dominant decode-memory term and attention reduces
        over S, which partitions as partial-softmax + all-reduce.
    """
    msize = mesh.shape["model"]
    model = spec.make_model()
    cfg = getattr(model, "cfg", None)
    lm = getattr(cfg, "lm", None) or cfg
    ov = {}

    def dims(name, default=0):
        return getattr(lm, name, default)

    n_heads = dims("n_heads")
    n_kv = dims("n_kv_heads")
    vocab = dims("vocab")
    is_mla = dims("attention", "gqa") == "mla"
    if n_heads and n_heads % msize:
        ov["heads"] = None
    kv_sharded = bool(n_kv) and n_kv % msize == 0
    if n_kv and not kv_sharded:
        ov["kv_heads"] = None
    if vocab and vocab % msize:
        ov["vocab"] = None
    # KV-indivisible caches sequence-shard over "model" — for decode (the
    # cache is the dominant read) AND prefill (the emitted cache is the
    # dominant resident: llama4 32k prefill carries 12.9 GiB/device of
    # otherwise-replicated KV).
    if kind in ("decode", "prefill") and (is_mla or not kv_sharded):
        ov["kv_seq"] = "model"
    return ov


def pick_microbatch(requested: int, global_batch: int, mesh) -> int:
    shards = mesh.shape["data"] * mesh.shape.get("pod", 1)
    k = max(1, requested)
    while k > 1 and (global_batch % k or (global_batch // k) % shards):
        k -= 1
    return k


def build_cell(arch: str, shape: str, mesh, variant: str = "base"):
    """Returns (jit_fn, example_args) for lowering."""
    spec = get(arch)
    model = spec.make_model()
    cell = SHAPES[shape]
    ov = sharding_overrides(spec, mesh, cell.kind)
    if variant in ("decode_tp_weights", "zero2_weights"):
        # Hillclimb variant: weights TP-only — no per-microbatch FSDP
        # all-gathers (ZeRO-2-style: optimizer state stays sharded via its
        # own out_shardings; weights replicate over "data").  Trades HBM
        # for collective time on deep models (deepseek-67b: 95 layers x 16
        # microbatches of re-gathers).
        ov["embed"] = None
    if variant == "train_seq_shard":
        ov["sequence"] = "model"

    batch_shards = mesh.shape["data"] * mesh.shape.get("pod", 1)
    batch_axes = shd.batch_axes(mesh)
    if cell.global_batch % batch_shards:
        batch_axes = None  # batch=1 long-context: replicate batch dim
        ov["batch"] = None  # caches carry a batch dim too
        # the idle "data" axis takes the cache sequence dim instead
        # (zamba2 long_500k: 6.1 GiB of 524k-seq KV otherwise replicated)
        if cell.kind == "decode":
            ov.setdefault("kv_seq", "data")

    # logits output: vocab-sharded only when divisible by the TP degree
    logit_axis = None if "vocab" in ov else "model"

    rules = shd.make_rules(mesh, ov)
    from repro.models import sharding_ctx
    sharding_ctx.set_rules({**rules, "batch": batch_axes,
                            "_mesh_sizes": dict(mesh.shape)})
    pspecs = param_pspecs(model.param_defs(), rules)
    params_abs = abstract_params(model.param_defs())

    in_specs = spec.input_specs(shape)
    input_ps = {}
    for name, s in in_specs.items():
        if s.ndim == 0:
            input_ps[name] = P()
        else:
            input_ps[name] = P(*((batch_axes,) + (None,) * (s.ndim - 1)))

    if cell.kind == "train":
        mb = pick_microbatch(spec.microbatch.get(shape, 1),
                             cell.global_batch, mesh)
        opt_cfg = OptConfig()
        step = make_train_step(model, opt_cfg, microbatches=mb,
                               batch_axes=batch_axes)
        opt_ps = opt_state_pspecs(pspecs, opt_cfg)
        opt_abs = {
            "mu": jax.tree.map(
                lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32),
                params_abs),
            "nu": jax.tree.map(
                lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32),
                params_abs),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        labels_ps = {k: v for k, v in input_ps.items()}
        fn = jax.jit(
            step,
            in_shardings=shd.named(
                mesh, (pspecs, opt_ps, labels_ps, P())),
            out_shardings=shd.named(mesh, (pspecs, opt_ps, P())),
            donate_argnums=(0, 1),   # params/opt update in place (as train.py)
        )
        args = (params_abs, opt_abs, in_specs,
                jax.ShapeDtypeStruct((), jnp.uint32))
        return fn, args, {"microbatch": mb, "overrides": repr(ov)}

    if cell.kind == "prefill":
        fn_raw = make_prefill_step(model, spec.family)
        cache_ps = param_pspecs(
            model.cache_defs(cell.global_batch, cell.seq_len), rules)
        extras = {k: v for k, v in in_specs.items() if k != "tokens"}
        extra_ps = {k: input_ps[k] for k in extras}
        fn = jax.jit(
            fn_raw,
            in_shardings=shd.named(
                mesh, (pspecs, input_ps["tokens"], extra_ps)
                if extras else (pspecs, input_ps["tokens"])),
            out_shardings=shd.named(
                mesh, (P(batch_axes, logit_axis), cache_ps)),
        )
        args = ((params_abs, in_specs["tokens"], extras) if extras
                else (params_abs, in_specs["tokens"]))
        return fn, args, {"overrides": repr(ov)}

    # decode
    fn_raw = make_decode_step(model)
    cache_abs = spec.cache_specs(shape)
    cache_ps = param_pspecs(
        model.cache_defs(cell.global_batch, cell.seq_len), rules)
    fn = jax.jit(
        fn_raw,
        in_shardings=shd.named(
            mesh, (pspecs, cache_ps, input_ps["tokens"], P())),
        out_shardings=shd.named(
            mesh, (P(batch_axes, logit_axis), cache_ps)),
        donate_argnums=(1,),   # KV/state cache updates in place
    )
    args = (params_abs, cache_abs, in_specs["tokens"],
            jax.ShapeDtypeStruct((), jnp.int32))
    return fn, args, {"overrides": repr(ov)}


def run_cell(arch: str, shape: str, mesh, mesh_name: str,
             variant: str = "base", save_hlo: bool = False) -> dict:
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "variant": variant, "n_devices": mesh.size}
    fn, args, meta = build_cell(arch, shape, mesh, variant)
    rec.update(meta)

    t0 = time.time()
    with mesh:
        lowered = fn.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        # NOTE: XLA counts while bodies once (scan trip counts ignored);
        # kept for reference only — the roofline uses the trip-count-aware
        # analyzer below.
        rec["xla_flops_scan_once"] = float(ca.get("flops", -1.0))
        rec["xla_bytes_scan_once"] = float(ca.get("bytes accessed", -1.0))
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = str(e)[:200]

    try:
        ma = compiled.memory_analysis()
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
            v = getattr(ma, field, None)
            if v is not None:
                rec[field] = int(v)
        rec["peak_bytes_per_device"] = (
            rec.get("argument_size_in_bytes", 0)
            + rec.get("temp_size_in_bytes", 0)
            + rec.get("output_size_in_bytes", 0)
            - rec.get("alias_size_in_bytes", 0))
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = str(e)[:200]

    hlo = compiled.as_text()
    from repro.launch.hlo_analysis import summarize
    s = summarize(hlo)
    rec["flops_per_device"] = s["flops"]
    rec["bytes_per_device"] = s["bytes"]
    rec["collectives"] = {
        "bytes": s["collective_bytes"],
        "counts": s["collective_counts"],
        "total_bytes": s["total_collective_bytes"],
    }
    if save_hlo:
        (ART / mesh_name).mkdir(parents=True, exist_ok=True)
        (ART / mesh_name / f"{arch}__{shape}__{variant}.hlo.txt"
         ).write_text(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--variant", default="base")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    mesh_name = args.mesh
    outdir = ART / mesh_name
    outdir.mkdir(parents=True, exist_ok=True)

    archs = [args.arch] if args.arch else all_archs()
    n_ok = n_skip = n_fail = 0
    for arch in archs:
        spec = get(arch)
        shapes = [args.shape] if args.shape else spec.shapes
        for shape in shapes:
            if shape not in spec.shapes:
                continue
            tag = f"{arch}__{shape}" + (
                "" if args.variant == "base" else f"__{args.variant}")
            path = outdir / f"{tag}.json"
            if path.exists() and not args.force:
                n_skip += 1
                continue
            print(f"[dryrun:{mesh_name}] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape, mesh, mesh_name, args.variant,
                               args.save_hlo)
                rec["ok"] = True
                path.write_text(json.dumps(rec, indent=1))
                print(f"  ok: flops/dev={rec.get('flops_per_device', 0):.3e}"
                      f" coll={rec['collectives']['total_bytes']:.3e}B"
                      f" peak={rec.get('peak_bytes_per_device', 0)/2**30:.2f}"
                      f"GiB lower={rec['lower_s']}s"
                      f" compile={rec['compile_s']}s", flush=True)
                n_ok += 1
            except Exception as e:
                n_fail += 1
                err = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "variant": args.variant, "ok": False,
                       "error": f"{type(e).__name__}: {e}"[:2000]}
                path.with_suffix(".error.json").write_text(
                    json.dumps(err, indent=1))
                print(f"  FAIL: {type(e).__name__}: {str(e)[:300]}",
                      flush=True)
    print(f"[dryrun:{mesh_name}] done ok={n_ok} skip={n_skip} fail={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
