"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` visits every computation ONCE —
a `lax.scan` over 95 layers reports the flops of a single layer (verified in
tests/test_hlo_analysis.py).  Since the whole framework leans on scans for
layers / microbatches / loss chunks, the roofline needs real totals.

This module parses the post-optimization, post-SPMD HLO text (per-device
module) into computations + ops and aggregates three roofline quantities with
while-loop multipliers taken from ``backend_config={"known_trip_count":...}``:

  * flops            — dot/convolution (2*M*N*K from operand shapes)
  * traffic bytes    — operand+result bytes of top-level memory-moving ops
                       (fusions count at their boundary, not internals)
  * collective bytes — by kind; all-reduce counted 2x (ring = RS + AG)

Known approximations (documented in EXPERIMENTS.md §Roofline):
  * conditional branches count once each (none on the hot paths here);
  * convolution flops assume depthwise/grouped (exact for the Mamba2 conv);
  * traffic counts buffer touches, ignoring cache reuse between ops — an
    upper bound on HBM bytes, conservative for the memory term.
"""
from __future__ import annotations

import dataclasses
import re

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
               "u16": 2, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
               "c64": 8, "c128": 16}

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"([\w\-]+)\(")
_TUPLE_OP = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*\((.*?)\)\s+([\w\-]+)\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
# ``fusion`` uses calls=; ``call`` (current jaxlib wraps parallel kLoop
# fusions in a call computation) and ``reduce``/``sort`` use to_apply=.
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_COND_BODY = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "iota", "after-all", "broadcast", "reshape",
               # control flow: the loop-carried tuple is not per-iteration
               # HBM traffic; the body's ops are counted (x trip) instead
               "while", "conditional", "call"}


def _nelem(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(dt: str, dims: str) -> int:
    return _nelem(dims) * DTYPE_BYTES.get(dt, 0)


def _all_shape_bytes(text: str) -> int:
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE.findall(text))


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_bytes: int
    result_elems: int
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    shapes: dict          # %name -> (dtype, dims) for dot flop resolution


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr and "{" in line:
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            # parameter shapes from the signature
            for pname, dt, dims in re.findall(
                    r"([\w.\-]+):\s*([a-z0-9]+)\[([0-9,]*)\]", hdr.group(2)):
                cur.shapes[pname] = (dt, dims)
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if m:
            name, dt, dims, kind = m.groups()
            cur.shapes[name] = (dt, dims)
            cur.ops.append(Op(name, kind, _shape_bytes(dt, dims),
                              _nelem(dims), line))
            continue
        mt = _TUPLE_OP.match(line)
        if mt:
            name, inner, kind = mt.groups()
            b = _all_shape_bytes(inner)
            # record first element shape for gte resolution best-effort
            cur.ops.append(Op(name, kind, b, 0, line))
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    # operands appear after the opcode '('
    tail = op.line.split(op.kind + "(", 1)[-1]
    names = _OPERAND.findall(tail)
    if not names:
        return 0.0
    lhs = comp.shapes.get(names[0])
    contract = _CONTRACT.search(op.line)
    if lhs is None or contract is None:
        # fall back: assume square-ish contraction of result dim
        return 2.0 * op.result_elems
    dims = [int(x) for x in contract.group(1).split(",") if x]
    lhs_dims = [int(x) for x in lhs[1].split(",") if x]
    k = 1
    for d in dims:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    return 2.0 * op.result_elems * k


_DIM_LABELS = re.compile(r"dim_labels=([\w\d]+)_([\w\d]+)->([\w\d]+)")


def _conv_flops(op: Op, comp: Computation) -> float:
    """Convolution flops with grouped/depthwise and gradient-conv handling.

    Two regimes:
      * filter-like (one operand is a small kernel): the usual
        2 * out * kernel_elems / (feature_groups * batch_groups) — exact for
        the depthwise Mamba2 conv (groups == channels -> 2 * out * window).
      * both operands large — XLA expresses the *weight gradient* of a conv
        as a convolution whose "window" is the whole sequence
        (window={size=4096}, batch_group_count=C).  Counting that as dense
        over-counted mamba2-1.3b/train_4k by ~70,000x (8.1e15 of 8.2e15
        reported flops).  True work = 2 * larger_operand * out_spatial.
    """
    tail = op.line.split(op.kind + "(", 1)[-1]
    names = _OPERAND.findall(tail)
    if len(names) < 2:
        return 0.0
    lhs = comp.shapes.get(names[0])
    rhs = comp.shapes.get(names[1])
    if rhs is None:
        return 2.0 * op.result_elems
    lhs_elems = _nelem(lhs[1]) if lhs else 0
    rhs_elems = _nelem(rhs[1])
    fg = re.search(r"feature_group_count=(\d+)", op.line)
    bg = re.search(r"batch_group_count=(\d+)", op.line)
    groups = (int(fg.group(1)) if fg else 1) * (int(bg.group(1)) if bg else 1)
    small = min(lhs_elems or rhs_elems, rhs_elems)
    if small <= 100_000:  # a real filter
        return 2.0 * op.result_elems * max(1, small // groups)
    # gradient-shaped conv: reduction spans the big operand once per output
    # spatial position (digit-labeled dims of the result).
    out_spatial = 1
    m = _DIM_LABELS.search(op.line)
    if m and lhs:
        out_labels = m.group(3)
        out_dims = [int(x) for x in op.line.split("[", 1)[1]
                    .split("]")[0].split(",") if x]
        for lbl, dim in zip(out_labels, out_dims):
            if lbl.isdigit():
                out_spatial *= dim
    return 2.0 * max(lhs_elems, rhs_elems) * out_spatial


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = None
    collective_counts: dict = None

    def __post_init__(self):
        self.collective_bytes = self.collective_bytes or dict.fromkeys(
            COLLECTIVES, 0.0)
        self.collective_counts = self.collective_counts or dict.fromkeys(
            COLLECTIVES, 0.0)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVES:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(hlo: str, entry: str | None = None) -> Cost:
    comps = parse_computations(hlo)
    # find entry: the computation named in 'ENTRY %name' line
    if entry is None:
        m = re.search(r"^ENTRY %?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))
    memo: dict[tuple[str, bool], Cost] = {}

    def _op_traffic_bytes(op: Op, comp: Computation,
                          operand_names: list[str]) -> float:
        """HBM traffic model for one top-level op.

        Naive operands+result over-counts loop-body accesses 10x+ (measured
        8.1 TB/device on qwen3/train_4k): a scan iteration's fusion lists the
        whole stacked activation stash bf16[28,2,4096,2048] as an operand but
        reads one layer's slice.  Rules:
          * dynamic-update-slice (op or fusion root): the big buffer is
            aliased in place; traffic = 2 x the non-buffer operands
            (read update + write slice).
          * dynamic-slice: traffic = 2 x result (read slice, write result).
          * kLoop fusions and gather: output-driven — each operand
            contributes min(its bytes, result_elems x its dtype size)
            (elementwise semantics; big operands are sliced or gathered).
          * everything else (dot, convolution, kInput/reduce fusions,
            concatenate, copy, ...): full operands + result — reductions and
            contractions genuinely read every operand element.
        """
        is_dus = ("dynamic-update-slice" in op.name
                  or op.kind == "dynamic-update-slice")
        is_ds = not is_dus and ("dynamic-slice" in op.name
                                or op.kind == "dynamic-slice")
        sizes = []
        dtypes = []
        for nm in operand_names:
            sh = comp.shapes.get(nm)
            if sh:
                sizes.append(_shape_bytes(*sh))
                dtypes.append(sh[0])
        if is_dus:
            # in-place update: traffic = read update + write slice.  Count
            # only sub-buffer-sized operands — a DUS fusion can list several
            # buffer-sized aliases (e.g. the carried cache and its converted
            # copy), none of which move per iteration.
            small = [b for b in sizes if b < 0.5 * op.result_bytes]
            return 2.0 * sum(small)
        if is_ds:
            return 2.0 * op.result_bytes
        cap_elems = None
        if op.kind == "gather":
            cap_elems = op.result_elems or None
        elif op.kind == "fusion" and "kind=kLoop" in op.line \
                and op.result_elems:
            cap_elems = op.result_elems
        tot = float(op.result_bytes)
        for b, dt in zip(sizes, dtypes):
            if cap_elems:
                b = min(b, cap_elems * DTYPE_BYTES.get(dt, 4))
            tot += b
        return tot

    def comp_cost(name: str, in_fusion: bool) -> Cost:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        total = Cost()
        memo[key] = total  # guard cycles
        comp = comps.get(name)
        if comp is None:
            return total
        for op in comp.ops:
            line = op.line
            if op.kind == "dot":
                total.flops += _dot_flops(op, comp)
            elif op.kind == "convolution":
                total.flops += _conv_flops(op, comp)
            is_coll = None
            for ck in COLLECTIVES:
                if op.kind.startswith(ck) and not op.kind.endswith("-done"):
                    is_coll = ck
                    break
            if is_coll:
                sizes = [_shape_bytes(dt, dims)
                         for dt, dims in _SHAPE.findall(line)]
                b = max(sizes) if sizes else 0
                mult = 2.0 if is_coll == "all-reduce" else 1.0
                total.collective_bytes[is_coll] += b * mult
                total.collective_counts[is_coll] += 1
            # bytes at top level only (fusion internals don't touch HBM)
            if not in_fusion and op.kind not in _SKIP_BYTES:
                operand_tail = line.split("(", 1)[-1]
                total.bytes += _op_traffic_bytes(
                    op, comp, _OPERAND.findall(operand_tail))
            # recurse into called computations
            wb = _COND_BODY.search(line)
            if wb and op.kind == "while":
                trip = 1
                mt = _TRIP.search(line)
                if mt:
                    trip = int(mt.group(1))
                total.add(comp_cost(wb.group(1), in_fusion), trip)
                total.add(comp_cost(wb.group(2), in_fusion), trip)
                continue
            mc = _CALLS.search(line)
            if mc:
                callee_fused = in_fusion or op.kind == "fusion"
                total.add(comp_cost(mc.group(1), callee_fused), 1.0)
            mb = _BRANCHES.search(line)
            if mb:
                for br in _OPERAND.findall(mb.group(1)):
                    total.add(comp_cost(br, in_fusion), 1.0)
        memo[key] = total
        return total

    return comp_cost(entry, False)


def summarize(hlo: str) -> dict:
    c = analyze(hlo)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.collective_bytes,
        "collective_counts": c.collective_counts,
        "total_collective_bytes": c.total_collective_bytes,
    }
