"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).

Target: TPU v5e pods — 256 chips/pod as (data=16, model=16); multi-pod adds a
leading "pod" axis (2 pods = 512 chips).  "model" is the TP/EP axis (fast ICI
within a pod slice); "data" carries DP + FSDP; "pod" carries cross-pod DP
(gradient all-reduce over DCN/optical links).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU multi-device tests (subprocess with forced host
    device count)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_mesh_for_devices():
    """Largest ("data", "model") factorization of the visible devices —
    model axis capped at 8 — the launcher default without an explicit mesh.
    """
    n = len(jax.devices())
    model = 1
    for m in (8, 4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def parse_mesh_spec(spec: str):
    """``"D,M"`` (e.g. ``--mesh 2,4``) -> a ("data", "model") host mesh.

    The one place a CLI mesh request turns into a ``Mesh`` — device-mesh
    construction is confined to this module (analysis/lint.py:
    no-mesh-outside-launch-mesh).
    """
    try:
        data, model = (int(p) for p in spec.split(","))
    except ValueError:
        raise ValueError(
            f"mesh spec {spec!r} is not 'DATA,MODEL' (e.g. '2,4')") from None
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got {data}x{model}")
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(
            f"mesh {data}x{model} needs {data * model} devices but only "
            f"{n} visible — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={data * model}")
    return make_host_mesh(data, model)


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh, portable
    across JAX versions.

    ``jax.set_mesh`` (0.6+) / ``jax.sharding.use_mesh`` (0.5.x) replaced the
    older ``with mesh:`` resource-env context; on the jaxlib pinned here only
    the latter exists.  All launchers and mesh tests go through this helper so
    the call site never references a removed API.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # Mesh is itself a context manager on older JAX
