"""Batched serving driver: prefill + greedy decode, optional PUD GeMV path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --preset smoke --batch 4 --prompt-len 32 --gen 16 --pud-gemv

With ``--pud-gemv`` the FFN and unembed projections (plus attention with
``--pud-attention``) are packed into 4-bit bit-planes (the PUD/MVDRAM weight
layout) and every decode step executes them through the Pallas bit-plane
kernel. The driver reports:

  * numerics: max |logit delta| and token agreement vs the bf16 path,
  * the DRAM-side performance model: tokens/s a real 4-channel DDR4 PUD
    system would sustain for this model at the calibrated error-free column
    fraction — baseline B_{3,0,0} vs PUDTune T_{2,1,0} (the paper's Eq. 1
    applied end-to-end).

With ``--calib-cache`` the device's persisted per-subarray table drives the
whole chain: calibration masks -> column placement (error-free physical
columns only, repro/pud/placement.py) -> physically-permuted packs -> the
placed Pallas kernel, and the serving rate is derived from the actual
placement occupancy instead of a mean error-free fraction.

With ``--engine`` generation runs through the continuous-batching
``ServingEngine`` (runtime/engine.py): each prompt row becomes a queued
request, slots admit/evict at step granularity, and ``--batch-size``
(default: the session's occupancy-derived optimum) sets the padded decode
batch.  Batched decode is bit-identical per request to the lockstep loop.

All of that wiring lives behind ``repro.api.PUDSession`` (docs/api.md);
this driver is one consumer of the session, not the owner of the chain.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.models.params import init_params, param_count
from repro.pud.gemv import ATTN_PACKABLE, FFN_PACKABLE, PUDGemvConfig
from repro.runtime.steps import make_serve_step

#: Seed of the default serve-step key when the caller does not thread one
#: (greedy decode never consumes it; sampling steps derive from here).
DEFAULT_SEED = 0


@functools.lru_cache(maxsize=8)
def _jitted(model):
    """Per-model jitted (prefill, serve step) pair, cached so repeated
    greedy_generate calls (bf16 + pud legs, tests) reuse one trace cache."""
    return (jax.jit(model.prefill, static_argnames=("max_len",)),
            jax.jit(make_serve_step(model)))


def greedy_generate(model, params, tokens, gen: int, max_len: int,
                    extras: dict | None = None, prefix_len: int = 0,
                    key: jax.Array | None = None):
    """Prefill then ``gen`` greedy steps. Returns [B, gen] tokens.

    prefix_len: non-token positions preceding the prompt in the cache
    (VLM patch prefix) — decode positions start after prompt + prefix.
    key: explicit PRNG key threaded into the serve step (step ``i`` sees
    ``fold_in(key, i)``); defaults to ``jax.random.key(0)``, the former
    implicit constant.  Greedy decode never consumes it, but threading it
    explicitly keeps batched-vs-sequential comparisons (and any sampling
    serve step) reproducible from one seed.

    Prefill runs jitted (like the decode steps and the ServingEngine's
    per-request prefill), so per-request sequential decode and batched
    engine decode see bit-identical logits end to end.
    """
    prefill, step = _jitted(model)
    if extras:
        logits, cache = prefill(params, tokens, *extras.values(),
                                max_len=max_len)
    else:
        logits, cache = prefill(params, tokens, max_len=max_len)
    cur = tokens.shape[1] + prefix_len
    out = []
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    if key is None:
        key = jax.random.key(DEFAULT_SEED)
    all_logits = [logits]
    for i in range(gen):
        out.append(nxt)
        nxt, logits, cache = step(params, cache, nxt, jnp.int32(cur + i),
                                  jax.random.fold_in(key, i))
        all_logits.append(logits)
    return jnp.concatenate(out, axis=1), jnp.stack(all_logits, axis=1)


def _scheduler_kwargs(args) -> dict:
    """Engine scheduler extensions from the CLI flags (all default-off)."""
    return {
        "chunk_prefill": args.chunk_prefill,
        "prefix_cache": bool(args.prefix_cache),
        "slo": args.slo_ms,
    }


def _print_scheduler_extras(sched: dict, indent: str = "    ") -> None:
    """Prefix-cache / SLO telemetry lines (only when the features are on)."""
    pc = sched.get("prefix_cache")
    if pc:
        print(f"{indent}prefix cache: {pc['hits']} hits / "
              f"{pc['misses']} misses ({pc['hit_rate']:.0%}), "
              f"{pc['entries']} entries ({pc['bytes'] / 1024:.0f} KiB), "
              f"{pc['invalidations']} invalidations")
    slo = sched.get("slo")
    if slo:
        print(f"{indent}slo: {slo['met']} met / {slo['missed']} missed "
              f"(shed {slo['shed_on_admit']} at admission, "
              f"{slo['shed_admitted']} in flight; "
              f"modeled step {slo['step_ms']:.3f} ms)")


def _monitored_serve(args, session, engine, model, params, requests,
                     tokens, max_len) -> int:
    """Serve ``requests`` under the drift monitor (--monitor).

    With ``--drift-sim`` the simulator drifts the device's sense offsets at
    step ``--drift-at`` and the live pack is corrupted to match (faults
    re-derived from the drifted offsets, injected into the serving tree),
    so the canary probes are detecting a real numeric failure, not a flag.
    The controller then drives detection -> partial recalibration ->
    repack -> between-steps hot swap, and a post-swap spot check proves
    decode is bit-identical to a fresh pack on the recovered table.
    """
    from repro.core.canary import probe_ecr
    from repro.core.reliability import DriftSimulator
    from repro.pud.placement import inject_read_faults, refresh_fault_state
    from repro.runtime.drift import (DriftConfig, DriftController,
                                     DriftMonitor)
    from repro.runtime.engine import Request

    sim = DriftSimulator.for_session(session)
    mon = DriftMonitor(session, sim, config=DriftConfig(
        n_canary=args.n_canary, probe_every=args.probe_every))
    read_faults = None
    if args.drift_sim and session.placement is not None:
        def read_faults(packed_params):
            masks = np.asarray(session.calibration.masks, bool)
            pl = refresh_fault_state(session.placement, masks,
                                     np.asarray(sim.sense_offsets()))
            return inject_read_faults(packed_params, pl)
    ctl = DriftController(engine, mon, params,
                          pack_name=f"{args.arch}-{args.preset}",
                          read_faults=read_faults)
    print(f"  monitor: probing {args.n_canary} canaries/subarray every "
          f"{args.probe_every} steps "
          f"(amortized overhead {mon.probe_overhead():.2%} of decode)")

    engine.submit_all(requests)
    drifted, steps = False, 0
    while (engine.n_pending or engine.n_active
           or ctl.phase != "monitor" or engine.swap_pending):
        if args.drift_sim and not drifted and steps >= args.drift_at:
            subs = [int(s) for s in args.drift_subarrays.split(",") if s]
            sim.advance(temp_c=args.drift_temp, days=args.drift_days,
                        subarrays=subs)
            _, masks = probe_ecr(
                jax.random.fold_in(jax.random.key(args.seed), 0xD21F),
                sim.sense_offsets(), mon._charges(), session.physics,
                session.n_fracs, n_trials=128)
            if session.placement is not None:
                engine.params = inject_read_faults(
                    engine.params, refresh_fault_state(
                        session.placement, np.asarray(masks, bool),
                        np.asarray(sim.sense_offsets())))
            print(f"  drift-sim: offsets drifted at step {steps} "
                  f"(temp {args.drift_temp:.0f}C, {args.drift_days:g} "
                  f"days, subarrays {subs}); live pack corrupted")
            drifted = True
        ctl.step()
        steps += 1
        if steps > 64 * (len(requests) + 8):
            raise RuntimeError("monitor loop did not converge")

    rep = ctl.report()
    for ev in mon.detector.events:
        print(f"    drift event: subarray {ev.subarray} {ev.severity} "
              f"(canary ECR {ev.new_ecr:.3f}, probe round "
              f"{ev.probe_round})")
    for rec in rep["recoveries"]:
        ecr = ", ".join(f"{g}: {e:.3f}"
                        for g, e in rec["recalibrated_ecr"].items())
        print(f"    recovery: detected step {rec['detected_step']}, "
              f"recalibrated subarrays {rec['subarrays']} "
              f"(post-recal table ECR {{{ecr}}}), hot swap staged at "
              f"step {rec['swap_staged_step']}")
    print(f"    swaps at steps {rep['swap_steps']}, tokens on swap steps "
          f"{rep['swap_step_tokens']}, min tokens/step "
          f"{rep['min_tokens_per_step']} (zero-downtime: no stalled step)")
    sched = engine.scheduler_report()
    print(f"  engine: {sched['completed']} requests, "
          f"{sched['generated_tokens']} tokens in {sched['steps']} steps "
          f"({sched['batch_size']} slots, "
          f"occupancy {sched['slot_occupancy']:.1%})")

    if rep["recoveries"]:
        # Spot check: post-swap decode must equal a fresh decode on the
        # recovered pack (the bit-exactness contract, tests/test_drift.py).
        post = [Request(request_id=1000 + i,
                        tokens=tokens[i], max_new_tokens=args.gen)
                for i in range(min(2, len(requests)))]
        comps = {c.request_id: c for c in ctl.run(post)}
        fresh = session.packed.params
        n_ok = 0
        for r in post:
            want, _ = greedy_generate(
                model, fresh, jnp.asarray(r.tokens)[None, :],
                args.gen, max_len)
            n_ok += comps[r.request_id].tokens == list(np.asarray(want[0]))
        print(f"    post-swap spot check: {n_ok}/{len(post)} requests "
              "bit-identical to fresh decode on the recovered pack")
        if n_ok != len(post):
            raise RuntimeError("post-swap decode diverged from fresh pack")
    age = session.calibration_age()
    if age is not None:
        print(f"    table age: {age['age_days']:.4f} days "
              f"(assumed temp {age['assumed_temp_c']:.0f}C)")
    return 0


def _sharded_serve(args, spec, model, params, tokens, ref_toks,
                   max_len) -> int:
    """Serve through the tensor+data-parallel fleet (``--mesh D,M``).

    Opens one logical ``PUDSession`` per mesh device, calibrates and packs
    each lane's tensor-parallel shards (placement windows never straddle a
    shard), then drains the request queue through one ``ServingEngine``
    lane per data row — per-request decode stays bit-identical to the
    single-device engine, which the token-agreement print verifies against
    the bf16 reference exactly like the unsharded path.
    """
    from repro.core.calibrate import CalibrationConfig
    from repro.core.fleet import FleetConfig
    from repro.launch.mesh import parse_mesh_spec
    from repro.runtime.engine import Request
    from repro.runtime.session import PUDSession

    mesh = parse_mesh_spec(args.mesh)
    n_data, n_model = int(mesh.shape["data"]), int(mesh.shape["model"])
    packable = FFN_PACKABLE + (ATTN_PACKABLE if args.pud_attention else ())
    cfg = PUDGemvConfig(weight_bits=args.weight_bits, packable=packable)
    fleet = PUDSession.open_fleet(
        args.arch, mesh=mesh,
        grid=FleetConfig(n_channels=1, n_banks=1,
                         n_subarrays=args.fleet_subarrays,
                         n_cols=args.fleet_cols),
        cache_dir=args.calib_cache, device_id=args.device_id,
        calib=CalibrationConfig(n_iterations=12, n_samples=256),
        key=jax.random.key(args.seed + 2), placement=args.placement)
    print(f"[serve] fleet mesh {n_data}x{n_model} (data x model), "
          f"{fleet.n_devices} logical devices")
    t0 = time.time()
    fleet.calibrate()
    print(f"  calibration: {fleet.n_devices} devices in "
          f"{time.time() - t0:.2f}s")
    fleet.pack(params, cfg, name=f"{args.arch}-{args.preset}-fleet")
    statuses = sorted({s.placement_status or "logical"
                       for row in fleet.sessions for s in row})
    print(f"  placement per shard: {statuses}; "
          f"shard widths {list(fleet.shard_widths)} "
          f"(windows never straddle a shard)")
    if args.tune:
        trep = fleet.tune()
        n_hit = sum(1 for r in trep["keys"].values()
                    if r["status"] == "hit")
        print(f"  autotune: {len(trep['keys'])} per-shard keys "
              f"({n_hit} cache hits, {len(trep['keys']) - n_hit} searched)")

    engine = fleet.serving_engine(model, max_len=max_len,
                                  batch_size=args.batch_size,
                                  **_scheduler_kwargs(args))
    requests = [Request(request_id=i, tokens=tokens[i],
                        max_new_tokens=args.gen)
                for i in range(args.batch)]
    completions = engine.run(requests)
    sched = engine.scheduler_report()
    print(f"  fleet engine: {sched['completed']} requests over "
          f"{sched['n_lanes']} lanes in {sched['steps']} steps "
          f"({sched['batch_size']} slots/lane, "
          f"{sched['generated_tokens']} tokens)")
    _print_scheduler_extras(sched)
    agree = float(np.mean(
        [c.tokens == list(np.asarray(ref_toks[c.request_id]))
         for c in completions]))
    print(f"    token agreement vs bf16: {100 * agree:.1f}% "
          "(quantization only — sharded decode is bit-identical to the "
          "single-device engine)")
    perf = engine.perf_report(2 * spec.n_active_params)
    print(f"    aggregate DDR4-PUD model: {perf['aggregate_tok_s']:.2f} "
          f"tok/s over {perf['n_devices']} devices, scaling efficiency "
          f"{perf['scaling_efficiency']:.2f} "
          f"(slowest-shard work share {perf['shard_fraction']:.3f})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--preset", default="smoke", choices=("smoke", "full"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--pud-gemv", action="store_true")
    ap.add_argument("--engine", action="store_true",
                    help="also serve through the continuous-batching "
                         "ServingEngine (one request per batch row); "
                         "combine with --pud-gemv to feed it the packed "
                         "PUD path, alone it serves the bf16 tree")
    ap.add_argument("--chunk-prefill", type=int, default=None, metavar="N",
                    help="chunked prefill: admit prompts N tokens per step "
                         "interleaved with decode waves (pow2-rounded; "
                         "bit-identical to whole-request prefill)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="LRU reuse of completed prefills: repeated "
                         "prompts skip prefill, shared system prompts "
                         "resume after the cached prefix (invalidated on "
                         "every drift hot swap)")
    ap.add_argument("--slo-ms", type=float, default=None, metavar="X",
                    help="SLO-aware admission with an X ms default "
                         "deadline per request: earliest-deadline-first "
                         "admission priced by the placement perf model, "
                         "hopeless/expired requests shed")
    ap.add_argument("--batch-size", type=int, default=None,
                    help="engine decode slots; default = the session's "
                         "occupancy-derived optimal batch")
    ap.add_argument("--pud-attention", action="store_true",
                    help="also pack attention wq/wk/wv/wo onto the PUD path")
    ap.add_argument("--tune", action="store_true",
                    help="with --pud-gemv: autotune kernel tile plans at "
                         "startup (persisted under <calib-cache>/tuning; "
                         "cache hits cost a file read, cold start falls "
                         "back to the divisor heuristic)")
    ap.add_argument("--weight-bits", type=int, default=4)
    ap.add_argument("--no-placement", dest="placement",
                    action="store_false", default=True,
                    help="with --calib-cache: skip column placement and "
                         "pack onto logical columns (faulty ones included)")
    ap.add_argument("--monitor", action="store_true",
                    help="with --pud-gemv --engine: reserve canary columns, "
                         "probe them between decode steps (runtime/drift.py) "
                         "and recover from detected drift via partial "
                         "recalibration + a between-steps hot swap")
    ap.add_argument("--drift-sim", action="store_true",
                    help="with --monitor: inject simulated offset drift "
                         "(core/reliability.DriftSimulator) mid-serve and "
                         "demonstrate the full detect/recal/swap loop")
    ap.add_argument("--drift-at", type=int, default=3,
                    help="engine step at which --drift-sim injects drift")
    ap.add_argument("--drift-temp", type=float, default=3000.0,
                    help="simulated operating temperature in C; the default "
                         "is a deliberate stress far beyond the paper's "
                         "envelope so detection is certain in one round")
    ap.add_argument("--drift-days", type=float, default=0.0,
                    help="simulated days since calibration (time-drift leg)")
    ap.add_argument("--drift-subarrays", default="1,5",
                    help="comma-separated subarray ids hit by --drift-sim")
    ap.add_argument("--probe-every", type=int, default=4,
                    help="canary probe cadence in engine steps")
    ap.add_argument("--n-canary", type=int, default=16,
                    help="reserved canary columns per subarray")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="with --pud-gemv --engine: serve through a "
                         "tensor+data-parallel fleet on a DATAxMODEL host "
                         "mesh (PUDSession.open_fleet) — one calibrated "
                         "device per mesh position, packs sharded on "
                         "placement-window boundaries, one engine lane per "
                         "data row; requires XLA_FLAGS="
                         "--xla_force_host_platform_device_count>=DATA*MODEL")
    ap.add_argument("--calib-cache", default=None, metavar="DIR",
                    help="persistent calibration-table cache; serving "
                         "starts from the device's stored per-subarray "
                         "offset table instead of recalibrating")
    ap.add_argument("--device-id", default="dimm0")
    ap.add_argument("--fleet-subarrays", type=int, default=16,
                    help="subarray grid size used on a cache miss")
    ap.add_argument("--fleet-cols", type=int, default=2048,
                    help="columns per subarray used on a cache miss")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.monitor and not (args.pud_gemv and args.engine):
        ap.error("--monitor requires --pud-gemv and --engine")
    if args.drift_sim and not args.monitor:
        ap.error("--drift-sim requires --monitor")
    if args.mesh and not (args.pud_gemv and args.engine):
        ap.error("--mesh requires --pud-gemv and --engine")
    if args.mesh and args.monitor:
        ap.error("--mesh and --monitor are mutually exclusive (use "
                 "runtime.drift.FleetDriftMonitor programmatically)")

    spec = get(args.arch)
    model = spec.make_smoke() if args.preset == "smoke" else spec.make_model()
    lm_cfg = getattr(model.cfg, "lm", None) or model.cfg
    params = init_params(model.param_defs(), jax.random.key(args.seed))
    print(f"[serve] {args.arch} ({args.preset}, "
          f"{param_count(model.param_defs()):,} params) "
          f"batch={args.batch} prompt={args.prompt_len} gen={args.gen}")

    key = jax.random.key(args.seed + 1)
    tokens = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, lm_cfg.vocab, jnp.int32)
    max_len = args.prompt_len + args.gen + 1
    prefix_len = 0
    extras = {}
    if spec.family == "vlm":
        extras["patches"] = 0.1 * jax.random.normal(
            key, (args.batch, model.cfg.n_patches, model.cfg.d_vit),
            jnp.bfloat16)
        prefix_len = model.cfg.n_patches   # cache spans patches + text
        max_len += prefix_len
    elif spec.family == "encdec":
        extras["frames"] = 0.1 * jax.random.normal(
            key, (args.batch, model.cfg.n_frames, model.cfg.d_model),
            jnp.bfloat16)

    t0 = time.time()
    ref_toks, ref_logits = greedy_generate(
        model, params, tokens, args.gen, max_len, extras, prefix_len)
    dt = time.time() - t0
    print(f"  bf16 path: {args.batch * args.gen} tokens in {dt:.2f}s "
          "(CPU wall; TPU perf comes from the dry-run roofline)")

    if args.mesh:
        if extras:
            print("  fleet: vlm/encdec families not supported yet "
                  "(extras require family-specific prefill); skipping")
            return 0
        return _sharded_serve(args, spec, model, params, tokens, ref_toks,
                              max_len)

    if args.pud_gemv:
        packable = FFN_PACKABLE + (ATTN_PACKABLE if args.pud_attention
                                   else ())
        cfg = PUDGemvConfig(weight_bits=args.weight_bits, packable=packable)

        # All PUD wiring (calibration table, persistence, placement,
        # packing, rate models) lives behind the session facade.
        from repro.core.calibrate import CalibrationConfig
        from repro.core.fleet import FleetConfig
        from repro.runtime.session import PUDSession
        session = PUDSession.open(
            args.arch,
            grid=FleetConfig(n_channels=1, n_banks=1,
                             n_subarrays=args.fleet_subarrays,
                             n_cols=args.fleet_cols),
            cache_dir=args.calib_cache, device_id=args.device_id,
            calib=CalibrationConfig(n_iterations=12, n_samples=256),
            key=jax.random.key(args.seed + 2), placement=args.placement)
        if args.calib_cache:
            # Device-specific model from the persisted per-subarray table:
            # a cache hit costs a file read, not an Algorithm-1 run.
            st = session.calibrate()
            status = ("HIT (no recalibration)" if st.cache_hit
                      else "MISS (identified + persisted)")
            mean_ecr = 1 - session.tuned_perf_model().mean_error_free_frac
            print(f"  calibration table [{args.device_id}] {status} "
                  f"in {st.wall_s:.2f}s: "
                  f"{session.fleet_cfg.n_subarrays_total} subarrays, "
                  f"mean ECR {mean_ecr:.3f}")

        if args.monitor:
            # Canaries must be carved out before packing so placement
            # avoids them; a cache-less session calibrates here.
            if session.calibration is None:
                st = session.calibrate()
                print(f"  calibration (for --monitor): identified "
                      f"{session.fleet_cfg.n_subarrays_total} subarrays "
                      f"in {st.wall_s:.2f}s")
            session.reserve_canaries(args.n_canary)
            print(f"  canaries: {args.n_canary}/subarray reserved "
                  f"(set {session.canaries.fingerprint()}), excluded "
                  "from placement")

        packed = session.pack(params, cfg,
                              name=f"{args.arch}-{args.preset}")
        if session.placement_status == "skipped":
            print(f"  placement: SKIPPED ({session.placement_error}); "
                  "serving on logical columns")
        elif session.placement is not None:
            rep = session.perf_report()["placement"]
            pstatus = ("HIT" if session.placement_status == "hit"
                       else "planned + persisted")
            print(f"  placement [{session.placement_name}] {pstatus}: "
                  f"{rep['used_cols']:,}/{rep['usable_cols']:,} "
                  "error-free columns used "
                  f"(occupancy {rep['occupancy']:.1%}, "
                  f"{rep['occupied_subarrays']}"
                  f"/{rep['n_subarrays']} subarrays, "
                  f"{len(rep['spilled_tensors'])} tensors spilled)")

        if args.tune:
            # Tile plans load from the persistent tuning cache (miss =
            # search + persist) and are stamped onto the packs, so the
            # greedy and engine paths below both decode on tuned tiles.
            trep = session.tune()
            n_hit = sum(1 for r in trep["keys"].values()
                        if r["status"] == "hit")
            n_tuned = len(trep["keys"]) - n_hit
            print(f"  autotune: {len(trep['keys'])} keys "
                  f"({n_hit} cache hits, {n_tuned} searched)")
            for tkey, row in sorted(trep["keys"].items()):
                speed = (f"  {row['speedup']:.2f}x vs heuristic"
                         if "speedup" in row else "")
                print(f"    {row['status']:<5s} {tkey}: "
                      f"{row['plan'] or 'heuristic'}{speed}")
            packed = session.packed   # re-fetch: packs now carry plans

        extras_rep = session.decode_extras()
        toks, logits = greedy_generate(
            model, packed.params, tokens, args.gen, max_len, extras,
            prefix_len)
        agree = float((toks == ref_toks).mean())
        delta = float(jnp.abs(logits - ref_logits).max())
        print(f"  pud-gemv path ({cfg.weight_bits}-bit planes, "
              f"{extras_rep['n_packed']} projections packed, "
              f"{extras_rep['layout']} columns, "
              f"{extras_rep['stored_bytes'] / 2**20:.1f} MiB bit-packed "
              f"vs {extras_rep['dense_equiv_bytes'] / 2**20:.1f} MiB dense "
              f"— {extras_rep['traffic_reduction']:.1f}x less weight "
              "traffic/token):")
        print(f"    token agreement vs bf16: {100 * agree:.1f}%   "
              f"max |logit delta|: {delta:.3f} "
              "(quantization, not error — the kernel is exact int math)")

        # DRAM-side throughput model: what the paper's system sustains.
        perf = session.perf_report(2 * spec.n_active_params)
        print(f"    DDR4-PUD serving model ({args.arch} full config, "
              f"{args.weight_bits}-bit): "
              f"baseline {perf['baseline_tok_s']:.2f} tok/s"
              f" -> PUDTune {perf['tuned_tok_s']:.2f}"
              f" tok/s ({perf['gain']:.2f}x, Eq. 1)")
        if session.placement is not None:
            print("    placement-derived rate (occupied-subarray waves): "
                  f"{perf['placed_tok_s']:.2f} "
                  f"tok/s at {session.placement.occupancy:.1%} occupancy")

    if args.engine:
        if extras:
            print("  engine: vlm/encdec families not supported yet "
                  "(extras require family-specific prefill); skipping")
            return 0
        from repro.runtime.engine import Request, ServingEngine
        serve_params = packed.params if args.pud_gemv else params
        engine = ServingEngine(
            model, serve_params,
            session=session if args.pud_gemv else None,
            max_len=max_len, batch_size=args.batch_size,
            **_scheduler_kwargs(args))
        requests = [Request(request_id=i, tokens=tokens[i],
                            max_new_tokens=args.gen)
                    for i in range(args.batch)]
        if args.monitor:
            return _monitored_serve(args, session, engine, model, params,
                                    requests, tokens, max_len)
        completions = engine.run(requests)
        sched = engine.scheduler_report()
        print(f"  engine: {sched['completed']} requests, "
              f"{sched['generated_tokens']} tokens in {sched['steps']} steps "
              f"({sched['batch_size']} slots, "
              f"occupancy {sched['slot_occupancy']:.1%}, "
              f"{sched['wall_tok_s']:.1f} tok/s CPU wall)")
        if args.chunk_prefill:
            print(f"    chunked prefill: {sched['prefill_chunks']} chunks "
                  f"of {engine.chunk_prefill} tokens "
                  f"({sched['chunk_traces']} compiled variants)")
        _print_scheduler_extras(sched)
        # continuous batching must not change any request's tokens
        seq = ref_toks if not args.pud_gemv else toks
        agree = float(np.mean([c.tokens == list(np.asarray(seq[i]))
                               for i, c in enumerate(completions)]))
        print("    batched vs lockstep decode: "
              f"{100 * agree:.1f}% of requests bit-identical")
        if args.pud_gemv:
            perf = session.perf_report(2 * spec.n_active_params,
                                       batch_size=engine.batch_size)
            if "batched_tok_s" in perf:
                print("    DDR4-PUD batched rate: "
                      f"{perf['batched_tok_s']:.2f} aggregate tok/s at "
                      f"batch {perf['batch_size']} "
                      f"({perf['batch_speedup']:.2f}x over batch-1; "
                      f"occupancy-derived optimum {perf['optimal_batch']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
