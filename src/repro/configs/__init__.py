"""Architecture configs (one per assigned arch) + shape grid."""
from .registry import (ALL_SHAPES, SHAPES, ArchSpec, ShapeCell, all_archs,  # noqa: F401
                       get, grid)
