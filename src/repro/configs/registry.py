"""Architecture registry: the 10 assigned configs, their input shapes, and
reduced smoke-test variants.

Each ArchSpec provides:
  * model()         — full-size model object (Model protocol)
  * smoke_model()   — reduced same-family config for CPU smoke tests
  * input_specs(shape) — ShapeDtypeStruct stand-ins for every model input of
    the given shape cell (the dry-run lowers against these; nothing is
    allocated)
  * shapes          — which of the 4 assigned cells apply (long_500k only for
    sub-quadratic-decode families, per the brief; see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
QUADRATIC_SHAPES = ("train_4k", "prefill_32k", "decode_32k")


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str
    make_model: Callable[[], Any]
    make_smoke: Callable[[], Any]
    shapes: tuple[str, ...]
    # approx parameter counts for MODEL_FLOPS = 6*N*D (total, active)
    n_params: float = 0.0
    n_active_params: float = 0.0
    microbatch: dict[str, int] = dataclasses.field(default_factory=dict)
    notes: str = ""

    def input_specs(self, shape_name: str) -> dict:
        """ShapeDtypeStruct inputs for (this arch x the shape cell)."""
        cell = SHAPES[shape_name]
        b, s = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        def tok(bb, ss):
            return jax.ShapeDtypeStruct((bb, ss), i32)

        extras = {}
        text_len = s
        if self.family == "vlm":
            n_patch, d_vit = 256, 1024
            extras["patches"] = jax.ShapeDtypeStruct((b, n_patch, d_vit),
                                                     jnp.bfloat16)
            text_len = s - n_patch
        if self.family == "encdec":
            extras["frames"] = jax.ShapeDtypeStruct((b, 1500, 1280),
                                                    jnp.bfloat16)

        if cell.kind == "train":
            return {"tokens": tok(b, text_len), "labels": tok(b, text_len),
                    **extras}
        if cell.kind == "prefill":
            return {"tokens": tok(b, text_len), **extras}
        # decode: one new token against a cache of seq_len
        return {"tokens": tok(b, 1),
                "cur_len": jax.ShapeDtypeStruct((), i32)}

    def cache_specs(self, shape_name: str):
        """Abstract decode-cache structs for the dry-run."""
        from repro.models.params import abstract_params
        cell = SHAPES[shape_name]
        model = self.make_model()
        return abstract_params(
            model.cache_defs(cell.global_batch, cell.seq_len))


_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get(arch_id: str) -> ArchSpec:
    from . import archs  # noqa: F401  (populate on first use)
    return _REGISTRY[arch_id]


def all_archs() -> list[str]:
    from . import archs  # noqa: F401
    return sorted(_REGISTRY)


def grid() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells (skips documented in DESIGN.md)."""
    from . import archs  # noqa: F401
    cells = []
    for a in sorted(_REGISTRY):
        for s in _REGISTRY[a].shapes:
            cells.append((a, s))
    return cells
