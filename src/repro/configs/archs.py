"""The 10 assigned architectures (public-literature configs; see brief).

Full configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); each arch also defines a REDUCED smoke config of the same family
that runs a real forward/train step on CPU (tests/test_arch_smoke.py).

long_500k applies only to the sub-quadratic-decode families (ssm, hybrid);
the 8 pure full-attention archs skip it (DESIGN.md §Shape-grid skips).
"""
from __future__ import annotations

from repro.models.encdec import EncDecConfig, EncDecLM
from repro.models.hybrid import HybridConfig, HybridLM
from repro.models.multimodal import VLM, VLMConfig
from repro.models.ssm_lm import SSMLM, SSMLMConfig
from repro.models.transformer import LMConfig, TransformerLM

from .registry import (ALL_SHAPES, QUADRATIC_SHAPES, ArchSpec, register)

MB = {"train_4k": 8, "prefill_32k": 4, "decode_32k": 1, "long_500k": 1}
MB_BIG = {"train_4k": 16, "prefill_32k": 8, "decode_32k": 1, "long_500k": 1}


# --- deepseek-v2-lite-16b [moe, MLA] [arXiv:2405.04434] ---------------------

register(ArchSpec(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    make_model=lambda: TransformerLM(LMConfig(
        name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=10944, vocab=102400, attention="mla",
        mla_kv_rank=512, mla_qk_nope=128, mla_qk_rope=64, mla_v_dim=128,
        n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
        first_dense_layers=1)),
    make_smoke=lambda: TransformerLM(LMConfig(
        name="smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=256, attention="mla", mla_kv_rank=32, mla_qk_nope=16,
        mla_qk_rope=8, mla_v_dim=16, n_experts=8, top_k=2, moe_d_ff=64,
        n_shared_experts=1, first_dense_layers=1, loss_chunk=32)),
    shapes=QUADRATIC_SHAPES,
    n_params=15.8e9, n_active_params=2.7e9,
    microbatch=MB,
    notes="MLA kv_lora=512; 64 routed + 2 shared, top-6 (V2-Lite; the "
          "brief's '160 routed' belongs to full V2 — see DESIGN.md)",
))


# --- llama4-scout-17b-a16e [moe] ---------------------------------------------

register(ArchSpec(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    make_model=lambda: TransformerLM(LMConfig(
        name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=8192, vocab=202048, head_dim=128,
        n_experts=16, top_k=1, moe_d_ff=8192, n_shared_experts=1)),
    make_smoke=lambda: TransformerLM(LMConfig(
        name="smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=8, n_experts=4, top_k=1, moe_d_ff=128,
        n_shared_experts=1, loss_chunk=32)),
    shapes=QUADRATIC_SHAPES,
    n_params=107e9, n_active_params=17e9,
    microbatch=MB_BIG,
    notes="16 routed top-1 + 1 shared expert; iRoPE/NoPE simplified to "
          "full-attention RoPE (DESIGN.md)",
))


# --- qwen3-1.7b [dense, qk_norm] ---------------------------------------------

register(ArchSpec(
    arch_id="qwen3-1.7b",
    family="dense",
    make_model=lambda: TransformerLM(LMConfig(
        name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=6144, vocab=151936, head_dim=128, qk_norm=True,
        rope_theta=1e6)),
    make_smoke=lambda: TransformerLM(LMConfig(
        name="smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16, qk_norm=True, loss_chunk=32)),
    shapes=QUADRATIC_SHAPES,
    n_params=2.03e9, n_active_params=2.03e9,
    microbatch=MB,
))


# --- gemma-7b [dense, GeGLU, head_dim 256] [arXiv:2403.08295] ----------------

register(ArchSpec(
    arch_id="gemma-7b",
    family="dense",
    make_model=lambda: TransformerLM(LMConfig(
        name="gemma-7b", n_layers=28, d_model=3072, n_heads=16,
        n_kv_heads=16, d_ff=24576, vocab=256000, head_dim=256,
        activation="gelu", embed_scale=True, zero_centered_norm=True)),
    make_smoke=lambda: TransformerLM(LMConfig(
        name="smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=512, head_dim=32, activation="gelu",
        embed_scale=True, zero_centered_norm=True, loss_chunk=32)),
    shapes=QUADRATIC_SHAPES,
    n_params=9.3e9, n_active_params=9.3e9,
    microbatch=MB,
))


# --- deepseek-67b [dense, 95L] [arXiv:2401.02954] ----------------------------

register(ArchSpec(
    arch_id="deepseek-67b",
    family="dense",
    make_model=lambda: TransformerLM(LMConfig(
        name="deepseek-67b", n_layers=95, d_model=8192, n_heads=64,
        n_kv_heads=8, d_ff=22016, vocab=102400, head_dim=128)),
    make_smoke=lambda: TransformerLM(LMConfig(
        name="smoke", n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=160, vocab=256, head_dim=8, loss_chunk=32)),
    shapes=QUADRATIC_SHAPES,
    n_params=67.4e9, n_active_params=67.4e9,
    microbatch=MB_BIG,
))


# --- granite-8b [dense, code] [arXiv:2405.04324] -----------------------------

register(ArchSpec(
    arch_id="granite-8b",
    family="dense",
    make_model=lambda: TransformerLM(LMConfig(
        name="granite-8b", n_layers=36, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab=49152, head_dim=128)),
    make_smoke=lambda: TransformerLM(LMConfig(
        name="smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=160, vocab=256, head_dim=8, loss_chunk=32)),
    shapes=QUADRATIC_SHAPES,
    n_params=8.3e9, n_active_params=8.3e9,
    microbatch=MB,
))


# --- pixtral-12b [vlm] --------------------------------------------------------

register(ArchSpec(
    arch_id="pixtral-12b",
    family="vlm",
    make_model=lambda: VLM(VLMConfig(lm=LMConfig(
        name="pixtral-12b", n_layers=40, d_model=5120, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab=131072, head_dim=128,
        rope_theta=1e9), n_patches=256, d_vit=1024)),
    make_smoke=lambda: VLM(VLMConfig(lm=LMConfig(
        name="smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16, loss_chunk=32),
        n_patches=8, d_vit=32)),
    shapes=QUADRATIC_SHAPES,
    n_params=12.3e9, n_active_params=12.3e9,
    microbatch=MB_BIG,
    notes="ViT frontend stubbed: input_specs provides [B,256,1024] patch "
          "embeddings; projector + text backbone implemented",
))


# --- whisper-large-v3 [audio, enc-dec] [arXiv:2212.04356] --------------------

register(ArchSpec(
    arch_id="whisper-large-v3",
    family="encdec",
    make_model=lambda: EncDecLM(EncDecConfig(
        name="whisper-large-v3", n_enc_layers=32, n_dec_layers=32,
        d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866,
        n_frames=1500)),
    make_smoke=lambda: EncDecLM(EncDecConfig(
        name="smoke", n_enc_layers=2, n_dec_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, n_frames=16, loss_chunk=32)),
    shapes=QUADRATIC_SHAPES,
    n_params=1.6e9, n_active_params=1.6e9,
    microbatch=MB,
    notes="conv/mel frontend stubbed: input_specs provides [B,1500,1280] "
          "frame embeddings; enc-dec (not encoder-only) so decode runs",
))


# --- zamba2-7b [hybrid] [arXiv:2411.15242] ------------------------------------

register(ArchSpec(
    arch_id="zamba2-7b",
    family="hybrid",
    make_model=lambda: HybridLM(HybridConfig(
        name="zamba2-7b", n_blocks=81, shared_every=6, d_model=3584,
        n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000, d_state=64)),
    make_smoke=lambda: HybridLM(HybridConfig(
        name="smoke", n_blocks=12, shared_every=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, d_state=16, ssm_head_dim=16,
        lora_rank=8, loss_chunk=32, ssd_chunk=16)),
    shapes=ALL_SHAPES,
    n_params=5.9e9, n_active_params=5.9e9,
    microbatch=MB,
    notes="Mamba2 backbone + shared attn block every 6th position with "
          "per-occurrence FFN LoRA; sub-quadratic decode -> runs long_500k",
))


# --- mamba2-1.3b [ssm, SSD] [arXiv:2405.21060] --------------------------------

register(ArchSpec(
    arch_id="mamba2-1.3b",
    family="ssm",
    make_model=lambda: SSMLM(SSMLMConfig(
        name="mamba2-1.3b", n_layers=48, d_model=2048, d_state=128,
        vocab=50280)),
    make_smoke=lambda: SSMLM(SSMLMConfig(
        name="smoke", n_layers=3, d_model=64, d_state=16, vocab=256,
        head_dim=16, loss_chunk=32, ssd_chunk=16)),
    shapes=ALL_SHAPES,
    n_params=1.44e9, n_active_params=1.44e9,
    microbatch=MB,
    notes="attention-free SSD; O(1) decode state -> runs long_500k",
))
