"""Physical column placement: calibration masks -> the serving layout.

The paper's calibration decides *which physical columns* are safe to compute
on (Eq. 1, Table I); this module is the layer that makes serving actually run
on those columns.  ``plan_placement`` takes the fleet's per-column error-prone
masks (``core/ecr.measure_ecr_fleet``) and maps every packed projection's
logical columns onto error-free physical columns across the
``(channel, bank, subarray)`` grid — greedy first-fit bin-packing inside a
subarray with spill into the next one.  The result is a ``Placement`` pytree:
per-tensor column index maps plus a capacity report, persisted alongside the
calibration table by ``runtime/calib_cache.py``.

Layout model (matches the MVDRAM weight layout of kernels/bitplane_gemv.py):
a packed ``[K, N]`` projection occupies one physical column per output column
n — its WB bit-planes live in that column's rows — so a tensor's demand is N
columns per stacked slice.  Physical columns are numbered subarray-major:
``global_col = subarray_index * n_cols + col``.

**Block-aligned windows** (the format the placed kernels block over): a
tensor's N logical columns split into blocks of ``block_cols`` (the largest
divisor of N <= ``PLACE_BLOCK``, mirroring the kernel's N-tile choice).
Each block's columns are consecutive usable physical columns; the physical
span they cover — including the faulty columns interleaved between them —
becomes one *window block*, and every window block pads to the common
per-tensor stride ``window_block`` (= the max span).  The materialized
window is the concatenation of these blocks, so logical block j's columns
all live inside window slice ``[j*window_block, (j+1)*window_block)`` and
the placed kernel streams exactly one window block per N-tile instead of
holding the whole physical region in VMEM.  ``local_cols`` are absolute
window positions (block base + in-block offset), which is what the packer
scatters to and what ``col_ids`` store; faulty columns inside a block's
span are materialized (holding zero planes, marked in ``faulty``) and
never addressed, while pad positions beyond a span back no physical column
at all.

Fault model (``inject_read_faults``): an error-prone column is one whose
sense-amp threshold offset exceeds the SiMRA margin (pud/physics), so its
reads saturate to a *stuck* value regardless of the stored charge —
``offset < 0`` lowers the threshold and reads 1, otherwise 0.  Injecting
this corruption into the physical planes breaks serving numerics exactly
when a logical column was placed on a faulty physical column; a placement
built with ``avoid_faulty=True`` is immune by construction.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import zipfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import N_BLOCK, largest_divisor

PLACEMENT_FORMAT = "pud-placement-v2"
_PLACEMENT_FORMAT_V1 = "pud-placement-v1"

# Logical columns per window block: the kernels' N tile, so one window
# block feeds exactly one (full-size) output tile by construction.
PLACE_BLOCK = N_BLOCK


class PlacementError(RuntimeError):
    """Raised when the error-free capacity cannot hold the requested layout."""


@dataclasses.dataclass(frozen=True)
class PlacementRequest:
    """Column demand of one packable projection.

    ``block_cols`` forces the window-block width instead of the default
    ``largest_divisor(n_cols, PLACE_BLOCK)`` rule.  Sharded packing uses
    this: every model shard plans its column slice with the *full* tensor's
    block width so the per-shard window geometry stays uniform across the
    mesh (see ``shard_column_slices``).
    """

    name: str                 # tensor path, e.g. "layers_0_dense/mixer/wi"
    n_cols: int               # logical (output) columns per slice
    n_slices: int = 0         # leading stacked-layer count; 0 = unstacked
    block_cols: int = 0       # forced window-block width; 0 = derive

    @property
    def total_cols(self) -> int:
        return self.n_cols * max(1, self.n_slices)


def requests_fingerprint(requests: list[PlacementRequest]) -> str:
    """Stable short hash of a request list (keys persisted placements)."""
    blob = json.dumps([
        (r.name, r.n_cols, r.n_slices) if not r.block_cols
        else (r.name, r.n_cols, r.n_slices, r.block_cols)
        for r in requests])
    return hashlib.sha256(blob.encode()).hexdigest()[:10]


def shard_column_slices(n_cols: int,
                        n_shards: int) -> tuple[tuple[tuple[int, int], ...],
                                                int]:
    """Split a tensor's N columns across model shards on block boundaries.

    Returns ``(((lo, hi), ...), block_cols)``: one half-open column span per
    shard plus the block width the split respects — the *full* tensor's
    ``largest_divisor(n_cols, PLACE_BLOCK)``, the same width the unsharded
    allocator would pick.  Every shard owns a whole number of window blocks
    (earlier shards take the remainder blocks), so no placement window ever
    straddles a shard; when there are fewer blocks than shards the trailing
    shards own zero columns and serve pure padding.
    """
    if n_cols <= 0 or n_shards <= 0:
        raise PlacementError(
            f"shard_column_slices needs positive n_cols/n_shards, got "
            f"{n_cols}/{n_shards}")
    block_cols = largest_divisor(n_cols, PLACE_BLOCK)
    n_blocks = n_cols // block_cols
    base, extra = divmod(n_blocks, n_shards)
    spans, lo = [], 0
    for i in range(n_shards):
        hi = lo + (base + (1 if i < extra else 0)) * block_cols
        spans.append((lo, hi))
        lo = hi
    return tuple(spans), block_cols


@dataclasses.dataclass
class TensorPlacement:
    """Column index maps of one placed tensor (block-aligned windows).

    Shapes: unstacked tensors use ``[N]`` maps; stacked use ``[L, N]`` with
    per-slice windows (all slices share ``block_cols``/``window_block`` so
    stacked planes keep a uniform shape for ``lax.scan``).  ``phys_cols``
    are global physical column ids; ``block_starts`` give the physical
    column each window block originates at; ``faulty``/``stuck`` describe
    the error-prone columns inside the materialized window (length
    ``region_size = n_blocks * window_block``) for the fault-injection
    model.
    """

    phys_cols: np.ndarray      # [L?, N] int32 global physical column ids
    block_cols: int            # logical columns per block (B)
    window_block: int          # window stride per block (P_blk >= max span)
    block_starts: np.ndarray   # [L?, NB] int32 physical origin per block
    faulty: np.ndarray         # [L?, W] bool — error-prone cols in window
    stuck: np.ndarray          # [L?, W] int8 — read value of faulty cols

    @property
    def n_blocks(self) -> int:
        return self.block_starts.shape[-1]

    @property
    def region_size(self) -> int:
        """Materialized window length W = n_blocks * window_block."""
        return self.n_blocks * self.window_block

    @property
    def local_cols(self) -> np.ndarray:
        """[L?, N] absolute window positions (what ``col_ids`` store):
        block base + offset of the physical column inside its block span."""
        n = self.phys_cols.shape[-1]
        blk = np.arange(n) // self.block_cols                  # [N]
        base = (blk * self.window_block).astype(np.int64)      # [N]
        if self.phys_cols.ndim == 1:
            starts = self.block_starts[blk]
        else:
            starts = self.block_starts[:, blk]
        return (base + self.phys_cols - starts).astype(np.int32)


@dataclasses.dataclass
class Placement:
    """Device-wide placement: per-tensor maps + capacity accounting."""

    entries: dict[str, TensorPlacement]
    grid_shape: tuple[int, int, int]
    n_cols_per_subarray: int
    used_per_subarray: np.ndarray      # [G] int32 columns holding weights
    usable_per_subarray: np.ndarray    # [G] int32 allocatable columns
    avoid_faulty: bool

    @property
    def n_subarrays(self) -> int:
        return int(np.prod(self.grid_shape))

    @property
    def used_total(self) -> int:
        return int(self.used_per_subarray.sum())

    @property
    def usable_total(self) -> int:
        return int(self.usable_per_subarray.sum())

    @property
    def occupancy(self) -> float:
        """Fraction of allocatable (error-free) columns holding weights."""
        return self.used_total / max(1, self.usable_total)

    @property
    def spilled_tensors(self) -> list[str]:
        """Tensors whose slices cross a subarray boundary."""
        n = self.n_cols_per_subarray
        out = []
        for name, tp in self.entries.items():
            cols = tp.phys_cols
            if (cols // n).min() != (cols // n).max():
                out.append(name)
        return out

    def capacity_report(self) -> dict:
        used = self.used_per_subarray
        return {
            "n_subarrays": self.n_subarrays,
            "n_cols_per_subarray": self.n_cols_per_subarray,
            "usable_cols": self.usable_total,
            "used_cols": self.used_total,
            "occupancy": self.occupancy,
            "occupied_subarrays": int((used > 0).sum()),
            "spilled_tensors": self.spilled_tensors,
            "avoid_faulty": self.avoid_faulty,
        }


def _register(cls, array_fields, aux_fields):
    def flatten(obj):
        children = tuple(getattr(obj, f) for f in array_fields)
        aux = tuple(getattr(obj, f) for f in aux_fields)
        return children, aux

    def unflatten(aux, children):
        return cls(**dict(zip(array_fields, children)),
                   **dict(zip(aux_fields, aux)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)


_register(TensorPlacement,
          ("phys_cols", "block_starts", "faulty", "stuck"),
          ("block_cols", "window_block"))
_register(Placement,
          ("entries", "used_per_subarray", "usable_per_subarray"),
          ("grid_shape", "n_cols_per_subarray", "avoid_faulty"))


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------


def _stuck_values(global_cols: np.ndarray,
                  sense_offsets: np.ndarray | None) -> np.ndarray:
    """Stuck read value of faulty columns (pud/physics sense convention).

    With the per-column sense offsets available, a negative offset lowers
    the threshold so every read saturates to 1; positive saturates to 0.
    From a warm cache only the masks persist — fall back to a deterministic
    per-column value so injection stays reproducible.
    """
    if sense_offsets is not None:
        flat = np.asarray(sense_offsets).reshape(-1)
        return (flat[global_cols] < 0).astype(np.int8)
    return (global_cols % 2).astype(np.int8)


def _slice_blocks(cols: np.ndarray, block_cols: int):
    """Split one slice's columns into blocks; returns (starts, spans)."""
    nb = cols.size // block_cols
    chunks = cols.reshape(nb, block_cols)
    starts = chunks[:, 0].astype(np.int64)
    spans = (chunks[:, -1] - chunks[:, 0] + 1).astype(np.int64)
    return starts, spans


def _window_masks(starts: np.ndarray, spans: np.ndarray, window_block: int,
                  flat_faulty: np.ndarray,
                  sense_offsets) -> tuple[np.ndarray, np.ndarray]:
    """Faulty/stuck masks of one slice's materialized window.

    Window position j*window_block + t backs physical column
    ``starts[j] + t`` when t < spans[j]; positions past a block's span are
    pure padding (no physical column: never faulty, zero stuck value).
    """
    nb = starts.size
    n_total = flat_faulty.size
    faulty = np.zeros(nb * window_block, bool)
    stuck = np.zeros(nb * window_block, np.int8)
    for j in range(nb):
        t = np.arange(min(int(spans[j]), window_block), dtype=np.int64)
        phys = starts[j] + t
        t = t[phys < n_total]
        phys = phys[phys < n_total]
        faulty[j * window_block + t] = flat_faulty[phys]
        stuck[j * window_block + t] = _stuck_values(phys, sense_offsets)
    return faulty, stuck


def plan_placement(
    masks,                              # [G, n_cols] bool, True = error-prone
    requests: list[PlacementRequest],
    *,
    avoid_faulty: bool = True,
    sense_offsets=None,                 # [G, n_cols] float, optional
) -> Placement:
    """Greedy first-fit allocation of every request onto the column grid.

    Requests are placed in order; each slice draws consecutive usable
    columns from the current subarray and spills into the next when the
    subarray is exhausted.  ``avoid_faulty=False`` builds the *identity*
    layout (logical columns land on physical columns in raw order, faulty
    or not) — the comparison baseline for fault injection.

    Raises ``PlacementError`` when total demand exceeds usable capacity.
    """
    masks = np.asarray(masks, bool)
    g, n_cols = masks.shape
    flat_faulty = masks.reshape(-1)
    if avoid_faulty:
        usable_ids = np.nonzero(~flat_faulty)[0].astype(np.int64)
    else:
        usable_ids = np.arange(g * n_cols, dtype=np.int64)

    demand = sum(r.total_cols for r in requests)
    if demand > usable_ids.size:
        raise PlacementError(
            f"placement demand {demand} columns exceeds usable capacity "
            f"{usable_ids.size} ({g} subarrays x {n_cols} cols, "
            f"avoid_faulty={avoid_faulty})")

    entries: dict[str, TensorPlacement] = {}
    cursor = 0
    for req in requests:
        n_slices = max(1, req.n_slices)
        block_cols = req.block_cols or largest_divisor(req.n_cols,
                                                       PLACE_BLOCK)
        if block_cols > PLACE_BLOCK or req.n_cols % block_cols:
            raise PlacementError(
                f"request {req.name!r}: forced block_cols {block_cols} "
                f"must divide n_cols {req.n_cols} and stay within "
                f"PLACE_BLOCK {PLACE_BLOCK}")
        slice_cols, slice_starts, slice_spans = [], [], []
        for _ in range(n_slices):
            cols = usable_ids[cursor:cursor + req.n_cols]
            cursor += req.n_cols
            starts, spans = _slice_blocks(cols, block_cols)
            slice_cols.append(cols.astype(np.int32))
            slice_starts.append(starts)
            slice_spans.append(spans)
        window_block = int(max(s.max() for s in slice_spans))

        faulty, stuck = [], []
        for starts, spans in zip(slice_starts, slice_spans):
            f, s = _window_masks(starts, spans, window_block, flat_faulty,
                                 sense_offsets)
            faulty.append(f)
            stuck.append(s)

        if req.n_slices:
            tp = TensorPlacement(
                phys_cols=np.stack(slice_cols),
                block_cols=block_cols, window_block=window_block,
                block_starts=np.stack(slice_starts).astype(np.int32),
                faulty=np.stack(faulty), stuck=np.stack(stuck))
        else:
            tp = TensorPlacement(
                phys_cols=slice_cols[0],
                block_cols=block_cols, window_block=window_block,
                block_starts=slice_starts[0].astype(np.int32),
                faulty=faulty[0], stuck=stuck[0])
        entries[req.name] = tp

    used = np.zeros(g * n_cols, bool)
    used[usable_ids[:cursor]] = True
    usable_per = (~masks).sum(axis=1) if avoid_faulty \
        else np.full(g, n_cols)
    return Placement(
        entries=entries,
        grid_shape=(1, 1, g),
        n_cols_per_subarray=n_cols,
        used_per_subarray=used.reshape(g, n_cols).sum(axis=1)
                              .astype(np.int32),
        usable_per_subarray=np.asarray(usable_per, np.int32),
        avoid_faulty=avoid_faulty,
    )


def plan_for_grid(masks, requests, grid_shape, **kw) -> Placement:
    """``plan_placement`` with the true (channels, banks, subarrays) shape."""
    p = plan_placement(masks, requests, **kw)
    return dataclasses.replace(p, grid_shape=tuple(grid_shape))


def refresh_fault_state(placement: Placement, masks,
                        sense_offsets=None) -> Placement:
    """Recompute every entry's faulty/stuck window masks from new masks.

    Drift changes *which* columns are error-prone, not where tensors live:
    the column maps were planned at calibration time and the packs built
    from them.  This re-reads each materialized window's fault state out of
    fresh (drifted) per-column masks, which is exactly what
    ``inject_read_faults`` needs to model serving from the aged device — a
    column that went bad after planning now corrupts the window position it
    backs.  Capacity accounting keeps its calibration-time values;
    re-planning against the new masks is the recovery path's job, not this
    view's.
    """
    masks = np.asarray(masks, bool)
    flat_faulty = masks.reshape(-1)
    entries: dict[str, TensorPlacement] = {}
    for name, tp in placement.entries.items():
        stacked = tp.phys_cols.ndim == 2
        slices = tp.phys_cols if stacked else tp.phys_cols[None]
        faulty, stuck = [], []
        for cols in slices:
            starts, spans = _slice_blocks(
                np.asarray(cols, np.int64), tp.block_cols)
            f, s = _window_masks(starts, spans, tp.window_block,
                                 flat_faulty, sense_offsets)
            faulty.append(f)
            stuck.append(s)
        entries[name] = dataclasses.replace(
            tp,
            faulty=np.stack(faulty) if stacked else faulty[0],
            stuck=np.stack(stuck) if stacked else stuck[0])
    return dataclasses.replace(placement, entries=entries)


# ---------------------------------------------------------------------------
# Fault injection (pud/physics stuck-read model)
# ---------------------------------------------------------------------------


def corrupt_planes(planes: jax.Array, tp: TensorPlacement) -> jax.Array:
    """Replace every bit stored on an error-prone column with its stuck read.

    planes: [WB, K(/8), W] (or [L, WB, K(/8), W]); the trailing axis is the
    materialized window of ``tp``.  Column-wide corruption — every bit-plane
    and row of a faulty column reads the same stuck value.  Works on both
    plane layouts: in the bit-packed one a stuck-1 column reads 0xFF words
    (all eight K rows of every plane bit saturate high), stuck-0 reads 0x00.
    """
    faulty = jnp.asarray(tp.faulty)[..., None, None, :]
    stuck = jnp.asarray(tp.stuck)[..., None, None, :]
    if planes.dtype == jnp.uint8:      # bit-packed words: saturate the byte
        stuck = stuck.astype(jnp.uint8) * jnp.uint8(0xFF)
    else:
        stuck = stuck.astype(planes.dtype)
    return jnp.where(faulty, stuck, planes)


def inject_read_faults(packed_params: dict, placement: Placement) -> dict:
    """Simulate serving from the real (faulty) device.

    Walks a ``pack_for_serving`` output tree and corrupts the physical
    planes of every placed pack per ``corrupt_planes``.  With
    ``avoid_faulty=True`` placements the gather indices never touch a
    corrupted column, so serving numerics are bit-identical; identity
    placements put logical columns on faulty physical columns and break.
    """

    from .packed import as_packed_tensor, is_pack

    def walk(tree, path):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for key, sub in tree.items():
            if key.endswith("_pud") and is_pack(sub) and "col_ids" in sub:
                name = "/".join(path + (key[: -len("_pud")],))
                tp = placement.entries.get(name)
                if tp is None:
                    raise KeyError(
                        f"packed tensor {name!r} has no placement entry "
                        f"(have: {sorted(placement.entries)})")
                pt = as_packed_tensor(sub)
                out[key] = pt.replace(planes=corrupt_planes(pt.planes, tp))
            elif isinstance(sub, dict):
                out[key] = walk(sub, path + (key,))
            else:
                out[key] = sub
        return out

    return walk(packed_params, ())


# ---------------------------------------------------------------------------
# Serialization (used by runtime/calib_cache.py)
# ---------------------------------------------------------------------------


def save_placement_npz(path, placement: Placement) -> None:
    """Write a Placement to ``path`` as a single .npz (no pickle)."""
    meta = {
        "format": PLACEMENT_FORMAT,
        "names": list(placement.entries),
        "block_cols": [placement.entries[n].block_cols
                       for n in placement.entries],
        "window_blocks": [placement.entries[n].window_block
                          for n in placement.entries],
        "grid_shape": list(placement.grid_shape),
        "n_cols_per_subarray": placement.n_cols_per_subarray,
        "avoid_faulty": placement.avoid_faulty,
    }
    arrays = {
        "meta": np.array(json.dumps(meta)),
        "used": np.asarray(placement.used_per_subarray, np.int32),
        "usable": np.asarray(placement.usable_per_subarray, np.int32),
    }
    for i, name in enumerate(placement.entries):
        tp = placement.entries[name]
        arrays[f"e{i}_phys"] = np.asarray(tp.phys_cols, np.int32)
        arrays[f"e{i}_start"] = np.asarray(tp.block_starts, np.int32)
        arrays[f"e{i}_faulty"] = np.asarray(tp.faulty, bool)
        arrays[f"e{i}_stuck"] = np.asarray(tp.stuck, np.int8)
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def _upgrade_v1_entry(phys: np.ndarray, region_start: np.ndarray,
                      region_size: int, faulty_v1: np.ndarray,
                      stuck_v1: np.ndarray) -> TensorPlacement:
    """Rebuild the block-aligned window from a PR-2/PR-3 era (v1) entry.

    A v1 entry materialized one physical span per slice: window position p
    backed physical column ``region_start + p``.  The block structure is
    fully derivable — block origins come from ``phys_cols`` (the same
    ``PLACE_BLOCK`` divisor rule the allocator uses), and each window
    block's faulty/stuck values are re-read out of the v1 span at offset
    ``block_start - region_start``.
    """
    n = phys.shape[-1]
    block_cols = largest_divisor(n, PLACE_BLOCK)
    stacked = phys.ndim == 2
    slices = phys if stacked else phys[None]
    r_starts = (np.asarray(region_start).reshape(-1) if stacked
                else np.asarray([region_start]))
    f_v1 = faulty_v1 if stacked else faulty_v1[None]
    s_v1 = stuck_v1 if stacked else stuck_v1[None]

    all_starts, all_spans = [], []
    for cols in slices:
        starts, spans = _slice_blocks(cols.astype(np.int64), block_cols)
        all_starts.append(starts)
        all_spans.append(spans)
    window_block = int(max(s.max() for s in all_spans))

    faulty, stuck = [], []
    for starts, spans, r0, f1, s1 in zip(all_starts, all_spans, r_starts,
                                         f_v1, s_v1):
        nb = starts.size
        f = np.zeros(nb * window_block, bool)
        s = np.zeros(nb * window_block, np.int8)
        for j in range(nb):
            t = np.arange(min(int(spans[j]), window_block), dtype=np.int64)
            src = starts[j] - int(r0) + t
            t = t[(src >= 0) & (src < region_size)]
            src = src[(src >= 0) & (src < region_size)]
            f[j * window_block + t] = f1[src]
            s[j * window_block + t] = s1[src]
        faulty.append(f)
        stuck.append(s)

    return TensorPlacement(
        phys_cols=phys,
        block_cols=block_cols, window_block=window_block,
        block_starts=(np.stack(all_starts).astype(np.int32) if stacked
                      else all_starts[0].astype(np.int32)),
        faulty=(np.stack(faulty) if stacked else faulty[0]),
        stuck=(np.stack(stuck) if stacked else stuck[0]))


def load_placement_npz(path) -> Placement | None:
    """Read a Placement back; None on any corruption or format mismatch.

    v1 archives (PR-2/PR-3 artifacts: one physical span per slice, no block
    structure) load through ``_upgrade_v1_entry`` — old caches keep their
    placements instead of re-planning.
    """
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            fmt = meta.get("format")
            if fmt not in (PLACEMENT_FORMAT, _PLACEMENT_FORMAT_V1):
                return None
            entries = {}
            for i, name in enumerate(meta["names"]):
                if fmt == _PLACEMENT_FORMAT_V1:
                    entries[name] = _upgrade_v1_entry(
                        z[f"e{i}_phys"], z[f"e{i}_start"],
                        int(meta["region_sizes"][i]),
                        z[f"e{i}_faulty"], z[f"e{i}_stuck"])
                else:
                    entries[name] = TensorPlacement(
                        phys_cols=z[f"e{i}_phys"],
                        block_cols=int(meta["block_cols"][i]),
                        window_block=int(meta["window_blocks"][i]),
                        block_starts=z[f"e{i}_start"],
                        faulty=z[f"e{i}_faulty"],
                        stuck=z[f"e{i}_stuck"])
            return Placement(
                entries=entries,
                grid_shape=tuple(meta["grid_shape"]),
                n_cols_per_subarray=int(meta["n_cols_per_subarray"]),
                used_per_subarray=z["used"],
                usable_per_subarray=z["usable"],
                avoid_faulty=bool(meta["avoid_faulty"]))
    except (OSError, ValueError, KeyError, EOFError, json.JSONDecodeError,
            zipfile.BadZipFile):
        return None
