"""Physical column placement: calibration masks -> the serving layout.

The paper's calibration decides *which physical columns* are safe to compute
on (Eq. 1, Table I); this module is the layer that makes serving actually run
on those columns.  ``plan_placement`` takes the fleet's per-column error-prone
masks (``core/ecr.measure_ecr_fleet``) and maps every packed projection's
logical columns onto error-free physical columns across the
``(channel, bank, subarray)`` grid — greedy first-fit bin-packing inside a
subarray with spill into the next one.  The result is a ``Placement`` pytree:
per-tensor column index maps plus a capacity report, persisted alongside the
calibration table by ``runtime/calib_cache.py``.

Layout model (matches the MVDRAM weight layout of kernels/bitplane_gemv.py):
a packed ``[K, N]`` projection occupies one physical column per output column
n — its WB bit-planes live in that column's rows — so a tensor's demand is N
columns per stacked slice.  Physical columns are numbered subarray-major:
``global_col = subarray_index * n_cols + col``.

Fault model (``inject_read_faults``): an error-prone column is one whose
sense-amp threshold offset exceeds the SiMRA margin (pud/physics), so its
reads saturate to a *stuck* value regardless of the stored charge —
``offset < 0`` lowers the threshold and reads 1, otherwise 0.  Injecting
this corruption into the physical planes breaks serving numerics exactly
when a logical column was placed on a faulty physical column; a placement
built with ``avoid_faulty=True`` is immune by construction.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import zipfile

import jax
import jax.numpy as jnp
import numpy as np

PLACEMENT_FORMAT = "pud-placement-v1"


class PlacementError(RuntimeError):
    """Raised when the error-free capacity cannot hold the requested layout."""


@dataclasses.dataclass(frozen=True)
class PlacementRequest:
    """Column demand of one packable projection."""

    name: str                 # tensor path, e.g. "layers_0_dense/mixer/wi"
    n_cols: int               # logical (output) columns per slice
    n_slices: int = 0         # leading stacked-layer count; 0 = unstacked

    @property
    def total_cols(self) -> int:
        return self.n_cols * max(1, self.n_slices)


def requests_fingerprint(requests: list[PlacementRequest]) -> str:
    """Stable short hash of a request list (keys persisted placements)."""
    blob = json.dumps([(r.name, r.n_cols, r.n_slices) for r in requests])
    return hashlib.sha256(blob.encode()).hexdigest()[:10]


@dataclasses.dataclass
class TensorPlacement:
    """Column index maps of one placed tensor.

    Shapes: unstacked tensors use ``[N]`` maps; stacked use ``[L, N]`` with a
    per-slice region.  ``phys_cols`` are global physical column ids;
    ``region_start``/``region_size`` define the physical window the packer
    materializes per slice (all slices padded to one common ``region_size``
    so stacked planes keep a uniform shape for ``lax.scan``);
    ``faulty``/``stuck`` describe the error-prone columns inside each window
    for the fault-injection model.
    """

    phys_cols: np.ndarray      # [L?, N] int32 global physical column ids
    region_start: np.ndarray   # [L?] int32 window start per slice
    region_size: int           # common padded window span P
    faulty: np.ndarray         # [L?, P] bool — error-prone cols in window
    stuck: np.ndarray          # [L?, P] int8 — read value of faulty cols

    @property
    def local_cols(self) -> np.ndarray:
        """[L?, N] column ids relative to the slice window (kernel gather)."""
        if self.phys_cols.ndim == 1:
            return (self.phys_cols - self.region_start).astype(np.int32)
        return (self.phys_cols
                - self.region_start[:, None]).astype(np.int32)


@dataclasses.dataclass
class Placement:
    """Device-wide placement: per-tensor maps + capacity accounting."""

    entries: dict[str, TensorPlacement]
    grid_shape: tuple[int, int, int]
    n_cols_per_subarray: int
    used_per_subarray: np.ndarray      # [G] int32 columns holding weights
    usable_per_subarray: np.ndarray    # [G] int32 allocatable columns
    avoid_faulty: bool

    @property
    def n_subarrays(self) -> int:
        return int(np.prod(self.grid_shape))

    @property
    def used_total(self) -> int:
        return int(self.used_per_subarray.sum())

    @property
    def usable_total(self) -> int:
        return int(self.usable_per_subarray.sum())

    @property
    def occupancy(self) -> float:
        """Fraction of allocatable (error-free) columns holding weights."""
        return self.used_total / max(1, self.usable_total)

    @property
    def spilled_tensors(self) -> list[str]:
        """Tensors whose slices cross a subarray boundary."""
        n = self.n_cols_per_subarray
        out = []
        for name, tp in self.entries.items():
            cols = tp.phys_cols
            if (cols // n).min() != (cols // n).max():
                out.append(name)
        return out

    def capacity_report(self) -> dict:
        used = self.used_per_subarray
        return {
            "n_subarrays": self.n_subarrays,
            "n_cols_per_subarray": self.n_cols_per_subarray,
            "usable_cols": self.usable_total,
            "used_cols": self.used_total,
            "occupancy": self.occupancy,
            "occupied_subarrays": int((used > 0).sum()),
            "spilled_tensors": self.spilled_tensors,
            "avoid_faulty": self.avoid_faulty,
        }


def _register(cls, array_fields, aux_fields):
    def flatten(obj):
        children = tuple(getattr(obj, f) for f in array_fields)
        aux = tuple(getattr(obj, f) for f in aux_fields)
        return children, aux

    def unflatten(aux, children):
        return cls(**dict(zip(array_fields, children)),
                   **dict(zip(aux_fields, aux)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)


_register(TensorPlacement,
          ("phys_cols", "region_start", "faulty", "stuck"), ("region_size",))
_register(Placement,
          ("entries", "used_per_subarray", "usable_per_subarray"),
          ("grid_shape", "n_cols_per_subarray", "avoid_faulty"))


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------


def _stuck_values(global_cols: np.ndarray,
                  sense_offsets: np.ndarray | None) -> np.ndarray:
    """Stuck read value of faulty columns (pud/physics sense convention).

    With the per-column sense offsets available, a negative offset lowers
    the threshold so every read saturates to 1; positive saturates to 0.
    From a warm cache only the masks persist — fall back to a deterministic
    per-column value so injection stays reproducible.
    """
    if sense_offsets is not None:
        flat = np.asarray(sense_offsets).reshape(-1)
        return (flat[global_cols] < 0).astype(np.int8)
    return (global_cols % 2).astype(np.int8)


def plan_placement(
    masks,                              # [G, n_cols] bool, True = error-prone
    requests: list[PlacementRequest],
    *,
    avoid_faulty: bool = True,
    sense_offsets=None,                 # [G, n_cols] float, optional
) -> Placement:
    """Greedy first-fit allocation of every request onto the column grid.

    Requests are placed in order; each slice draws consecutive usable
    columns from the current subarray and spills into the next when the
    subarray is exhausted.  ``avoid_faulty=False`` builds the *identity*
    layout (logical columns land on physical columns in raw order, faulty
    or not) — the comparison baseline for fault injection.

    Raises ``PlacementError`` when total demand exceeds usable capacity.
    """
    masks = np.asarray(masks, bool)
    g, n_cols = masks.shape
    flat_faulty = masks.reshape(-1)
    if avoid_faulty:
        usable_ids = np.nonzero(~flat_faulty)[0].astype(np.int64)
    else:
        usable_ids = np.arange(g * n_cols, dtype=np.int64)

    demand = sum(r.total_cols for r in requests)
    if demand > usable_ids.size:
        raise PlacementError(
            f"placement demand {demand} columns exceeds usable capacity "
            f"{usable_ids.size} ({g} subarrays x {n_cols} cols, "
            f"avoid_faulty={avoid_faulty})")

    entries: dict[str, TensorPlacement] = {}
    cursor = 0
    for req in requests:
        n_slices = max(1, req.n_slices)
        slice_cols, starts, spans = [], [], []
        for _ in range(n_slices):
            cols = usable_ids[cursor:cursor + req.n_cols]
            cursor += req.n_cols
            slice_cols.append(cols.astype(np.int32))
            starts.append(int(cols[0]))
            spans.append(int(cols[-1]) - int(cols[0]) + 1)
        region = max(spans)

        faulty, stuck = [], []
        for cols, start in zip(slice_cols, starts):
            window = np.arange(start, start + region, dtype=np.int64)
            in_dev = window < g * n_cols
            f = np.zeros(region, bool)
            f[in_dev] = flat_faulty[window[in_dev]]
            s = np.zeros(region, np.int8)
            s[in_dev] = _stuck_values(window[in_dev], sense_offsets)
            faulty.append(f)
            stuck.append(s)

        if req.n_slices:
            tp = TensorPlacement(
                phys_cols=np.stack(slice_cols),
                region_start=np.asarray(starts, np.int32),
                region_size=region,
                faulty=np.stack(faulty), stuck=np.stack(stuck))
        else:
            tp = TensorPlacement(
                phys_cols=slice_cols[0],
                region_start=np.int32(starts[0]),
                region_size=region,
                faulty=faulty[0], stuck=stuck[0])
        entries[req.name] = tp

    used = np.zeros(g * n_cols, bool)
    used[usable_ids[:cursor]] = True
    usable_per = (~masks).sum(axis=1) if avoid_faulty \
        else np.full(g, n_cols)
    return Placement(
        entries=entries,
        grid_shape=(1, 1, g),
        n_cols_per_subarray=n_cols,
        used_per_subarray=used.reshape(g, n_cols).sum(axis=1)
                              .astype(np.int32),
        usable_per_subarray=np.asarray(usable_per, np.int32),
        avoid_faulty=avoid_faulty,
    )


def plan_for_grid(masks, requests, grid_shape, **kw) -> Placement:
    """``plan_placement`` with the true (channels, banks, subarrays) shape."""
    p = plan_placement(masks, requests, **kw)
    return dataclasses.replace(p, grid_shape=tuple(grid_shape))


# ---------------------------------------------------------------------------
# Fault injection (pud/physics stuck-read model)
# ---------------------------------------------------------------------------


def corrupt_planes(planes: jax.Array, tp: TensorPlacement) -> jax.Array:
    """Replace every bit stored on an error-prone column with its stuck read.

    planes: [WB, K, P] (or [L, WB, K, P]); the trailing axis is the physical
    window of ``tp``.  Column-wide corruption — every bit-plane and row of a
    faulty column reads the same stuck value.
    """
    faulty = jnp.asarray(tp.faulty)[..., None, None, :]
    stuck = jnp.asarray(tp.stuck)[..., None, None, :].astype(planes.dtype)
    return jnp.where(faulty, stuck, planes)


def inject_read_faults(packed_params: dict, placement: Placement) -> dict:
    """Simulate serving from the real (faulty) device.

    Walks a ``pack_for_serving`` output tree and corrupts the physical
    planes of every placed pack per ``corrupt_planes``.  With
    ``avoid_faulty=True`` placements the gather indices never touch a
    corrupted column, so serving numerics are bit-identical; identity
    placements put logical columns on faulty physical columns and break.
    """

    from .packed import as_packed_tensor, is_pack

    def walk(tree, path):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for key, sub in tree.items():
            if key.endswith("_pud") and is_pack(sub) and "col_ids" in sub:
                name = "/".join(path + (key[: -len("_pud")],))
                tp = placement.entries.get(name)
                if tp is None:
                    raise KeyError(
                        f"packed tensor {name!r} has no placement entry "
                        f"(have: {sorted(placement.entries)})")
                pt = as_packed_tensor(sub)
                out[key] = pt.replace(planes=corrupt_planes(pt.planes, tp))
            elif isinstance(sub, dict):
                out[key] = walk(sub, path + (key,))
            else:
                out[key] = sub
        return out

    return walk(packed_params, ())


# ---------------------------------------------------------------------------
# Serialization (used by runtime/calib_cache.py)
# ---------------------------------------------------------------------------


def save_placement_npz(path, placement: Placement) -> None:
    """Write a Placement to ``path`` as a single .npz (no pickle)."""
    meta = {
        "format": PLACEMENT_FORMAT,
        "names": list(placement.entries),
        "region_sizes": [placement.entries[n].region_size
                         for n in placement.entries],
        "grid_shape": list(placement.grid_shape),
        "n_cols_per_subarray": placement.n_cols_per_subarray,
        "avoid_faulty": placement.avoid_faulty,
    }
    arrays = {
        "meta": np.array(json.dumps(meta)),
        "used": np.asarray(placement.used_per_subarray, np.int32),
        "usable": np.asarray(placement.usable_per_subarray, np.int32),
    }
    for i, name in enumerate(placement.entries):
        tp = placement.entries[name]
        arrays[f"e{i}_phys"] = np.asarray(tp.phys_cols, np.int32)
        arrays[f"e{i}_start"] = np.asarray(tp.region_start, np.int32)
        arrays[f"e{i}_faulty"] = np.asarray(tp.faulty, bool)
        arrays[f"e{i}_stuck"] = np.asarray(tp.stuck, np.int8)
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def load_placement_npz(path) -> Placement | None:
    """Read a Placement back; None on any corruption or format mismatch."""
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            if meta.get("format") != PLACEMENT_FORMAT:
                return None
            entries = {}
            for i, name in enumerate(meta["names"]):
                entries[name] = TensorPlacement(
                    phys_cols=z[f"e{i}_phys"],
                    region_start=z[f"e{i}_start"],
                    region_size=int(meta["region_sizes"][i]),
                    faulty=z[f"e{i}_faulty"],
                    stuck=z[f"e{i}_stuck"])
            return Placement(
                entries=entries,
                grid_shape=tuple(meta["grid_shape"]),
                n_cols_per_subarray=int(meta["n_cols_per_subarray"]),
                used_per_subarray=z["used"],
                usable_per_subarray=z["usable"],
                avoid_faulty=bool(meta["avoid_faulty"]))
    except (OSError, ValueError, KeyError, EOFError, json.JSONDecodeError,
            zipfile.BadZipFile):
        return None
