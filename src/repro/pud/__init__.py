"""PUD device plane: DRAM physics, command simulator, timing, bit-serial ops."""
from .physics import NEUTRAL, PhysicsParams, sense  # noqa: F401
from .device import (SubarrayState, frac, maj_outputs, make_subarray,  # noqa: F401
                     read_row, rowcopy, set_params, simra, write_row)
from .timing import (DDR4Timing, OpCounts, SystemConfig,  # noqa: F401
                     throughput_ops, wave_latency_ns)
