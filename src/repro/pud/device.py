"""Command-level DRAM subarray simulator for PUD.

State is a pytree (``SubarrayState``) so every command is a pure JAX function;
the simulator composes under jit/vmap/scan.  Rows are the leading axis,
columns the trailing (column-parallel, like the real device).

Commands implemented (Sec. II-B of the paper):
  * ``write_row``   — host write (reliable, full charge).
  * ``rowcopy``     — ACT -> PRE -> ACT intra-subarray copy (reliable; see
                      physics.py for why single-row sensing is modeled exact).
  * ``frac``        — violated-timing partial restore: charge moves a factor
                      ``frac_alpha`` toward neutral.
  * ``simra``       — simultaneous many-row activation: charge sharing across
                      the opened rows, per-column sense with offset + noise,
                      result restored into *all* opened rows (paper Fig. 1 step 4).

The fast path used by calibration / ECR measurement (``maj_outputs``) computes
the same arithmetic without materializing row state per trial.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .physics import NEUTRAL, PhysicsParams, sense


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SubarrayState:
    """Charge state of one subarray plus its (static) sense-amp offsets."""

    charge: jax.Array         # [n_rows, n_cols] float32, V_DD units in [0, 1]
    sense_offset: jax.Array   # [n_cols] float32, threshold deviation from 0.5

    @property
    def n_rows(self) -> int:
        return self.charge.shape[0]

    @property
    def n_cols(self) -> int:
        return self.charge.shape[1]


def make_subarray(
    key: jax.Array,
    n_rows: int,
    n_cols: int,
    params: PhysicsParams,
) -> SubarrayState:
    """Manufacture a subarray: cells neutral, offsets ~ N(0, sigma_static)."""
    offs = params.sigma_static * jax.random.normal(key, (n_cols,), jnp.float32)
    charge = jnp.full((n_rows, n_cols), NEUTRAL, jnp.float32)
    return SubarrayState(charge=charge, sense_offset=offs)


def write_row(state: SubarrayState, row: int, bits: jax.Array) -> SubarrayState:
    charge = state.charge.at[row].set(bits.astype(jnp.float32))
    return dataclasses.replace(state, charge=charge)


def read_row(state: SubarrayState, row: int) -> jax.Array:
    """Normal-timing single-row read: reliable full-margin sensing."""
    return (state.charge[row] > NEUTRAL).astype(jnp.float32)


def rowcopy(state: SubarrayState, src: int, dst: Sequence[int]) -> SubarrayState:
    """ACT(src) -> PRE -> ACT(dst): copy src's digital value into dst row(s).

    AAP-style multi-destination copy (Ambit): the restore phase can drive more
    than one row, so ``dst`` may list several rows at one command cost.
    Also restores src to full charge (sensing digitizes the source).
    """
    bits = read_row(state, src)
    charge = state.charge.at[src].set(bits)
    for d in dst:
        charge = charge.at[d].set(bits)
    return dataclasses.replace(state, charge=charge)


def frac(state: SubarrayState, row: int) -> SubarrayState:
    """One Frac op: charge moves a factor ``frac_alpha`` toward neutral."""
    # Placement noise is accounted at sensing time (physics.sensing_sigma);
    # the deterministic state keeps the ideal multi-level value.
    q = state.charge[row]
    p = _params(state)
    q = NEUTRAL + (q - NEUTRAL) * p.frac_alpha
    return dataclasses.replace(state, charge=state.charge.at[row].set(q))


# The params object travels alongside rather than inside the pytree (it is
# static); module-level holder keeps the command signatures simple.
_PARAMS: PhysicsParams = PhysicsParams()


def set_params(params: PhysicsParams) -> None:
    global _PARAMS
    _PARAMS = params


def _params(_: SubarrayState) -> PhysicsParams:
    return _PARAMS


def simra(
    state: SubarrayState,
    rows: Sequence[int],
    key: jax.Array,
    n_fracs_applied: int = 0,
) -> tuple[SubarrayState, jax.Array]:
    """Simultaneous many-row activation over ``rows`` (normally 8 rows).

    Returns the new state (result restored into all opened rows) and the
    sensed bits [n_cols].
    """
    p = _params(state)
    rows = list(rows)
    q = state.charge[jnp.array(rows)]                      # [k, n_cols]
    v = p.bitline_voltage(q.sum(axis=0), len(rows))        # [n_cols]
    swing_sq = ((2.0 * (q - NEUTRAL)) ** 2).sum(axis=0)    # [n_cols]
    sigma = p.sensing_sigma(jnp.float32(n_fracs_applied), swing_sq)
    bits = sense(v, state.sense_offset, sigma, key)
    charge = state.charge
    for r in rows:
        charge = charge.at[r].set(bits)
    return dataclasses.replace(state, charge=charge), bits


# ---------------------------------------------------------------------------
# Fast path: closed-form MAJX outputs for calibration / ECR measurement.
# ---------------------------------------------------------------------------

def maj_outputs(
    inputs: jax.Array,           # [..., n_inputs, n_cols] bits in {0, 1}
    calib_charge: jax.Array,     # [n_calib, n_cols] charge of non-operand rows
    sense_offset: jax.Array,     # [n_cols]
    key: jax.Array,
    params: PhysicsParams,
    n_fracs_applied: int,
    const_charge_sum: float = 0.0,
    const_swing_sq: float = 0.0,
) -> jax.Array:
    """Sense result of SiMRA(inputs + calib rows + const rows), vectorized.

    ``inputs`` may carry arbitrary leading batch dims (trials).  The noise is
    drawn fresh per trial per column, as each SiMRA is an independent analog
    event.  ``const_*`` account for constant rows (e.g. the 0/1 pair used by
    MAJ3) that are full-swing but carry no per-column information.
    """
    q_in = inputs.astype(jnp.float32)
    charge_sum = (
        q_in.sum(axis=-2) + calib_charge.sum(axis=0) + const_charge_sum
    )
    v = params.bitline_voltage(charge_sum, params.n_simra_rows)
    swing_sq = (
        ((2.0 * (q_in - NEUTRAL)) ** 2).sum(axis=-2)
        + ((2.0 * (calib_charge - NEUTRAL)) ** 2).sum(axis=0)
        + const_swing_sq
    )
    sigma = params.sensing_sigma(jnp.float32(n_fracs_applied), swing_sq)
    return sense(v, sense_offset, sigma, key)
