"""MAJ-based bit-serial arithmetic on PUD (dual-rail encoding).

PUD arithmetic is built from MAJX (paper Sec. II-B; MVDRAM [4]):

    AND(x, y)  = MAJ3(x, y, 0)
    OR(x, y)   = MAJ3(x, y, 1)
    cout       = MAJ3(a, b, cin)
    sum        = MAJ5(a, b, cin, !cout, !cout)

Commodity DRAM has no in-array NOT, so operands are stored *dual-rail*
(value and complement); complements of intermediates are computed by running
the same MAJ on complemented inputs (MAJ is self-dual).

Every MAJX here is an 8-row SiMRA whose 3 non-operand rows hold either the
baseline neutral/constant pattern or PUDTune calibration data — so arithmetic
reliability compounds over the MAJ graph, which is exactly how the paper's
ADD/MUL throughput gains (1.88x / 1.89x) exceed the bare column gain (1.81x).

Command-cost accounting (OpCounts) mirrors an MVDRAM-style layout where
operand bit-columns are staged once and the carry/sum rails chain in place;
each MAJX then pays only for its non-operand row copies, Fracs and the SiMRA:

    standalone MAJ5 : 7 RowCopies (3 operands + 1 dup pair + 3 calib) + SiMRA
    staged MAJ5     : 4 RowCopies (1 dup pair + 3 calib) + SiMRA
    staged MAJ3     : 5 RowCopies (0/1 const pair + 3 calib) + SiMRA
    staged AND/OR   : 6 RowCopies (operand const + 0/1 pair + 3 calib) + SiMRA

With these counts the DDR4-2133 model in ``timing.py`` lands within ~5 % of
every Table-I absolute number (see EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .device import maj_outputs
from .physics import PhysicsParams
from .timing import OpCounts


# ---------------------------------------------------------------------------
# Functional MAJ context: device stand-in for a column-parallel MAJX engine.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MajContext:
    """Executes MAJX ops against a (simulated) calibrated subarray.

    All bit tensors have shape [..., n_cols]; leading dims are trials.
    """

    params: PhysicsParams
    sense_offset: jax.Array   # [n_cols]
    calib_charge: jax.Array   # [3, n_cols] non-operand row charge
    n_fracs: int              # Fracs applied per MAJX execution

    def _maj(self, inputs, key, const_sum, const_swing):
        x = jnp.stack(inputs, axis=-2)
        return maj_outputs(
            x, self.calib_charge, self.sense_offset, key, self.params,
            self.n_fracs, const_charge_sum=const_sum, const_swing_sq=const_swing,
        )

    # 5 operand rows + 3 calib rows = 8-row SiMRA.
    def maj5(self, a, b, c, d, e, key):
        return self._maj((a, b, c, d, e), key, 0.0, 0.0)

    # 3 operand rows + 0/1 constant pair + 3 calib rows.
    def maj3(self, a, b, c, key):
        return self._maj((a, b, c), key, 1.0, 2.0)

    # AND = MAJ3(x, y, const 0); one more constant row than maj3.
    def and_(self, x, y, key):
        return self._maj((x, y), key, 1.0, 3.0)

    # OR = MAJ3(x, y, const 1).
    def or_(self, x, y, key):
        return self._maj((x, y), key, 2.0, 3.0)


def _split(key, n):
    return jax.random.split(key, n)


# ---------------------------------------------------------------------------
# Dual-rail arithmetic graphs (value, complement) + their command costs.
# ---------------------------------------------------------------------------


def full_adder(ctx: MajContext, a, ab, b, bb, c, cb, key, want_sum_bar=True):
    """One dual-rail full adder. Returns (s, sb, cout, coutb).

    4 MAJX ops (3 if the sum complement is not needed downstream).
    """
    k1, k2, k3, k4 = _split(key, 4)
    cout = ctx.maj3(a, b, c, k1)
    coutb = ctx.maj3(ab, bb, cb, k2)
    s = ctx.maj5(a, b, c, coutb, coutb, k3)
    sb = ctx.maj5(ab, bb, cb, cout, cout, k4) if want_sum_bar else None
    return s, sb, cout, coutb


def add_n(ctx: MajContext, a_bits, ab_bits, b_bits, bb_bits, key,
          want_sum_bar=False):
    """Ripple-carry add of two n-bit dual-rail integers (LSB first).

    a_bits: [n, ..., n_cols]. Returns (sum_bits [n,...], sum_bar,
    carry_out, carry_out_bar).  Implemented as a lax.scan over bit position
    so the compiled graph holds one full-adder body, not n of them (compile
    time matters at 65 536-column scale on this CPU-only container).
    The complement rail is always *simulated*; ``want_sum_bar`` only controls
    whether it is returned (command-count pricing is separate, in
    ``add8_counts``/``mul8_counts``).
    """
    n = a_bits.shape[0]
    keys = _split(key, n)

    def body(carry, xs):
        c, cb = carry
        a, ab_, b, bb_, k = xs
        s, sb, c, cb = full_adder(ctx, a, ab_, b, bb_, c, cb, k,
                                  want_sum_bar=True)
        return (c, cb), (s, sb)

    init = (jnp.zeros_like(a_bits[0]), jnp.ones_like(a_bits[0]))
    (c, cb), (sums, sbars) = jax.lax.scan(
        body, init, (a_bits, ab_bits, b_bits, bb_bits, keys))
    return sums, (sbars if want_sum_bar else None), c, cb


def mul8_truncated(ctx: MajContext, a_bits, ab_bits, b_bits, bb_bits, key):
    """8-bit x 8-bit -> low 8 bits (fixed-point truncated product).

    Shift-and-add: partial product row j is ANDed (p_i = a_i AND b_j, with
    complements via OR on the complement rails) and ripple-added into the
    accumulator at offset j.  Scanned over j with rotation + masking so the
    compiled graph is one partial-product body; masked lanes pass through
    unchanged, so the error statistics match the true (8-j)-wide schedule.
    """
    k0, krest = _split(key, 2)
    keys0 = _split(k0, 16)
    acc = jnp.stack([ctx.and_(a_bits[i], b_bits[0], keys0[i])
                     for i in range(8)])
    accb = jnp.stack([ctx.or_(ab_bits[i], bb_bits[0], keys0[8 + i])
                      for i in range(8)])

    def body(carry, xs):
        acc, accb = carry
        j, k = xs
        b_j = jnp.take(b_bits, j, axis=0)
        bb_j = jnp.take(bb_bits, j, axis=0)
        kk = _split(k, 17)
        p = jnp.stack([ctx.and_(a_bits[i], b_j, kk[i]) for i in range(8)])
        pb = jnp.stack([ctx.or_(ab_bits[i], bb_j, kk[8 + i])
                        for i in range(8)])
        # rotate so target bit j sits at position 0, ripple-add, rotate back
        acc_r = jnp.roll(acc, -j, axis=0)
        accb_r = jnp.roll(accb, -j, axis=0)
        kfa = _split(kk[16], 8)

        def fa_body(cc, xs2):
            c, cb = cc
            i, ar, abr, pi, pbi, k2 = xs2
            s, sb, c2, cb2 = full_adder(ctx, ar, abr, pi, pbi, c, cb, k2,
                                        want_sum_bar=True)
            valid = (i < 8 - j)
            def keep(new, old):
                return jnp.where(valid, new, old)
            return ((keep(c2, c), keep(cb2, cb)),
                    (keep(s, ar), keep(sb, abr)))

        init = (jnp.zeros_like(acc[0]), jnp.ones_like(acc[0]))
        _, (s_new, sb_new) = jax.lax.scan(
            fa_body, init, (jnp.arange(8), acc_r, accb_r, p, pb, kfa))
        return (jnp.roll(s_new, j, axis=0), jnp.roll(sb_new, j, axis=0)), None

    keys = _split(krest, 7)
    (acc, accb), _ = jax.lax.scan(body, (acc, accb),
                                  (jnp.arange(1, 8), keys))
    return acc


# --- command costs (OpCounts) for the graphs above -------------------------


def maj5_standalone_counts(n_fracs: int) -> OpCounts:
    return OpCounts(rowcopies=7, fracs=n_fracs, simras=1)


def maj5_staged_counts(n_fracs: int) -> OpCounts:
    return OpCounts(rowcopies=4, fracs=n_fracs, simras=1)


def maj3_staged_counts(n_fracs: int) -> OpCounts:
    return OpCounts(rowcopies=5, fracs=n_fracs, simras=1)


def andor_staged_counts(n_fracs: int) -> OpCounts:
    return OpCounts(rowcopies=6, fracs=n_fracs, simras=1)


def full_adder_counts(n_fracs: int, want_sum_bar=True) -> OpCounts:
    c = 2 * maj3_staged_counts(n_fracs) + maj5_staged_counts(n_fracs)
    if want_sum_bar:
        c = c + maj5_staged_counts(n_fracs)
    return c


def add8_counts(n_fracs: int) -> OpCounts:
    # Standalone ADD does not need the sum complement rail.
    return 8 * full_adder_counts(n_fracs, want_sum_bar=False)


def mul8_counts(n_fracs: int) -> OpCounts:
    counts = OpCounts()
    for j in range(8):
        width = 8 - j
        counts = counts + 2 * width * andor_staged_counts(n_fracs)
        if j > 0:
            counts = counts + width * full_adder_counts(n_fracs,
                                                        want_sum_bar=True)
    return counts


# ---------------------------------------------------------------------------
# Bit/int conversion helpers (LSB first).
# ---------------------------------------------------------------------------


def int_to_bits(x: jax.Array, n_bits: int) -> jax.Array:
    """[...]: int -> [n_bits, ...] float bits, LSB first."""
    shifts = jnp.arange(n_bits, dtype=x.dtype)
    bits = (x[None, ...] >> shifts.reshape((-1,) + (1,) * x.ndim)) & 1
    return bits.astype(jnp.float32)


def bits_to_int(bits: jax.Array) -> jax.Array:
    """[n_bits, ...] bits -> [...] int32, LSB first."""
    n = bits.shape[0]
    weights = (2 ** jnp.arange(n, dtype=jnp.int32)).reshape(
        (-1,) + (1,) * (bits.ndim - 1))
    return (bits.astype(jnp.int32) * weights).sum(axis=0)
