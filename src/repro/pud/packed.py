"""Typed pack pytrees: the PUD serving weight format as first-class objects.

``PackedTensor`` is one projection in the PUD layout — WB bit-planes over
columns, the per-output-channel dequant scale, and (when column placement is
active) the ``col_ids`` gather map into the physical window.  ``PackedModel``
is a whole serving tree (bf16 leaves + ``PackedTensor`` packs) plus the
packing metadata that used to live in an ad-hoc report dict.

Both are registered JAX pytrees, so they jit, ``lax.scan`` (stacked layers
slice leaf-wise along the L axis), shard, and checkpoint like any other
params.  ``PackedTensor`` also speaks the legacy mapping protocol
(``pack["planes"]``, ``pack.get("col_ids")``, ``"col_ids" in pack``) so
pre-session call sites and raw-dict packs keep working; ``as_packed_tensor``
is the one coercion point between the two worlds.
"""
from __future__ import annotations

import dataclasses

import jax

_FIELDS = ("planes", "scale", "col_ids")


@dataclasses.dataclass(eq=False)
class PackedTensor:
    """One projection in the PUD bit-plane layout.

    Shapes (optionally with a leading stacked-layer axis L):
      planes   [L?, WB, K, N]  int8 in {0,1} — offset-binary weight bits;
               with placement the trailing axis is the physical window P
      scale    [L?, N]         float32 per-output-channel dequant scale
      col_ids  [L?, N]         int32 logical -> window column map, or None
                               for the logical (unplaced) layout

    ``backend`` (pytree aux, not data) names the execution backend the pack
    was built for: model forwards dispatch packed projections without access
    to the session, so the backend choice rides on the pack itself
    (``pud_linear`` resolution: explicit arg > config > pack > legacy flag).
    """

    planes: jax.Array
    scale: jax.Array
    col_ids: jax.Array | None = None
    backend: str | None = None

    @property
    def placed(self) -> bool:
        return self.col_ids is not None

    def replace(self, **kw) -> "PackedTensor":
        return dataclasses.replace(self, **kw)

    # -- legacy mapping protocol (the pre-PUDSession dict pack format) ------

    def __getitem__(self, key: str):
        if key not in _FIELDS:
            raise KeyError(key)
        value = getattr(self, key)
        if value is None:
            raise KeyError(key)
        return value

    def get(self, key: str, default=None):
        value = getattr(self, key, None) if key in _FIELDS else None
        return default if value is None else value

    def __contains__(self, key: str) -> bool:
        return key in _FIELDS and getattr(self, key) is not None

    def keys(self):
        return tuple(f for f in _FIELDS if getattr(self, f) is not None)

    def items(self):
        return tuple((k, getattr(self, k)) for k in self.keys())

    def __iter__(self):
        return iter(self.keys())


def as_packed_tensor(pack) -> PackedTensor:
    """Coerce a legacy {"planes", "scale", "col_ids"?} dict (or a
    PackedTensor, passed through) to the typed form."""
    if isinstance(pack, PackedTensor):
        return pack
    return PackedTensor(planes=pack["planes"], scale=pack["scale"],
                        col_ids=pack.get("col_ids"))


def is_pack(value) -> bool:
    """Is ``value`` a pack in either format (typed or legacy dict)?"""
    if isinstance(value, PackedTensor):
        return True
    return (isinstance(value, dict) and "planes" in value and "scale" in value)


jax.tree_util.register_pytree_node(
    PackedTensor,
    lambda pt: ((pt.planes, pt.scale, pt.col_ids), pt.backend),
    lambda aux, ch: PackedTensor(*ch, backend=aux))


@dataclasses.dataclass(eq=False)
class PackedModel:
    """A whole serving tree packed for the PUD path.

    ``params`` is the tree ``model.prefill``/``decode_step`` consume: packed
    projections replaced by ``<name>_pud`` ``PackedTensor``s, everything else
    untouched.  The static metadata (what packed, what skipped, bit width,
    layout) rides along as pytree aux data so a jitted function treats two
    packs of the same shape+metadata as one trace.
    """

    params: dict
    packed_names: tuple[str, ...] = ()
    skipped_names: tuple[str, ...] = ()
    weight_bits: int = 4
    placed: bool = False

    @property
    def report(self) -> dict:
        """The legacy ``pack_for_serving`` report dict."""
        return {"packed": list(self.packed_names),
                "skipped": list(self.skipped_names),
                "bits": self.weight_bits, "placed": self.placed}

    @property
    def tensors(self) -> dict[str, PackedTensor]:
        """Flat view: tensor path (report name) -> its PackedTensor.

        Computed once per instance and cached — per-call lookups
        (``PUDSession.linear``) must not re-walk the whole tree.
        """
        cached = self.__dict__.get("_tensors")
        if cached is not None:
            return cached
        out: dict[str, PackedTensor] = {}

        def walk(tree, path):
            for key, sub in tree.items():
                if key.endswith("_pud") and is_pack(sub):
                    name = "/".join(path + (key[: -len("_pud")],))
                    out[name] = as_packed_tensor(sub)
                elif isinstance(sub, dict):
                    walk(sub, path + (key,))

        walk(self.params, ())
        self.__dict__["_tensors"] = out
        return out

    def tensor(self, name: str) -> PackedTensor:
        """Look up one pack by its report name (or unique path suffix)."""
        tensors = self.tensors
        if name in tensors:
            return tensors[name]
        hits = [k for k in tensors if k.endswith(name)]
        if len(hits) == 1:
            return tensors[hits[0]]
        raise KeyError(
            f"packed tensor {name!r} "
            + (f"is ambiguous: {sorted(hits)}" if hits
               else f"not found (have: {sorted(tensors)})"))


jax.tree_util.register_pytree_node(
    PackedModel,
    lambda pm: ((pm.params,),
                (pm.packed_names, pm.skipped_names, pm.weight_bits,
                 pm.placed)),
    lambda aux, ch: PackedModel(ch[0], *aux))


def packed_bytes(params) -> dict:
    """Storage accounting: bf16 bytes vs packed bit-plane bytes.

    Accepts a ``PackedModel`` or a raw serving tree in either pack format.
    """
    if isinstance(params, PackedModel):
        params = params.params
    stats = {"bf16_bytes": 0, "pud_bytes": 0}

    def count(pack):
        stats["pud_bytes"] += pack.planes.size // 8 + pack.scale.size * 4
        if pack.col_ids is not None:
            stats["pud_bytes"] += pack.col_ids.size * 4

    def walk(tree):
        for k, v in tree.items():
            if k.endswith("_pud") and is_pack(v):
                count(as_packed_tensor(v))
            elif isinstance(v, dict):
                walk(v)
            elif isinstance(v, jax.Array):
                stats["bf16_bytes"] += v.size * v.dtype.itemsize
    walk(params)
    return stats
