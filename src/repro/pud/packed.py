"""Typed pack pytrees: the PUD serving weight format as first-class objects.

``PackedTensor`` is one projection in the PUD layout — WB bit-planes over
columns, the per-output-channel dequant scale, and (when column placement is
active) the ``col_ids`` gather map into the physical window.  ``PackedModel``
is a whole serving tree (bf16 leaves + ``PackedTensor`` packs) plus the
packing metadata that used to live in an ad-hoc report dict.

Since the bit-packing refactor the stored planes are *actually* bit-packed:
the default ``layout`` is ``"bitpack8"`` — eight K rows per uint8 word,
``[L?, WB, ceil(K/8), N]`` (see ``kernels.ref.pack_plane_words`` and
docs/kernels.md for why the word axis is K, not N).  The pre-refactor dense
``[L?, WB, K, N]`` int8-per-bit layout survives as ``layout="dense"`` —
legacy dict packs coerce to it, and ``to_bitpacked``/``to_dense`` convert
either way bit-exactly.

Both classes are registered JAX pytrees, so they jit, ``lax.scan`` (stacked
layers slice leaf-wise along the L axis), shard, and checkpoint like any
other params; the layout metadata rides as pytree aux, so the kernel
dispatch is trace-static.  ``PackedTensor`` also speaks the legacy mapping
protocol (``pack["planes"]``, ``pack.get("col_ids")``, ``"col_ids" in
pack``) so pre-session call sites and raw-dict packs keep working;
``as_packed_tensor`` is the one coercion point between the two worlds.
"""
from __future__ import annotations

import dataclasses
import json
import zipfile

import jax
import jax.numpy as jnp
import numpy as np

_FIELDS = ("planes", "scale", "col_ids")

LAYOUT_DENSE = "dense"        # [L?, WB, K, N/P] int8, one byte per bit
LAYOUT_BITPACK = "bitpack8"   # [L?, WB, ceil(K/8), N/P] uint8, 8 bits/byte

# .npz serialization tag.  The loader also accepts "pud-pack-v1": the
# dense-layout archive convention without per-entry layout metadata (each
# pack coerced by dtype), so dense-era archives deserialize under v2 code.
PACK_FORMAT = "pud-pack-v2"


@dataclasses.dataclass(eq=False)
class PackedTensor:
    """One projection in the PUD bit-plane layout.

    Shapes (optionally with a leading stacked-layer axis L):
      planes   [L?, WB, Kw, N]  ``layout="bitpack8"``: uint8 words, eight K
               rows per byte (Kw = ceil(K/8), LSB-first);
               ``layout="dense"``: [L?, WB, K, N] int8 in {0,1}.  With
               placement the trailing axis is the physical window W.
      scale    [L?, N]          float32 per-output-channel dequant scale
      col_ids  [L?, N]          int32 logical -> window column map, or None
                                for the logical (unplaced) layout

    Aux metadata (pytree aux, not data — trace-static):
      backend       execution backend the pack was built for; model forwards
                    dispatch packed projections without access to the
                    session, so the choice rides on the pack itself
                    (``pud_linear`` resolution: arg > config > pack > flag).
      layout        plane storage format tag (see module constants).
      logical_k     K before byte-padding (bitpack8 pads K to 8); None for
                    dense packs, where K is the planes shape itself.
      window_block  placed packs only: window columns per N-block — the
                    block-aligned placed layout guarantees logical block j's
                    columns live inside window slice [j*wb, (j+1)*wb), so
                    the kernel blocks the window axis like any other.  None
                    = single-block window (or unplaced).
      tile_plan     autotuned execution plan (kernels/autotune.py): a
                    ``TunedTile`` shared by both entries or a hashable
                    tuple of ``("gemv"|"gemm", TunedTile)`` pairs, stamped
                    by ``PUDSession.tune()``.  None = divisor heuristic
                    (the cold-start fallback).  Plans never change
                    numerics, only tiling/unpack strategy.
    """

    planes: jax.Array
    scale: jax.Array
    col_ids: jax.Array | None = None
    backend: str | None = None
    layout: str = LAYOUT_DENSE
    logical_k: int | None = None
    window_block: int | None = None
    tile_plan: object | None = None

    @property
    def placed(self) -> bool:
        return self.col_ids is not None

    @property
    def bitpacked(self) -> bool:
        return self.layout == LAYOUT_BITPACK

    @property
    def n_bits(self) -> int:
        return self.planes.shape[-3]

    @property
    def k(self) -> int:
        """Logical reduction length (un-padded K)."""
        if self.layout == LAYOUT_BITPACK:
            return self.logical_k or self.planes.shape[-2] * 8
        return self.planes.shape[-2]

    @property
    def n(self) -> int:
        """Logical output columns."""
        return self.scale.shape[-1]

    @property
    def stored_bytes(self) -> int:
        """Actual bytes of the stored arrays (what HBM really holds)."""
        total = self.planes.size * self.planes.dtype.itemsize
        total += self.scale.size * self.scale.dtype.itemsize
        if self.col_ids is not None:
            total += self.col_ids.size * self.col_ids.dtype.itemsize
        return total

    @property
    def dense_equiv_bytes(self) -> int:
        """Bytes the same pack occupies in the dense one-byte-per-bit
        layout (the pre-bitpack format) — the 8x comparison baseline."""
        shape = self.planes.shape
        k_axis = self.k if self.layout == LAYOUT_BITPACK else shape[-2]
        dense_planes = int(np.prod(shape[:-2], dtype=np.int64)) \
            * k_axis * shape[-1]
        total = dense_planes + self.scale.size * self.scale.dtype.itemsize
        if self.col_ids is not None:
            total += self.col_ids.size * self.col_ids.dtype.itemsize
        return total

    def replace(self, **kw) -> "PackedTensor":
        return dataclasses.replace(self, **kw)

    # -- legacy mapping protocol (the pre-PUDSession dict pack format) ------

    def __getitem__(self, key: str):
        if key not in _FIELDS:
            raise KeyError(key)
        value = getattr(self, key)
        if value is None:
            raise KeyError(key)
        return value

    def get(self, key: str, default=None):
        value = getattr(self, key, None) if key in _FIELDS else None
        return default if value is None else value

    def __contains__(self, key: str) -> bool:
        return key in _FIELDS and getattr(self, key) is not None

    def keys(self):
        return tuple(f for f in _FIELDS if getattr(self, f) is not None)

    def items(self):
        return tuple((k, getattr(self, k)) for k in self.keys())

    def __iter__(self):
        return iter(self.keys())


def as_packed_tensor(pack) -> PackedTensor:
    """Coerce a legacy {"planes", "scale", "col_ids"?} dict (or a
    PackedTensor, passed through) to the typed form.

    Dict packs carry no layout tag, so the plane dtype decides: uint8 planes
    are bit-packed words (logical K = Kw*8 — a dict cannot record byte
    padding), anything else is the legacy dense one-byte-per-bit layout.
    """
    if isinstance(pack, (PackedTensor, ShardedPackedTensor)):
        return pack
    planes = pack["planes"]
    layout = (LAYOUT_BITPACK if planes.dtype == jnp.uint8 else LAYOUT_DENSE)
    return PackedTensor(planes=planes, scale=pack["scale"],
                        col_ids=pack.get("col_ids"), layout=layout)


def to_dense(pt: PackedTensor) -> PackedTensor:
    """Bit-exact conversion to the dense one-byte-per-bit layout."""
    pt = as_packed_tensor(pt)
    if pt.layout == LAYOUT_DENSE:
        return pt
    from repro.kernels.ref import unpack_plane_words
    unpack = unpack_plane_words
    planes = pt.planes
    if planes.ndim == 4:                       # stacked [L, WB, Kw, N]
        unpack = jax.vmap(lambda w: unpack_plane_words(w, pt.k))
        dense = unpack(planes)
    else:
        dense = unpack(planes, pt.k)
    return pt.replace(planes=dense, layout=LAYOUT_DENSE, logical_k=None)


def to_bitpacked(pt: PackedTensor) -> PackedTensor:
    """Bit-exact conversion of a dense pack to bit-packed words."""
    pt = as_packed_tensor(pt)
    if pt.layout == LAYOUT_BITPACK:
        return pt
    from repro.kernels.ref import pack_plane_words
    planes = pt.planes
    k = planes.shape[-2]
    if planes.ndim == 4:
        words = jax.vmap(pack_plane_words)(planes)
    else:
        words = pack_plane_words(planes)
    return pt.replace(planes=words, layout=LAYOUT_BITPACK, logical_k=k)


@dataclasses.dataclass(eq=False)
class ShardedPackedTensor:
    """One projection split column-wise across the "model" mesh axis.

    The tensor-parallel serving format: shard s owns the whole placement
    windows of logical columns ``[lo_s, hi_s)`` (``shard_widths[s]`` wide,
    always a multiple of ``block_cols`` — see
    ``pud.placement.shard_column_slices``), packed with that shard's own
    calibration/placement state.  Per-shard packs are padded to a common
    per-device shape (shard_map runs one SPMD program) and stacked on a
    shard axis S just inside the optional stacked-layer axis:

      planes   [L?, S, WB, Kw, R]   R = common padded window (placed) or
                                    padded column count (logical layout)
      scale    [L?, S, Np]          Np = max shard width, padded with 1.0
      col_ids  [L?, S, Np]          padded entries point at their own
                                    (zero-plane) window block, or None

    Keeping L leading means a layer ``lax.scan`` slices the children to
    ``[S, ...]`` per step, exactly like ``PackedTensor``.  Padding columns
    back zero planes, so they accumulate zero and are statically sliced
    away after the per-shard GEMM; zero-width shards (fewer blocks than
    devices) are all-padding and still run the same program.

    Aux metadata adds to ``PackedTensor``'s: ``shard_widths`` (static
    per-shard logical column counts), ``block_cols`` (the full tensor's
    window-block width every shard split on), ``axis`` (mesh axis name the
    shard dimension maps to) and ``mesh`` (the ``jax.sharding.Mesh`` the
    pack was built for — hashable, so it rides as trace-static aux).
    """

    planes: jax.Array
    scale: jax.Array
    col_ids: jax.Array | None = None
    shard_widths: tuple[int, ...] = ()
    block_cols: int = 0
    backend: str | None = None
    layout: str = LAYOUT_BITPACK
    logical_k: int | None = None
    window_block: int | None = None
    tile_plan: object | None = None
    axis: str = "model"
    mesh: object | None = None

    @property
    def placed(self) -> bool:
        return self.col_ids is not None

    @property
    def n_shards(self) -> int:
        return self.planes.shape[-4]

    @property
    def n_bits(self) -> int:
        return self.planes.shape[-3]

    @property
    def k(self) -> int:
        if self.layout == LAYOUT_BITPACK:
            return self.logical_k or self.planes.shape[-2] * 8
        return self.planes.shape[-2]

    @property
    def n(self) -> int:
        """Logical output columns across all shards (un-padded)."""
        return sum(self.shard_widths)

    @property
    def padded_n(self) -> int:
        """Per-shard padded column count Np (what each device computes)."""
        return self.scale.shape[-1]

    @property
    def stored_bytes(self) -> int:
        total = self.planes.size * self.planes.dtype.itemsize
        total += self.scale.size * self.scale.dtype.itemsize
        if self.col_ids is not None:
            total += self.col_ids.size * self.col_ids.dtype.itemsize
        return total

    def replace(self, **kw) -> "ShardedPackedTensor":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_pytree_node(
    ShardedPackedTensor,
    lambda st: ((st.planes, st.scale, st.col_ids),
                (st.shard_widths, st.block_cols, st.backend, st.layout,
                 st.logical_k, st.window_block, st.tile_plan, st.axis,
                 st.mesh)),
    lambda aux, ch: ShardedPackedTensor(
        *ch, shard_widths=aux[0], block_cols=aux[1], backend=aux[2],
        layout=aux[3], logical_k=aux[4], window_block=aux[5],
        tile_plan=aux[6], axis=aux[7], mesh=aux[8]))


def is_pack(value) -> bool:
    """Is ``value`` a pack in either format (typed or legacy dict)?"""
    if isinstance(value, (PackedTensor, ShardedPackedTensor)):
        return True
    return (isinstance(value, dict) and "planes" in value and "scale" in value)


jax.tree_util.register_pytree_node(
    PackedTensor,
    lambda pt: ((pt.planes, pt.scale, pt.col_ids),
                (pt.backend, pt.layout, pt.logical_k, pt.window_block,
                 pt.tile_plan)),
    lambda aux, ch: PackedTensor(*ch, backend=aux[0], layout=aux[1],
                                 logical_k=aux[2], window_block=aux[3],
                                 tile_plan=aux[4]))


@dataclasses.dataclass(eq=False)
class PackedModel:
    """A whole serving tree packed for the PUD path.

    ``params`` is the tree ``model.prefill``/``decode_step`` consume: packed
    projections replaced by ``<name>_pud`` ``PackedTensor``s, everything else
    untouched.  The static metadata (what packed, what skipped, bit width,
    layout) rides along as pytree aux data so a jitted function treats two
    packs of the same shape+metadata as one trace.
    """

    params: dict
    packed_names: tuple[str, ...] = ()
    skipped_names: tuple[str, ...] = ()
    weight_bits: int = 4
    placed: bool = False

    @property
    def report(self) -> dict:
        """The legacy ``pack_for_serving`` report dict."""
        return {"packed": list(self.packed_names),
                "skipped": list(self.skipped_names),
                "bits": self.weight_bits, "placed": self.placed}

    @property
    def tensors(self) -> dict[str, PackedTensor]:
        """Flat view: tensor path (report name) -> its PackedTensor.

        Computed once per instance and cached — per-call lookups
        (``PUDSession.linear``) must not re-walk the whole tree.
        """
        cached = self.__dict__.get("_tensors")
        if cached is not None:
            return cached
        out: dict[str, PackedTensor] = {}

        def walk(tree, path):
            for key, sub in tree.items():
                if key.endswith("_pud") and is_pack(sub):
                    name = "/".join(path + (key[: -len("_pud")],))
                    out[name] = as_packed_tensor(sub)
                elif isinstance(sub, dict):
                    walk(sub, path + (key,))

        walk(self.params, ())
        self.__dict__["_tensors"] = out
        return out

    def tensor(self, name: str) -> PackedTensor:
        """Look up one pack by its report name (or unique path suffix)."""
        tensors = self.tensors
        if name in tensors:
            return tensors[name]
        hits = [k for k in tensors if k.endswith(name)]
        if len(hits) == 1:
            return tensors[hits[0]]
        raise KeyError(
            f"packed tensor {name!r} "
            + (f"is ambiguous: {sorted(hits)}" if hits
               else f"not found (have: {sorted(tensors)})"))


jax.tree_util.register_pytree_node(
    PackedModel,
    lambda pm: ((pm.params,),
                (pm.packed_names, pm.skipped_names, pm.weight_bits,
                 pm.placed)),
    lambda aux, ch: PackedModel(ch[0], *aux))


def packed_bytes(params) -> dict:
    """Storage accounting: bf16 bytes vs packed bit-plane bytes.

    Accepts a ``PackedModel`` or a raw serving tree in either pack format.
    Reports both the bytes actually stored (``stored_bytes`` — with the
    bit-packed layout this is the real array footprint, planes at one *bit*
    per weight bit) and what the same packs would occupy in the dense
    one-byte-per-bit layout (``dense_equiv_bytes``).  ``pud_bytes`` is kept
    as a legacy alias of ``stored_bytes``.
    """
    if isinstance(params, PackedModel):
        params = params.params
    stats = {"bf16_bytes": 0, "stored_bytes": 0, "dense_equiv_bytes": 0}

    def count(pack):
        stats["stored_bytes"] += pack.stored_bytes
        stats["dense_equiv_bytes"] += pack.dense_equiv_bytes

    def walk(tree):
        for k, v in tree.items():
            if k.endswith("_pud") and is_pack(v):
                count(as_packed_tensor(v))
            elif isinstance(v, dict):
                walk(v)
            elif isinstance(v, jax.Array):
                stats["bf16_bytes"] += v.size * v.dtype.itemsize
    walk(params)
    stats["pud_bytes"] = stats["stored_bytes"]
    return stats


# ---------------------------------------------------------------------------
# Serialization: one .npz per PackedModel (versioned, no pickle)
# ---------------------------------------------------------------------------


def _tile_plan_to_json(tile_plan):
    """TunedTile | ((entry, TunedTile), ...) -> JSON-safe value."""
    if tile_plan is None:
        return None
    if hasattr(tile_plan, "to_dict"):
        return tile_plan.to_dict()
    return [[entry, plan.to_dict()] for entry, plan in tile_plan]


def _tile_plan_from_json(value):
    if value is None:
        return None
    from repro.kernels.autotune import TunedTile
    if isinstance(value, dict):
        return TunedTile.from_dict(value)
    return tuple((entry, TunedTile.from_dict(d)) for entry, d in value)


def save_packed_npz(path, pm: PackedModel) -> None:
    """Write a ``PackedModel``'s packs to ``path`` as a single .npz.

    Only the packed projections serialize (bf16 leaves belong to the
    checkpointing layer); format ``pud-pack-v2`` records layout metadata
    per tensor.
    """
    tensors = pm.tensors
    meta = {
        "format": PACK_FORMAT,
        "names": list(tensors),
        "weight_bits": pm.weight_bits,
        "placed": pm.placed,
        "entries": {
            name: {"layout": pt.layout, "logical_k": pt.logical_k,
                   "window_block": pt.window_block, "backend": pt.backend,
                   "tile_plan": _tile_plan_to_json(pt.tile_plan)}
            for name, pt in tensors.items()
        },
    }
    arrays = {"meta": np.array(json.dumps(meta))}
    for i, (name, pt) in enumerate(tensors.items()):
        arrays[f"t{i}_planes"] = np.asarray(pt.planes)
        arrays[f"t{i}_scale"] = np.asarray(pt.scale)
        if pt.col_ids is not None:
            arrays[f"t{i}_col_ids"] = np.asarray(pt.col_ids, np.int32)
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def load_packed_npz(path) -> dict[str, PackedTensor] | None:
    """Read the packs back as {name: PackedTensor}; None on corruption.

    Version fallback: a ``pud-pack-v1`` archive (the dense-layout
    convention — plane arrays only, no per-entry layout metadata) still
    loads, each pack coerced through ``as_packed_tensor``'s dtype
    inference; unknown format tags read as misses.
    """
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            if meta.get("format") not in (PACK_FORMAT, "pud-pack-v1"):
                return None
            entries = meta.get("entries", {})
            out = {}
            for i, name in enumerate(meta["names"]):
                e = entries.get(name, {})
                pack = {"planes": jnp.asarray(z[f"t{i}_planes"]),
                        "scale": jnp.asarray(z[f"t{i}_scale"])}
                if f"t{i}_col_ids" in z:
                    pack["col_ids"] = jnp.asarray(z[f"t{i}_col_ids"])
                pt = as_packed_tensor(pack)
                if e:                       # v2: explicit layout metadata
                    pt = pt.replace(
                        layout=e.get("layout", pt.layout),
                        logical_k=e.get("logical_k"),
                        window_block=e.get("window_block"),
                        backend=e.get("backend"),
                        tile_plan=_tile_plan_from_json(e.get("tile_plan")))
                out[name] = pt
            return out
    except (OSError, ValueError, KeyError, EOFError, json.JSONDecodeError,
            zipfile.BadZipFile):
        return None
