"""Serving-time weight packer: swap model projections onto the PUD path.

``pack_for_serving`` walks a trained/initialized parameter tree and replaces
selected 2-D projections with PUD bit-plane packs ({"planes", "scale"}),
which ``models.layers.linear`` dispatches to the Pallas bit-plane GeMV.
This is how the paper's technique becomes a first-class serving feature:
any arch config can be served with ``--pud-gemv`` and its FFN/unembed
projections execute in the (simulated) DRAM layout.

Scope (documented in DESIGN.md §4): FFN wi/wg/wo and the unembed projection
— the dominant GeMV flops at decode time. Attention projections and MoE
expert banks keep the bf16 path (same mechanism would apply; the expert dim
adds a leading axis the serving kernel does not tile yet).

Stacked (scanned) layers pack per-slice: [L, K, N] -> [L, WB, K, N]; under
the layer ``lax.scan`` each iteration sees one [WB, K, N] pack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .gemv import PUDGemvConfig, pack_linear

PACKABLE = ("wi", "wg", "wo")


def _pack_stacked(w: jax.Array, n_bits: int) -> dict:
    """[L, K, N] (or [K, N]) weights -> stacked {"planes", "scale"}."""
    if w.ndim == 2:
        return pack_linear(w, n_bits)
    packs = [pack_linear(w[i], n_bits) for i in range(w.shape[0])]
    return {"planes": jnp.stack([p["planes"] for p in packs]),
            "scale": jnp.stack([p["scale"] for p in packs])}


def pack_for_serving(params: dict, cfg: PUDGemvConfig = PUDGemvConfig(),
                     include_unembed: bool = True) -> tuple[dict, dict]:
    """Returns (serving params, report). Original fp weights are dropped
    from packed projections (the bit-planes ARE the stored layout)."""
    report = {"packed": [], "skipped": [], "bits": cfg.weight_bits}

    def walk(tree, path):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for key, sub in tree.items():
            p = path + (key,)
            if (key in PACKABLE and isinstance(sub, jax.Array)
                    and sub.ndim in (2, 3) and "mixer" in path):
                out[key + "_pud"] = _pack_stacked(sub, cfg.weight_bits)
                report["packed"].append("/".join(p))
            elif key in PACKABLE and not isinstance(sub, jax.Array):
                out[key] = walk(sub, p)   # nested dict coincidence
            else:
                if isinstance(sub, dict):
                    out[key] = walk(sub, p)
                else:
                    out[key] = sub
                    if key in PACKABLE and isinstance(sub, jax.Array):
                        report["skipped"].append("/".join(p))
        return out

    packed = walk(params, ())
    if include_unembed and "unembed" in packed:
        w = packed["unembed"].pop("w")
        packed["unembed"]["w_pud"] = _pack_stacked(w, cfg.weight_bits)
        report["packed"].append("unembed/w")
    return packed, report


def packed_bytes(params: dict) -> dict:
    """Storage accounting: bf16 bytes vs packed bit-plane bytes."""
    stats = {"bf16_bytes": 0, "pud_bytes": 0}

    def walk(tree):
        if isinstance(tree, dict):
            for k, v in tree.items():
                if isinstance(v, dict):
                    if "planes" in v and "scale" in v and k.endswith("_pud"):
                        stats["pud_bytes"] += v["planes"].size // 8 \
                            + v["scale"].size * 4
                    else:
                        walk(v)
                elif isinstance(v, jax.Array):
                    stats["bf16_bytes"] += v.size * v.dtype.itemsize
    walk(params)
    return stats
