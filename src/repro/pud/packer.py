"""Serving-time weight packer: swap model projections onto the PUD path.

``pack_model`` walks a trained/initialized parameter tree and replaces
selected projections with ``PackedTensor`` bit-plane packs (repro/pud/
packed.py), which ``models.layers.linear`` / ``models.attention`` dispatch
to the Pallas bit-plane GeMV.  This is how the paper's technique becomes a
first-class serving feature: any arch config can be served with
``--pud-gemv`` and its projections execute in the (simulated) DRAM layout.
``pack_for_serving`` is the legacy tuple-returning shim over it.

Which projections pack is configured by ``PUDGemvConfig.packable`` — entries
are either a bare key name ("wi") or scoped "component.key" ("mixer.wi",
matching when "mixer" appears on the tree path).  The default covers FFN
wi/wg/wo; add ``ATTN_PACKABLE`` for attention wq/wk/wv/wo, whose 3-D
``[D, H, Dh]`` weights pack as the flattened 2-D ``[D, H*Dh]`` case (the
head split is a view — the GeMV columns are the same either way).  MoE
routed expert banks keep the bf16 path (the expert dim adds a leading axis
the serving kernel does not tile yet).

Packs come out in the *bit-packed* storage layout by default (eight K rows
per uint8 word — ``pud/packed.py`` ``LAYOUT_BITPACK``), so the HBM bytes a
pack occupies finally match the bits the PUD format stores.  Stacked
(scanned) layers pack per-slice: [L, K, N] -> [L, WB, ceil(K/8), N]; under
the layer ``lax.scan`` each iteration sees one [WB, ceil(K/8), N] pack.

With a ``Placement`` (repro/pud/placement.py) the packer emits
*physically-permuted* planes in the block-aligned window layout: each
slice's bit-planes are scattered into the per-N-block physical windows its
logical columns were placed on, then bit-packed, plus the ``col_ids``
gather map the placed kernel consumes.  Faulty physical columns inside a
window hold zeros and are never addressed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .gemv import ATTN_PACKABLE, FFN_PACKABLE, PUDGemvConfig, pack_linear
from .packed import (LAYOUT_BITPACK, PackedModel, PackedTensor,
                     ShardedPackedTensor)
from .packed import packed_bytes  # noqa: F401  (legacy import location)
from .placement import (Placement, PlacementRequest, TensorPlacement,
                        shard_column_slices)


def _match(packable: tuple[str, ...], key: str, path: tuple[str, ...]) -> bool:
    """Does ``key`` at ``path`` belong to the packable set?

    "scope.name" entries require ``scope`` somewhere on the path; bare
    entries match the key in any context.
    """
    for entry in packable:
        if "." in entry:
            scope, name = entry.rsplit(".", 1)
            if key == name and scope in path:
                return True
        elif key == entry:
            return True
    return False


def _canonical(key: str, path: tuple[str, ...], w: jax.Array):
    """Matched projection -> canonical [K, N] / [L, K, N] view, or None.

    Attention weights carry explicit head axes; the PUD column layout does
    not care about the split, so wq/wk/wv flatten the trailing (H, Dh) axes
    and wo the leading ones.  Everything else (FFN, unembed) must already be
    2-D, optionally with a stacked-layer axis in front.
    """
    if "attn" in path:
        if key in ("wq", "wk", "wv"):
            if w.ndim == 3:       # [D, H, Dh]
                return w.reshape(w.shape[0], -1)
            if w.ndim == 4:       # [L, D, H, Dh]
                return w.reshape(w.shape[0], w.shape[1], -1)
        elif key == "wo":
            if w.ndim == 3:       # [H, Dh, D]
                return w.reshape(-1, w.shape[-1])
            if w.ndim == 4:       # [L, H, Dh, D]
                return w.reshape(w.shape[0], -1, w.shape[-1])
        return None
    if w.ndim in (2, 3):
        return w
    return None


def _pack_stacked(w: jax.Array, n_bits: int,
                  backend: str | None) -> PackedTensor:
    """[L, K, N] (or [K, N]) weights -> stacked ``PackedTensor``."""
    if w.ndim == 2:
        return pack_linear(w, n_bits, backend)
    packs = [pack_linear(w[i], n_bits) for i in range(w.shape[0])]
    return PackedTensor(planes=jnp.stack([p.planes for p in packs]),
                        scale=jnp.stack([p.scale for p in packs]),
                        backend=backend, layout=packs[0].layout,
                        logical_k=packs[0].logical_k)


def _pack_placed(w: jax.Array, n_bits: int, tp: TensorPlacement,
                 backend: str | None) -> PackedTensor:
    """Physically-placed pack: planes scattered into the column window.

    The window is the *block-aligned* layout (repro/pud/placement.py):
    logical N-block j's columns sit inside window slice
    ``[j*tp.window_block, (j+1)*tp.window_block)``, so the placed kernels
    block the window axis per N-tile.  The scatter happens on dense planes
    (the window axis is the column axis, untouched by bit-packing), then
    the whole window bit-packs along K.  Returns a ``PackedTensor`` with
    planes [L?, WB, ceil(K/8), W] uint8 words, scale [L?, N], col_ids
    [L?, N] (absolute window positions) and ``window_block`` aux, where
    W = tp.region_size.
    """
    from repro.kernels.ref import pack_plane_words

    local = np.asarray(tp.local_cols)

    def one(w2, loc):
        pk = pack_linear(w2, n_bits, bitpack=False)
        planes = jnp.zeros(pk.planes.shape[:2] + (tp.region_size,),
                           jnp.int8)
        idx = jnp.asarray(loc, jnp.int32)
        planes = planes.at[:, :, idx].set(pk.planes)
        return PackedTensor(planes=pack_plane_words(planes), scale=pk.scale,
                            col_ids=idx)

    kw = dict(backend=backend, layout=LAYOUT_BITPACK,
              logical_k=w.shape[-2], window_block=tp.window_block)
    if w.ndim == 2:
        return dataclasses.replace(one(w, local), **kw)
    packs = [one(w[i], local[i]) for i in range(w.shape[0])]
    return PackedTensor(
        planes=jnp.stack([p.planes for p in packs]),
        scale=jnp.stack([p.scale for p in packs]),
        col_ids=jnp.stack([p.col_ids for p in packs]),
        **kw)


def _pad_axis(a: jax.Array, axis: int, target: int, value=0) -> jax.Array:
    """Zero-risk trailing pad of one axis up to ``target`` columns."""
    grow = target - a.shape[axis]
    if grow <= 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, grow)
    return jnp.pad(a, pad, constant_values=value)


def _pad_col_ids(ids: jax.Array, n_max: int, block_cols: int,
                 window_block: int) -> jax.Array:
    """Extend a col_ids map to ``n_max`` columns of the padded geometry.

    Padded logical columns must still satisfy the block-slice invariant the
    placed kernels check (``analysis/contracts.check_col_ids``), so each
    points at the start of its *own* padded window block — those blocks
    hold zero planes, so the gathered contribution is zero and the padded
    output columns are sliced away after the shard GEMM anyway.
    """
    n_i = ids.shape[-1]
    if n_max <= n_i:
        return ids
    pad = (jnp.arange(n_i, n_max, dtype=jnp.int32) // block_cols) \
        * window_block
    pad = jnp.broadcast_to(pad, ids.shape[:-1] + (n_max - n_i,))
    return jnp.concatenate([ids, pad], axis=-1)


def _normalize_placed_shard(pk: PackedTensor, bc: int, w_common: int,
                            nb_max: int, n_max: int):
    """Re-window one shard's placed pack onto the common padded geometry.

    The shard packed at its own ``window_block`` (wb_i, the max physical
    span of *its* blocks); the fleet needs every shard at the common stride
    ``w_common = max_i wb_i`` with ``nb_max`` blocks.  The window axis is
    the plane's trailing axis (untouched by bit-packing), so re-windowing
    is a reshape/pad: block j's columns move from ``j*wb_i + t`` to
    ``j*w_common + t``.
    """
    wb_i = pk.window_block
    n_i = pk.col_ids.shape[-1]
    nb_i = n_i // bc
    planes = pk.planes                       # [L?, WB, Kw, nb_i*wb_i]
    pl = planes.reshape(planes.shape[:-1] + (nb_i, wb_i))
    pl = _pad_axis(_pad_axis(pl, -1, w_common), -2, nb_max)
    pl = pl.reshape(planes.shape[:-1] + (nb_max * w_common,))
    j = pk.col_ids // wb_i
    ids = (j * w_common + pk.col_ids - j * wb_i).astype(jnp.int32)
    ids = _pad_col_ids(ids, n_max, bc, w_common)
    scale = _pad_axis(pk.scale, -1, n_max, value=1.0)
    return pl, scale, ids


def pack_linear_sharded(w: jax.Array, n_shards: int, *, n_bits: int = 4,
                        placements: list[Placement | None] | None = None,
                        name: str | None = None, backend: str | None = None,
                        mesh=None, axis: str = "model",
                        ) -> ShardedPackedTensor:
    """Pack one canonical [K, N] / [L, K, N] projection across model shards.

    The N axis splits on the full tensor's window-block boundaries
    (``shard_column_slices``) so every shard owns whole placement windows;
    each shard's slice packs independently — with that shard's own
    ``Placement`` when ``placements`` is given (placed layout; entries are
    looked up under ``name``), logically otherwise — then all shards pad
    to a common per-device shape and stack on the shard axis.
    """
    n = w.shape[-1]
    spans, bc = shard_column_slices(n, n_shards)
    widths = tuple(hi - lo for lo, hi in spans)
    placed = placements is not None
    lead = w.shape[:-2]                          # () or (L,)

    packs: list[PackedTensor | None] = []
    for m, (lo, hi) in enumerate(spans):
        if hi == lo:
            packs.append(None)
            continue
        wi = w[..., lo:hi]
        if placed:
            tp = placements[m].entries[name]
            if tp.block_cols != bc:
                raise ValueError(
                    f"shard {m} placement of {name!r} planned block_cols="
                    f"{tp.block_cols}, the sharded split uses {bc} — plan "
                    "per-shard placements with the forced block width")
            packs.append(_pack_placed(wi, n_bits, tp, backend))
        else:
            packs.append(_pack_stacked(wi, n_bits, backend))

    live = [p for p in packs if p is not None]
    ref = live[0]
    logical_k = ref.logical_k
    kw_words = ref.planes.shape[-2]
    wb = ref.planes.shape[-3]
    n_max = max(widths)

    if placed:
        w_common = max(p.window_block for p in live)
        nb_max = n_max // bc
        region = nb_max * w_common
        norm = []
        pad_ids = jnp.broadcast_to(
            (jnp.arange(n_max, dtype=jnp.int32) // bc) * w_common,
            lead + (n_max,))
        for p in packs:
            if p is None:
                norm.append((jnp.zeros(lead + (wb, kw_words, region),
                                       jnp.uint8),
                             jnp.ones(lead + (n_max,), jnp.float32),
                             pad_ids))
            else:
                norm.append(_normalize_placed_shard(p, bc, w_common,
                                                    nb_max, n_max))
        planes = jnp.stack([t[0] for t in norm], axis=-4)
        scale = jnp.stack([t[1] for t in norm], axis=-2)
        col_ids = jnp.stack([t[2] for t in norm], axis=-2)
    else:
        w_common = None
        planes = jnp.stack(
            [_pad_axis(p.planes, -1, n_max) if p is not None
             else jnp.zeros(lead + (wb, kw_words, n_max), jnp.uint8)
             for p in packs], axis=-4)
        scale = jnp.stack(
            [_pad_axis(p.scale, -1, n_max, value=1.0) if p is not None
             else jnp.ones(lead + (n_max,), jnp.float32)
             for p in packs], axis=-2)
        col_ids = None

    return ShardedPackedTensor(
        planes=planes, scale=scale, col_ids=col_ids, shard_widths=widths,
        block_cols=bc, backend=backend, layout=LAYOUT_BITPACK,
        logical_k=logical_k, window_block=w_common, axis=axis, mesh=mesh)


def pack_model_sharded(params: dict, cfg: PUDGemvConfig = PUDGemvConfig(),
                       *, n_shards: int,
                       placements: list[Placement | None] | None = None,
                       include_unembed: bool = True, mesh=None,
                       axis: str = "model") -> PackedModel:
    """Tensor-parallel ``pack_model``: every pack is a ShardedPackedTensor.

    ``placements`` gives one per-shard ``Placement`` (planned on that
    shard's own calibration masks over its column slice of every request —
    see ``PUDFleetSession.pack``); None packs the logical layout.  The
    returned tree drops fp weights from packed projections exactly like
    ``pack_model``, so the single-device model code serves it unchanged —
    ``pud_linear`` dispatches on the pack type.
    """
    packed_names: list[str] = []
    skipped: list[str] = []

    def one(w, name):
        return pack_linear_sharded(
            w, n_shards, n_bits=cfg.weight_bits, placements=placements,
            name=name, backend=cfg.backend, mesh=mesh, axis=axis)

    def walk(tree, path):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for key, sub in tree.items():
            p = path + (key,)
            if isinstance(sub, dict):
                out[key] = walk(sub, p)
                continue
            if isinstance(sub, jax.Array) and _match(cfg.packable, key, path):
                w = _canonical(key, path, sub)
                if w is not None:
                    name = "/".join(p)
                    out[key + "_pud"] = one(w, name)
                    packed_names.append(name)
                    continue
                skipped.append("/".join(p))
            out[key] = sub
        return out

    packed = walk(params, ())
    if include_unembed and "unembed" in packed:
        w = packed["unembed"].pop("w")
        packed["unembed"]["w_pud"] = one(w, "unembed/w")
        packed_names.append("unembed/w")
    return PackedModel(params=packed,
                       packed_names=tuple(packed_names),
                       skipped_names=tuple(skipped),
                       weight_bits=cfg.weight_bits,
                       placed=placements is not None)


def _pack_any(w, n_bits: int, name: str, placement: Placement | None,
              backend: str | None) -> PackedTensor:
    if placement is None:
        return _pack_stacked(w, n_bits, backend)
    tp = placement.entries.get(name)
    if tp is None:
        raise KeyError(
            f"placement has no entry for packed tensor {name!r}; plan it "
            "from packing_requests() of the same params/config "
            f"(have: {sorted(placement.entries)})")
    return _pack_placed(w, n_bits, tp, backend)


def packing_requests(params: dict, cfg: PUDGemvConfig = PUDGemvConfig(),
                     include_unembed: bool = True) -> list[PlacementRequest]:
    """Column demand of every projection ``pack_for_serving`` would pack.

    Feed this to ``placement.plan_placement`` — the request names match the
    report/placement keys the packer uses.
    """
    reqs: list[PlacementRequest] = []

    def walk(tree, path):
        for key, sub in tree.items():
            p = path + (key,)
            if isinstance(sub, dict):
                walk(sub, p)
            elif (isinstance(sub, jax.Array)
                  and _match(cfg.packable, key, path)):
                w = _canonical(key, path, sub)
                if w is None:
                    continue
                if w.ndim == 2:
                    reqs.append(PlacementRequest("/".join(p), w.shape[1], 0))
                else:
                    reqs.append(PlacementRequest(
                        "/".join(p), w.shape[2], w.shape[0]))

    walk(params, ())
    if include_unembed and "w" in params.get("unembed", {}):
        reqs.append(PlacementRequest(
            "unembed/w", params["unembed"]["w"].shape[1], 0))
    return reqs


def pack_model(params: dict, cfg: PUDGemvConfig = PUDGemvConfig(),
               include_unembed: bool = True,
               placement: Placement | None = None) -> PackedModel:
    """Pack a parameter tree for PUD serving; returns a ``PackedModel``.

    Original fp weights are dropped from packed projections (the bit-planes
    ARE the stored layout).  With ``placement``, every pack is emitted in
    its physical column layout (see ``_pack_placed``); the placement must
    cover exactly the tensors this config packs — build it from
    ``packing_requests(params, cfg)``.
    """
    packed_names: list[str] = []
    skipped: list[str] = []

    def walk(tree, path):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for key, sub in tree.items():
            p = path + (key,)
            if isinstance(sub, dict):
                out[key] = walk(sub, p)
                continue
            if isinstance(sub, jax.Array) and _match(cfg.packable, key, path):
                w = _canonical(key, path, sub)
                if w is not None:
                    name = "/".join(p)
                    out[key + "_pud"] = _pack_any(
                        w, cfg.weight_bits, name, placement, cfg.backend)
                    packed_names.append(name)
                    continue
                skipped.append("/".join(p))
            out[key] = sub
        return out

    packed = walk(params, ())
    if include_unembed and "unembed" in packed:
        w = packed["unembed"].pop("w")
        packed["unembed"]["w_pud"] = _pack_any(
            w, cfg.weight_bits, "unembed/w", placement, cfg.backend)
        packed_names.append("unembed/w")
    return PackedModel(params=packed,
                       packed_names=tuple(packed_names),
                       skipped_names=tuple(skipped),
                       weight_bits=cfg.weight_bits,
                       placed=placement is not None)


def pack_for_serving(params: dict, cfg: PUDGemvConfig = PUDGemvConfig(),
                     include_unembed: bool = True,
                     placement: Placement | None = None) -> tuple[dict, dict]:
    """Legacy entry point: returns (serving params tree, report dict).

    Thin shim over ``pack_model`` — new code should use that (or
    ``PUDSession.pack``, which also owns calibration + placement) and work
    with the typed ``PackedModel`` instead of the loose tuple.
    """
    pm = pack_model(params, cfg, include_unembed=include_unembed,
                    placement=placement)
    return pm.params, pm.report
