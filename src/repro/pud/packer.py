"""Serving-time weight packer: swap model projections onto the PUD path.

``pack_model`` walks a trained/initialized parameter tree and replaces
selected projections with ``PackedTensor`` bit-plane packs (repro/pud/
packed.py), which ``models.layers.linear`` / ``models.attention`` dispatch
to the Pallas bit-plane GeMV.  This is how the paper's technique becomes a
first-class serving feature: any arch config can be served with
``--pud-gemv`` and its projections execute in the (simulated) DRAM layout.
``pack_for_serving`` is the legacy tuple-returning shim over it.

Which projections pack is configured by ``PUDGemvConfig.packable`` — entries
are either a bare key name ("wi") or scoped "component.key" ("mixer.wi",
matching when "mixer" appears on the tree path).  The default covers FFN
wi/wg/wo; add ``ATTN_PACKABLE`` for attention wq/wk/wv/wo, whose 3-D
``[D, H, Dh]`` weights pack as the flattened 2-D ``[D, H*Dh]`` case (the
head split is a view — the GeMV columns are the same either way).  MoE
routed expert banks keep the bf16 path (the expert dim adds a leading axis
the serving kernel does not tile yet).

Packs come out in the *bit-packed* storage layout by default (eight K rows
per uint8 word — ``pud/packed.py`` ``LAYOUT_BITPACK``), so the HBM bytes a
pack occupies finally match the bits the PUD format stores.  Stacked
(scanned) layers pack per-slice: [L, K, N] -> [L, WB, ceil(K/8), N]; under
the layer ``lax.scan`` each iteration sees one [WB, ceil(K/8), N] pack.

With a ``Placement`` (repro/pud/placement.py) the packer emits
*physically-permuted* planes in the block-aligned window layout: each
slice's bit-planes are scattered into the per-N-block physical windows its
logical columns were placed on, then bit-packed, plus the ``col_ids``
gather map the placed kernel consumes.  Faulty physical columns inside a
window hold zeros and are never addressed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .gemv import ATTN_PACKABLE, FFN_PACKABLE, PUDGemvConfig, pack_linear
from .packed import LAYOUT_BITPACK, PackedModel, PackedTensor
from .packed import packed_bytes  # noqa: F401  (legacy import location)
from .placement import Placement, PlacementRequest, TensorPlacement


def _match(packable: tuple[str, ...], key: str, path: tuple[str, ...]) -> bool:
    """Does ``key`` at ``path`` belong to the packable set?

    "scope.name" entries require ``scope`` somewhere on the path; bare
    entries match the key in any context.
    """
    for entry in packable:
        if "." in entry:
            scope, name = entry.rsplit(".", 1)
            if key == name and scope in path:
                return True
        elif key == entry:
            return True
    return False


def _canonical(key: str, path: tuple[str, ...], w: jax.Array):
    """Matched projection -> canonical [K, N] / [L, K, N] view, or None.

    Attention weights carry explicit head axes; the PUD column layout does
    not care about the split, so wq/wk/wv flatten the trailing (H, Dh) axes
    and wo the leading ones.  Everything else (FFN, unembed) must already be
    2-D, optionally with a stacked-layer axis in front.
    """
    if "attn" in path:
        if key in ("wq", "wk", "wv"):
            if w.ndim == 3:       # [D, H, Dh]
                return w.reshape(w.shape[0], -1)
            if w.ndim == 4:       # [L, D, H, Dh]
                return w.reshape(w.shape[0], w.shape[1], -1)
        elif key == "wo":
            if w.ndim == 3:       # [H, Dh, D]
                return w.reshape(-1, w.shape[-1])
            if w.ndim == 4:       # [L, H, Dh, D]
                return w.reshape(w.shape[0], -1, w.shape[-1])
        return None
    if w.ndim in (2, 3):
        return w
    return None


def _pack_stacked(w: jax.Array, n_bits: int,
                  backend: str | None) -> PackedTensor:
    """[L, K, N] (or [K, N]) weights -> stacked ``PackedTensor``."""
    if w.ndim == 2:
        return pack_linear(w, n_bits, backend)
    packs = [pack_linear(w[i], n_bits) for i in range(w.shape[0])]
    return PackedTensor(planes=jnp.stack([p.planes for p in packs]),
                        scale=jnp.stack([p.scale for p in packs]),
                        backend=backend, layout=packs[0].layout,
                        logical_k=packs[0].logical_k)


def _pack_placed(w: jax.Array, n_bits: int, tp: TensorPlacement,
                 backend: str | None) -> PackedTensor:
    """Physically-placed pack: planes scattered into the column window.

    The window is the *block-aligned* layout (repro/pud/placement.py):
    logical N-block j's columns sit inside window slice
    ``[j*tp.window_block, (j+1)*tp.window_block)``, so the placed kernels
    block the window axis per N-tile.  The scatter happens on dense planes
    (the window axis is the column axis, untouched by bit-packing), then
    the whole window bit-packs along K.  Returns a ``PackedTensor`` with
    planes [L?, WB, ceil(K/8), W] uint8 words, scale [L?, N], col_ids
    [L?, N] (absolute window positions) and ``window_block`` aux, where
    W = tp.region_size.
    """
    from repro.kernels.ref import pack_plane_words

    local = np.asarray(tp.local_cols)

    def one(w2, loc):
        pk = pack_linear(w2, n_bits, bitpack=False)
        planes = jnp.zeros(pk.planes.shape[:2] + (tp.region_size,),
                           jnp.int8)
        idx = jnp.asarray(loc, jnp.int32)
        planes = planes.at[:, :, idx].set(pk.planes)
        return PackedTensor(planes=pack_plane_words(planes), scale=pk.scale,
                            col_ids=idx)

    kw = dict(backend=backend, layout=LAYOUT_BITPACK,
              logical_k=w.shape[-2], window_block=tp.window_block)
    if w.ndim == 2:
        return dataclasses.replace(one(w, local), **kw)
    packs = [one(w[i], local[i]) for i in range(w.shape[0])]
    return PackedTensor(
        planes=jnp.stack([p.planes for p in packs]),
        scale=jnp.stack([p.scale for p in packs]),
        col_ids=jnp.stack([p.col_ids for p in packs]),
        **kw)


def _pack_any(w, n_bits: int, name: str, placement: Placement | None,
              backend: str | None) -> PackedTensor:
    if placement is None:
        return _pack_stacked(w, n_bits, backend)
    tp = placement.entries.get(name)
    if tp is None:
        raise KeyError(
            f"placement has no entry for packed tensor {name!r}; plan it "
            "from packing_requests() of the same params/config "
            f"(have: {sorted(placement.entries)})")
    return _pack_placed(w, n_bits, tp, backend)


def packing_requests(params: dict, cfg: PUDGemvConfig = PUDGemvConfig(),
                     include_unembed: bool = True) -> list[PlacementRequest]:
    """Column demand of every projection ``pack_for_serving`` would pack.

    Feed this to ``placement.plan_placement`` — the request names match the
    report/placement keys the packer uses.
    """
    reqs: list[PlacementRequest] = []

    def walk(tree, path):
        for key, sub in tree.items():
            p = path + (key,)
            if isinstance(sub, dict):
                walk(sub, p)
            elif (isinstance(sub, jax.Array)
                  and _match(cfg.packable, key, path)):
                w = _canonical(key, path, sub)
                if w is None:
                    continue
                if w.ndim == 2:
                    reqs.append(PlacementRequest("/".join(p), w.shape[1], 0))
                else:
                    reqs.append(PlacementRequest(
                        "/".join(p), w.shape[2], w.shape[0]))

    walk(params, ())
    if include_unembed and "w" in params.get("unembed", {}):
        reqs.append(PlacementRequest(
            "unembed/w", params["unembed"]["w"].shape[1], 0))
    return reqs


def pack_model(params: dict, cfg: PUDGemvConfig = PUDGemvConfig(),
               include_unembed: bool = True,
               placement: Placement | None = None) -> PackedModel:
    """Pack a parameter tree for PUD serving; returns a ``PackedModel``.

    Original fp weights are dropped from packed projections (the bit-planes
    ARE the stored layout).  With ``placement``, every pack is emitted in
    its physical column layout (see ``_pack_placed``); the placement must
    cover exactly the tensors this config packs — build it from
    ``packing_requests(params, cfg)``.
    """
    packed_names: list[str] = []
    skipped: list[str] = []

    def walk(tree, path):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for key, sub in tree.items():
            p = path + (key,)
            if isinstance(sub, dict):
                out[key] = walk(sub, p)
                continue
            if isinstance(sub, jax.Array) and _match(cfg.packable, key, path):
                w = _canonical(key, path, sub)
                if w is not None:
                    name = "/".join(p)
                    out[key + "_pud"] = _pack_any(
                        w, cfg.weight_bits, name, placement, cfg.backend)
                    packed_names.append(name)
                    continue
                skipped.append("/".join(p))
            out[key] = sub
        return out

    packed = walk(params, ())
    if include_unembed and "unembed" in packed:
        w = packed["unembed"].pop("w")
        packed["unembed"]["w_pud"] = _pack_any(
            w, cfg.weight_bits, "unembed/w", placement, cfg.backend)
        packed_names.append("unembed/w")
    return PackedModel(params=packed,
                       packed_names=tuple(packed_names),
                       skipped_names=tuple(skipped),
                       weight_bits=cfg.weight_bits,
                       placed=placement is not None)


def pack_for_serving(params: dict, cfg: PUDGemvConfig = PUDGemvConfig(),
                     include_unembed: bool = True,
                     placement: Placement | None = None) -> tuple[dict, dict]:
    """Legacy entry point: returns (serving params tree, report dict).

    Thin shim over ``pack_model`` — new code should use that (or
    ``PUDSession.pack``, which also owns calibration + placement) and work
    with the typed ``PackedModel`` instead of the loose tuple.
    """
    pm = pack_model(params, cfg, include_unembed=include_unembed,
                    placement=placement)
    return pm.params, pm.report
