"""PUD GeMV serving path: low-bit linear layers computed "in DRAM".

This is the MVDRAM [4] application layer that PUDTune's calibration makes
viable: serving-time projections of a quantized LLM execute as bit-plane
GeMV over the DRAM subarray's columns, and the usable throughput is set by
the calibrated error-free column fraction (paper Eq. 1).

Two coupled halves:

  * **Numerics** (`pack_linear`, `pud_linear`) — exact low-bit integer GeMV
    via the Pallas bit-plane kernel (kernels/bitplane_gemv.py). The weight
    layout IS the PUD layout: WB bit-planes over columns. On TPU the kernel
    computes it on the MXU; in real PUD the same planes sit in subarray rows.
  * **Performance model** (`PUDPerfModel`) — what a real 4-channel DDR4
    system would sustain for those GeMVs, derived from the bit-serial
    MAC command schedule (mul + add graphs of pud/bitserial.py) priced on
    the DDR4 timing model, scaled by the measured error-free fraction.
    ``speedup_vs_baseline`` is then PUDTune's end-to-end serving claim.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import pud_gemv, pud_matmul_sharded
from repro.kernels.ref import pack_bitplanes, pack_plane_words

from .bitserial import add8_counts, mul8_counts
from .packed import (LAYOUT_BITPACK, PackedTensor, ShardedPackedTensor,
                     as_packed_tensor, packed_bytes)
from .timing import OpCounts, SystemConfig, wave_latency_ns

# Default packable set: FFN projections (dominant decode GeMV flops).
# Entries are "scope.name" (scope = any path component) or a bare name.
FFN_PACKABLE = ("mixer.wi", "mixer.wg", "mixer.wo")
# Attention projections (2-D case: head axes flattened to one column axis).
ATTN_PACKABLE = ("attn.wq", "attn.wk", "attn.wv", "attn.wo")

# Table-I operating points: ECR of the uncalibrated B_{3,0,0} baseline vs
# the calibrated T_{2,1,0} ladder (the paper's headline 1.81x comes from
# the ratio of the error-free fractions these leave).
ECR_BASELINE_B300 = 0.466
ECR_PUDTUNE_T210 = 0.033


@dataclasses.dataclass(frozen=True)
class PUDGemvConfig:
    weight_bits: int = 4
    mode: str = "folded"         # "planes" (faithful) | "folded" (optimized)
    interpret: bool = True       # CPU container; False on real TPU
    # Which projections pack_for_serving swaps onto the PUD path.
    packable: tuple[str, ...] = FFN_PACKABLE
    # Named execution backend (kernels/backends.py); None falls back to the
    # legacy interpret flag ("interpret" when True, "pallas" when False).
    backend: str | None = None


def pack_linear(w: jax.Array, n_bits: int = 4,
                backend: str | None = None,
                bitpack: bool = True) -> PackedTensor:
    """[K, N] float weights -> per-output-channel-quantized bit-planes.

    Returns a ``PackedTensor`` — by default in the *bit-packed* storage
    layout (planes [WB, ceil(K/8), N] uint8 words, eight K rows per byte;
    ``layout="bitpack8"``), the format whose HBM footprint actually matches
    the bits the PUD layout stores.  ``bitpack=False`` keeps the legacy
    dense one-byte-per-bit planes [WB, K, N] int8 in {0,1}; both are
    bit-exact through every kernel entry.  The legacy ``pack["planes"]``
    mapping access still works.  Symmetric per-channel: w ~ scale * q,
    q in [-2^{b-1}, 2^{b-1}).  ``backend`` stamps the pack with the
    execution backend model forwards should dispatch it through.
    """
    qmax = (1 << (n_bits - 1)) - 1
    scale = jnp.maximum(jnp.abs(w).max(axis=0), 1e-8) / qmax       # [N]
    q = jnp.clip(jnp.round(w / scale[None, :]), -qmax - 1, qmax)
    planes = pack_bitplanes(q.astype(jnp.int32), n_bits)
    if not bitpack:
        return PackedTensor(planes=planes, scale=scale.astype(jnp.float32),
                            backend=backend)
    return PackedTensor(planes=pack_plane_words(planes),
                        scale=scale.astype(jnp.float32), backend=backend,
                        layout=LAYOUT_BITPACK, logical_k=w.shape[0])


def pud_linear(x: jax.Array, packed: "PackedTensor | dict",
               cfg: PUDGemvConfig = PUDGemvConfig(),
               backend: str | None = None) -> jax.Array:
    """x: [..., K] float -> [..., N] float32 through the bit-plane GeMV.

    ``packed`` is a ``PackedTensor`` (or a legacy pack dict, coerced).
    The pack's layout metadata (dense vs bit-packed words, placed window
    stride) rides into the kernel dispatch.  Backend resolution: explicit
    ``backend`` arg > ``cfg.backend`` > the backend stamped on the pack
    (how a session's choice reaches model forwards, which call this with
    the default config) > the legacy ``interpret`` flag.
    """
    if isinstance(packed, ShardedPackedTensor):
        lead = x.shape[:-1]
        x2 = x.reshape((-1, x.shape[-1]))
        y = pud_matmul_sharded(x2, packed, mode=cfg.mode,
                               interpret=cfg.interpret,
                               backend=backend or cfg.backend)
        return y.reshape(lead + (y.shape[-1],))
    pt = as_packed_tensor(packed)
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y = pud_gemv(x2, pt.planes, pt.scale,
                 mode=cfg.mode, interpret=cfg.interpret,
                 col_ids=pt.col_ids,
                 backend=backend or cfg.backend or pt.backend,
                 layout=pt.layout, logical_k=pt.logical_k,
                 window_block=pt.window_block, tile_plan=pt.tile_plan)
    return y.reshape(lead + (y.shape[-1],))


def pud_linear_ref(x: jax.Array, w: jax.Array, n_bits: int = 4) -> jax.Array:
    """Oracle: quantize w the same way, do the float matmul on dequantized q."""
    qmax = (1 << (n_bits - 1)) - 1
    scale = jnp.maximum(jnp.abs(w).max(axis=0), 1e-8) / qmax
    q = jnp.clip(jnp.round(w / scale[None, :]), -qmax - 1, qmax)
    from repro.kernels.ops import quantize_activations
    xq, x_scale = quantize_activations(x.reshape((-1, x.shape[-1])))
    y = (xq.astype(jnp.float32) @ q.astype(jnp.float32))
    y = y * x_scale * scale[None, :]
    return y.reshape(x.shape[:-1] + (w.shape[-1],))


# ---------------------------------------------------------------------------
# Weight-byte traffic (the memory side of the serving hot path)
# ---------------------------------------------------------------------------

# Peak weight-staging bandwidth of the paper's 4-channel DDR4-2133 system:
# 8 B/transfer x 2133 MT/s per channel.  Every decoded token streams each
# packed projection once (GeMV is weight-bound), so bytes/token is simply
# the pack's stored footprint — which the bit-packed layout cuts ~8x.
WEIGHT_STAGING_BW_BYTES_S = 4 * 8 * 2133e6


def weight_traffic(packed) -> dict:
    """Per-token weight-traffic terms of a packed serving tree.

    Accepts a ``PackedModel`` or raw serving params (either pack format).
    ``stored_bytes_per_token`` is what the new bit-packed layout actually
    streams; ``dense_equiv_bytes_per_token`` is what the same packs cost in
    the legacy one-byte-per-bit layout; ``traffic_reduction`` is their
    ratio (~8x for bit-packed packs, 1x for dense ones).
    """
    stats = packed_bytes(packed)
    stored = stats["stored_bytes"]
    dense = stats["dense_equiv_bytes"]
    return {
        "stored_bytes_per_token": stored,
        "dense_equiv_bytes_per_token": dense,
        "traffic_reduction": dense / max(1, stored),
        "staging_bound_tok_s": WEIGHT_STAGING_BW_BYTES_S / max(1, stored),
    }


# ---------------------------------------------------------------------------
# DRAM-side performance model (Eq. 1 applied to GeMV).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PUDPerfModel:
    """Sustained GeMV rate of the PUD system for one calibrated device.

    A [K, N] GeMV with b-bit weights and 8-bit activations maps each of the
    K*N MACs onto one column's bit-serial MUL8 + accumulate-ADD8 graphs; the
    65 536-column wave executes error_free_frac*65 536 MACs per sequence.
    """

    error_free_frac: float
    n_fracs: int = 3                  # T_{2,1,0}
    sys: SystemConfig = dataclasses.field(default_factory=SystemConfig)

    @property
    def macs_per_second(self) -> float:
        mac_counts = mul8_counts(self.n_fracs) + add8_counts(self.n_fracs)
        lat_s = wave_latency_ns(mac_counts, self.sys) * 1e-9
        cols = self.error_free_frac * self.sys.n_cols_per_subarray
        return cols * self.sys.n_banks_parallel * self.sys.n_channels / lat_s

    def gemv_latency_s(self, k: int, n: int) -> float:
        return (k * n) / self.macs_per_second

    def tokens_per_second(self, flops_per_token: float) -> float:
        """flops_per_token = 2 * active params (one MAC = 2 flops)."""
        return self.macs_per_second / (flops_per_token / 2.0)

    def speedup_vs(self, baseline: "PUDPerfModel") -> float:
        return self.macs_per_second / baseline.macs_per_second

    def step_seconds(self, flops_per_token: float, batch: int = 1) -> float:
        """Modeled wall seconds of one batched decode wave (``batch``
        tokens emitted per step; no batching gain on a single device)."""
        return max(1, int(batch)) / self.tokens_per_second(flops_per_token)


@dataclasses.dataclass(frozen=True)
class FleetPerfModel:
    """Serving-rate model for a whole calibrated device grid.

    Built from the per-subarray ECR distribution of a persisted calibration
    table (runtime/calib_cache.py) rather than a single point estimate: the
    sustained rate prices waves rotating uniformly over the grid (mean
    error-free fraction), and the distribution bounds what a worst-case
    subarray placement would cost.

    The batched extension models multi-request (continuous-batching) decode:

      * **Replication** — a placement that occupies ``occupied_subarrays``
        of ``total_subarrays`` leaves idle subarrays that can hold replicas
        of the same placed weights; up to ``n_replicas`` requests execute
        fully in parallel.
      * **Operand amortization** — within one replica, the weight bit
        columns are static across the batch, so the weight-side staging row
        copies of each MAC's MUL8 partial-product ops are paid once per
        wave instead of once per request; only operand staging + the MAJ
        graph itself scale with the per-replica batch.
      * **Operand residency** — a subarray stages at most ``operand_slots``
        operand vectors per wave; past ``n_replicas * operand_slots``
        requests serialize into extra wave groups and aggregate throughput
        stops improving.  That bound is the occupancy-derived optimal
        batch size (``optimal_batch_size``).
    """

    error_free_fracs: tuple[float, ...]      # per subarray
    n_fracs: int = 3
    sys: SystemConfig = dataclasses.field(default_factory=SystemConfig)
    # Batched-serving shape of the device: how many copies of the placed
    # weights fit (from placement occupancy), and how many operand vectors
    # a subarray can stage per wave.
    occupied_subarrays: int | None = None
    total_subarrays: int | None = None
    operand_slots: int = 4

    @classmethod
    def from_table(cls, ecr_per_subarray, n_fracs: int = 3,
                   sys: SystemConfig | None = None) -> "FleetPerfModel":
        fracs = tuple(float(1.0 - e) for e in ecr_per_subarray)
        return cls(error_free_fracs=fracs, n_fracs=n_fracs,
                   sys=sys or SystemConfig())

    @classmethod
    def from_placement(cls, placement, n_fracs: int = 3,
                       sys: SystemConfig | None = None) -> "FleetPerfModel":
        """Rate from the *actual* column placement, not a mean fraction.

        Waves rotate over the subarrays the placement occupies; each wave
        executes exactly the columns placed there (repro/pud/placement.py),
        so the per-wave usable fraction is used/total per occupied
        subarray rather than the device-mean error-free fraction.
        """
        used = np.asarray(placement.used_per_subarray, np.float64)
        occupied = used[used > 0]
        if occupied.size == 0:
            raise ValueError("placement occupies no subarray")
        fracs = tuple(float(u / placement.n_cols_per_subarray)
                      for u in occupied)
        return cls(error_free_fracs=fracs, n_fracs=n_fracs,
                   sys=sys or SystemConfig(),
                   occupied_subarrays=int(occupied.size),
                   total_subarrays=int(placement.n_subarrays))

    def _point(self, frac: float) -> PUDPerfModel:
        return PUDPerfModel(error_free_frac=frac, n_fracs=self.n_fracs,
                            sys=self.sys)

    @property
    def mean_error_free_frac(self) -> float:
        return sum(self.error_free_fracs) / len(self.error_free_fracs)

    @property
    def macs_per_second(self) -> float:
        return self._point(self.mean_error_free_frac).macs_per_second

    @property
    def worst_subarray_macs_per_second(self) -> float:
        return self._point(min(self.error_free_fracs)).macs_per_second

    def tokens_per_second(self, flops_per_token: float) -> float:
        return self.macs_per_second / (flops_per_token / 2.0)

    def speedup_vs(self, baseline: "PUDPerfModel | FleetPerfModel") -> float:
        return self.macs_per_second / baseline.macs_per_second

    # -- weight-byte traffic ------------------------------------------------

    def staging_bound_tokens_per_second(self, weight_bytes: float) -> float:
        """Weight-staging bandwidth ceiling: each decoded token restages
        every packed projection's stored bytes once, so the DDR4 channels
        bound decode at BW / bytes-per-token.  With the bit-packed plane
        layout ``weight_bytes`` is ~8x smaller than the legacy dense
        layout's, which lifts this ceiling 8x (see ``weight_traffic``)."""
        return WEIGHT_STAGING_BW_BYTES_S / max(1.0, float(weight_bytes))

    def traffic_aware_tokens_per_second(self, flops_per_token: float,
                                        weight_bytes: float) -> float:
        """Sustained decode rate under both limits: the Eq.-1 compute rate
        and the weight-staging bandwidth bound."""
        return min(self.tokens_per_second(flops_per_token),
                   self.staging_bound_tokens_per_second(weight_bytes))

    # -- batched serving ----------------------------------------------------

    @property
    def n_replicas(self) -> int:
        """Independent weight copies the grid can hold in parallel."""
        if self.occupied_subarrays and self.total_subarrays:
            return max(1, self.total_subarrays // self.occupied_subarrays)
        return 1

    def _mac_counts_split(self) -> tuple[OpCounts, OpCounts]:
        """(shared, per-operand) command counts of one MAC's MUL8+ADD8 graph.

        Shared across a batched wave: the weight-bit constant copy of each
        of the 72 AND/OR partial-product ops (the weight columns are static
        for the whole batch).  Everything else — operand staging, calib-row
        copies, Fracs, SiMRAs — executes once per in-flight request.
        """
        total = mul8_counts(self.n_fracs) + add8_counts(self.n_fracs)
        n_andor = sum(2 * (8 - j) for j in range(8))
        shared = OpCounts(rowcopies=n_andor)
        per_op = OpCounts(rowcopies=total.rowcopies - n_andor,
                          fracs=total.fracs, simras=total.simras)
        return shared, per_op

    def batch_speedup(self, batch: int) -> float:
        """Aggregate-throughput gain of serving ``batch`` requests vs one.

        Strictly increasing up to ``optimal_batch_size()`` (replication is
        linear, amortization sub-linear), flat beyond it (operand residency
        exhausted: extra requests serialize into additional wave groups).
        """
        b = max(1, int(batch))
        b_eff = min(b, self.optimal_batch_size())
        active = min(self.n_replicas, b_eff)
        per_rep = b_eff / active
        shared, per_op = self._mac_counts_split()
        lat1 = wave_latency_ns(shared + per_op, self.sys)
        lat_b = wave_latency_ns(shared + per_rep * per_op, self.sys)
        return b_eff * lat1 / lat_b

    def batched_macs_per_second(self, batch: int) -> float:
        return self.macs_per_second * self.batch_speedup(batch)

    def batched_tokens_per_second(self, flops_per_token: float,
                                  batch: int) -> float:
        """Aggregate decode rate (all requests summed) at ``batch``."""
        return self.batched_macs_per_second(batch) / (flops_per_token / 2.0)

    def optimal_batch_size(self, max_batch: int | None = None) -> int:
        """Occupancy-derived optimum: replicas x per-subarray operand slots.

        Aggregate tokens/s increases monotonically up to this batch and is
        flat beyond it, so it is the smallest batch reaching peak rate.
        """
        opt = self.n_replicas * self.operand_slots
        return min(opt, max_batch) if max_batch else opt

    def step_seconds(self, flops_per_token: float, batch: int = 1) -> float:
        """Modeled wall seconds of one batched decode wave: the engine's
        SLO admission prices a step as ``batch`` tokens at the batched
        aggregate rate (runtime/engine.py's virtual clock)."""
        b = max(1, int(batch))
        return b / self.batched_tokens_per_second(flops_per_token, b)


@dataclasses.dataclass(frozen=True)
class FleetPerfAggregate:
    """Cross-shard serving-rate model of a sharded mesh deployment.

    ``shards`` are the per-model-shard :class:`FleetPerfModel`s (one per
    "model"-axis device — each built from that device's own calibration
    table/placement); ``n_data`` counts the data-parallel engine lanes.

    A decoded token needs *every* model shard's partial GEMM, so the
    per-lane token rate is bound by the slowest shard evaluated at the
    slowest shard's work share: with the N axis split on window-block
    boundaries the largest shard owns ``shard_fraction`` of the columns
    (> 1/S when the block count does not divide the shard count — the
    padding/imbalance cost the scaling-efficiency column measures).
    Aggregate throughput then scales linearly with the independent data
    lanes.
    """

    shards: tuple[FleetPerfModel, ...]
    n_data: int = 1
    shard_widths: tuple[int, ...] | None = None   # logical columns per shard

    @property
    def n_model(self) -> int:
        return len(self.shards)

    @property
    def n_devices(self) -> int:
        return self.n_model * self.n_data

    @property
    def shard_fraction(self) -> float:
        """Work share of the slowest (widest) model shard."""
        if self.shard_widths:
            total = sum(self.shard_widths)
            return max(self.shard_widths) / max(1, total)
        return 1.0 / self.n_model

    def _working_shards(self):
        """Shards that own columns — a zero-width shard (more shards than
        window blocks) executes no GEMM work and never bounds the lane."""
        if self.shard_widths:
            live = [m for m, w in zip(self.shards, self.shard_widths) if w]
            if live:
                return live
        return list(self.shards)

    def tokens_per_second(self, flops_per_token: float) -> float:
        lane = min(m.tokens_per_second(flops_per_token * self.shard_fraction)
                   for m in self._working_shards())
        return self.n_data * lane

    def batched_tokens_per_second(self, flops_per_token: float,
                                  batch: int) -> float:
        """Aggregate decode rate across all lanes at per-lane ``batch``."""
        lane = min(
            m.batched_tokens_per_second(
                flops_per_token * self.shard_fraction, batch)
            for m in self._working_shards())
        return self.n_data * lane

    def step_seconds(self, flops_per_token: float, batch: int = 1) -> float:
        """Modeled seconds of one decode wave on a single lane (the slowest
        shard bounds it; lanes step independently)."""
        b = max(1, int(batch))
        per_lane = self.batched_tokens_per_second(flops_per_token, b) \
            / self.n_data
        return b / per_lane

    def scaling_efficiency(self, flops_per_token: float,
                           batch: int = 1) -> float:
        """Aggregate rate vs ``n_devices`` ideal copies of shard 0 alone."""
        single = self.shards[0].batched_tokens_per_second(
            flops_per_token, batch)
        agg = self.batched_tokens_per_second(flops_per_token, batch)
        return agg / (self.n_devices * single)

    def report(self, flops_per_token: float, batch: int = 1) -> dict:
        return {
            "n_model": self.n_model,
            "n_data": self.n_data,
            "shard_fraction": self.shard_fraction,
            "agg_tok_s": self.batched_tokens_per_second(
                flops_per_token, batch),
            "scaling_efficiency": self.scaling_efficiency(
                flops_per_token, batch),
        }
