"""Analog physics of Processing-Using-DRAM (PUD).

Implements the capacitance/charge-sharing model the paper itself uses in
Sec. II-C: a cell capacitor C_cell = 30 fF sharing charge with a bitline
C_bitline = 270 fF.  A single-row activation of a fully charged cell yields

    V = (1 * 30 + 0.5 * 270) / (30 + 270) = 0.55 V_DD

and an 8-row SiMRA of a MAJ5(1,1,1,0,0) pattern with three neutral rows yields

    V = ((3 + 1.5) * 30 + 0.5 * 270) / (8 * 30 + 270) = 0.5294 V_DD

— both numbers quoted in the paper, which this module reproduces exactly
(`test_pud_device.py::test_paper_voltage_examples`).

Noise model (fitted once to the paper's baseline operating point, see
``repro.core.fit``):
  * ``sigma_static``   — per-column sense-amp threshold deviation (process
    variation), the error source the paper attributes errors to (Sec. II-C).
  * ``sigma_dynamic``  — per-sensing thermal/electrical noise.
  * ``sigma_frac``     — per-Frac charge placement variation (each Frac is a
    violated-timing partial restore; repeated Fracs accumulate placement error).
  * ``sigma_transfer`` — charge-sharing non-ideality proportional to the charge
    actually moved; rows at full swing perturb the bitline more than rows
    already near neutral.  (This is what makes T_{0,0,0}'s three full-swing
    rows slightly noisier than T_{2,1,0}'s partially discharged rows.)

Single-row ACT / RowCopy sensing is modeled reliable: with normal (JEDEC)
timing the sense amp has the full 0.05 V_DD margin and its offset is
compensated by the longer amplification window.  Only violated-timing SiMRA
sensing sees the offset + noise — matching the paper's attribution of errors
to "the precise charge sharing process required for MAJX".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEUTRAL = 0.5  # precharge / neutral charge level, in V_DD units


@dataclasses.dataclass(frozen=True)
class PhysicsParams:
    """Device physics constants. Defaults are fitted (see repro/core/fit.py)."""

    c_cell_ff: float = 30.0
    c_bitline_ff: float = 270.0
    n_simra_rows: int = 8
    # Per-Frac geometric convergence toward neutral. Fitted 0.4184 (ideal
    # halving would be 0.5); FracDRAM reports 6-10 Fracs to reach neutral,
    # consistent: 0.5 * 0.4184^6 = 0.003 of full charge left.
    frac_alpha: float = 0.418438
    # --- fitted noise constants (V_DD units), see repro/core/fit.py ---
    sigma_static: float = 0.033281    # sense threshold process variation
    sigma_dynamic: float = 0.001315   # base per-sensing noise
    sigma_frac: float = 0.000024      # per applied Frac, at the bitline
    sigma_transfer: float = 0.000400  # per unit of squared row swing
    # --- reliability drift (Sec. IV-B.3) ---
    # Calibrated to the paper's Fig.-6 envelope (new ECR < 0.14 % over
    # 40-100 C, < 0.27 % over one week): the measured drift of calibrated
    # columns is tiny, so the per-degC / per-sqrt(day) threshold drift must
    # stay well inside the T210 margin slack.  Note the model also carries a
    # ~0.5-0.7 % re-measurement churn floor the silicon does not show
    # (EXPERIMENTS.md §Paper, Fig. 6 discussion).
    temp_nominal_c: float = 50.0
    sigma_temp_drift: float = 0.00002   # threshold drift stddev per degC
    sigma_time_drift: float = 0.00012   # threshold drift stddev per sqrt(day)

    def c_total_ff(self, k_rows: int) -> float:
        return k_rows * self.c_cell_ff + self.c_bitline_ff

    def bitline_voltage(self, charge_sum: jax.Array, k_rows: int) -> jax.Array:
        """Charge-sharing voltage for ``k_rows`` simultaneously opened rows.

        charge_sum: sum of the cell charges (V_DD units) of the opened rows.
        """
        num = charge_sum * self.c_cell_ff + NEUTRAL * self.c_bitline_ff
        return num / self.c_total_ff(k_rows)

    @property
    def cell_weight(self) -> float:
        """Bitline voltage shift per unit of cell charge in an 8-row SiMRA."""
        return self.c_cell_ff / self.c_total_ff(self.n_simra_rows)

    @property
    def maj_margin(self) -> float:
        """|V - 0.5| for the closest MAJ5 patterns (3-of-5 vs 2-of-5).

        (k + 1.5 + 0.5) either side of 4.0 total charge => +-0.5 cell units.
        """
        return 0.5 * self.cell_weight

    def frac_charge(self, bit: jax.Array, n_frac: jax.Array) -> jax.Array:
        """Cell charge after ``n_frac`` Frac ops applied to a stored bit."""
        return NEUTRAL + (bit - NEUTRAL) * self.frac_alpha ** n_frac

    def sensing_sigma(
        self, n_fracs_total: jax.Array, sum_swing_sq: jax.Array
    ) -> jax.Array:
        """Effective dynamic noise std of one SiMRA sensing.

        n_fracs_total: Frac ops applied in this MAJX execution (charge
          placement error accumulates per Frac).
        sum_swing_sq:  sum over opened rows of (2*(q - 0.5))^2 — the charge
          transfer non-ideality term.
        """
        var = (
            self.sigma_dynamic**2
            + self.sigma_frac**2 * n_fracs_total
            + self.sigma_transfer**2 * sum_swing_sq
        )
        return jnp.sqrt(var)


def sense(
    v_bitline: jax.Array,
    threshold_offset: jax.Array,
    noise_sigma: jax.Array | float,
    key: jax.Array,
) -> jax.Array:
    """Sense-amplifier decision: 1 iff V + noise > 0.5 + per-column offset."""
    eps = noise_sigma * jax.random.normal(key, v_bitline.shape, dtype=jnp.float32)
    return (v_bitline + eps > NEUTRAL + threshold_offset).astype(jnp.float32)
