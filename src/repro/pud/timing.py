"""DDR4 command-level latency model for PUD operation sequences.

The paper (Sec. IV-A) derives throughput for a 4-channel DDR4-2133 system with
16-bank-parallel PUD "under ACT power constraints".  The binding constraint at
that parallelism is tFAW: at most 4 ACTs per rolling tFAW window per rank, so a
wave of 16 banks each issuing an n-ACT operation sequence takes

    t_wave = max( 16 * n_act * tFAW / 4 ,  per-bank serial time )

and for every sequence of interest the power term dominates.  One global
``controller_overhead`` multiplier absorbs command-bus, tRCD/tWR recovery and
DRAM-Bender scheduling slack; it is calibrated ONCE against the paper's
baseline MAJ5 operating point (B_{3,0,0} = 0.89 TOPS at 46.6 % ECR) and then
every other latency (ADD8, MUL8, other T_{x,y,z}) is *derived* from command
counts — the ratios reported in EXPERIMENTS.md are model outputs, not fits.

ACT counts per PUD primitive (ComputeDRAM/FracDRAM command sequences):
  RowCopy (AAP)   : ACT -> PRE -> ACT            = 2 ACTs
  Frac            : ACT -> early PRE             = 1 ACT
  SiMRA (APA)     : ACT -> PRE -> ACT (glitch)   = 2 ACTs
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DDR4Timing:
    """DDR4-2133 (JEDEC) timing, ns."""

    tck_ns: float = 0.9375
    tras_ns: float = 33.0
    trp_ns: float = 13.2
    trcd_ns: float = 13.2
    trrd_s_ns: float = 3.7
    tfaw_ns: float = 25.0
    # Calibrated once against the paper's B_{3,0,0} MAJ5 throughput
    # (0.89 TOPS at 46.6% ECR -> 2.52 us wave latency for the 19-ACT
    # standalone MAJ5). Covers command bus + controller slack.
    controller_overhead: float = 1.325

    @property
    def trc_ns(self) -> float:
        return self.tras_ns + self.trp_ns

    @property
    def act_rate_ns(self) -> float:
        """Minimum average spacing between ACTs under the tFAW power window."""
        return self.tfaw_ns / 4.0


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """The paper's evaluation system (Sec. IV-A)."""

    n_channels: int = 4
    n_banks_parallel: int = 16
    n_cols_per_subarray: int = 65536
    timing: DDR4Timing = dataclasses.field(default_factory=DDR4Timing)


@dataclasses.dataclass(frozen=True)
class OpCounts:
    """Command counts of one PUD operation sequence (per bank)."""

    rowcopies: int = 0
    fracs: int = 0
    simras: int = 0

    @property
    def acts(self) -> int:
        return 2 * self.rowcopies + self.fracs + 2 * self.simras

    def __add__(self, other: "OpCounts") -> "OpCounts":
        return OpCounts(
            self.rowcopies + other.rowcopies,
            self.fracs + other.fracs,
            self.simras + other.simras,
        )

    def __mul__(self, k: int) -> "OpCounts":
        return OpCounts(self.rowcopies * k, self.fracs * k, self.simras * k)

    __rmul__ = __mul__


def maj5_counts(frac_counts: tuple[int, int, int]) -> OpCounts:
    """MAJ5 with PUDTune/baseline non-operand rows (Fig. 1 flow).

    RowCopies: operands a, b, c (3; carry-in reuse is *not* assumed here),
    one AAP copy driving the duplicated operand pair (MAJ5 uses the
    not-carry twice -> 1 copy to 2 rows), and 3 non-operand-row copies
    (calibration data or neutral+constants — identical count for baseline
    and PUDTune). One SiMRA; Frac count = sum of the row configuration.
    """
    return OpCounts(rowcopies=3 + 1 + 3, fracs=sum(frac_counts), simras=1)


def maj3_counts(frac_counts: tuple[int, int, int]) -> OpCounts:
    """MAJ3 with 8-row SiMRA: 3 operand copies, the 0/1 constant pair
    (2 copies), 3 calibration/neutral copies, one SiMRA."""
    return OpCounts(rowcopies=3 + 2 + 3, fracs=sum(frac_counts), simras=1)


def wave_latency_ns(counts: OpCounts, sys: SystemConfig) -> float:
    """Latency for all ``n_banks_parallel`` banks to finish one op sequence.

    Power-limited term: total ACTs across banks spaced by tFAW/4.
    Serial term: one bank's sequence at tRC per ACT-pair (never binding at
    16-bank parallelism, kept for small-bank configs).
    """
    t = sys.timing
    power_ns = counts.acts * sys.n_banks_parallel * t.act_rate_ns
    serial_ns = (
        counts.rowcopies * (t.tras_ns + t.trp_ns + 2 * t.tck_ns)
        + counts.fracs * (0.45 * t.tras_ns + t.trp_ns)
        + counts.simras * (t.tras_ns + t.trp_ns + 2 * t.tck_ns)
    )
    return max(power_ns, serial_ns) * t.controller_overhead


def throughput_ops(
    counts: OpCounts, error_free_cols: float, sys: SystemConfig
) -> float:
    """Paper Eq. 1, generalized: ops/s for the full 4-channel system."""
    lat_s = wave_latency_ns(counts, sys) * 1e-9
    return error_free_cols * sys.n_banks_parallel * sys.n_channels / lat_s
