"""Kernel contract checker: verify bit-plane kernel invariants statically.

Given (shape, layout, window structure) — *without executing a kernel* —
recompute everything the four bit-plane entry points (gemv/gemm ×
logical/placed) derive at trace time and verify the invariants they assume:

  * **tile selection** — the divisor-based block sizes and grid the kernel
    wrappers will pick (same ``largest_divisor`` rule, same caps: the
    constants are imported from ``kernels.ops``, so the checker cannot
    drift from the kernels);
  * **bitpack8 metadata** — ``logical_k`` consistent with the activation K
    and the stored word count (``Kw == ceil(K/8)``, the ``pack_plane_words``
    guarantee);
  * **placed windows** — ``window_block`` tiles the physical window, each
    window block has capacity for its logical block, and (when values are
    available) every ``col_ids`` entry lands statically inside its block's
    window slice;
  * **VMEM budget** — the per-grid-step footprint derived from the
    BlockSpecs (streamed blocks double-buffered + compute transients) stays
    under :data:`VMEM_BUDGET_BYTES`.  This is the check that outlaws the
    pre-block-alignment "whole window per K-tile" layout.

Violations raise :class:`ContractViolation` naming the kernel, the failed
invariant, and (where it localizes) the tile.  The kernels raise the same
error type from their own runtime checks; this module is the superset that
runs before any array exists.

Integration points: ``kernels.ops.pud_matmul(check_contracts=True)`` is the
opt-in pre-flight; the ``interpret`` backend (kernels/backends.py) runs the
check unconditionally; ``python -m repro.analysis`` sweeps
:func:`default_matrix` plus :func:`adversarial_fixtures` as the CI gate.

The per-grid-step VMEM budget table in docs/kernels.md is *generated* from
this module (``python -m repro.analysis --write-docs``) so the doc math can
never drift from the code again.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.kernels.ops import (B_BLOCK, K_BLOCK, N_BLOCK, heuristic_block,
                               largest_divisor)

from .errors import ContractViolation

LAYOUTS = ("dense", "bitpack8")
ENTRIES = ("gemv", "gemm")

#: Per-grid-step footprint cap: streamed blocks (double-buffered) plus
#: compute transients must fit well inside one TPU core's ~16 MiB VMEM,
#: leaving headroom for the pipeline and scalar state.  Every shipped
#: config sits 2-3 orders of magnitude below this; what it outlaws is the
#: degenerate whole-window placed layout (a fleet-sized window dragged into
#: VMEM per K-tile — the exact bug the block-aligned layout removed).
VMEM_BUDGET_BYTES = 4 * 1024 * 1024

_KERNEL_NAMES = {
    ("gemv", False): "bitplane_gemv",
    ("gemv", True): "bitplane_gemv_placed",
    ("gemm", False): "bitplane_gemm",
    ("gemm", True): "bitplane_gemm_placed",
}


@dataclasses.dataclass(frozen=True)
class KernelCall:
    """Static description of one kernel invocation (shapes only, no arrays).

    ``plane_k`` is the planes' K-axis extent as stored: the word count
    ``Kw`` for ``layout="bitpack8"``, the row count ``K`` for dense.
    ``window`` is the physical window length of a placed call (None =
    logical layout); ``window_block`` follows the kernel convention
    (None = whole window as a single block, the hand-built-pack
    degenerate case).
    """

    entry: str                     # "gemv" | "gemm"
    b: int                         # activation rows
    k: int                         # activation (logical) reduction length
    n: int                         # logical output columns
    wb: int = 4                    # bit-planes
    layout: str = "dense"
    plane_k: int | None = None     # planes.shape[-2]; default: derived
    logical_k: int | None = None   # bitpack8 pack metadata
    window: int | None = None      # physical window length W (placed)
    window_block: int | None = None
    mode: str = "folded"
    # Tuned tile overrides (kernels/autotune.py); None = the divisor
    # heuristic the wrappers default to.
    b_block: int | None = None
    n_block: int | None = None
    k_block: int | None = None

    @property
    def placed(self) -> bool:
        return self.window is not None

    @property
    def kernel(self) -> str:
        return _KERNEL_NAMES[(self.entry, self.placed)]

    def resolved_plane_k(self) -> int:
        if self.plane_k is not None:
            return self.plane_k
        return -(-self.k // 8) if self.layout == "bitpack8" else self.k


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """The derived execution plan: what the kernel wrapper will build.

    ``vmem`` maps operand/transient names to their per-grid-step bytes;
    ``grid`` matches the wrapper's ``pallas_call`` grid exactly
    ((N/Nb, K-steps) for GeMV, (B/Bb, N/Nb, K-steps) for GEMM).
    """

    kernel: str
    grid: tuple[int, ...]
    bb: int                        # batch rows per block
    nb: int                        # output columns per block
    x_kb: int                      # activation K rows per block
    plane_kb: int                  # plane K rows (or words) per block
    block_cols: int                # logical columns per window block
    window_block: int | None       # resolved window stride (placed only)
    vmem: dict[str, int]

    @property
    def streamed_bytes(self) -> int:
        return (self.vmem["x"] + self.vmem["planes"]
                + self.vmem.get("col_ids", 0))

    @property
    def vmem_total_bytes(self) -> int:
        """Budget-relevant total: streamed blocks double-buffered, the
        resident output accumulator and compute transients once."""
        return (2 * self.streamed_bytes + self.vmem["out"]
                + self.vmem.get("transient", 0))


def _k_plan(call: KernelCall) -> tuple[int, int, int]:
    """Replicates ``bitplane_gemv._k_tiling``: (plane_kb, x_kb, k_steps).

    ``k_steps`` counts padded grid steps — an explicit ``k_block`` (or the
    degenerate-tile pow2 fallback) pads the reduction axis with zeros, which
    contribute nothing to the integer dot products.
    """
    kernel = call.kernel
    if call.layout == "bitpack8":
        kw = call.resolved_plane_k()
        if (call.logical_k or kw * 8) != call.k or call.k > kw * 8:
            raise ContractViolation(
                kernel, "bitpack8-logical-k",
                f"x K={call.k} inconsistent with word planes Kw={kw} "
                f"(logical_k={call.logical_k})")
        if kw != -(-call.k // 8):
            raise ContractViolation(
                kernel, "bitpack8-word-count",
                f"stored word count Kw={kw} != ceil(K/8)="
                f"{-(-call.k // 8)} for K={call.k} — the pack was not "
                "built by pack_plane_words")
        if call.k_block is not None:
            if call.k_block <= 0 or call.k_block % 8:
                raise ContractViolation(
                    kernel, "tile-plan",
                    f"bitpack8 k_block {call.k_block} must be a positive "
                    "multiple of 8 (whole word rows)")
            kwb = call.k_block // 8
        else:
            kwb = heuristic_block(kw, K_BLOCK // 8)
        return kwb, kwb * 8, -(-kw // kwb)
    if call.layout != "dense":
        raise ContractViolation(
            kernel, "layout",
            f"unknown plane layout {call.layout!r}; one of {LAYOUTS}")
    if call.resolved_plane_k() != call.k:
        raise ContractViolation(
            kernel, "k-mismatch",
            f"x K={call.k} vs planes K={call.resolved_plane_k()}")
    if call.k_block is not None:
        if call.k_block <= 0:
            raise ContractViolation(
                kernel, "tile-plan",
                f"k_block {call.k_block} must be positive")
        kb = call.k_block
    else:
        kb = heuristic_block(call.k, K_BLOCK)
    return kb, kb, -(-call.k // kb)


def _n_plan(call: KernelCall) -> tuple[int, int, int | None, int]:
    """Replicates the wrappers' N/window tiling:
    (nb, block_cols, pwb, n_pad)."""
    kernel = call.kernel
    if not call.placed:
        if call.n_block is not None:
            if call.n_block <= 0:
                raise ContractViolation(
                    kernel, "tile-plan",
                    f"n_block {call.n_block} must be positive")
            nb = call.n_block
        else:
            nb = heuristic_block(call.n, N_BLOCK)
        return nb, call.n, None, -(-call.n // nb) * nb
    w_len = call.window
    pwb = call.window_block or w_len
    if pwb <= 0 or w_len % pwb or call.n % (w_len // pwb):
        raise ContractViolation(
            kernel, "window-tiling",
            f"window length {w_len} / window_block {pwb} does not tile "
            f"N={call.n}")
    n_blocks = w_len // pwb
    block_cols = call.n // n_blocks
    if block_cols > pwb:
        raise ContractViolation(
            kernel, "window-capacity",
            f"window_block {pwb} cannot hold {block_cols} logical columns "
            f"per block ({n_blocks} blocks for N={call.n})")
    if call.n_block is not None:
        if call.n_block <= 0 or block_cols % call.n_block:
            raise ContractViolation(
                kernel, "tile-plan",
                f"placed n_block {call.n_block} must divide the "
                f"{block_cols} logical columns per window block")
        nb = call.n_block
    else:
        nb = largest_divisor(block_cols, N_BLOCK)
    return nb, block_cols, pwb, call.n


def plan_kernel(call: KernelCall) -> TilePlan:
    """Recompute the tile plan of ``call`` and verify its invariants.

    Raises :class:`ContractViolation` on the first violated invariant;
    otherwise returns the :class:`TilePlan` the kernel wrapper will
    materialize (same divisor rule, caps, and grid construction).
    """
    if call.entry not in ENTRIES:
        raise ContractViolation(
            call.kernel, "entry", f"unknown entry {call.entry!r}")
    if min(call.b, call.k, call.n, call.wb) < 1:
        raise ContractViolation(
            call.kernel, "shape",
            f"non-positive dimension in B={call.b} K={call.k} N={call.n} "
            f"WB={call.wb}")
    plane_kb, x_kb, k_steps = _k_plan(call)
    nb, block_cols, pwb, n_pad = _n_plan(call)

    if call.entry == "gemm":
        if call.b_block is not None and call.b_block <= 0:
            raise ContractViolation(
                call.kernel, "tile-plan",
                f"b_block {call.b_block} must be positive")
        bb = (min(call.b_block, call.b) if call.b_block is not None
              else min(call.b, B_BLOCK))
        bp = -(-call.b // bb) * bb                    # zero-row batch pad
        grid: tuple[int, ...] = (bp // bb, n_pad // nb, k_steps)
    else:
        if call.b_block is not None:
            raise ContractViolation(
                call.kernel, "tile-plan",
                "b_block override is meaningless for the gemv entry — it "
                "keeps the whole batch in one block")
        bb = call.b                                   # whole batch, one block
        grid = (n_pad // nb, k_steps)

    # Internal consistency of the recomputation itself: the grid must tile
    # the (padded) operands exactly — block selection guarantees it, so a
    # failure here means the checker no longer matches the kernels.
    padded_k = plane_kb * k_steps * (8 if call.layout == "bitpack8" else 1)
    if x_kb * k_steps != padded_k or grid[-2] * nb != n_pad:
        raise ContractViolation(
            call.kernel, "tile-selection",
            f"recomputed tiling does not cover the operand: grid {grid}, "
            f"nb={nb}, x_kb={x_kb}, k_steps={k_steps}")

    plane_cols = pwb if call.placed else nb
    vmem = {
        "x": bb * x_kb,                               # int8
        "planes": call.wb * plane_kb * plane_cols,    # int8/uint8 words
        "out": 4 * bb * nb,                           # int32 accumulator
    }
    if call.placed:
        vmem["col_ids"] = 4 * nb
    transient = 0
    if call.layout == "bitpack8":
        transient += call.wb * x_kb * nb              # in-VMEM unpacked tile
    if call.mode == "folded":
        transient += 4 * x_kb * nb                    # folded int32 weights
    else:
        transient += 4 * bb * nb                      # shifted plane partial
    if transient:
        vmem["transient"] = transient

    plan = TilePlan(kernel=call.kernel, grid=grid, bb=bb, nb=nb, x_kb=x_kb,
                    plane_kb=plane_kb, block_cols=block_cols,
                    window_block=pwb, vmem=vmem)
    if plan.vmem_total_bytes > VMEM_BUDGET_BYTES:
        raise ContractViolation(
            call.kernel, "vmem-budget",
            f"per-grid-step footprint {plan.vmem_total_bytes} B exceeds "
            f"the {VMEM_BUDGET_BYTES} B budget (blocks: {vmem})")
    return plan


def check_col_ids(col_ids, n: int, window: int, window_block: int | None,
                  block_cols: int, kernel: str) -> None:
    """Verify a concrete ``col_ids`` map against the block-aligned layout.

    Every logical column's window position must fall inside its block's
    window slice ``[blk*window_block, (blk+1)*window_block)`` — that is the
    static guarantee the placed BlockSpecs rely on to stream one window
    block per N-tile.
    """
    ids = np.asarray(col_ids).reshape(-1, n)          # [L?, N] -> slices
    pwb = window_block or window
    blk = np.arange(n) // block_cols
    lo, hi = blk * pwb, (blk + 1) * pwb
    for sl in ids:
        if (sl < 0).any() or (sl >= window).any():
            bad = int(np.argmax((sl < 0) | (sl >= window)))
            raise ContractViolation(
                kernel, "col-ids-range",
                f"col_ids[{bad}]={int(sl[bad])} outside window "
                f"[0, {window})")
        out = (sl < lo) | (sl >= hi)
        if out.any():
            bad = int(np.argmax(out))
            raise ContractViolation(
                kernel, "col-ids-range",
                f"col_ids[{bad}]={int(sl[bad])} escapes its window block "
                f"slice [{int(lo[bad])}, {int(hi[bad])})",
                tile=int(blk[bad]))


def check_shard_slices(spans, n: int, block_cols: int,
                       kernel: str = "sharded_gemm") -> None:
    """Verify a model-shard column split against the block-aligned layout.

    ``spans`` are per-shard half-open ``(lo, hi)`` column ranges (what
    ``pud.placement.shard_column_slices`` emits).  The placed kernels
    stream whole ``block_cols``-wide window blocks, so a shard boundary
    that lands mid-block would make one window straddle two devices — the
    invariant here is that every span starts and ends on a block multiple,
    the spans tile ``[0, n)`` contiguously in order, and no span is
    negative.  Raises :class:`ContractViolation` (invariant
    ``"shard-straddle"``) on the first violation.
    """
    if block_cols <= 0 or n % block_cols:
        raise ContractViolation(
            kernel, "shard-straddle",
            f"block_cols {block_cols} does not tile N={n}")
    lo_expect = 0
    for i, (lo, hi) in enumerate(spans):
        if lo != lo_expect or hi < lo:
            raise ContractViolation(
                kernel, "shard-straddle",
                f"shard {i} span [{lo}, {hi}) does not continue the "
                f"previous shard (expected lo={lo_expect})", tile=i)
        if lo % block_cols or hi % block_cols:
            raise ContractViolation(
                kernel, "shard-straddle",
                f"shard {i} span [{lo}, {hi}) straddles a {block_cols}-"
                "column window block — placement windows must stay whole "
                "per shard", tile=i)
        lo_expect = hi
    if lo_expect != n:
        raise ContractViolation(
            kernel, "shard-straddle",
            f"shard spans cover [0, {lo_expect}) but the tensor has "
            f"N={n} columns")


def _concrete(a):
    """Best-effort numpy view of ``a``; None for tracers (shape-only
    checks still run under jit, value checks are skipped)."""
    if a is None:
        return None
    if isinstance(a, np.ndarray):
        return a
    import jax

    if isinstance(a, jax.core.Tracer):
        return None
    try:
        return np.asarray(a)
    except Exception:
        return None


def check_kernel_args(entry: str, x_shape, planes_shape, *,
                      layout: str = "dense", logical_k: int | None = None,
                      col_ids=None, window_block: int | None = None,
                      mode: str = "folded", wb: int | None = None,
                      b_block: int | None = None,
                      n_block: int | None = None,
                      k_block: int | None = None) -> TilePlan:
    """Pre-flight an actual kernel call from its argument shapes.

    This is what ``pud_matmul(check_contracts=True)`` and the ``interpret``
    backend run: shapes in, :class:`TilePlan` out, :class:`ContractViolation`
    on any violated invariant.  ``col_ids`` may be an array (value-checked
    when concrete) or an int column count (shape checks only).
    ``b_block``/``n_block``/``k_block`` are tuned tile overrides, verified
    against the same invariants as the derived tiles.
    """
    b, k = int(x_shape[-2]), int(x_shape[-1])
    wb_ = int(wb if wb is not None else planes_shape[-3])
    plane_k, last = int(planes_shape[-2]), int(planes_shape[-1])
    if col_ids is None:
        call = KernelCall(entry=entry, b=b, k=k, n=last, wb=wb_,
                          layout=layout, plane_k=plane_k,
                          logical_k=logical_k, mode=mode, b_block=b_block,
                          n_block=n_block, k_block=k_block)
        return plan_kernel(call)
    n = col_ids if isinstance(col_ids, int) else int(np.shape(col_ids)[-1])
    call = KernelCall(entry=entry, b=b, k=k, n=n, wb=wb_, layout=layout,
                      plane_k=plane_k, logical_k=logical_k, window=last,
                      window_block=window_block, mode=mode, b_block=b_block,
                      n_block=n_block, k_block=k_block)
    plan = plan_kernel(call)
    ids = None if isinstance(col_ids, int) else _concrete(col_ids)
    if ids is not None:
        check_col_ids(ids, n, last, window_block, plan.block_cols,
                      call.kernel)
    return plan


def _plan_field(plan, field):
    if isinstance(plan, dict):
        return plan.get(field)
    return getattr(plan, field, None)


def check_tile_plan(plan, entry: str, x_shape, planes_shape, *,
                    layout: str = "dense", logical_k: int | None = None,
                    col_ids=None, window_block: int | None = None,
                    mode: str = "folded", wb: int | None = None) -> TilePlan:
    """Pre-flight an externally-supplied tuned tile plan.

    ``plan`` carries ``b_block``/``n_block``/``k_block``/``window_block``/
    ``mode`` fields (a ``kernels.autotune.TunedTile`` or a plain dict — a
    tuning-cache entry deserializes to either); the remaining arguments
    describe the call exactly like :func:`check_kernel_args`, with
    ``window_block`` naming the *pack's* block-aligned stride.

    A tuned ``window_block`` must be a whole multiple of the pack stride
    whose multiplier divides the block count — grouping c adjacent window
    blocks keeps every column's in-block residue arithmetic exact (column t
    of logical block r inside a group starts at residue ``r*pwb + t``).
    Anything else would silently gather the wrong physical columns, so it
    raises ``ContractViolation('window-stride')`` here, before any kernel
    runs.  All other overrides flow through the same invariants as derived
    tiles (:func:`check_kernel_args`), including the VMEM budget gate.
    """
    tuned_wb = _plan_field(plan, "window_block")
    eff_window_block = window_block
    if tuned_wb is not None:
        if col_ids is None:
            raise ContractViolation(
                _KERNEL_NAMES[(entry, False)], "tile-plan",
                f"window_block override {tuned_wb} on a logical "
                "(non-placed) call")
        kernel = _KERNEL_NAMES[(entry, True)]
        w_len = int(planes_shape[-1])
        pack_wb = window_block or w_len
        n_blocks = w_len // pack_wb if pack_wb and w_len % pack_wb == 0 else 0
        if (tuned_wb <= 0 or tuned_wb % pack_wb
                or n_blocks % (tuned_wb // pack_wb)):
            raise ContractViolation(
                kernel, "window-stride",
                f"tuned window_block {tuned_wb} must be a multiple of the "
                f"pack stride {pack_wb} whose multiplier divides the "
                f"{n_blocks} window blocks — the placed layout is fixed "
                "at pack time")
        eff_window_block = tuned_wb
    return check_kernel_args(
        entry, x_shape, planes_shape, layout=layout, logical_k=logical_k,
        col_ids=col_ids, window_block=eff_window_block,
        mode=_plan_field(plan, "mode") or mode, wb=wb,
        b_block=_plan_field(plan, "b_block"),
        n_block=_plan_field(plan, "n_block"),
        k_block=_plan_field(plan, "k_block"))


def check_pack(pt, batch: int = 1, entry: str | None = None,
               mode: str = "folded") -> list[TilePlan]:
    """Contract-check a ``PackedTensor`` for every entry point it can serve.

    Stacked packs ([L, WB, Kw, N] planes) check one representative slice
    shape plus every slice's ``col_ids`` values.  Returns the plans (one
    per entry checked).
    """
    from repro.pud.packed import as_packed_tensor

    pt = as_packed_tensor(pt)
    entries = (entry,) if entry else ENTRIES
    plane_shape = pt.planes.shape[-3:]
    x_shape = (batch, pt.k)
    plans = []
    for e in entries:
        plans.append(check_kernel_args(
            e, x_shape, plane_shape, layout=pt.layout,
            logical_k=pt.logical_k, col_ids=pt.col_ids,
            window_block=pt.window_block, mode=mode))
    return plans


# ---------------------------------------------------------------------------
# Sweep matrix: the configurations tier-1 exercises, plus adversarial
# fixtures that MUST violate — both sides gate CI.
# ---------------------------------------------------------------------------


def synthetic_placed(n: int, pad: int = 8):
    """A minimal valid block-aligned placement for N logical columns.

    Mirrors the allocator: ``block_cols = largest_divisor(n, N_BLOCK)``
    blocks, each spanning ``block_cols + pad`` window columns (the pad
    standing in for interleaved faulty columns, skipped mid-block like a
    real first-fit plan).  Returns (window, window_block, col_ids [N]).
    """
    block_cols = largest_divisor(n, N_BLOCK)
    n_blocks = n // block_cols
    window_block = block_cols + pad
    offs = np.arange(block_cols)
    offs = offs + (offs >= block_cols // 2) * pad     # gap mid-span
    col_ids = (np.arange(n_blocks)[:, None] * window_block
               + offs[None, :]).reshape(-1).astype(np.int32)
    return n_blocks * window_block, window_block, col_ids


def default_matrix() -> list[tuple[KernelCall, np.ndarray | None]]:
    """(call, col_ids) pairs covering what tier-1 runs: all four entry
    points × both layouts × aligned and odd shapes × both modes."""
    shapes = [(1, 64, 64), (8, 256, 512), (4, 300, 172), (2, 1024, 256)]
    out: list[tuple[KernelCall, np.ndarray | None]] = []
    for b, k, n in shapes:
        for layout in LAYOUTS:
            for entry in ENTRIES:
                for mode in ("planes", "folded"):
                    out.append((KernelCall(
                        entry=entry, b=b, k=k, n=n, layout=layout,
                        logical_k=k if layout == "bitpack8" else None,
                        mode=mode), None))
                window, wblk, ids = synthetic_placed(n)
                out.append((KernelCall(
                    entry=entry, b=b, k=k, n=n, layout=layout,
                    logical_k=k if layout == "bitpack8" else None,
                    window=window, window_block=wblk), ids))
    return out


def adversarial_fixtures() -> list[tuple[str, str, KernelCall,
                                         np.ndarray | None]]:
    """(name, expected invariant, call, col_ids) — each MUST violate."""
    window, wblk, ids = synthetic_placed(512)
    bad_ids = ids.copy()
    bad_ids[7] = window + 3                           # escapes the window
    slice_ids = ids.copy()
    slice_ids[300] = 0                                # wrong block's slice
    return [
        ("oversized-window-block", "window-tiling",
         KernelCall(entry="gemv", b=1, k=256, n=512, window=window,
                    window_block=wblk + 1), ids),
        ("window-under-capacity", "window-capacity",
         KernelCall(entry="gemm", b=4, k=256, n=512, window=256,
                    window_block=128), None),
        ("inconsistent-logical-k", "bitpack8-logical-k",
         KernelCall(entry="gemm", b=8, k=300, n=128, layout="bitpack8",
                    plane_k=32, logical_k=300), None),
        ("word-count-drift", "bitpack8-word-count",
         KernelCall(entry="gemv", b=1, k=96, n=128, layout="bitpack8",
                    plane_k=16, logical_k=96), None),
        ("col-ids-out-of-window", "col-ids-range",
         KernelCall(entry="gemv", b=1, k=256, n=512, window=window,
                    window_block=wblk), bad_ids),
        ("col-ids-wrong-block", "col-ids-range",
         KernelCall(entry="gemm", b=4, k=256, n=512, window=window,
                    window_block=wblk), slice_ids),
        ("whole-window-vmem-blowout", "vmem-budget",
         KernelCall(entry="gemv", b=8, k=2048, n=256, window=1 << 16,
                    window_block=None),
         np.arange(256, dtype=np.int32) * 17),
        # A tuned tile is not exempt from the budget: the autotuner's
        # candidate filter must reject this, exactly as plan_kernel does.
        ("over-budget-tuned-tile", "vmem-budget",
         KernelCall(entry="gemm", b=128, k=4096, n=4096, b_block=128,
                    n_block=4096, k_block=4096), None),
        ("degenerate-negative-tile", "tile-plan",
         KernelCall(entry="gemv", b=1, k=256, n=512, n_block=-64), None),
        ("unknown-layout", "layout",
         KernelCall(entry="gemv", b=1, k=64, n=64, layout="bitpack4"),
         None),
    ]


def _check_pair(call: KernelCall, ids) -> None:
    plan = plan_kernel(call)
    if ids is not None:
        check_col_ids(ids, call.n, call.window, call.window_block,
                      plan.block_cols, call.kernel)


def run_contracts() -> list[str]:
    """The CI contract pass: sweep the valid matrix (must all hold) and the
    adversarial fixtures (must all trip, with the expected invariant).
    Returns human-readable findings; empty means the gate is green."""
    findings: list[str] = []
    for call, ids in default_matrix():
        try:
            _check_pair(call, ids)
        except ContractViolation as e:
            findings.append(
                f"valid config rejected: {call.kernel} "
                f"B={call.b} K={call.k} N={call.n} {call.layout}: {e}")
    for name, invariant, call, ids in adversarial_fixtures():
        try:
            _check_pair(call, ids)
        except ContractViolation as e:
            if e.invariant != invariant:
                findings.append(
                    f"fixture {name!r} tripped {e.invariant!r}, "
                    f"expected {invariant!r}")
        else:
            findings.append(
                f"adversarial fixture {name!r} did not violate "
                f"{invariant!r}")
    return findings


# ---------------------------------------------------------------------------
# Generated VMEM budget table (docs/kernels.md) — the doc math IS this code.
# ---------------------------------------------------------------------------

DOC_BEGIN = "<!-- BEGIN GENERATED: vmem-budget (python -m repro.analysis --write-docs) -->"
DOC_END = "<!-- END GENERATED: vmem-budget -->"

#: Reference operating point of the documented table: serving decode with
#: a full MXU-aligned tile (Kb = Nb = 256) and the placed example at the
#: ~3 % ECR window stride the placement benchmark measures.
_DOC_REF = dict(b=8, k=2048, n=2048, wb=4)
_DOC_PLACED_WINDOW_BLOCK = 264


def _kib(nbytes: int) -> str:
    return f"{nbytes / 1024:.1f} KiB"


def _doc_plans() -> dict[str, TilePlan]:
    b, k, n, wb = (_DOC_REF[f] for f in ("b", "k", "n", "wb"))
    pwb = _DOC_PLACED_WINDOW_BLOCK
    n_blocks = n // largest_divisor(n, N_BLOCK)
    return {
        "dense": plan_kernel(KernelCall(entry="gemv", b=b, k=k, n=n, wb=wb)),
        "bitpack8": plan_kernel(KernelCall(
            entry="gemv", b=b, k=k, n=n, wb=wb, layout="bitpack8",
            logical_k=k)),
        "placed": plan_kernel(KernelCall(
            entry="gemv", b=b, k=k, n=n, wb=wb, layout="bitpack8",
            logical_k=k, window=n_blocks * pwb, window_block=pwb)),
    }


def render_vmem_table() -> str:
    """The markdown VMEM-budget block docs/kernels.md embeds verbatim."""
    p = _doc_plans()
    d, bp, pl = p["dense"], p["bitpack8"], p["placed"]
    ref = _DOC_REF
    rows = [
        f"Derived from `analysis/contracts.py` at B = {ref['b']}, "
        f"WB = {ref['wb']}, Kb = Nb = 256 (K = N = {ref['k']}); the placed "
        "column streams one window block of "
        f"`window_block = {_DOC_PLACED_WINDOW_BLOCK}` (≈ 3 % ECR span):",
        "",
        "| per-grid-step block | dense (legacy) | bit-packed "
        "| bit-packed placed |",
        "|---|---|---|---|",
        f"| x `[B, Kb]` int8 | {_kib(d.vmem['x'])} | {_kib(bp.vmem['x'])} "
        f"| {_kib(pl.vmem['x'])} |",
        f"| planes `[WB, Kb(/8), Nb/wb]` | {_kib(d.vmem['planes'])} "
        f"| {_kib(bp.vmem['planes'])} | {_kib(pl.vmem['planes'])} |",
        f"| col_ids `[1, Nb]` int32 | — | — | {_kib(pl.vmem['col_ids'])} |",
        f"| out `[B, Nb]` int32 | {_kib(d.vmem['out'])} "
        f"| {_kib(bp.vmem['out'])} | {_kib(pl.vmem['out'])} |",
        f"| **streamed + out** | **{_kib(d.streamed_bytes + d.vmem['out'])}**"
        f" | **{_kib(bp.streamed_bytes + bp.vmem['out'])}**"
        f" | **{_kib(pl.streamed_bytes + pl.vmem['out'])}** |",
        "",
        "Budget check: double-buffered streaming plus compute transients "
        "(folded int32 weight tile, bit-unpack scratch) must stay under "
        f"**{VMEM_BUDGET_BYTES // (1024 * 1024)} MiB** per step "
        "(`contracts.VMEM_BUDGET_BYTES`) — totals here: "
        f"dense {_kib(d.vmem_total_bytes)}, "
        f"bit-packed {_kib(bp.vmem_total_bytes)}, "
        f"placed {_kib(pl.vmem_total_bytes)}.",
    ]
    return "\n".join(rows)


def doc_table_block() -> str:
    return f"{DOC_BEGIN}\n{render_vmem_table()}\n{DOC_END}"


def write_doc_table(path) -> None:
    """Splice the generated block between the markers in ``path``."""
    text = open(path, encoding="utf-8").read()
    updated = _replace_block(text, path)
    with open(path, "w", encoding="utf-8") as f:
        f.write(updated)


def _replace_block(text: str, path) -> str:
    start, end = text.find(DOC_BEGIN), text.find(DOC_END)
    if start < 0 or end < 0:
        raise ValueError(f"{path}: generated-block markers not found")
    return text[:start] + doc_table_block() + text[end + len(DOC_END):]


def check_doc_table(path) -> list[str]:
    """Doc-drift gate: the committed table must equal the generated one."""
    try:
        text = open(path, encoding="utf-8").read()
    except OSError:
        return [f"{path}: missing (cannot verify generated VMEM table)"]
    if DOC_BEGIN not in text or DOC_END not in text:
        return [f"{path}: generated-block markers not found"]
    if _replace_block(text, path) != text:
        return [f"{path}: VMEM budget table is stale — run "
                "`python -m repro.analysis --write-docs`"]
    return []
