"""CI gate: ``python -m repro.analysis``.

Default run (no flags) executes all three passes and exits nonzero on any
finding:

  1. **contracts** — sweep the tier-1 kernel-config matrix (must all hold)
     and the adversarial fixtures (must all trip their expected invariant);
  2. **lint** — the AST rules over ``src/`` (see analysis/lint.py);
  3. **doc sync** — the generated VMEM-budget table in docs/kernels.md must
     match what contracts.py renders today.

Flags: ``--contracts-only`` / ``--lint-only`` restrict to one pass;
``--doc-table`` prints the generated markdown block; ``--write-docs``
splices it into docs/kernels.md; positional paths override the lint
target.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import contracts, lint

REPO_ROOT = Path(__file__).resolve().parents[3]
LINT_DEFAULT = REPO_ROOT / "src"
KERNELS_DOC = REPO_ROOT / "docs" / "kernels.md"


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="kernel contract checker + repo lint (the CI gate)")
    parser.add_argument("paths", nargs="*", type=Path,
                        help=f"lint targets (default: {LINT_DEFAULT})")
    group = parser.add_mutually_exclusive_group()
    group.add_argument("--contracts-only", action="store_true",
                       help="run only the kernel contract sweep")
    group.add_argument("--lint-only", action="store_true",
                       help="run only the AST lint")
    group.add_argument("--doc-table", action="store_true",
                       help="print the generated VMEM-budget table")
    group.add_argument("--write-docs", action="store_true",
                       help="regenerate the VMEM-budget table in "
                            "docs/kernels.md")
    args = parser.parse_args(argv)

    if args.doc_table:
        print(contracts.doc_table_block())
        return 0
    if args.write_docs:
        contracts.write_doc_table(KERNELS_DOC)
        print(f"wrote VMEM budget table -> {KERNELS_DOC}")
        return 0

    findings: list[str] = []
    if not args.lint_only:
        contract_findings = contracts.run_contracts()
        findings += [f"contracts: {f}" for f in contract_findings]
        n = len(contracts.default_matrix())
        a = len(contracts.adversarial_fixtures())
        print(f"contracts: {n} valid configs, {a} adversarial fixtures, "
              f"{len(contract_findings)} findings")
    if not args.contracts_only:
        targets = args.paths or [LINT_DEFAULT]
        lint_findings = lint.lint_paths(targets)
        findings += [f"lint: {f}" for f in lint_findings]
        print(f"lint: {len(lint.RULES)} rules over "
              f"{', '.join(str(t) for t in targets)}, "
              f"{len(lint_findings)} findings")
    if not (args.lint_only or args.contracts_only):
        doc_findings = contracts.check_doc_table(KERNELS_DOC)
        findings += [f"docs: {f}" for f in doc_findings]
        print(f"docs: VMEM table {'stale' if doc_findings else 'in sync'}")

    for f in findings:
        print(f, file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
