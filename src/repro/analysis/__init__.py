"""Static analysis for the PUD serving stack: kernel contracts + repo lint.

Two passes, both runnable via ``python -m repro.analysis`` (the CI gate):

  * ``contracts``  — recomputes, *without executing a kernel*, the block
    selection, placed-window structure, and per-grid-step VMEM footprint of
    every bit-plane entry point for a given (shape, layout, backend) and
    verifies the invariants the kernels assume.  Violations raise
    :class:`ContractViolation` naming the kernel, tile, and invariant.
  * ``lint``       — AST rules enforcing the architecture the PR sequence
    established (kernel code stays in ``kernels/``, call sites go through
    the registry, packs are typed, no trace-invisible ``assert``s, ...).

This ``__init__`` stays import-light (lazy submodule access) because the
kernel modules import :mod:`repro.analysis.errors` at import time while
:mod:`repro.analysis.contracts` imports the kernel package right back.
"""
from __future__ import annotations

from .errors import ContractViolation  # noqa: F401

__all__ = ["ContractViolation", "contracts", "lint"]


def __getattr__(name: str):
    if name in ("contracts", "lint"):
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
