"""Structured contract errors shared by the checker and the kernels.

This module is an import leaf (stdlib only) on purpose: the kernel wrappers
raise ``ContractViolation`` at trace time, and ``analysis/contracts.py``
imports the kernel package to recompute its tiling — putting the exception
anywhere heavier would close that loop into an import cycle.
"""
from __future__ import annotations


class ContractViolation(ValueError):
    """A kernel-contract invariant does not hold for a (shape, layout) combo.

    Subclasses ``ValueError`` so pre-existing call sites catching the old
    bare errors keep working; the structured fields name what failed:

      kernel     entry point ("bitplane_gemv", "bitplane_gemm_placed", ...)
      invariant  stable id of the failed check (see docs/analysis.md)
      tile       grid/tile coordinate the violation localizes to, or None
    """

    def __init__(self, kernel: str, invariant: str, message: str,
                 *, tile=None):
        self.kernel = kernel
        self.invariant = invariant
        self.tile = tile
        where = f" (tile {tile})" if tile is not None else ""
        super().__init__(f"[{kernel}] {invariant}: {message}{where}")
