"""AST repo lint: the architecture rules PRs 3-5 established, enforced.

Each rule is a small AST visitor registered in :data:`RULES`; the CLI
(``python -m repro.analysis``) runs them over ``src/`` and exits nonzero on
any finding.  Rules are path-scoped with repo-relative posix paths, so test
fixtures can exercise them with virtual paths (``lint_source(snippet,
"src/repro/models/fake.py")``).

Rule catalog (docs/analysis.md mirrors this):

  no-pallas-outside-kernels   ``pl.pallas_call`` belongs in ``kernels/``;
                              everything else goes through ``kernels.ops``
                              or the backend registry.
  no-direct-kernel-imports    the kernel implementation modules
                              (``bitplane_gemv``/``bitplane_gemm``/``majx``)
                              are private to the ``kernels`` package — call
                              sites import ``kernels.ops`` / ``backends``.
  no-raw-pack-dicts           packs are ``PackedTensor`` pytrees; raw
                              ``{"planes": ..., "scale": ...}`` dicts may
                              only be built inside ``pud/packed.py`` (the
                              one legacy-coercion point).
  no-assert-in-kernels        ``assert`` inside kernel code is stripped
                              under ``python -O`` and invisible in a traced
                              kernel body — raise ``ContractViolation``.
  no-constant-prng-key        ``jax.random.key(0)``-style literal seeds in
                              library code produce hidden cross-call
                              correlation; thread keys (or derive them from
                              config seeds) instead.
  no-removed-jax-api          APIs removed from the pinned jax
                              (``jax.set_mesh``) — use the portable
                              ``launch/mesh.use_mesh`` shim.
  no-recal-on-decode-path     ladder identification (Algorithm 1) is
                              minutes of work and must never run inside
                              the decode loop — the decode path
                              (``runtime/engine.py``, ``models/``) may not
                              import or call fleet recalibration; drift
                              recovery recalibrates from the controller
                              between steps (``runtime/drift.py``) and
                              hands the engine a finished pack.
  no-mesh-outside-launch-mesh device meshes (``jax.make_mesh`` /
                              ``jax.sharding.Mesh(...)``) are constructed
                              only by the ``launch/mesh.py`` factories, so
                              device-topology decisions live in one place;
                              call sites take a mesh as an argument.
  no-prefill-on-decode-wave   chunk-scheduling helpers (decode-path
                              functions with ``chunk`` in their name) may
                              not call whole-request prefill — a whole
                              prefill inside the decode wave stalls every
                              decoding slot for the full prompt length,
                              which is exactly what chunked prefill exists
                              to prevent; chunk helpers advance via
                              ``prefill_chunk`` only.
"""
from __future__ import annotations

import ast
import dataclasses
import os

#: Kernel implementation modules private to the kernels package.
KERNEL_MODULES = frozenset({"bitplane_gemv", "bitplane_gemm", "majx"})

#: jax attributes removed on the pinned jaxlib (rule: no-removed-jax-api).
REMOVED_JAX_APIS = frozenset({"set_mesh"})

#: Fleet recalibration entrypoints (rule: no-recal-on-decode-path).
#: Anything that runs Algorithm-1 ladder identification — step-granular
#: serving must reach these only from the drift controller, never from
#: the decode loop itself.
RECALIBRATION_ENTRYPOINTS = frozenset({
    "calibrate_fleet", "identify_calibration", "identify_calibration_fn",
    "load_or_calibrate", "recalibrate_subarrays"})

#: Modules on the step-granular decode path (rule: no-recal-on-decode-path).
DECODE_PATH_PREFIXES = ("repro/runtime/engine.py", "repro/models/")

#: Whole-request prefill entrypoints (rule: no-prefill-on-decode-wave).
#: Chunk-scheduling helpers advance admitted prompts one chunk at a time;
#: reaching any of these from a chunk helper re-introduces the full-prompt
#: stall the chunked scheduler exists to remove.
WHOLE_PREFILL_ENTRYPOINTS = frozenset({
    "prefill", "_prefill", "_prefill_fn",
    "_prefill_bucketed", "_prefill_bucketed_fn"})


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


RULES: dict[str, "LintRule"] = {}


@dataclasses.dataclass(frozen=True)
class LintRule:
    id: str
    description: str
    check: object  # callable(tree, path) -> iterable[Finding]


def rule(rule_id: str, description: str):
    def register(fn):
        RULES[rule_id] = LintRule(rule_id, description, fn)
        return fn

    return register


def _norm(path) -> str:
    return str(path).replace(os.sep, "/")


def _in_kernels(path: str) -> bool:
    return "repro/kernels/" in _norm(path)


def _attr_chain(node: ast.AST) -> str:
    """Dotted name of an attribute/name chain ('jax.random.key'), '' if the
    chain bottoms out in something dynamic."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


@rule("no-pallas-outside-kernels",
      "pl.pallas_call is only lowered inside src/repro/kernels/")
def _check_pallas(tree: ast.AST, path: str):
    if _in_kernels(path):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if chain.split(".")[-1] == "pallas_call":
            yield Finding(
                "no-pallas-outside-kernels", path, node.lineno,
                "pallas_call outside kernels/ — add a kernel module and "
                "expose it through kernels.ops / the backend registry")


def _imported_kernel_module(node: ast.AST) -> str | None:
    """The private kernel module an import statement reaches into, if any."""
    if isinstance(node, ast.Import):
        for alias in node.names:
            parts = alias.name.split(".")
            if "kernels" in parts and parts[-1] in KERNEL_MODULES:
                return alias.name
    if isinstance(node, ast.ImportFrom):
        mod = node.module or ""
        parts = mod.split(".") if mod else []
        if parts and parts[-1] in KERNEL_MODULES and (
                "kernels" in parts or node.level > 0):
            return mod
        if parts and parts[-1] == "kernels":
            for alias in node.names:
                if alias.name in KERNEL_MODULES:
                    return f"{mod}.{alias.name}"
    return None


@rule("no-direct-kernel-imports",
      "kernel implementation modules are private to the kernels package")
def _check_kernel_imports(tree: ast.AST, path: str):
    if _in_kernels(path):
        return
    for node in ast.walk(tree):
        mod = _imported_kernel_module(node)
        if mod is not None:
            yield Finding(
                "no-direct-kernel-imports", path, node.lineno,
                f"import of private kernel module {mod!r} — go through "
                "kernels.ops or kernels.backends")


def _is_raw_pack_dict(node: ast.AST) -> bool:
    if isinstance(node, ast.Dict):
        keys = {k.value for k in node.keys
                if isinstance(k, ast.Constant) and isinstance(k.value, str)}
        return {"planes", "scale"} <= keys
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "dict"):
        kws = {kw.arg for kw in node.keywords}
        return {"planes", "scale"} <= kws
    return False


@rule("no-raw-pack-dicts",
      "packs are typed PackedTensor pytrees; raw dicts only in pud/packed.py")
def _check_raw_packs(tree: ast.AST, path: str):
    if _norm(path).endswith("repro/pud/packed.py"):
        return
    for node in ast.walk(tree):
        if _is_raw_pack_dict(node):
            yield Finding(
                "no-raw-pack-dicts", path, node.lineno,
                "raw {'planes', 'scale'} pack construction — build a "
                "PackedTensor (pud/packed.py) instead")


@rule("no-assert-in-kernels",
      "assert in kernel code is stripped under -O and invisible in a trace")
def _check_kernel_asserts(tree: ast.AST, path: str):
    if not _in_kernels(path):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            yield Finding(
                "no-assert-in-kernels", path, node.lineno,
                "bare assert in kernel code — raise ContractViolation "
                "(repro.analysis.errors) so the failure names the kernel "
                "and invariant")


@rule("no-constant-prng-key",
      "literal PRNG seeds in library code hide cross-call correlation")
def _check_prng(tree: ast.AST, path: str):
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and node.args):
            continue
        chain = _attr_chain(node.func)
        parts = chain.split(".")
        if parts[-1] not in ("PRNGKey", "key") or "random" not in parts:
            continue
        arg = node.args[0]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, int):
            yield Finding(
                "no-constant-prng-key", path, node.lineno,
                f"{chain}({arg.value}) with a literal seed — thread an "
                "explicit key (fold_in per call site) or derive the seed "
                "from config")


@rule("no-removed-jax-api",
      "references to APIs removed on the pinned jax (use launch/mesh shims)")
def _check_removed_apis(tree: ast.AST, path: str):
    if _norm(path).endswith("repro/launch/mesh.py"):
        return  # the one portability shim allowed to probe the old API
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute)
                and node.attr in REMOVED_JAX_APIS
                and isinstance(node.value, ast.Name)
                and node.value.id == "jax"):
            yield Finding(
                "no-removed-jax-api", path, node.lineno,
                f"jax.{node.attr} was removed on the pinned jax — use "
                "repro.launch.mesh.use_mesh")


def _on_decode_path(path: str) -> bool:
    p = _norm(path)
    return any(f"src/{pre}" in p or p.startswith(pre) or f"/{pre}" in p
               for pre in DECODE_PATH_PREFIXES)


@rule("no-recal-on-decode-path",
      "the decode path must not import or call fleet recalibration")
def _check_decode_recal(tree: ast.AST, path: str):
    if not _on_decode_path(path):
        return
    msg = ("Algorithm-1 recalibration reached from the decode path — "
           "drift recovery runs it in the controller between steps "
           "(runtime/drift.py) and hands the engine a finished pack via "
           "stage_params")
    for node in ast.walk(tree):
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            names = {a.name.split(".")[-1] for a in node.names}
            hit = names & RECALIBRATION_ENTRYPOINTS
            if hit:
                yield Finding(
                    "no-recal-on-decode-path", path, node.lineno,
                    f"import of {sorted(hit)[0]!r}: {msg}")
        elif isinstance(node, ast.Call):
            tail = _attr_chain(node.func).split(".")[-1]
            if tail in RECALIBRATION_ENTRYPOINTS:
                yield Finding(
                    "no-recal-on-decode-path", path, node.lineno,
                    f"call to {tail!r}: {msg}")


@rule("no-prefill-on-decode-wave",
      "chunk scheduling helpers may not call whole-request prefill")
def _check_chunk_prefill(tree: ast.AST, path: str):
    if not _on_decode_path(path):
        return
    msg = ("whole-request prefill reached from a chunk-scheduling helper — "
           "a full-prompt prefill inside the decode wave stalls every "
           "decoding slot for the whole prompt; advance the slot with "
           "prefill_chunk and let admission handle un-chunked requests")
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if "chunk" not in fn.name:
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            tail = _attr_chain(node.func).split(".")[-1]
            if tail in WHOLE_PREFILL_ENTRYPOINTS:
                yield Finding(
                    "no-prefill-on-decode-wave", path, node.lineno,
                    f"call to {tail!r} inside {fn.name!r}: {msg}")


@rule("no-mesh-outside-launch-mesh",
      "device meshes are constructed only by the launch/mesh.py factories")
def _check_mesh_construction(tree: ast.AST, path: str):
    if _norm(path).endswith("repro/launch/mesh.py"):
        return  # the one mesh factory module
    # Aliases `from jax.sharding import Mesh [as M]` binds in this module —
    # importing Mesh for annotations is fine, *calling* it is not.
    mesh_ctors = {a.asname or a.name
                  for node in ast.walk(tree)
                  if isinstance(node, ast.ImportFrom)
                  and node.module == "jax.sharding"
                  for a in node.names if a.name == "Mesh"}
    msg = ("mesh construction outside launch/mesh.py — use "
           "make_production_mesh / make_host_mesh / make_mesh_for_devices / "
           "parse_mesh_spec so device-topology decisions live in one place")
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        parts = chain.split(".")
        if (chain == "jax.sharding.Mesh"
                or (parts[0] == "jax" and parts[-1] == "make_mesh")):
            yield Finding("no-mesh-outside-launch-mesh", path, node.lineno,
                          f"{chain}(...): {msg}")
        elif isinstance(node.func, ast.Name) and node.func.id in mesh_ctors:
            yield Finding("no-mesh-outside-launch-mesh", path, node.lineno,
                          f"{node.func.id}(...): {msg}")


def lint_source(source: str, path: str) -> list[Finding]:
    """Lint one file's source text under a (possibly virtual) path."""
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [Finding("syntax-error", _norm(path), e.lineno or 0, str(e))]
    findings: list[Finding] = []
    for r in RULES.values():
        findings.extend(r.check(tree, _norm(path)))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def lint_file(path) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), path)


def lint_paths(paths) -> list[Finding]:
    """Lint every ``.py`` file under the given files/directories."""
    findings: list[Finding] = []
    for root in paths:
        root = str(root)
        if os.path.isfile(root):
            findings.extend(lint_file(root))
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames
                           if not d.startswith(".") and d != "__pycache__"]
            for name in sorted(filenames):
                if name.endswith(".py"):
                    findings.extend(lint_file(os.path.join(dirpath, name)))
    return findings
