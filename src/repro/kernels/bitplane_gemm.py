"""Pallas TPU kernel: bit-plane GEMM — the batched MVDRAM compute pattern.

``bitplane_gemv.py`` keeps the whole activation batch in one VMEM block,
which is exactly right for the single-vector decode the paper evaluates but
wrong for a serving engine: a continuous-batching scheduler feeds the array
``(B, K)`` operand matrices whose B is the number of in-flight requests (or
B*S prefill rows), and a single unblocked batch axis either blows the VMEM
budget or serializes the MXU.

This kernel is the GEMM generalization: the same HBM bit-plane layout
(weights as WB planes W_b in {0,1} — what a PUD subarray holds), with the
batch axis tiled into the grid:

    grid (B/Bb, N/Nb, K/Kb);  blocks x [Bb, Kb] int8,
    planes [WB, Kb, Nb] int8 (dense) or [WB, Kb/8, Nb] uint8 (bit-packed),
    out [Bb, Nb] int32.

K is the reduction axis (innermost, accumulated in the output block — the
out block index depends only on (b, n)).  Both execution modes of the GeMV
kernel carry over unchanged (``planes`` = one MXU pass per bit-plane,
``folded`` = planes folded to int8 in VMEM, one pass per K-tile), both
storage layouts too (``bitpack8`` words unpack inside VMEM — see
bitplane_gemv.py), and the placed variant fuses the logical->physical
column gather exactly like ``bitplane_gemv_placed``, streaming one
block-aligned window block per grid step.

Ragged batches (a continuous-batching step whose live-slot count is not a
tile multiple) are handled here: B pads up to the batch tile with zero rows,
which cannot perturb other rows — every output element is an independent
integer dot product — and the pad is sliced off after the kernel.  Bit-exact
vs a row-vmapped ``bitplane_gemv`` (enforced in tests/test_bitplane_gemm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.errors import ContractViolation

# The kernel bodies are the GeMV ones with the K reduction axis moved to
# grid position 2 (after the new batch axis); only the grid/BlockSpec
# plumbing differs.
from .bitplane_gemv import (_gemv_kernel, _gemv_placed_kernel, _k_tiling,
                            _n_tiling, _placed_n_block, _sign_fix)

B_BLOCK = 128
K_BLOCK = 256
N_BLOCK = 256


def _pad_batch(x: jax.Array, bb: int) -> jax.Array:
    b = x.shape[0]
    if b % bb == 0:
        return x
    return jnp.pad(x, ((0, bb - b % bb), (0, 0)))


def _batch_block(b: int, b_block: int | None, kernel: str) -> int:
    """Batch tile: an explicit tuned block (ragged batches pad with zero
    rows, sliced off after the kernel) or the VMEM-bounded default."""
    if b_block is None:
        return min(b, B_BLOCK)
    if b_block <= 0:
        raise ContractViolation(
            kernel, "tile-plan", f"b_block {b_block} must be positive")
    return min(b_block, b)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "interpret", "layout", "logical_k",
                     "b_block", "n_block", "k_block"))
def bitplane_gemm(
    x: jax.Array,        # [B, K] int8 activations (any B, padded here)
    planes: jax.Array,   # [WB, K, N] int8 bits | [WB, K/8, N] uint8 words
    mode: str = "planes",
    interpret: bool = True,
    layout: str = "dense",
    logical_k: int | None = None,
    b_block: int | None = None,
    n_block: int | None = None,
    k_block: int | None = None,
) -> jax.Array:
    """Batched offset-binary bit-plane GEMM; returns [B, N] int32 of
    x @ (W - 2^{WB-1}).  Bit-exact vs ``bitplane_gemv`` row by row.
    ``b_block``/``n_block``/``k_block`` are tuned tile overrides
    (kernels/autotune.py); non-multiple shapes pad with zeros."""
    b, k = x.shape
    wb, _, n = planes.shape
    xp, pp, pkb, xkb, k_steps = _k_tiling(x, planes, layout, logical_k,
                                          kernel="bitplane_gemm",
                                          k_block=k_block)
    nb, n_pad = _n_tiling(n, n_block, "bitplane_gemm")
    if n_pad != n:                       # zero columns, sliced off below
        pp = jnp.pad(pp, ((0, 0), (0, 0), (0, n_pad - n)))
    bb = _batch_block(b, b_block, "bitplane_gemm")
    xp = _pad_batch(xp, bb)
    bp = xp.shape[0]
    grid = (bp // bb, n_pad // nb, k_steps)
    kernel = functools.partial(_gemv_kernel, mode=mode, n_bits=wb, k_axis=2,
                               packed=(layout == "bitpack8"))
    unsigned = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, xkb), lambda jb, jn, jk: (jb, jk)),
            pl.BlockSpec((wb, pkb, nb), lambda jb, jn, jk: (0, jk, jn)),
        ],
        out_specs=pl.BlockSpec((bb, nb), lambda jb, jn, jk: (jb, jn)),
        out_shape=jax.ShapeDtypeStruct((bp, n_pad), jnp.int32),
        interpret=interpret,
    )(xp, pp)
    return unsigned[:b, :n] - _sign_fix(x, wb)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "interpret", "layout", "logical_k",
                     "window_block", "b_block", "n_block", "k_block"))
def bitplane_gemm_placed(
    x: jax.Array,         # [B, K] int8 activations
    planes: jax.Array,    # [WB, K(/8), W] physical window (placed layout)
    col_ids: jax.Array,   # [N] int32 logical -> window column map
    mode: str = "planes",
    interpret: bool = True,
    layout: str = "dense",
    logical_k: int | None = None,
    window_block: int | None = None,
    b_block: int | None = None,
    n_block: int | None = None,
    k_block: int | None = None,
) -> jax.Array:
    """Column-placed batched GEMM; returns [B, N] like ``bitplane_gemm``.

    ``planes`` is the block-aligned physically-permuted window layout a
    placement-aware packer emits (repro/pud/placement.py); the gather is
    fused into the kernel per N-block, streaming ``window_block`` window
    columns per grid step (None = whole window as one block, the degenerate
    hand-built-pack case).  Bit-exact vs ``bitplane_gemv_placed`` row by
    row.
    """
    b, k = x.shape
    wb, _, w_len = planes.shape
    (n,) = col_ids.shape
    xp, pp, pkb, xkb, k_steps = _k_tiling(x, planes, layout, logical_k,
                                          kernel="bitplane_gemm_placed",
                                          k_block=k_block)
    pwb = window_block or w_len
    if w_len % pwb or n % (w_len // pwb):
        raise ContractViolation(
            "bitplane_gemm_placed", "window-tiling",
            f"window length {w_len} / window_block {pwb} does not tile "
            f"N={n}")
    block_cols = n // (w_len // pwb)
    nb = _placed_n_block(n_block, block_cols, "bitplane_gemm_placed")
    bb = _batch_block(b, b_block, "bitplane_gemm_placed")
    xp = _pad_batch(xp, bb)
    bp = xp.shape[0]
    grid = (bp // bb, n // nb, k_steps)
    kernel = functools.partial(_gemv_placed_kernel, mode=mode, n_bits=wb,
                               k_axis=2, packed=(layout == "bitpack8"),
                               window_block=pwb)
    unsigned = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, xkb), lambda jb, jn, jk: (jb, jk)),
            pl.BlockSpec((1, nb), lambda jb, jn, jk: (0, jn)),
            # one window block per grid step (block-aligned placed layout)
            pl.BlockSpec((wb, pkb, pwb),
                         lambda jb, jn, jk, _nb=nb, _bc=block_cols:
                         (0, jk, (jn * _nb) // _bc)),
        ],
        out_specs=pl.BlockSpec((bb, nb), lambda jb, jn, jk: (jb, jn)),
        out_shape=jax.ShapeDtypeStruct((bp, n), jnp.int32),
        interpret=interpret,
    )(xp, col_ids.astype(jnp.int32)[None, :], pp)
    return unsigned[:b] - _sign_fix(x, wb)
