"""Pallas TPU kernel: column-parallel SiMRA/MAJX charge-sharing sensing.

This is the hot loop of calibration and ECR measurement: for a batch of
SiMRA events (trials), share charge across the 8 opened rows of every column,
add sensing noise, and compare against the per-column threshold.

TPU mapping (hardware adaptation, DESIGN.md §3): a DRAM subarray's 65 536
columns map to TPU lanes; one SiMRA event is a small reduction over the
8-row axis.  The kernel tiles [trials × columns] into VMEM blocks of
(TRIAL_BLOCK, 8, COL_BLOCK) charge + (TRIAL_BLOCK, COL_BLOCK) noise, with
COL_BLOCK a multiple of 128 lanes.  All math is VPU elementwise + an 8-wide
reduction — memory-bound by design, so the BlockSpec keeps each block's
working set (8+2 planes * 4 B * COL_BLOCK) comfortably inside VMEM.

Noise is passed in as standard-normal draws (host PRNG) so the kernel is
deterministic and bit-exact against ref.py in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.pud.physics import NEUTRAL, PhysicsParams

TRIAL_BLOCK = 8
COL_BLOCK = 1024


def _majx_kernel(charge_ref, offset_ref, noise_ref, out_ref, *,
                 params: PhysicsParams, n_fracs: int):
    charge = charge_ref[...]                      # [Tb, R, Cb]
    offset = offset_ref[...]                      # [Cb]
    noise = noise_ref[...]                        # [Tb, Cb]
    n_rows = charge.shape[1]

    q_sum = charge.sum(axis=1)                    # [Tb, Cb]
    v = (q_sum * params.c_cell_ff + NEUTRAL * params.c_bitline_ff) / (
        n_rows * params.c_cell_ff + params.c_bitline_ff)
    swing_sq = ((2.0 * (charge - NEUTRAL)) ** 2).sum(axis=1)
    var = (params.sigma_dynamic ** 2
           + params.sigma_frac ** 2 * float(n_fracs)
           + params.sigma_transfer ** 2 * swing_sq)
    sigma = jnp.sqrt(var)
    bits = (v + sigma * noise) > (NEUTRAL + offset[None, :])
    out_ref[...] = bits.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("params", "n_fracs", "interpret"))
def majx_sense(
    charge: jax.Array,        # [T, R, C] float32 cell charges (V_DD units)
    sense_offset: jax.Array,  # [C] float32
    noise: jax.Array,         # [T, C] float32 standard normal
    params: PhysicsParams = PhysicsParams(),
    n_fracs: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Sensed bits [T, C] for T SiMRA events over C columns."""
    t, r, c = charge.shape
    assert t % TRIAL_BLOCK == 0 and c % COL_BLOCK == 0, (t, c)
    grid = (t // TRIAL_BLOCK, c // COL_BLOCK)
    kernel = functools.partial(_majx_kernel, params=params, n_fracs=n_fracs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TRIAL_BLOCK, r, COL_BLOCK), lambda i, j: (i, 0, j)),
            pl.BlockSpec((COL_BLOCK,), lambda i, j: (j,)),
            pl.BlockSpec((TRIAL_BLOCK, COL_BLOCK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((TRIAL_BLOCK, COL_BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, c), jnp.float32),
        interpret=interpret,
    )(charge, sense_offset, noise)
