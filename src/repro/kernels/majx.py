"""Pallas TPU kernel: column-parallel SiMRA/MAJX charge-sharing sensing.

This is the hot loop of calibration and ECR measurement: for a batch of
SiMRA events (trials), share charge across the 8 opened rows of every column,
add sensing noise, and compare against the per-column threshold.

TPU mapping (hardware adaptation, DESIGN.md §3): a DRAM subarray's 65 536
columns map to TPU lanes; one SiMRA event is a small reduction over the
8-row axis.  The kernel tiles [trials × columns] into VMEM blocks of
(TRIAL_BLOCK, 8, COL_BLOCK) charge + (TRIAL_BLOCK, COL_BLOCK) noise, with
COL_BLOCK a multiple of 128 lanes.  All math is VPU elementwise + an 8-wide
reduction — memory-bound by design, so the BlockSpec keeps each block's
working set (8+2 planes * 4 B * COL_BLOCK) comfortably inside VMEM.

Noise is passed in as standard-normal draws (host PRNG) so the kernel is
deterministic and bit-exact against ref.py in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.errors import ContractViolation
from repro.pud.physics import NEUTRAL, PhysicsParams

TRIAL_BLOCK = 8
COL_BLOCK = 1024


def _majx_kernel(charge_ref, offset_ref, noise_ref, out_ref, *,
                 params: PhysicsParams, n_fracs: int):
    charge = charge_ref[...]                      # [Tb, R, Cb]
    offset = offset_ref[...]                      # [Cb]
    noise = noise_ref[...]                        # [Tb, Cb]
    n_rows = charge.shape[1]

    q_sum = charge.sum(axis=1)                    # [Tb, Cb]
    v = (q_sum * params.c_cell_ff + NEUTRAL * params.c_bitline_ff) / (
        n_rows * params.c_cell_ff + params.c_bitline_ff)
    swing_sq = ((2.0 * (charge - NEUTRAL)) ** 2).sum(axis=1)
    var = (params.sigma_dynamic ** 2
           + params.sigma_frac ** 2 * float(n_fracs)
           + params.sigma_transfer ** 2 * swing_sq)
    sigma = jnp.sqrt(var)
    bits = (v + sigma * noise) > (NEUTRAL + offset[None, :])
    out_ref[...] = bits.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Fused calibration iteration (Algorithm 1 inner loop in one pass).
# ---------------------------------------------------------------------------
#
# The unfused path runs three jitted stages per iteration — levels_to_charges
# (gather), maj_outputs (sense), bias/level-step (reduce + select) — each
# round-tripping [S, C]-shaped intermediates through HBM.  This kernel fuses
# them: per column block it gathers the ladder's per-level charge/swing sums
# (static unrolled select over <= 8 levels, no dynamic gather needed on TPU),
# senses all sample blocks while accumulating the per-column bias in the
# output block (revisited across the innermost sample-grid axis), and applies
# the threshold level step on the last sample block.  One HBM read of the
# inputs, one write of [C] levels + [C] bias.

CAL_SAMPLE_BLOCKS = (64, 32, 16, 8, 4, 2, 1)
CAL_COL_BLOCKS = (1024, 512, 256, 128)


def _pick_block(n: int, candidates: tuple[int, ...],
                kernel: str = "calib_iter_fused") -> int:
    for c in candidates:
        if n % c == 0:
            return c
    raise ContractViolation(
        kernel, "block-selection",
        f"no block size in {candidates} divides {n}")


def _calib_iter_kernel(inputs_ref, noise_ref, levels_ref, offset_ref,
                       levels_out_ref, bias_ref, *,
                       params: PhysicsParams, n_fracs: int,
                       level_qsum: tuple[float, ...],
                       level_swing: tuple[float, ...],
                       n_samples: int, n_sample_blocks: int,
                       threshold: float, maj_inputs: int,
                       const_charge_sum: float, const_swing_sq: float):
    j = pl.program_id(1)                          # sample-block (innermost)

    @pl.when(j == 0)
    def _init():
        bias_ref[...] = jnp.zeros_like(bias_ref)

    levels = levels_ref[...]                      # [Cb] int32
    inp = inputs_ref[...]                         # [Sb, M, Cb] bits as f32
    noise = noise_ref[...]                        # [Sb, Cb]
    offset = offset_ref[...]                      # [Cb]

    # Ladder lookup: per-level calibration-row charge sum and swing^2 sum are
    # static scalars; select instead of gathering [n_rows, C] charges.
    calib_qsum = jnp.zeros(levels.shape, jnp.float32)
    calib_swing = jnp.zeros(levels.shape, jnp.float32)
    for lvl, (q, s) in enumerate(zip(level_qsum, level_swing)):
        sel = levels == lvl
        calib_qsum = jnp.where(sel, jnp.float32(q), calib_qsum)
        calib_swing = jnp.where(sel, jnp.float32(s), calib_swing)

    charge_sum = inp.sum(axis=1) + calib_qsum[None, :] + const_charge_sum
    v = params.bitline_voltage(charge_sum, params.n_simra_rows)
    swing_sq = (((2.0 * (inp - NEUTRAL)) ** 2).sum(axis=1)
                + calib_swing[None, :] + const_swing_sq)
    sigma = params.sensing_sigma(jnp.float32(n_fracs), swing_sq)
    out = ((v + sigma * noise) > (NEUTRAL + offset[None, :])).astype(
        jnp.float32)
    truth = (inp.sum(axis=1) > maj_inputs // 2).astype(jnp.float32)
    bias_ref[...] += (out - truth).sum(axis=0) / n_samples

    @pl.when(j == n_sample_blocks - 1)
    def _step():
        bias = bias_ref[...]
        step = jnp.where(bias > threshold, -1, 0) + jnp.where(
            bias < -threshold, 1, 0)
        levels_out_ref[...] = jnp.clip(
            levels + step, 0, len(level_qsum) - 1)


@functools.partial(
    jax.jit, static_argnames=("params", "n_fracs", "level_qsum",
                              "level_swing", "threshold", "maj_inputs",
                              "const_charge_sum", "const_swing_sq",
                              "interpret"))
def calib_iter_fused(
    inputs: jax.Array,        # [S, M, C] float32 operand bits
    noise: jax.Array,         # [S, C] float32 standard normal
    levels: jax.Array,        # [C] int32 current ladder levels
    sense_offset: jax.Array,  # [C] float32
    params: PhysicsParams,
    n_fracs: int,
    level_qsum: tuple[float, ...],    # per-level calib-row charge sum
    level_swing: tuple[float, ...],   # per-level calib-row swing^2 sum
    threshold: float,
    maj_inputs: int = 5,
    const_charge_sum: float = 0.0,
    const_swing_sq: float = 0.0,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One fused Algorithm-1 iteration; returns (new levels [C], bias [C])."""
    s, m, c = inputs.shape
    sb = _pick_block(s, CAL_SAMPLE_BLOCKS)
    cb = _pick_block(c, CAL_COL_BLOCKS)
    grid = (c // cb, s // sb)                     # sample axis innermost
    kernel = functools.partial(
        _calib_iter_kernel, params=params, n_fracs=n_fracs,
        level_qsum=level_qsum, level_swing=level_swing, n_samples=s,
        n_sample_blocks=s // sb, threshold=threshold, maj_inputs=maj_inputs,
        const_charge_sum=const_charge_sum, const_swing_sq=const_swing_sq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, m, cb), lambda i, j: (j, 0, i)),
            pl.BlockSpec((sb, cb), lambda i, j: (j, i)),
            pl.BlockSpec((cb,), lambda i, j: (i,)),
            pl.BlockSpec((cb,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((cb,), lambda i, j: (i,)),
            pl.BlockSpec((cb,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c,), jnp.int32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        ],
        interpret=interpret,
    )(inputs, noise, levels, sense_offset)


@functools.partial(jax.jit, static_argnames=("params", "n_fracs", "interpret"))
def majx_sense(
    charge: jax.Array,        # [T, R, C] float32 cell charges (V_DD units)
    sense_offset: jax.Array,  # [C] float32
    noise: jax.Array,         # [T, C] float32 standard normal
    params: PhysicsParams = PhysicsParams(),
    n_fracs: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Sensed bits [T, C] for T SiMRA events over C columns."""
    t, r, c = charge.shape
    if t % TRIAL_BLOCK or c % COL_BLOCK:
        # Not a bare assert: stripped under -O and invisible in a trace.
        raise ContractViolation(
            "majx_sense", "block-alignment",
            f"trials {t} / columns {c} must tile "
            f"({TRIAL_BLOCK}, {COL_BLOCK}) blocks")
    grid = (t // TRIAL_BLOCK, c // COL_BLOCK)
    kernel = functools.partial(_majx_kernel, params=params, n_fracs=n_fracs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TRIAL_BLOCK, r, COL_BLOCK), lambda i, j: (i, 0, j)),
            pl.BlockSpec((COL_BLOCK,), lambda i, j: (j,)),
            pl.BlockSpec((TRIAL_BLOCK, COL_BLOCK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((TRIAL_BLOCK, COL_BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, c), jnp.float32),
        interpret=interpret,
    )(charge, sense_offset, noise)
