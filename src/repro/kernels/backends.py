"""Named execution backends for the bit-plane GeMV.

The serving stack picks *where* a packed GeMV executes by name instead of
threading ``interpret``/oracle flags through every call site:

  * ``pallas``    — the Pallas TPU kernel; lowers natively on TPU and falls
    back to interpret mode elsewhere (this container is CPU-only).
  * ``interpret`` — the same Pallas kernel forced through the interpreter,
    regardless of platform.  Useful for debugging kernel changes on TPU.
  * ``reference`` — the pure-jnp oracle (kernels/ref.py).

Every backend implements the same entry points — ``gemv``/``gemv_placed``
for the single-block GeMV and ``gemm``/``gemm_placed`` for the batch-tiled
GEMM the serving engine feeds — and all are bit-exact against each other,
enforced by tests/test_session.py and tests/test_bitplane_gemm.py across
placed and unplaced packs, dense and bit-packed plane layouts.  Layout
metadata (``layout``/``logical_k``/``window_block`` — see
repro/pud/packed.py) arrives as keyword arguments; the Pallas backends
hand them to the kernel wrappers, the reference backend densifies the
words first and runs the unchanged jnp oracle.  ``PUDSession`` selects a
backend per session and per call; register custom ones (e.g. a future GPU
lowering) with ``register_backend`` (backends without GEMM lowerings fall
back to their GeMV entry, which already accepts a [B, K] operand block).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import ref
from .bitplane_gemm import bitplane_gemm, bitplane_gemm_placed
from .bitplane_gemv import bitplane_gemv, bitplane_gemv_placed

DEFAULT_BACKEND = "pallas"


@dataclasses.dataclass(frozen=True)
class Backend:
    """One named lowering of the bit-plane GeMV/GEMM.

    ``gemv(x, planes, mode, **layout_kw)``: [B, K] int8 x [WB, K(/8), N]
    planes -> [B, N] int32 with the whole B in one block (decode-shaped).
    ``gemv_placed(x, planes, col_ids, mode, **layout_kw)``: same, with
    planes in the physical-window layout and the logical->window gather
    map.  ``gemm``/``gemm_placed``: identical signatures and numerics with
    the batch axis tiled into the kernel grid (serving-engine-shaped);
    None falls back to the GeMV entry.  ``layout_kw`` is the pack-format
    metadata: ``layout`` ("dense" | "bitpack8"), ``logical_k`` (un-padded
    K of a bit-packed pack), ``window_block`` (placed entries only).
    """

    name: str
    gemv: Callable[..., jax.Array]
    gemv_placed: Callable[..., jax.Array]
    gemm: Callable[..., jax.Array] | None = None
    gemm_placed: Callable[..., jax.Array] | None = None

    def matmul(self, x, planes, mode="folded", **kw):
        """Batch-tiled entry, falling back to the one-block GeMV."""
        return (self.gemm or self.gemv)(x, planes, mode, **kw)

    def matmul_placed(self, x, planes, col_ids, mode="folded", **kw):
        return (self.gemm_placed or self.gemv_placed)(x, planes, col_ids,
                                                      mode, **kw)


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    if backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{backend_names()}") from None


def backend_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _pallas_interpret() -> bool:
    # Lower natively only where the BlockSpecs actually target hardware.
    return jax.default_backend() != "tpu"


def _pallas_entries(interpret, check=False):
    """The four kernel entries at a fixed interpret policy (callable so the
    ``pallas`` backend re-reads the platform on every call).

    ``check=True`` runs the static contract checker
    (repro.analysis.contracts) before every launch; the ``interpret``
    backend enables it unconditionally, so the debugging backend also
    validates tiling/layout/VMEM invariants the hardware path assumes.
    """

    def preflight(entry, x, planes, **kw):
        if check:
            # Deferred: analysis.contracts imports kernels.ops, which
            # imports this module at its own top level.
            from repro.analysis.contracts import check_kernel_args
            check_kernel_args(entry, x.shape, planes.shape, **kw)

    def gemv(x, planes, mode="folded", *, layout="dense", logical_k=None,
             n_block=None, k_block=None):
        preflight("gemv", x, planes, layout=layout, logical_k=logical_k,
                  mode=mode, n_block=n_block, k_block=k_block)
        return bitplane_gemv(x, planes, mode=mode, interpret=interpret(),
                             layout=layout, logical_k=logical_k,
                             n_block=n_block, k_block=k_block)

    def gemv_placed(x, planes, col_ids, mode="folded", *, layout="dense",
                    logical_k=None, window_block=None, n_block=None,
                    k_block=None):
        preflight("gemv", x, planes, layout=layout, logical_k=logical_k,
                  col_ids=col_ids, window_block=window_block, mode=mode,
                  n_block=n_block, k_block=k_block)
        return bitplane_gemv_placed(
            x, planes, col_ids, mode=mode, interpret=interpret(),
            layout=layout, logical_k=logical_k, window_block=window_block,
            n_block=n_block, k_block=k_block)

    def gemm(x, planes, mode="folded", *, layout="dense", logical_k=None,
             b_block=None, n_block=None, k_block=None):
        preflight("gemm", x, planes, layout=layout, logical_k=logical_k,
                  mode=mode, b_block=b_block, n_block=n_block,
                  k_block=k_block)
        return bitplane_gemm(x, planes, mode=mode, interpret=interpret(),
                             layout=layout, logical_k=logical_k,
                             b_block=b_block, n_block=n_block,
                             k_block=k_block)

    def gemm_placed(x, planes, col_ids, mode="folded", *, layout="dense",
                    logical_k=None, window_block=None, b_block=None,
                    n_block=None, k_block=None):
        preflight("gemm", x, planes, layout=layout, logical_k=logical_k,
                  col_ids=col_ids, window_block=window_block, mode=mode,
                  b_block=b_block, n_block=n_block, k_block=k_block)
        return bitplane_gemm_placed(
            x, planes, col_ids, mode=mode, interpret=interpret(),
            layout=layout, logical_k=logical_k, window_block=window_block,
            b_block=b_block, n_block=n_block, k_block=k_block)

    return gemv, gemv_placed, gemm, gemm_placed


def _densify(planes, layout, logical_k):
    """Reference-backend adapter: bit-words -> dense planes (jnp oracle
    input); dense planes pass through untouched."""
    if layout == "bitpack8":
        return ref.unpack_plane_words(planes, logical_k)
    return planes


def _ref_gemv(x, planes, mode="folded", *, layout="dense", logical_k=None,
              b_block=None, n_block=None, k_block=None):
    # Tile overrides are execution hints; the oracle's numerics ignore them
    # (bit-exactness across tuned and heuristic tiles rests on this).
    planes = _densify(planes, layout, logical_k)
    if layout == "bitpack8" and planes.shape[1] != x.shape[1]:
        x = jnp.pad(x, ((0, 0), (0, planes.shape[1] - x.shape[1])))
    return ref.bitplane_gemv_ref(x, planes)


def _ref_gemv_placed(x, planes, col_ids, mode="folded", *, layout="dense",
                     logical_k=None, window_block=None, b_block=None,
                     n_block=None, k_block=None):
    planes = _densify(planes, layout, logical_k)
    if layout == "bitpack8" and planes.shape[1] != x.shape[1]:
        x = jnp.pad(x, ((0, 0), (0, planes.shape[1] - x.shape[1])))
    return ref.bitplane_gemv_placed_ref(x, planes, col_ids)


_pl = _pallas_entries(_pallas_interpret)
register_backend(Backend(
    name="pallas",
    gemv=_pl[0], gemv_placed=_pl[1], gemm=_pl[2], gemm_placed=_pl[3],
))

_it = _pallas_entries(lambda: True, check=True)
register_backend(Backend(
    name="interpret",
    gemv=_it[0], gemv_placed=_it[1], gemm=_it[2], gemm_placed=_it[3],
))

register_backend(Backend(
    name="reference",
    # The jnp oracle is already batch-shaped: the same entry serves both.
    gemv=_ref_gemv,
    gemv_placed=_ref_gemv_placed,
    gemm=_ref_gemv,
    gemm_placed=_ref_gemv_placed,
))
