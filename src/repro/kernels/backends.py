"""Named execution backends for the bit-plane GeMV.

The serving stack picks *where* a packed GeMV executes by name instead of
threading ``interpret``/oracle flags through every call site:

  * ``pallas``    — the Pallas TPU kernel; lowers natively on TPU and falls
    back to interpret mode elsewhere (this container is CPU-only).
  * ``interpret`` — the same Pallas kernel forced through the interpreter,
    regardless of platform.  Useful for debugging kernel changes on TPU.
  * ``reference`` — the pure-jnp oracle (kernels/ref.py).

Every backend implements the same entry points — ``gemv``/``gemv_placed``
for the single-block GeMV and ``gemm``/``gemm_placed`` for the batch-tiled
GEMM the serving engine feeds — and all are bit-exact against each other,
enforced by tests/test_session.py and tests/test_bitplane_gemm.py across
placed and unplaced packs.  ``PUDSession`` selects a backend per session and
per call; register custom ones (e.g. a future GPU lowering) with
``register_backend`` (backends without GEMM lowerings fall back to their
GeMV entry, which already accepts a [B, K] operand block).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from . import ref
from .bitplane_gemm import bitplane_gemm, bitplane_gemm_placed
from .bitplane_gemv import bitplane_gemv, bitplane_gemv_placed

DEFAULT_BACKEND = "pallas"


@dataclasses.dataclass(frozen=True)
class Backend:
    """One named lowering of the bit-plane GeMV/GEMM.

    ``gemv(x, planes, mode)``: [B, K] int8 x [WB, K, N] planes -> [B, N]
    int32 with the whole B in one block (decode-shaped).  ``gemv_placed
    (x, planes, col_ids, mode)``: same, with planes in the physical-window
    layout and the logical->window gather map.  ``gemm``/``gemm_placed``:
    identical signatures and numerics with the batch axis tiled into the
    kernel grid (serving-engine-shaped); None falls back to the GeMV entry.
    """

    name: str
    gemv: Callable[..., jax.Array]
    gemv_placed: Callable[..., jax.Array]
    gemm: Callable[..., jax.Array] | None = None
    gemm_placed: Callable[..., jax.Array] | None = None

    def matmul(self, x, planes, mode="folded"):
        """Batch-tiled entry, falling back to the one-block GeMV."""
        return (self.gemm or self.gemv)(x, planes, mode)

    def matmul_placed(self, x, planes, col_ids, mode="folded"):
        return (self.gemm_placed or self.gemv_placed)(x, planes, col_ids,
                                                      mode)


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    if backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{backend_names()}") from None


def backend_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _pallas_interpret() -> bool:
    # Lower natively only where the BlockSpecs actually target hardware.
    return jax.default_backend() != "tpu"


register_backend(Backend(
    name="pallas",
    gemv=lambda x, planes, mode="folded": bitplane_gemv(
        x, planes, mode=mode, interpret=_pallas_interpret()),
    gemv_placed=lambda x, planes, col_ids, mode="folded":
        bitplane_gemv_placed(x, planes, col_ids, mode=mode,
                             interpret=_pallas_interpret()),
    gemm=lambda x, planes, mode="folded": bitplane_gemm(
        x, planes, mode=mode, interpret=_pallas_interpret()),
    gemm_placed=lambda x, planes, col_ids, mode="folded":
        bitplane_gemm_placed(x, planes, col_ids, mode=mode,
                             interpret=_pallas_interpret()),
))

register_backend(Backend(
    name="interpret",
    gemv=lambda x, planes, mode="folded": bitplane_gemv(
        x, planes, mode=mode, interpret=True),
    gemv_placed=lambda x, planes, col_ids, mode="folded":
        bitplane_gemv_placed(x, planes, col_ids, mode=mode, interpret=True),
    gemm=lambda x, planes, mode="folded": bitplane_gemm(
        x, planes, mode=mode, interpret=True),
    gemm_placed=lambda x, planes, col_ids, mode="folded":
        bitplane_gemm_placed(x, planes, col_ids, mode=mode, interpret=True),
))

register_backend(Backend(
    name="reference",
    # The jnp oracle is already batch-shaped: the same entry serves both.
    gemv=lambda x, planes, mode="folded": ref.bitplane_gemv_ref(x, planes),
    gemv_placed=lambda x, planes, col_ids, mode="folded":
        ref.bitplane_gemv_placed_ref(x, planes, col_ids),
    gemm=lambda x, planes, mode="folded": ref.bitplane_gemv_ref(x, planes),
    gemm_placed=lambda x, planes, col_ids, mode="folded":
        ref.bitplane_gemv_placed_ref(x, planes, col_ids),
))
