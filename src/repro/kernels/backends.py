"""Named execution backends for the bit-plane GeMV.

The serving stack picks *where* a packed GeMV executes by name instead of
threading ``interpret``/oracle flags through every call site:

  * ``pallas``    — the Pallas TPU kernel; lowers natively on TPU and falls
    back to interpret mode elsewhere (this container is CPU-only).
  * ``interpret`` — the same Pallas kernel forced through the interpreter,
    regardless of platform.  Useful for debugging kernel changes on TPU.
  * ``reference`` — the pure-jnp oracle (kernels/ref.py).

Every backend implements the same two entry points (``gemv`` for the logical
layout, ``gemv_placed`` for the column-placed layout) and all are bit-exact
against each other — enforced by tests/test_session.py across placed and
unplaced packs.  ``PUDSession`` selects a backend per session and per call;
register custom ones (e.g. a future GPU lowering) with ``register_backend``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from . import ref
from .bitplane_gemv import bitplane_gemv, bitplane_gemv_placed

DEFAULT_BACKEND = "pallas"


@dataclasses.dataclass(frozen=True)
class Backend:
    """One named lowering of the bit-plane GeMV.

    ``gemv(x, planes, mode)``: [B, K] int8 x [WB, K, N] planes -> [B, N]
    int32.  ``gemv_placed(x, planes, col_ids, mode)``: same, with planes in
    the physical-window layout and the logical->window gather map.
    """

    name: str
    gemv: Callable[..., jax.Array]
    gemv_placed: Callable[..., jax.Array]


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    if backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{backend_names()}") from None


def backend_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _pallas_interpret() -> bool:
    # Lower natively only where the BlockSpecs actually target hardware.
    return jax.default_backend() != "tpu"


register_backend(Backend(
    name="pallas",
    gemv=lambda x, planes, mode="folded": bitplane_gemv(
        x, planes, mode=mode, interpret=_pallas_interpret()),
    gemv_placed=lambda x, planes, col_ids, mode="folded":
        bitplane_gemv_placed(x, planes, col_ids, mode=mode,
                             interpret=_pallas_interpret()),
))

register_backend(Backend(
    name="interpret",
    gemv=lambda x, planes, mode="folded": bitplane_gemv(
        x, planes, mode=mode, interpret=True),
    gemv_placed=lambda x, planes, col_ids, mode="folded":
        bitplane_gemv_placed(x, planes, col_ids, mode=mode, interpret=True),
))

register_backend(Backend(
    name="reference",
    gemv=lambda x, planes, mode="folded": ref.bitplane_gemv_ref(x, planes),
    gemv_placed=lambda x, planes, col_ids, mode="folded":
        ref.bitplane_gemv_placed_ref(x, planes, col_ids),
))
