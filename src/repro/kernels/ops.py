"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware pass interpret=False (the BlockSpecs are TPU-shaped: 128-lane
aligned columns, MXU-aligned matmul tiles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .backends import get_backend
from .bitplane_gemm import B_BLOCK, bitplane_gemm, bitplane_gemm_placed
from .bitplane_gemv import (K_BLOCK, N_BLOCK, bitplane_gemv,
                            bitplane_gemv_placed)
from .bitplane_gemv import _largest_divisor as largest_divisor
from .majx import calib_iter_fused, majx_sense

__all__ = [
    "majx_sense", "calib_iter_fused", "bitplane_gemv",
    "bitplane_gemv_placed", "bitplane_gemm", "bitplane_gemm_placed",
    "pud_matmul", "pud_gemv", "quantize_activations",
    # Tiling facts re-exported for non-kernel consumers (pud/placement.py,
    # analysis/contracts.py): the kernel implementation modules are private
    # to this package — the repo lint enforces that — so the block
    # constants and the divisor rule travel through this public surface.
    "B_BLOCK", "K_BLOCK", "N_BLOCK", "largest_divisor",
]


def quantize_activations(x: jax.Array, clip: float = 4.0) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization for the PUD GeMV input.

    Row-independent by construction (per-row scale), so batched and
    per-request execution quantize each request identically — the property
    the batched-vs-sequential bit-exactness guarantee rests on.
    """
    scale = jnp.maximum(jnp.abs(x).max(axis=-1, keepdims=True), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pud_matmul(
    x: jax.Array,          # [B, K] float activations
    planes: jax.Array,     # [WB, K(/8), N] bit-planes / bit-words
    w_scale: jax.Array,    # [N] or scalar dequant scale
    mode: str = "folded",
    interpret: bool = True,
    col_ids: jax.Array | None = None,   # [N] window map -> placed kernel
    backend: str | None = None,         # named backend (kernels/backends.py)
    layout: str = "dense",              # plane storage (repro/pud/packed.py)
    logical_k: int | None = None,       # un-padded K of a bit-packed pack
    window_block: int | None = None,    # placed window stride (block-aligned)
    check_contracts: bool = False,      # pre-flight analysis/contracts.py
) -> jax.Array:
    """Quantize -> bit-plane GEMM -> dequantize. Returns [B, N] float32.

    The batched primary entry of the PUD execution path: B = 1 runs the
    decode-shaped GeMV kernel (whole batch in one block, the faithful
    single-vector schedule), B > 1 the batch-tiled GEMM kernel — bit-exact
    against each other, so the dispatch is purely a tiling decision.

    With ``col_ids`` the planes are the physically-placed window layout
    (repro/pud/placement.py) and the column gather runs fused in the kernel.
    ``layout``/``logical_k``/``window_block`` carry the pack-format
    metadata of a ``PackedTensor`` (bit-packed words unpack inside the
    kernel).  ``backend`` names a registered lowering; without one the
    legacy ``interpret`` flag picks between the interpreted and native
    Pallas kernel.  All backends are bit-exact against each other.

    ``check_contracts=True`` runs the static kernel-contract checker
    (repro/analysis/contracts.py) over the resolved entry point before
    dispatch — tile selection, layout metadata consistency, placed-window
    bounds, VMEM budget — raising ``ContractViolation`` instead of letting
    a mis-built pack fail deep inside the kernel (the ``interpret``
    backend runs the same check unconditionally).
    """
    xq, x_scale = quantize_activations(x)
    be = get_backend(backend or ("interpret" if interpret else "pallas"))
    batched = xq.shape[0] > 1
    if check_contracts:
        from repro.analysis.contracts import check_kernel_args

        check_kernel_args(
            "gemm" if batched else "gemv", xq.shape, planes.shape,
            layout=layout, logical_k=logical_k, col_ids=col_ids,
            window_block=window_block, mode=mode)
    # Layout kwargs only travel when they carry information: a legacy dense
    # pack dispatches through the pre-refactor 3-arg entry signature, so
    # custom backends registered against it keep working (bit-packed packs
    # genuinely require the layout-aware signature).
    kw = {}
    if layout != "dense":
        kw = {"layout": layout, "logical_k": logical_k}
    if col_ids is not None:
        if window_block is not None:
            kw["window_block"] = window_block
        acc = (be.matmul_placed(xq, planes, col_ids, mode, **kw) if batched
               else be.gemv_placed(xq, planes, col_ids, mode, **kw))
    else:
        acc = (be.matmul(xq, planes, mode, **kw) if batched
               else be.gemv(xq, planes, mode, **kw))
    return acc.astype(jnp.float32) * x_scale * w_scale


def pud_gemv(
    x: jax.Array,          # [K] or [B, K] float activations
    planes: jax.Array,
    w_scale: jax.Array,
    mode: str = "folded",
    interpret: bool = True,
    col_ids: jax.Array | None = None,
    backend: str | None = None,
    layout: str = "dense",
    logical_k: int | None = None,
    window_block: int | None = None,
    check_contracts: bool = False,
) -> jax.Array:
    """Rank-dispatching shim over ``pud_matmul``.

    Kept as the legacy single-request entry: a 1-D ``x`` [K] returns [N],
    a 2-D ``x`` [B, K] behaves exactly like ``pud_matmul``.
    """
    kw = dict(mode=mode, interpret=interpret, col_ids=col_ids,
              backend=backend, layout=layout, logical_k=logical_k,
              window_block=window_block, check_contracts=check_contracts)
    if x.ndim == 1:
        return pud_matmul(x[None, :], planes, w_scale, **kw)[0]
    return pud_matmul(x, planes, w_scale, **kw)


def pud_gemv_ref(x, planes, w_scale, col_ids=None):
    xq, x_scale = quantize_activations(x)
    if col_ids is not None:
        acc = ref.bitplane_gemv_placed_ref(xq, planes, col_ids)
    else:
        acc = ref.bitplane_gemv_ref(xq, planes)
    return acc.astype(jnp.float32) * x_scale * w_scale
