"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware pass interpret=False (the BlockSpecs are TPU-shaped: 128-lane
aligned columns, MXU-aligned matmul tiles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .autotune import TunedTile, plan_for_entry, tune_kernel, tuning_key
from .backends import get_backend
from .bitplane_gemm import B_BLOCK, bitplane_gemm, bitplane_gemm_placed
from .bitplane_gemv import (DEGENERATE_TILE_FLOOR, K_BLOCK, N_BLOCK,
                            bitplane_gemv, bitplane_gemv_placed)
from .bitplane_gemv import _heuristic_block as heuristic_block
from .bitplane_gemv import _largest_divisor as largest_divisor
from .majx import calib_iter_fused, majx_sense

__all__ = [
    "majx_sense", "calib_iter_fused", "bitplane_gemv",
    "bitplane_gemv_placed", "bitplane_gemm", "bitplane_gemm_placed",
    "pud_matmul", "pud_matmul_sharded", "pud_gemv", "quantize_activations",
    # Autotuner surface (kernels/autotune.py): plans ride packs and the
    # tuning cache through these names.
    "TunedTile", "plan_for_entry", "tune_kernel", "tuning_key",
    # Tiling facts re-exported for non-kernel consumers (pud/placement.py,
    # analysis/contracts.py): the kernel implementation modules are private
    # to this package — the repo lint enforces that — so the block
    # constants and the divisor rule travel through this public surface.
    "B_BLOCK", "K_BLOCK", "N_BLOCK", "DEGENERATE_TILE_FLOOR",
    "largest_divisor", "heuristic_block",
]


def quantize_activations(x: jax.Array, clip: float = 4.0) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization for the PUD GeMV input.

    Row-independent by construction (per-row scale), so batched and
    per-request execution quantize each request identically — the property
    the batched-vs-sequential bit-exactness guarantee rests on.
    """
    scale = jnp.maximum(jnp.abs(x).max(axis=-1, keepdims=True), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pud_matmul(
    x: jax.Array,          # [B, K] float activations
    planes: jax.Array,     # [WB, K(/8), N] bit-planes / bit-words
    w_scale: jax.Array,    # [N] or scalar dequant scale
    mode: str = "folded",
    interpret: bool = True,
    col_ids: jax.Array | None = None,   # [N] window map -> placed kernel
    backend: str | None = None,         # named backend (kernels/backends.py)
    layout: str = "dense",              # plane storage (repro/pud/packed.py)
    logical_k: int | None = None,       # un-padded K of a bit-packed pack
    window_block: int | None = None,    # placed window stride (block-aligned)
    check_contracts: bool = False,      # pre-flight analysis/contracts.py
    tile_plan=None,                     # TunedTile | ((entry, TunedTile), ...)
) -> jax.Array:
    """Quantize -> bit-plane GEMM -> dequantize. Returns [B, N] float32.

    The batched primary entry of the PUD execution path: B = 1 runs the
    decode-shaped GeMV kernel (whole batch in one block, the faithful
    single-vector schedule), B > 1 the batch-tiled GEMM kernel — bit-exact
    against each other, so the dispatch is purely a tiling decision.

    With ``col_ids`` the planes are the physically-placed window layout
    (repro/pud/placement.py) and the column gather runs fused in the kernel.
    ``layout``/``logical_k``/``window_block`` carry the pack-format
    metadata of a ``PackedTensor`` (bit-packed words unpack inside the
    kernel).  ``backend`` names a registered lowering; without one the
    legacy ``interpret`` flag picks between the interpreted and native
    Pallas kernel.  All backends are bit-exact against each other.

    ``check_contracts=True`` runs the static kernel-contract checker
    (repro/analysis/contracts.py) over the resolved entry point before
    dispatch — tile selection, layout metadata consistency, placed-window
    bounds, VMEM budget — raising ``ContractViolation`` instead of letting
    a mis-built pack fail deep inside the kernel (the ``interpret``
    backend runs the same check unconditionally).

    ``tile_plan`` is the autotuner hook: a :class:`TunedTile` (or a tuple
    of ``(entry, TunedTile)`` pairs, resolved after the gemv/gemm dispatch)
    overriding block sizes / window stride / unpack mode.  Plans are
    execution choices only — every plan computes the identical result
    (kernels/autotune.py enforces it at tuning time); cold-start (no plan)
    falls back to the divisor heuristic unchanged.
    """
    xq, x_scale = quantize_activations(x)
    be = get_backend(backend or ("interpret" if interpret else "pallas"))
    batched = xq.shape[0] > 1
    entry = "gemm" if batched else "gemv"
    plan = plan_for_entry(tile_plan, entry)
    eff_mode = (plan.mode or mode) if plan is not None else mode
    eff_window_block = window_block
    if plan is not None and plan.window_block is not None:
        eff_window_block = plan.window_block
    if check_contracts:
        from repro.analysis.contracts import (check_kernel_args,
                                              check_tile_plan)

        if plan is not None:
            check_tile_plan(
                plan, entry, xq.shape, planes.shape, layout=layout,
                logical_k=logical_k, col_ids=col_ids,
                window_block=window_block, mode=mode)
        else:
            check_kernel_args(
                entry, xq.shape, planes.shape,
                layout=layout, logical_k=logical_k, col_ids=col_ids,
                window_block=window_block, mode=mode)
    # Layout kwargs only travel when they carry information: a legacy dense
    # pack dispatches through the pre-refactor 3-arg entry signature, so
    # custom backends registered against it keep working (bit-packed packs
    # genuinely require the layout-aware signature).
    kw = {}
    if layout != "dense":
        kw = {"layout": layout, "logical_k": logical_k}
    if plan is not None:
        if plan.n_block is not None:
            kw["n_block"] = plan.n_block
        if plan.k_block is not None:
            kw["k_block"] = plan.k_block
        if batched and plan.b_block is not None:
            kw["b_block"] = plan.b_block
    if col_ids is not None:
        if eff_window_block is not None:
            kw["window_block"] = eff_window_block
        acc = (be.matmul_placed(xq, planes, col_ids, eff_mode, **kw)
               if batched
               else be.gemv_placed(xq, planes, col_ids, eff_mode, **kw))
    else:
        acc = (be.matmul(xq, planes, eff_mode, **kw) if batched
               else be.gemv(xq, planes, eff_mode, **kw))
    return acc.astype(jnp.float32) * x_scale * w_scale


def pud_matmul_sharded(
    x: jax.Array,          # [B, K] float activations (replicated per device)
    st,                    # ShardedPackedTensor: children stacked [S, ...]
    mode: str = "folded",
    interpret: bool = True,
    backend: str | None = None,
    check_contracts: bool = False,
) -> jax.Array:
    """Tensor-parallel ``pud_matmul`` over the pack's mesh "model" axis.

    ``st`` is a ``pud.packed.ShardedPackedTensor`` (duck-typed here so the
    kernel layer stays import-free of ``pud``): per-shard packs padded to a
    common per-device shape and stacked on a leading shard axis S that maps
    onto ``st.axis`` of ``st.mesh``.  Each device runs the ordinary
    ``pud_matmul`` on its own shard — its own planes, dequant scales and
    (placed layout) ``col_ids`` — with ``x`` replicated in, then the
    per-shard outputs reassemble by static column slices.

    Bit-exact against the unsharded path by construction: activation
    quantization is per-row (identical on every replica), the integer
    accumulation per output column touches exactly the same K values, and
    the dequant multiply order ``acc * x_scale * w_scale`` is the same
    expression ``pud_matmul`` computes — float columns never cross a shard
    boundary, so no re-association happens anywhere.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if st.mesh is None:
        raise ValueError(
            "sharded pack carries no mesh — build it through "
            "PUDFleetSession.pack / pack_model_sharded(mesh=...)")
    axis = st.axis
    placed = st.col_ids is not None
    kw = dict(mode=mode, interpret=interpret,
              backend=backend or st.backend, layout=st.layout,
              logical_k=st.logical_k, window_block=st.window_block,
              check_contracts=check_contracts, tile_plan=st.tile_plan)

    if placed:
        def body(xr, planes, scale, col_ids):
            return pud_matmul(xr, planes[0], scale[0],
                              col_ids=col_ids[0], **kw)[None]

        f = shard_map(body, mesh=st.mesh,
                      in_specs=(P(), P(axis), P(axis), P(axis)),
                      out_specs=P(axis), check_rep=False)
        y = f(x, st.planes, st.scale, st.col_ids)
    else:
        def body(xr, planes, scale):
            return pud_matmul(xr, planes[0], scale[0], **kw)[None]

        f = shard_map(body, mesh=st.mesh,
                      in_specs=(P(), P(axis), P(axis)),
                      out_specs=P(axis), check_rep=False)
        y = f(x, st.planes, st.scale)
    # [S, B, Np] -> [B, N]: drop per-shard padding columns, concatenate in
    # logical order (shards own contiguous column ranges by construction).
    parts = [y[i, :, :w] for i, w in enumerate(st.shard_widths) if w]
    return jnp.concatenate(parts, axis=-1)


def pud_gemv(
    x: jax.Array,          # [K] or [B, K] float activations
    planes: jax.Array,
    w_scale: jax.Array,
    mode: str = "folded",
    interpret: bool = True,
    col_ids: jax.Array | None = None,
    backend: str | None = None,
    layout: str = "dense",
    logical_k: int | None = None,
    window_block: int | None = None,
    check_contracts: bool = False,
    tile_plan=None,
) -> jax.Array:
    """Rank-dispatching shim over ``pud_matmul``.

    Kept as the legacy single-request entry: a 1-D ``x`` [K] returns [N],
    a 2-D ``x`` [B, K] behaves exactly like ``pud_matmul``.
    """
    kw = dict(mode=mode, interpret=interpret, col_ids=col_ids,
              backend=backend, layout=layout, logical_k=logical_k,
              window_block=window_block, check_contracts=check_contracts,
              tile_plan=tile_plan)
    if x.ndim == 1:
        return pud_matmul(x[None, :], planes, w_scale, **kw)[0]
    return pud_matmul(x, planes, w_scale, **kw)


def pud_gemv_ref(x, planes, w_scale, col_ids=None):
    xq, x_scale = quantize_activations(x)
    if col_ids is not None:
        acc = ref.bitplane_gemv_placed_ref(xq, planes, col_ids)
    else:
        acc = ref.bitplane_gemv_ref(xq, planes)
    return acc.astype(jnp.float32) * x_scale * w_scale
