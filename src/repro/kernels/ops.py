"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware pass interpret=False (the BlockSpecs are TPU-shaped: 128-lane
aligned columns, MXU-aligned matmul tiles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .backends import get_backend
from .bitplane_gemv import bitplane_gemv, bitplane_gemv_placed
from .majx import majx_sense

__all__ = [
    "majx_sense", "bitplane_gemv", "bitplane_gemv_placed", "pud_gemv",
    "quantize_activations",
]


def quantize_activations(x: jax.Array, clip: float = 4.0) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization for the PUD GeMV input."""
    scale = jnp.maximum(jnp.abs(x).max(axis=-1, keepdims=True), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pud_gemv(
    x: jax.Array,          # [B, K] float activations
    planes: jax.Array,     # [WB, K, N] int8 bit-planes (offset-binary)
    w_scale: jax.Array,    # [N] or scalar dequant scale
    mode: str = "folded",
    interpret: bool = True,
    col_ids: jax.Array | None = None,   # [N] window map -> placed kernel
    backend: str | None = None,         # named backend (kernels/backends.py)
) -> jax.Array:
    """Quantize -> bit-plane GeMV -> dequantize. Returns [B, N] float32.

    With ``col_ids`` the planes are the physically-placed window layout
    (repro/pud/placement.py) and the column gather runs fused in the kernel.
    ``backend`` names a registered lowering; without one the legacy
    ``interpret`` flag picks between the interpreted and native Pallas
    kernel.  All backends are bit-exact against each other.
    """
    xq, x_scale = quantize_activations(x)
    be = get_backend(backend or ("interpret" if interpret else "pallas"))
    if col_ids is not None:
        acc = be.gemv_placed(xq, planes, col_ids, mode)
    else:
        acc = be.gemv(xq, planes, mode)
    return acc.astype(jnp.float32) * x_scale * w_scale


def pud_gemv_ref(x, planes, w_scale, col_ids=None):
    xq, x_scale = quantize_activations(x)
    if col_ids is not None:
        acc = ref.bitplane_gemv_placed_ref(xq, planes, col_ids)
    else:
        acc = ref.bitplane_gemv_ref(xq, planes)
    return acc.astype(jnp.float32) * x_scale * w_scale
