"""Kernel autotuner: search the tile/strategy space, keep only what wins.

The four bit-plane entry points pick block sizes with a *correctness*
heuristic (`largest_divisor`, degenerate-safe since the pow2 fallback) and
take the unpack strategy (``planes`` vs ``folded``) as a caller choice.
BENCH_kernels.json shows the winners flip between logical and placed
layouts, so a static choice leaves measured tokens/s on the table — the
same observation Proteus makes for PUD execution configs (PAPERS.md): adapt
the configuration to the workload instead of fixing it per tensor.

This module is the search half of that loop:

  * :class:`TunedTile` — a frozen, hashable tile plan (``b_block`` /
    ``n_block`` / ``k_block`` / ``window_block`` / ``mode``; None fields
    defer to the kernel's own heuristic), serializable for the persistent
    :class:`repro.runtime.tune.TuningCache`.
  * :func:`candidate_plans` — the search space: divisor and padded
    power-of-two blocks around the MXU-aligned caps, window-block grouping
    multiples for placed packs, both unpack modes.  Every candidate is
    pre-validated through ``analysis.contracts.check_tile_plan`` so no
    candidate can violate the 4 MiB VMEM gate (or any other kernel
    invariant) — invalid geometry is pruned, not timed.
  * :func:`tune_kernel` — warmup + ``block_until_ready`` median timing of
    each surviving candidate on a real operand set, cross-checking every
    candidate's output bit-exact against the heuristic plan (all tiles and
    modes compute the identical integer result; a mismatch is a kernel bug
    and raises).  The heuristic plan itself is always candidate #0, so the
    tuned winner is never slower than the fallback by construction.

The persistence half (cache files, fingerprints, CLI) lives in
``repro/runtime/tune.py``; the consumption half is ``ops.pud_matmul(...,
tile_plan=)`` / ``PUDSession.tune()``.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.analysis.errors import ContractViolation

from .backends import get_backend
from .bitplane_gemm import B_BLOCK
from .bitplane_gemv import K_BLOCK, N_BLOCK, _largest_divisor, _pow2_block

MODES = ("planes", "folded")

#: Search-space fields of one plan, in serialization order.
PLAN_FIELDS = ("b_block", "n_block", "k_block", "window_block", "mode")


@dataclasses.dataclass(frozen=True)
class TunedTile:
    """One execution plan for a bit-plane kernel call.

    Every field is optional: None defers to the kernel wrapper's built-in
    heuristic, so ``TunedTile()`` *is* the heuristic plan (the cold-start
    fallback).  Frozen and hashable — packs carry plans inside their jit
    static aux data.  ``k_block`` is in logical-K units (a multiple of 8
    for bit-packed packs, naming whole word rows); ``window_block`` must be
    a multiple of the pack's placed stride (``contracts.check_tile_plan``
    enforces it).
    """

    b_block: int | None = None
    n_block: int | None = None
    k_block: int | None = None
    window_block: int | None = None
    mode: str | None = None

    def is_default(self) -> bool:
        return all(getattr(self, f) is None for f in PLAN_FIELDS)

    def to_dict(self) -> dict:
        return {f: getattr(self, f) for f in PLAN_FIELDS
                if getattr(self, f) is not None}

    @classmethod
    def from_dict(cls, d: dict) -> "TunedTile":
        unknown = set(d) - set(PLAN_FIELDS)
        if unknown:
            raise ValueError(f"unknown TunedTile fields {sorted(unknown)}")
        return cls(**d)


def plan_for_entry(tile_plan, entry: str) -> TunedTile | None:
    """Resolve a pack-level ``tile_plan`` stamp for one entry point.

    Packs carry either a single :class:`TunedTile` (both entries share it)
    or a tuple of ``(entry, TunedTile)`` pairs keyed ``"gemv"``/``"gemm"``
    (hashable, so it can ride in jit-static aux data).  Returns None when
    no plan applies — the caller falls back to the heuristic.
    """
    if tile_plan is None:
        return None
    if isinstance(tile_plan, TunedTile):
        return tile_plan
    for key, plan in tile_plan:
        if key == entry:
            return plan
    return None


def tuning_key(entry: str, b: int, k: int, n: int, wb: int,
               layout: str, placed: bool) -> str:
    """Cache key of one tuning problem: the full (kernel, layout, format,
    shape) coordinate.  ``mode`` is searched, not keyed — every mode is
    bit-exact, so the winner subsumes the choice."""
    kind = "placed" if placed else "logical"
    return f"{entry}__{kind}__{layout}__{b}x{k}x{n}@{wb}b"


def median_time(fn, *, warmup: int = 1, reps: int = 3):
    """(median seconds, last output) of ``fn()`` with compile warmup and
    ``block_until_ready`` around every timed call."""
    out = None
    for _ in range(max(warmup, 1)):
        out = jax.block_until_ready(fn())
    times = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def _block_choices(dim: int, cap: int, *, step: int = 1) -> list[int]:
    """Candidate block sizes for one axis: divisors at a few caps plus the
    padded power-of-two, all multiples of ``step`` (8 for the bitpack8
    K axis), deduplicated and sorted."""
    caps = sorted({cap, cap // 2, cap // 4})
    out = set()
    for c in caps:
        if c >= step:
            d = _largest_divisor(dim, c)
            if d % step == 0:
                out.add(d)
    p = _pow2_block(dim, cap)
    if p % step == 0:
        out.add(p)
    if dim <= cap and dim % step == 0:
        out.add(dim)
    return sorted(b for b in out if b > 0)


def candidate_plans(entry: str, b: int, k: int, n: int, *,
                    layout: str = "dense", placed_window: int | None = None,
                    pack_window_block: int | None = None,
                    mode: str = "folded") -> list[TunedTile]:
    """The search space for one tuning key, heuristic plan first.

    Geometry candidates come from divisors at halved caps and the padded
    power-of-two block per axis; placed packs additionally try grouping
    2 or 4 adjacent window blocks per grid step (the only strides the
    block-aligned layout admits without repacking).  Both unpack modes are
    crossed with the geometry.  The list is an upper bound — the caller
    prunes through ``contracts.check_tile_plan`` before timing.
    """
    k_step = 8 if layout == "bitpack8" else 1
    nbs: list[int | None] = [None]
    kbs: list[int | None] = [None]
    if placed_window and pack_window_block:
        # Placed N-tiles must divide the per-window logical column count.
        block_cols = n // (placed_window // pack_window_block)
        nbs += [v for v in _block_choices(block_cols, N_BLOCK)
                if block_cols % v == 0]
    else:
        nbs += _block_choices(n, N_BLOCK)
    kbs += _block_choices(k, K_BLOCK, step=k_step)
    wbs: list[int | None] = [None]
    if placed_window and pack_window_block:
        n_blocks = placed_window // pack_window_block
        wbs += [c * pack_window_block for c in (2, 4)
                if n_blocks % c == 0 and c < n_blocks]
    bbs: list[int | None] = [None]
    if entry == "gemm":
        bbs += [v for v in _block_choices(b, B_BLOCK) if v != b]

    plans: list[TunedTile] = []
    seen = set()
    for m in (None, *(mm for mm in MODES if mm != mode)):
        for bb in bbs:
            for nb in nbs:
                for kb in kbs:
                    for wblk in wbs:
                        plan = TunedTile(b_block=bb, n_block=nb, k_block=kb,
                                         window_block=wblk, mode=m)
                        if plan not in seen:
                            seen.add(plan)
                            plans.append(plan)
    return plans


def valid_candidates(plans, entry: str, x_shape, planes_shape, *,
                     layout: str = "dense", logical_k: int | None = None,
                     col_ids=None, window_block: int | None = None,
                     mode: str = "folded") -> list[TunedTile]:
    """Filter candidates through the static contract checker: every plan
    the tuner will time has already passed the same tile/layout/VMEM
    invariants a derived plan must satisfy."""
    # Deferred: analysis.contracts imports kernels.ops, which imports this
    # module at its own top level.
    from repro.analysis.contracts import check_tile_plan

    out = []
    for plan in plans:
        try:
            check_tile_plan(plan, entry, x_shape, planes_shape,
                            layout=layout, logical_k=logical_k,
                            col_ids=col_ids, window_block=window_block,
                            mode=mode)
        except ContractViolation:
            continue
        out.append(plan)
    return out


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one tuning run: the winning plan plus the evidence."""

    key: str
    plan: TunedTile
    tuned_s: float
    heuristic_s: float
    n_candidates: int

    @property
    def speedup(self) -> float:
        return self.heuristic_s / self.tuned_s if self.tuned_s > 0 else 1.0

    def to_stats(self) -> dict:
        return {"tuned_s": self.tuned_s, "heuristic_s": self.heuristic_s,
                "speedup": self.speedup, "n_candidates": self.n_candidates}


def _call_kwargs(plan: TunedTile, entry: str) -> dict:
    kw = {}
    if plan.n_block is not None:
        kw["n_block"] = plan.n_block
    if plan.k_block is not None:
        kw["k_block"] = plan.k_block
    if entry == "gemm" and plan.b_block is not None:
        kw["b_block"] = plan.b_block
    return kw


def tune_kernel(entry: str, x, planes, *, col_ids=None,
                window_block: int | None = None, layout: str = "dense",
                logical_k: int | None = None, mode: str = "folded",
                backend: str = "pallas", warmup: int = 1, reps: int = 3,
                max_candidates: int = 16) -> TuneResult:
    """Time every valid candidate on real operands; return the winner.

    The heuristic plan (``TunedTile()``) is always timed first, so the
    result's ``plan`` is never slower than the fallback *as measured here*.
    Every candidate's output is cross-checked bit-exact against the
    heuristic's — tiles and modes are execution choices, never numeric
    ones — and a mismatch raises ``ContractViolation`` naming the plan.
    """
    if entry not in ("gemv", "gemm"):
        raise ContractViolation("autotune", "entry",
                                f"unknown entry {entry!r}")
    b, k = int(x.shape[0]), int(x.shape[1])
    wb, n = int(planes.shape[0]), int(planes.shape[-1])
    placed = col_ids is not None
    if placed:
        n = int(col_ids.shape[-1])
    key = tuning_key(entry, b, k, n, wb, layout, placed)
    plans = candidate_plans(
        entry, b, k, n, layout=layout,
        placed_window=int(planes.shape[-1]) if placed else None,
        pack_window_block=(window_block or int(planes.shape[-1]))
        if placed else None, mode=mode)
    plans = valid_candidates(
        plans, entry, x.shape, planes.shape, layout=layout,
        logical_k=logical_k, col_ids=col_ids, window_block=window_block,
        mode=mode)[:max_candidates]
    if not plans or not plans[0].is_default():
        raise ContractViolation(
            "autotune", "tile-plan",
            f"heuristic plan invalid for {key} — the fallback itself "
            "violates a kernel contract")

    be = get_backend(backend)
    layout_kw = {}
    if layout != "dense":
        layout_kw = {"layout": layout, "logical_k": logical_k}

    def run(plan: TunedTile):
        m = plan.mode or mode
        kw = dict(layout_kw, **_call_kwargs(plan, entry))
        if placed:
            pwb = plan.window_block or window_block
            if pwb is not None:
                kw["window_block"] = pwb
            fn = be.matmul_placed if entry == "gemm" else be.gemv_placed
            return fn(x, planes, col_ids, m, **kw)
        fn = be.matmul if entry == "gemm" else be.gemv
        return fn(x, planes, m, **kw)

    best = None
    oracle = None
    heuristic_s = None
    for plan in plans:
        t, out = median_time(lambda p=plan: run(p), warmup=warmup,
                             reps=reps)
        if oracle is None:
            oracle = out
            heuristic_s = t
        elif not bool(jnp.array_equal(out, oracle)):
            raise ContractViolation(
                "autotune", "bit-exactness",
                f"candidate {plan.to_dict()} for {key} diverges from the "
                "heuristic plan's output")
        if best is None or t < best[0]:
            best = (t, plan)
    return TuneResult(key=key, plan=best[1], tuned_s=best[0],
                      heuristic_s=heuristic_s, n_candidates=len(plans))
