"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pud.physics import NEUTRAL, PhysicsParams


def majx_sense_ref(
    charge: jax.Array,        # [T, R, C]
    sense_offset: jax.Array,  # [C]
    noise: jax.Array,         # [T, C]
    params: PhysicsParams = PhysicsParams(),
    n_fracs: int = 0,
) -> jax.Array:
    n_rows = charge.shape[1]
    v = (charge.sum(axis=1) * params.c_cell_ff
         + NEUTRAL * params.c_bitline_ff) / (
        n_rows * params.c_cell_ff + params.c_bitline_ff)
    swing_sq = ((2.0 * (charge - NEUTRAL)) ** 2).sum(axis=1)
    sigma = jnp.sqrt(params.sigma_dynamic**2
                     + params.sigma_frac**2 * float(n_fracs)
                     + params.sigma_transfer**2 * swing_sq)
    return ((v + sigma * noise) > NEUTRAL + sense_offset[None, :]).astype(
        jnp.float32)


def calib_iter_ref(
    inputs: jax.Array,        # [S, M, C] operand bits as float32
    noise: jax.Array,         # [S, C] standard normal
    levels: jax.Array,        # [C] int32
    sense_offset: jax.Array,  # [C]
    params: PhysicsParams,
    n_fracs: int,
    level_qsum: tuple[float, ...],
    level_swing: tuple[float, ...],
    threshold: float,
    maj_inputs: int = 5,
    const_charge_sum: float = 0.0,
    const_swing_sq: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels/majx.calib_iter_fused (same math, pure jnp)."""
    qsum = jnp.asarray(level_qsum, jnp.float32)[levels]      # [C]
    swing = jnp.asarray(level_swing, jnp.float32)[levels]    # [C]
    charge_sum = inputs.sum(axis=1) + qsum[None, :] + const_charge_sum
    v = params.bitline_voltage(charge_sum, params.n_simra_rows)
    swing_sq = (((2.0 * (inputs - NEUTRAL)) ** 2).sum(axis=1)
                + swing[None, :] + const_swing_sq)
    sigma = params.sensing_sigma(jnp.float32(n_fracs), swing_sq)
    out = ((v + sigma * noise) > (NEUTRAL + sense_offset[None, :])).astype(
        jnp.float32)
    truth = (inputs.sum(axis=1) > maj_inputs // 2).astype(jnp.float32)
    bias = (out - truth).sum(axis=0) / inputs.shape[0]
    step = jnp.where(bias > threshold, -1, 0) + jnp.where(
        bias < -threshold, 1, 0)
    new_levels = jnp.clip(levels + step, 0, len(level_qsum) - 1)
    return new_levels, bias


def bitplane_gemv_ref(x: jax.Array, planes: jax.Array) -> jax.Array:
    """[B,K] int8 x [WB,K,N] bit-planes -> [B,N] int32 signed GeMV."""
    wb = planes.shape[0]
    weights = sum((planes[b].astype(jnp.int32) << b) for b in range(wb))
    weights = weights - (1 << (wb - 1))
    return jax.lax.dot_general(
        x.astype(jnp.int32), weights, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def bitplane_gemv_placed_ref(x: jax.Array, planes: jax.Array,
                             col_ids: jax.Array) -> jax.Array:
    """Placed oracle: gather logical columns out of the physical window
    [WB, K, P] with ``col_ids`` [N], then the plain bit-plane GeMV."""
    return bitplane_gemv_ref(x, jnp.take(planes, col_ids, axis=2))


def pack_bitplanes(w: jax.Array, n_bits: int) -> jax.Array:
    """Signed int weights [K,N] in [-2^{b-1}, 2^{b-1}) -> [WB,K,N] bit-planes.

    Offset-binary: planes encode u = w + 2^{WB-1} in {0 .. 2^WB - 1}.
    """
    u = (w.astype(jnp.int32) + (1 << (n_bits - 1))).astype(jnp.int32)
    shifts = jnp.arange(n_bits, dtype=jnp.int32)
    planes = (u[None] >> shifts[:, None, None]) & 1
    return planes.astype(jnp.int8)
