"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pud.physics import NEUTRAL, PhysicsParams


def majx_sense_ref(
    charge: jax.Array,        # [T, R, C]
    sense_offset: jax.Array,  # [C]
    noise: jax.Array,         # [T, C]
    params: PhysicsParams = PhysicsParams(),
    n_fracs: int = 0,
) -> jax.Array:
    n_rows = charge.shape[1]
    v = (charge.sum(axis=1) * params.c_cell_ff
         + NEUTRAL * params.c_bitline_ff) / (
        n_rows * params.c_cell_ff + params.c_bitline_ff)
    swing_sq = ((2.0 * (charge - NEUTRAL)) ** 2).sum(axis=1)
    sigma = jnp.sqrt(params.sigma_dynamic**2
                     + params.sigma_frac**2 * float(n_fracs)
                     + params.sigma_transfer**2 * swing_sq)
    return ((v + sigma * noise) > NEUTRAL + sense_offset[None, :]).astype(
        jnp.float32)


def calib_iter_ref(
    inputs: jax.Array,        # [S, M, C] operand bits as float32
    noise: jax.Array,         # [S, C] standard normal
    levels: jax.Array,        # [C] int32
    sense_offset: jax.Array,  # [C]
    params: PhysicsParams,
    n_fracs: int,
    level_qsum: tuple[float, ...],
    level_swing: tuple[float, ...],
    threshold: float,
    maj_inputs: int = 5,
    const_charge_sum: float = 0.0,
    const_swing_sq: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels/majx.calib_iter_fused (same math, pure jnp)."""
    qsum = jnp.asarray(level_qsum, jnp.float32)[levels]      # [C]
    swing = jnp.asarray(level_swing, jnp.float32)[levels]    # [C]
    charge_sum = inputs.sum(axis=1) + qsum[None, :] + const_charge_sum
    v = params.bitline_voltage(charge_sum, params.n_simra_rows)
    swing_sq = (((2.0 * (inputs - NEUTRAL)) ** 2).sum(axis=1)
                + swing[None, :] + const_swing_sq)
    sigma = params.sensing_sigma(jnp.float32(n_fracs), swing_sq)
    out = ((v + sigma * noise) > (NEUTRAL + sense_offset[None, :])).astype(
        jnp.float32)
    truth = (inputs.sum(axis=1) > maj_inputs // 2).astype(jnp.float32)
    bias = (out - truth).sum(axis=0) / inputs.shape[0]
    step = jnp.where(bias > threshold, -1, 0) + jnp.where(
        bias < -threshold, 1, 0)
    new_levels = jnp.clip(levels + step, 0, len(level_qsum) - 1)
    return new_levels, bias


def bitplane_gemv_ref(x: jax.Array, planes: jax.Array) -> jax.Array:
    """[B,K] int8 x [WB,K,N] bit-planes -> [B,N] int32 signed GeMV."""
    wb = planes.shape[0]
    weights = sum((planes[b].astype(jnp.int32) << b) for b in range(wb))
    weights = weights - (1 << (wb - 1))
    return jax.lax.dot_general(
        x.astype(jnp.int32), weights, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def bitplane_gemv_placed_ref(x: jax.Array, planes: jax.Array,
                             col_ids: jax.Array) -> jax.Array:
    """Placed oracle: gather logical columns out of the physical window
    [WB, K, P] with ``col_ids`` [N], then the plain bit-plane GeMV."""
    return bitplane_gemv_ref(x, jnp.take(planes, col_ids, axis=2))


def pack_bitplanes(w: jax.Array, n_bits: int) -> jax.Array:
    """Signed int weights [K,N] in [-2^{b-1}, 2^{b-1}) -> [WB,K,N] bit-planes.

    Offset-binary: planes encode u = w + 2^{WB-1} in {0 .. 2^WB - 1}.
    """
    u = (w.astype(jnp.int32) + (1 << (n_bits - 1))).astype(jnp.int32)
    shifts = jnp.arange(n_bits, dtype=jnp.int32)
    planes = (u[None] >> shifts[:, None, None]) & 1
    return planes.astype(jnp.int8)


# ---------------------------------------------------------------------------
# Bit-word packing: the HBM layout the bit-packed serving path streams.
#
# Two candidate word axes exist (docs/kernels.md weighs them); the shipped
# format is the K axis: the N axis is the TPU lane axis AND the placement/
# gather axis, so keeping it element-addressable means ``col_ids`` gathers,
# block-aligned placed windows and per-channel scales all work on words
# unchanged, and the in-kernel unpack is a sublane-axis shift-mask-reshape.
# The N-axis uint32 variant is kept for the format comparison + property
# tests only.
# ---------------------------------------------------------------------------


def pack_plane_words(planes: jax.Array) -> jax.Array:
    """Dense bit-planes [WB, K, N] int8 in {0,1} -> [WB, ceil(K/8), N] uint8.

    Eight consecutive K rows fold into one byte, LSB-first: bit j of word i
    is the plane bit at k = i*8 + j.  K pads up to a byte multiple with zero
    bits (harmless: the kernel zero-pads the matching activation rows).
    """
    wb, k, n = planes.shape
    kw = -(-k // 8)
    p = jnp.pad(planes, ((0, 0), (0, kw * 8 - k), (0, 0)))
    p = p.reshape(wb, kw, 8, n).astype(jnp.uint32)
    shifts = jnp.arange(8, dtype=jnp.uint32)
    return (p << shifts[None, None, :, None]).sum(axis=2).astype(jnp.uint8)


def unpack_plane_words(words: jax.Array, k: int | None = None) -> jax.Array:
    """[WB, Kw, N] uint8 words -> dense [WB, k, N] int8 bit-planes.

    Exact inverse of ``pack_plane_words``; ``k`` slices off the byte-pad
    rows (default: all Kw*8 rows).
    """
    wb, kw, n = words.shape
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (words.astype(jnp.int32)[:, :, None, :]
            >> shifts[None, None, :, None]) & 1
    planes = bits.reshape(wb, kw * 8, n).astype(jnp.int8)
    return planes[:, : (kw * 8 if k is None else k), :]


def pack_plane_words_n(planes: jax.Array) -> jax.Array:
    """The rejected candidate axis: [WB, K, N] -> [WB, K, ceil(N/32)] uint32.

    32 consecutive N columns fold into one word, LSB-first.  Kept for the
    round-trip property tests that justify the K-axis choice — packing the
    lane axis would force a lane-interleaving unpack in-kernel and break
    column addressability (placement gathers, per-channel scales).
    """
    wb, k, n = planes.shape
    nw = -(-n // 32)
    p = jnp.pad(planes, ((0, 0), (0, 0), (0, nw * 32 - n)))
    p = p.reshape(wb, k, nw, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (p << shifts[None, None, None, :]).sum(axis=3).astype(jnp.uint32)


def unpack_plane_words_n(words: jax.Array, n: int | None = None) -> jax.Array:
    """Inverse of ``pack_plane_words_n``: [WB, K, Nw] uint32 -> [WB, K, n]."""
    wb, k, nw = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, :, None] >> shifts[None, None, None, :]) & 1
    planes = bits.reshape(wb, k, nw * 32).astype(jnp.int8)
    return planes[:, :, : (nw * 32 if n is None else n)]
