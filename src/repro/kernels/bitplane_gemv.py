"""Pallas TPU kernel: bit-plane GeMV — the MVDRAM compute pattern on the MXU.

MVDRAM [4] executes low-bit GeMV inside DRAM: weight bits live as *bit-planes*
across 65 536 columns and the product accumulates bit-serially through
MAJ-based adders.  PUDTune's calibration is what makes enough columns reliable
for this to pay off.

TPU adaptation (DESIGN.md §3): bit-serial column adders would waste the MXU.
The TPU-native equivalent keeps the **same HBM data layout** — weights stored
as WB bit-planes W_b in {0,1}, exactly what a PUD subarray would hold — and
turns the bit-serial accumulation into matmuls:

    y = x @ W - 2^{WB-1} * sum_k(x_k)        with  W = sum_b 2^b W_b
      = sum_b 2^b (x @ W_b) - offset         (offset-binary signed weights)

Two execution modes, both lowered by this kernel and oracled by ref.py:

  * ``planes``  — faithful PUD schedule: one MXU pass per bit-plane,
    partial products shifted and accumulated (what the DRAM does, made dense).
  * ``folded``  — beyond-paper optimization: planes are folded to int8 inside
    VMEM (sum_b 2^b W_b) and a single MXU pass per K-tile does the work —
    WB x fewer MXU flops at identical numerics.

Two storage layouts, selected by the static ``layout`` argument:

  * ``"dense"``    — one int8 byte per weight bit, planes [WB, K, N].  The
    legacy format; 8x more HBM bytes than the bits it encodes.
  * ``"bitpack8"`` — eight K rows per uint8 word, planes [WB, ceil(K/8), N].
    The word axis is K (the sublane axis): the unpack inside VMEM is a
    broadcast-shift-mask plus a sublane reshape, while N — the 128-lane
    axis and the placement/gather axis — stays element-addressable.  HBM ->
    VMEM weight traffic and streamed plane residency drop 8x; the dense
    tile exists only as a transient inside the compute stage.

Tiling: grid (N/Nb, K/Kb); K is the reduction axis, accumulated in the output
block across grid steps (out block depends only on the N index).  Block sizes
adapt to the operand: the preferred MXU-aligned tiles are Kb=256, Nb=256, and
non-multiple shapes fall back to the largest divisor (mirroring the GEMM
batch-pad path) instead of asserting.  VMEM per grid step at Kb=Nb=256, WB=4,
B=8: dense streams 4*256*256 planes + 8*256 x + 8*256*4 out ~ 266 KiB;
bit-packed streams 4*32*256 words instead of the planes ~ 42 KiB (see
docs/kernels.md for the full budget math, including the placed window).

The placed variant consumes the *block-aligned* physical window layout
(repro/pud/placement.py): logical N-block j's columns all live inside window
slice [j*window_block, (j+1)*window_block), so the window axis blocks like
any other axis — ``window_block`` columns per grid step instead of the whole
physical window P, and placed VMEM residency is set by the tile, not the
fleet window size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.errors import ContractViolation

K_BLOCK = 256
N_BLOCK = 256

LAYOUTS = ("dense", "bitpack8")

#: Smallest useful block width: a dimension whose largest divisor under the
#: cap falls below this (a prime N or K, e.g. 509 or 127) would serialize
#: the grid into 1-wide tiles, so the heuristic pads to a power-of-two
#: block instead.
DEGENERATE_TILE_FLOOR = 8


def _largest_divisor(dim: int, cap: int) -> int:
    """Largest block size <= cap that divides dim (>= 1)."""
    for d in range(min(dim, cap), 0, -1):
        if dim % d == 0:
            return d
    return 1


def _pow2_block(dim: int, cap: int) -> int:
    """Smallest power of two >= dim, capped at ``cap`` — the padded-block
    fallback for dimensions without a useful divisor."""
    p = 1
    while p < dim and p < cap:
        p *= 2
    return p


def _heuristic_block(dim: int, cap: int) -> int:
    """Divisor heuristic with the degenerate-tile fix.

    A dim with no divisor >= :data:`DEGENERATE_TILE_FLOOR` under the cap
    (prime N or K) used to select 1-wide tiles and silently serialize the
    grid; it now falls back to a padded power-of-two block (the pad is
    zeros, which contribute nothing to the integer dot products and are
    sliced off the output).
    """
    d = _largest_divisor(dim, cap)
    if d < min(dim, DEGENERATE_TILE_FLOOR):
        return _pow2_block(dim, cap)
    return d


def _unpack_bits(words: jax.Array) -> jax.Array:
    """In-VMEM unpack: [WB, Kw, Nb] uint8 words -> [WB, Kw*8, Nb] int8 bits.

    Broadcast-shift-mask along the sublane (K) axis, LSB-first — the exact
    inverse of ``ref.pack_plane_words``.  The dense tile is a compute-stage
    transient; only the 8x smaller words stream HBM -> VMEM.
    """
    wb, kw, nb = words.shape
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (words.astype(jnp.int32)[:, :, None, :]
            >> shifts[None, None, :, None]) & 1
    return bits.reshape(wb, kw * 8, nb).astype(jnp.int8)


def _accumulate(x, planes, out_shape, mode: str, n_bits: int):
    """Shared MXU accumulation: x [B, Kb] x planes [WB, Kb, Nb] -> [B, Nb]."""
    if mode == "folded":
        # Fold bit-planes to int8 weights in VMEM, single MXU pass.
        w = jnp.zeros(planes.shape[1:], jnp.int32)
        for b in range(n_bits):
            w = w + (planes[b].astype(jnp.int32) << b)
        return jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    # Faithful PUD schedule: one pass per plane, shift-accumulate.
    acc = jnp.zeros(out_shape, jnp.int32)
    for b in range(n_bits):
        part = jax.lax.dot_general(
            x, planes[b].astype(jnp.int32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc = acc + (part << b)
    return acc


def _gemv_kernel(x_ref, planes_ref, out_ref, *, mode: str, n_bits: int,
                 k_axis: int = 1, packed: bool = False):
    """``k_axis`` names the grid position of the K reduction axis: 1 for
    the GeMV grid (N, K), 2 for the batch-tiled GEMM grid (B, N, K) —
    bitplane_gemm.py reuses this body with k_axis=2.  ``packed`` marks the
    bit-word layout: the plane tile unpacks inside VMEM."""
    k_idx = pl.program_id(k_axis)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)              # [B, Kb]
    planes = planes_ref[...]
    if packed:
        planes = _unpack_bits(planes)
    out_ref[...] += _accumulate(x, planes, out_ref.shape, mode, n_bits)


def _gemv_placed_kernel(x_ref, cols_ref, planes_ref, out_ref, *,
                        mode: str, n_bits: int, k_axis: int = 1,
                        packed: bool = False, window_block: int = 0):
    """Placed variant: gather physical columns inside the kernel.

    ``planes_ref`` holds ONE window block [WB, Kb(/8), window_block] of this
    tensor's physical region — the block-aligned placed layout guarantees
    the output block's logical columns all live inside it.  ``cols_ref``
    [1, Nb] carries absolute window positions; the in-block residue is a
    modulo.  The gather is fused with the matmul — the permuted planes
    never round-trip through HBM — and runs on the words *before* the
    unpack in the bit-packed layout (8x cheaper gather).
    """
    k_idx = pl.program_id(k_axis)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)              # [B, Kb]
    cols = cols_ref[0, :] % window_block           # [Nb] in-block residues
    planes = jnp.take(planes_ref[...], cols, axis=2)   # [WB, Kb(/8), Nb]
    if packed:
        planes = _unpack_bits(planes)
    out_ref[...] += _accumulate(x, planes, out_ref.shape, mode, n_bits)


def _sign_fix(x: jax.Array, wb: int) -> jax.Array:
    """Offset-binary correction shared by the GeMV and GEMM wrappers:
    planes encode u = w + 2^{WB-1}, so the signed result subtracts
    2^{WB-1} * sum_k x_k per row."""
    return (1 << (wb - 1)) * x.astype(jnp.int32).sum(axis=1, keepdims=True)


def _k_tiling(x: jax.Array, planes: jax.Array, layout: str,
              logical_k: int | None, kernel: str = "bitplane_gemv",
              k_block: int | None = None):
    """Resolve the K-axis tiling for either storage layout.

    Returns (x_padded, planes_padded, planes_k_block, x_k_block, k_steps):
    both operands padded so the block tiles them exactly, the plane/word
    block height, the matching x block width, and the K grid extent.
    Padded x rows are zero, padded word bits are zero, and the sign fix is
    computed from the un-padded x — so the pad contributes exactly nothing
    on both sides.  ``k_block`` is an explicit tuned block in logical-K
    units (a multiple of 8 for bitpack8, where it names whole word rows);
    None picks the degenerate-safe divisor heuristic.  ``kernel`` names
    the entry point in ``ContractViolation`` errors (the same invariants
    the static checker in repro/analysis/contracts.py verifies without
    executing anything).
    """
    k = x.shape[1]
    if layout == "bitpack8":
        kw = planes.shape[1]
        if (logical_k or kw * 8) != k or k > kw * 8:
            raise ContractViolation(
                kernel, "bitpack8-logical-k",
                f"x K={k} inconsistent with word planes Kw={kw} "
                f"(logical_k={logical_k})")
        if k_block is not None:
            if k_block <= 0 or k_block % 8:
                raise ContractViolation(
                    kernel, "tile-plan",
                    f"bitpack8 k_block {k_block} must be a positive "
                    "multiple of 8 (whole word rows)")
            kwb = k_block // 8
        else:
            kwb = _heuristic_block(kw, K_BLOCK // 8)
        kw_pad = -(-kw // kwb) * kwb
        if kw_pad != kw:                 # zero words unpack to zero bits
            planes = jnp.pad(planes, ((0, 0), (0, kw_pad - kw), (0, 0)))
        xp = (jnp.pad(x, ((0, 0), (0, kw_pad * 8 - k)))
              if kw_pad * 8 != k else x)
        return xp, planes, kwb, kwb * 8, kw_pad // kwb
    if layout != "dense":
        raise ContractViolation(
            kernel, "layout",
            f"unknown plane layout {layout!r}; one of {LAYOUTS}")
    if planes.shape[1] != k:
        raise ContractViolation(
            kernel, "k-mismatch",
            f"x {tuple(x.shape)} vs planes {tuple(planes.shape)}")
    if k_block is not None:
        if k_block <= 0:
            raise ContractViolation(
                kernel, "tile-plan", f"k_block {k_block} must be positive")
        kb = k_block
    else:
        kb = _heuristic_block(k, K_BLOCK)
    k_pad = -(-k // kb) * kb
    if k_pad != k:                       # zero x cols x zero plane rows
        x = jnp.pad(x, ((0, 0), (0, k_pad - k)))
        planes = jnp.pad(planes, ((0, 0), (0, k_pad - k), (0, 0)))
    return x, planes, kb, kb, k_pad // kb


def _n_tiling(n: int, n_block: int | None, kernel: str) -> tuple[int, int]:
    """(nb, n_pad) for the logical kernels: an explicit tuned block (the
    operand pads up to a multiple, pad columns are zero planes sliced off
    the output) or the degenerate-safe divisor heuristic."""
    if n_block is not None:
        if n_block <= 0:
            raise ContractViolation(
                kernel, "tile-plan", f"n_block {n_block} must be positive")
        nb = n_block
    else:
        nb = _heuristic_block(n, N_BLOCK)
    return nb, -(-n // nb) * nb


def _placed_n_block(n_block: int | None, block_cols: int,
                    kernel: str) -> int:
    """Placed N-tile: an explicit tuned block must divide the per-window
    logical column count (the placed layout cannot pad the window axis);
    None keeps the divisor heuristic."""
    if n_block is None:
        return _largest_divisor(block_cols, N_BLOCK)
    if n_block <= 0 or block_cols % n_block:
        raise ContractViolation(
            kernel, "tile-plan",
            f"placed n_block {n_block} must divide the {block_cols} "
            "logical columns per window block")
    return n_block


@functools.partial(
    jax.jit,
    static_argnames=("mode", "interpret", "layout", "logical_k",
                     "n_block", "k_block"))
def bitplane_gemv(
    x: jax.Array,        # [B, K] int8 activations
    planes: jax.Array,   # [WB, K, N] int8 bits | [WB, K/8, N] uint8 words
    mode: str = "planes",
    interpret: bool = True,
    layout: str = "dense",
    logical_k: int | None = None,
    n_block: int | None = None,
    k_block: int | None = None,
) -> jax.Array:
    """Offset-binary bit-plane GeMV; returns [B, N] int32 of x @ (W - 2^{WB-1}).

    ``planes`` encode unsigned u = w + 2^{WB-1}; the signed correction
    subtracts 2^{WB-1} * sum_k x_k per output.  ``layout`` selects dense
    int8 planes or K-axis bit-words (unpacked inside the kernel).
    ``n_block``/``k_block`` are tuned tile overrides (kernels/autotune.py);
    non-multiple shapes pad with zeros, which the integer dot products
    never see.
    """
    b, k = x.shape
    wb, _, n = planes.shape
    xp, pp, pkb, xkb, k_steps = _k_tiling(x, planes, layout, logical_k,
                                          k_block=k_block)
    nb, n_pad = _n_tiling(n, n_block, "bitplane_gemv")
    if n_pad != n:                       # zero columns, sliced off below
        pp = jnp.pad(pp, ((0, 0), (0, 0), (0, n_pad - n)))
    grid = (n_pad // nb, k_steps)
    kernel = functools.partial(_gemv_kernel, mode=mode, n_bits=wb,
                               packed=(layout == "bitpack8"))
    unsigned = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, xkb), lambda jn, jk: (0, jk)),
            pl.BlockSpec((wb, pkb, nb), lambda jn, jk: (0, jk, jn)),
        ],
        out_specs=pl.BlockSpec((b, nb), lambda jn, jk: (0, jn)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad), jnp.int32),
        interpret=interpret,
    )(xp, pp)
    return unsigned[:, :n] - _sign_fix(x, wb)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "interpret", "layout", "logical_k",
                     "window_block", "n_block", "k_block"))
def bitplane_gemv_placed(
    x: jax.Array,         # [B, K] int8 activations
    planes: jax.Array,    # [WB, K(/8), W] physical window (placed layout)
    col_ids: jax.Array,   # [N] int32 logical -> window column map
    mode: str = "planes",
    interpret: bool = True,
    layout: str = "dense",
    logical_k: int | None = None,
    window_block: int | None = None,
    n_block: int | None = None,
    k_block: int | None = None,
) -> jax.Array:
    """Column-placed bit-plane GeMV; returns [B, N] like ``bitplane_gemv``.

    ``planes`` is the physically-permuted layout a placement-aware packer
    emits (repro/pud/placement.py): logical column n of the projection lives
    at window position ``col_ids[n]``; the remaining window columns belong
    to faulty/unused physical columns and are never read.  ``window_block``
    is the block-aligned window stride — logical N-block j's columns sit
    inside window slice [j*window_block, (j+1)*window_block), so the kernel
    streams one window block per grid step (None treats the whole window as
    a single block, the degenerate case for hand-built packs).  The gather
    is fused into the kernel per N-block.  Bit-exact vs
    ``ref.bitplane_gemv_placed_ref``.
    """
    b, k = x.shape
    wb, _, w_len = planes.shape
    (n,) = col_ids.shape
    xp, pp, pkb, xkb, k_steps = _k_tiling(x, planes, layout, logical_k,
                                          kernel="bitplane_gemv_placed",
                                          k_block=k_block)
    pwb = window_block or w_len
    if w_len % pwb or n % (w_len // pwb):
        raise ContractViolation(
            "bitplane_gemv_placed", "window-tiling",
            f"window length {w_len} / window_block {pwb} does not tile "
            f"N={n}")
    block_cols = n // (w_len // pwb)
    nb = _placed_n_block(n_block, block_cols, "bitplane_gemv_placed")
    grid = (n // nb, k_steps)
    kernel = functools.partial(_gemv_placed_kernel, mode=mode, n_bits=wb,
                               packed=(layout == "bitpack8"),
                               window_block=pwb)
    unsigned = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, xkb), lambda jn, jk: (0, jk)),
            pl.BlockSpec((1, nb), lambda jn, jk: (0, jn)),
            # one window block per grid step: the block-aligned layout
            # bounds the gather to this output block's window slice
            pl.BlockSpec((wb, pkb, pwb),
                         lambda jn, jk, _nb=nb, _bc=block_cols:
                         (0, jk, (jn * _nb) // _bc)),
        ],
        out_specs=pl.BlockSpec((b, nb), lambda jn, jk: (0, jn)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        interpret=interpret,
    )(xp, col_ids.astype(jnp.int32)[None, :], pp)
    return unsigned - _sign_fix(x, wb)
