"""Pallas TPU kernel: bit-plane GeMV — the MVDRAM compute pattern on the MXU.

MVDRAM [4] executes low-bit GeMV inside DRAM: weight bits live as *bit-planes*
across 65 536 columns and the product accumulates bit-serially through
MAJ-based adders.  PUDTune's calibration is what makes enough columns reliable
for this to pay off.

TPU adaptation (DESIGN.md §3): bit-serial column adders would waste the MXU.
The TPU-native equivalent keeps the **same HBM data layout** — weights stored
as WB bit-planes W_b in {0,1}, exactly what a PUD subarray would hold — and
turns the bit-serial accumulation into matmuls:

    y = x @ W - 2^{WB-1} * sum_k(x_k)        with  W = sum_b 2^b W_b
      = sum_b 2^b (x @ W_b) - offset         (offset-binary signed weights)

Two execution modes, both lowered by this kernel and oracled by ref.py:

  * ``planes``  — faithful PUD schedule: one MXU pass per bit-plane,
    partial products shifted and accumulated (what the DRAM does, made dense).
  * ``folded``  — beyond-paper optimization: planes are folded to int8 inside
    VMEM (sum_b 2^b W_b) and a single MXU pass per K-tile does the work —
    WB x fewer MXU flops at identical numerics.

Tiling: grid (N/Nb, K/Kb); K is the reduction axis, accumulated in the output
block across grid steps (out block depends only on the N index).  Blocks:
x [B, Kb] int8, planes [WB, Kb, Nb] int8, out [B, Nb] int32.  With
Kb=256, Nb=256, WB=4: (4*256*256 + 8*256 + 8*256*4) B ~ 270 KiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_BLOCK = 256
N_BLOCK = 256


def _accumulate(x, planes, out_shape, mode: str, n_bits: int):
    """Shared MXU accumulation: x [B, Kb] x planes [WB, Kb, Nb] -> [B, Nb]."""
    if mode == "folded":
        # Fold bit-planes to int8 weights in VMEM, single MXU pass.
        w = jnp.zeros(planes.shape[1:], jnp.int32)
        for b in range(n_bits):
            w = w + (planes[b].astype(jnp.int32) << b)
        return jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    # Faithful PUD schedule: one pass per plane, shift-accumulate.
    acc = jnp.zeros(out_shape, jnp.int32)
    for b in range(n_bits):
        part = jax.lax.dot_general(
            x, planes[b].astype(jnp.int32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc = acc + (part << b)
    return acc


def _gemv_kernel(x_ref, planes_ref, out_ref, *, mode: str, n_bits: int,
                 k_axis: int = 1):
    """``k_axis`` names the grid position of the K reduction axis: 1 for
    the GeMV grid (N, K), 2 for the batch-tiled GEMM grid (B, N, K) —
    bitplane_gemm.py reuses this body with k_axis=2."""
    k_idx = pl.program_id(k_axis)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)              # [B, Kb]
    out_ref[...] += _accumulate(x, planes_ref[...], out_ref.shape,
                                mode, n_bits)


def _gemv_placed_kernel(x_ref, cols_ref, planes_ref, out_ref, *,
                        mode: str, n_bits: int, k_axis: int = 1):
    """Placed variant: gather physical columns inside the kernel.

    ``planes_ref`` holds the PHYSICAL window [WB, Kb, P] of this tensor's
    column region; ``cols_ref`` [1, Nb] maps this output block's logical
    columns onto window positions.  The gather is fused with the matmul —
    the permuted planes never round-trip through HBM.
    """
    k_idx = pl.program_id(k_axis)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)              # [B, Kb]
    cols = cols_ref[0, :]                          # [Nb] window positions
    planes = jnp.take(planes_ref[...], cols, axis=2)   # [WB, Kb, Nb]
    out_ref[...] += _accumulate(x, planes, out_ref.shape, mode, n_bits)


def _sign_fix(x: jax.Array, wb: int) -> jax.Array:
    """Offset-binary correction shared by the GeMV and GEMM wrappers:
    planes encode u = w + 2^{WB-1}, so the signed result subtracts
    2^{WB-1} * sum_k x_k per row."""
    return (1 << (wb - 1)) * x.astype(jnp.int32).sum(axis=1, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("mode", "interpret"))
def bitplane_gemv(
    x: jax.Array,        # [B, K] int8 activations
    planes: jax.Array,   # [WB, K, N] int8 in {0,1} — offset-binary weight bits
    mode: str = "planes",
    interpret: bool = True,
) -> jax.Array:
    """Offset-binary bit-plane GeMV; returns [B, N] int32 of x @ (W - 2^{WB-1}).

    ``planes`` encode unsigned u = w + 2^{WB-1}; the signed correction
    subtracts 2^{WB-1} * sum_k x_k per output.
    """
    b, k = x.shape
    wb, k2, n = planes.shape
    # Blocks adapt down for sub-block (smoke-scale) dims; full-size archs
    # hit the MXU-aligned 256x256 tiles.
    kb, nb = min(k, K_BLOCK), min(n, N_BLOCK)
    assert k == k2 and k % kb == 0 and n % nb == 0, (x.shape, planes.shape)
    grid = (n // nb, k // kb)
    kernel = functools.partial(_gemv_kernel, mode=mode, n_bits=wb)
    unsigned = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, kb), lambda jn, jk: (0, jk)),
            pl.BlockSpec((wb, kb, nb), lambda jn, jk: (0, jk, jn)),
        ],
        out_specs=pl.BlockSpec((b, nb), lambda jn, jk: (0, jn)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        interpret=interpret,
    )(x, planes)
    return unsigned - _sign_fix(x, wb)


@functools.partial(
    jax.jit, static_argnames=("mode", "interpret"))
def bitplane_gemv_placed(
    x: jax.Array,         # [B, K] int8 activations
    planes: jax.Array,    # [WB, K, P] int8 physical window (placed layout)
    col_ids: jax.Array,   # [N] int32 logical -> window column map
    mode: str = "planes",
    interpret: bool = True,
) -> jax.Array:
    """Column-placed bit-plane GeMV; returns [B, N] like ``bitplane_gemv``.

    ``planes`` is the physically-permuted layout a placement-aware packer
    emits (repro/pud/placement.py): logical column n of the projection lives
    at window position ``col_ids[n]``; the remaining window columns belong
    to faulty/unused physical columns and are never read.  The gather is
    fused into the kernel per N-block.  Bit-exact vs
    ``ref.bitplane_gemv_placed_ref``.
    """
    b, k = x.shape
    wb, k2, p = planes.shape
    (n,) = col_ids.shape
    kb, nb = min(k, K_BLOCK), min(n, N_BLOCK)
    assert k == k2 and k % kb == 0 and n % nb == 0, \
        (x.shape, planes.shape, col_ids.shape)
    grid = (n // nb, k // kb)
    kernel = functools.partial(_gemv_placed_kernel, mode=mode, n_bits=wb)
    unsigned = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, kb), lambda jn, jk: (0, jk)),
            pl.BlockSpec((1, nb), lambda jn, jk: (0, jn)),
            # whole physical window per K-tile: the gather needs arbitrary
            # window columns, so the P axis stays unblocked
            pl.BlockSpec((wb, kb, p), lambda jn, jk: (0, jk, 0)),
        ],
        out_specs=pl.BlockSpec((b, nb), lambda jn, jk: (0, jn)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        interpret=interpret,
    )(x, col_ids.astype(jnp.int32)[None, :], planes)
    return unsigned - _sign_fix(x, wb)
