"""ServingEngine: continuous-batching request scheduler over a PUDSession.

The calibrated array only pays off when it is kept saturated: PUDTune's
extra error-free columns buy 1.81x more parallel MACs per wave, but a
serve loop that decodes one request at a time leaves them idle between
requests.  This engine turns the single-vector decode path into a
multi-request system:

  * **Requests** enter a queue (``submit``); each is one prompt plus a
    token budget and an optional latency deadline.
  * **Slots** — the engine owns a fixed-size padded batch of ``batch_size``
    decode slots and one KV-cache pytree sized ``[L, batch_size, max_len,
    ...]``; every slot holds at most one in-flight request.
  * **Continuous batching** — admission and eviction happen at *step*
    granularity: before every decode step, free slots are filled from the
    queue; after it, finished requests are evicted and their slots freed
    immediately — no waiting for the whole batch to drain.
  * **Bucketed prefill** — prompt lengths are padded to the next power of
    two before the jitted prefill (zero-pad at the tail; the causal mask
    keeps pads invisible to real positions and the logits row is read at
    the true last token), so 20 ragged prompts compile O(log max_len)
    prefill variants instead of 20.
  * **Chunked prefill** (``chunk_prefill=N``) — prefill is split into
    fixed-size chunks that interleave with decode waves: a slot spends
    several steps in the *prefilling* phase (one chunk per step, resumed
    into a private KV cache via ``model.prefill_chunk``) before its first
    token, so one long prompt no longer stalls every decode slot in the
    batch.  Chunked slots finish bit-identically to whole-request prefill
    (chunk rows see exactly the same kv rows/mask-tail as the whole pass).
  * **Prefix cache** (``prefix_cache=True``) — completed prefills are
    stored in an LRU keyed on (params version, prompt-token hash); a
    repeated prompt skips prefill entirely (full hit) and a repeated
    system prompt resumes chunked prefill after the shared prefix
    (partial hit).  The cache is invalidated on every ``stage_params``
    hot swap, so a stale prefix after drift recalibration is impossible.
  * **SLO-aware admission** (``slo=``) — admission is priced by the
    placement perf model (``FleetPerfModel.step_seconds``): requests
    admit in earliest-deadline-first order, hopeless ones shed at
    admission, and expired in-flight ones shed mid-decode; completions
    carry ``slo_met``.
  * **Per-slot positions** — one jitted decode step serves all slots at
    once with a [B] vector of cache lengths (models/attention.py's
    per-slot decode path), so requests admitted at different times decode
    correctly side by side with no host-side Python loop over slots.

Bit-exactness: every per-slot computation (per-row activation quantization,
the integer bit-plane kernel, per-row attention masks, rmsnorm) is
independent of the other batch lanes, so the tokens a request gets from a
batched engine are bit-identical to running it alone — enforced across
backends, layouts and scheduling modes by tests/test_engine.py and
tests/test_chunked_prefill.py.  MoE models are the exception (router
capacity is sequence-global): they keep the legacy exact-length
whole-prompt prefill (``model.supports_chunked_prefill``).

Batch-size selection: with a calibrated + placed ``PUDSession``, the
default ``batch_size`` comes from the placement-derived ``FleetPerfModel``
(``optimal_batch_size`` — weight replicas x operand residency), the point
up to which the DRAM-side aggregate tokens/s grows monotonically.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .prefix_cache import PrefixCache
from .watchdog import StepWatchdog

DEFAULT_MAX_BATCH = 32

#: Fallback modeled decode-step wall time when no perf model is available
#: (SLO virtual clock only; never used for measurement).
DEFAULT_STEP_MS = 5.0

#: run() raises after this many consecutive steps with queued/active work
#: but zero progress (a prefill_budget smaller than the chunk size is the
#: one configuration that can starve forever).
_STALL_LIMIT = 8


def _next_pow2(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass
class Request:
    """One generation request: a prompt, a token budget, and optionally a
    latency deadline (milliseconds from submit, on the engine's modeled
    clock) for SLO-aware admission."""

    request_id: int
    tokens: Any                   # [S] int prompt tokens (array-like)
    max_new_tokens: int
    deadline_ms: float | None = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class Completion:
    """A finished request: generated tokens plus scheduling metadata."""

    request_id: int
    tokens: list[int]             # generated tokens (length = max_new_tokens)
    slot: int
    admitted_step: int            # engine step index at admission
    finished_step: int            # engine step index after the last token
    logits: np.ndarray | None = None   # [gen, V] when collect_logits
    slo_met: bool | None = None   # None when the request had no deadline
    shed: bool = False            # dropped by the SLO policy / shed_request


@dataclasses.dataclass
class SLOConfig:
    """Admission policy knobs for deadline-carrying requests.

    ``step_time_ms`` overrides the modeled per-step wall time (the virtual
    clock the policy prices admission with); by default it comes from the
    session's placement perf model (``step_seconds`` at the engine batch
    size) and falls back to ``DEFAULT_STEP_MS``.  A deterministic modeled
    clock keeps the policy reproducible in tests and independent of host
    jitter.
    """

    default_deadline_ms: float | None = None  # applied when a request has none
    step_time_ms: float | None = None         # virtual-clock override
    shed_on_admit: bool = True                # shed hopeless requests at admit
    shed_admitted: bool = True                # evict expired in-flight requests


@dataclasses.dataclass
class _PrefillState:
    """Per-slot chunked-prefill progress (phase == "prefill")."""

    tokens: np.ndarray            # [bucket] prompt zero-padded to its bucket
    prompt_len: int
    bucket: int                   # pow2 prefill length (kv rows, static)
    chunk: int                    # chunk length (divides bucket)
    pos: int                      # positions < pos are already in the cache
    cache: Any                    # private batch-1 KV pytree [L,1,max_len,..]


@dataclasses.dataclass
class _Slot:
    request: Request
    admitted_step: int
    generated: list[int]
    logits: list[np.ndarray]
    phase: str = "decode"         # "prefill" | "decode"
    pf: _PrefillState | None = None
    deadline_vms: float | None = None   # virtual-clock deadline


class ServingEngine:
    """Continuous-batching decode engine for one model + packed params.

    ``params`` is the serving tree (``PackedModel.params`` for the PUD path
    or a raw bf16 tree); ``session`` is the ``PUDSession`` whose packed
    model is being served — it contributes the default batch size (from
    placement occupancy) and the DRAM-side rate model for ``perf_report``
    and SLO pricing.  The engine itself is execution-agnostic: the
    PUD-vs-bf16 choice already happened at pack time.

    The model must expose ``prefill(params, tokens, max_len=)`` and a
    ``decode_step(params, cache, tokens, cur_len)`` that accepts a [B]
    vector ``cur_len`` (transformer-family models; see models/attention).
    Bucketed and chunked prefill additionally require
    ``supports_chunked_prefill`` / ``prefill_chunk`` / ``cache_defs``
    (TransformerLM); models without them keep the legacy exact-length
    whole-prompt prefill.

    Scheduler extensions (all off by default — the default configuration
    behaves exactly like the step-granular FIFO engine):

    ``chunk_prefill``     chunk length in tokens (rounded up to a power of
                          two); prompts prefill one chunk per step,
                          interleaved with decode waves.
    ``prefill_budget``    max prefill tokens per step across slots (None =
                          one chunk per prefilling slot per step).
    ``prefix_cache``      True (build a default ``PrefixCache``) or a
                          configured instance; reuses completed prefills.
    ``slo``               ``SLOConfig``, or a float shorthand for
                          ``SLOConfig(default_deadline_ms=...)``; enables
                          EDF admission + shedding.
    """

    def __init__(self, model, params, *, max_len: int,
                 session=None, batch_size: int | None = None,
                 max_batch: int = DEFAULT_MAX_BATCH,
                 collect_logits: bool = False,
                 watchdog: StepWatchdog | None = None,
                 heartbeat=None,
                 chunk_prefill: int | None = None,
                 prefill_budget: int | None = None,
                 prefix_cache: bool | PrefixCache = False,
                 slo: SLOConfig | float | None = None):
        if batch_size is None:
            batch_size = self._default_batch_size(session, max_batch)
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.model = model
        self.params = params
        self.session = session
        self.batch_size = int(batch_size)
        self.max_len = int(max_len)
        self.collect_logits = collect_logits

        # Bucketed/chunked prefill require chunk-resumable models; MoE
        # configs (sequence-global router capacity) and models without the
        # protocol stay on the legacy exact-length path.
        self._bucketed = bool(getattr(model, "supports_chunked_prefill",
                                      False))
        if chunk_prefill is not None:
            if not self._bucketed:
                raise ValueError(
                    "chunk_prefill requires a model with bit-exact chunked "
                    "prefill (supports_chunked_prefill); MoE routing is "
                    "sequence-global")
            if chunk_prefill < 1:
                raise ValueError(
                    f"chunk_prefill must be >= 1, got {chunk_prefill}")
            chunk_prefill = min(_next_pow2(int(chunk_prefill)), self.max_len)
        self.chunk_prefill = chunk_prefill
        self.prefill_budget = prefill_budget
        if prefix_cache is True:
            prefix_cache = PrefixCache()
        elif prefix_cache is False:
            prefix_cache = None
        self._prefix_cache = prefix_cache
        if isinstance(slo, (int, float)):
            slo = SLOConfig(default_deadline_ms=float(slo))
        self._slo = slo

        self._queue: collections.deque[Request] = collections.deque()
        self._slots: list[_Slot | None] = [None] * self.batch_size
        self._cache = None                       # allocated on first admit
        # host-side slot state, shipped to the device once per step
        self._tokens = np.zeros((self.batch_size, 1), np.int32)
        self._lens = np.zeros((self.batch_size,), np.int32)
        self._completions: list[Completion] = []
        self._step_idx = 0
        self._active_slot_steps = 0              # sum of live slots per step
        self._decode_wall_s = 0.0

        # SLO virtual clock: deterministic modeled milliseconds, advanced by
        # one modeled step time per scheduling step.
        self._vtime_ms = 0.0
        self._step_ms = self._resolve_step_ms()
        self._deadlines: dict[int, float | None] = {}
        self._slo_stats = {"shed_on_admit": 0, "shed_admitted": 0,
                           "met": 0, "missed": 0}
        self._last_step_worked = False

        # Params identity for prefix-cache keys: bumped on every hot swap,
        # so entries computed under a pre-recalibration pack can never be
        # served afterwards (the swap also drops them outright).
        self._params_version = 0
        self._prefix_invalidated_entries = 0

        # jit trace counters (incremented inside the traced bodies, so they
        # tick once per compiled variant, not once per call)
        self._prefill_traces = 0
        self._chunk_traces = 0
        self._prefill_chunks = 0                 # chunk calls executed
        self._prefilled_tokens = 0               # kv rows actually computed

        # Step telemetry: every decode step is bracketed by a StepWatchdog
        # (EMA step time, straggler flags, optional hang callback) and
        # optionally announced through a Heartbeat for fleet-level liveness.
        # The default watchdog has no on_hang, so no monitor thread spawns.
        self.watchdog = watchdog if watchdog is not None else StepWatchdog()
        self.heartbeat = heartbeat
        self._hangs = 0
        user_hang = self.watchdog.on_hang
        if user_hang is not None:
            def _counted_hang(waited_s, _cb=user_hang):
                self._hangs += 1
                _cb(waited_s)
            self.watchdog.on_hang = _counted_hang

        # Double-buffered serving tree: ``stage_params`` parks a freshly
        # packed tree here and the NEXT ``step()`` swaps it in at the step
        # boundary, so decode never observes a half-replaced pack and no
        # request stalls (the params argument of the jitted step is not
        # donated — only the KV cache is — so the old tree stays valid
        # through the step that builds its replacement).
        self._staged_params = None
        self._swap_steps: list[int] = []

        # The cache argument is donated: the engine owns the single
        # [L, B, max_len, ...] KV pytree and rebinds it after every call,
        # so XLA updates it in place instead of copying it per token.  The
        # chunk step likewise donates the slot's private prefill cache.
        self._prefill = jax.jit(self._prefill_fn, static_argnames=("s",))
        self._prefill_bucketed = jax.jit(self._prefill_bucketed_fn,
                                         static_argnames=("sb",))
        self._chunk = jax.jit(self._chunk_fn,
                              static_argnames=("c", "kv_len"),
                              donate_argnums=(1,))
        self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))
        self._step = jax.jit(self._step_fn, donate_argnums=(1,))

    # -- construction helpers ------------------------------------------------

    @staticmethod
    def _default_batch_size(session, max_batch: int) -> int:
        """Occupancy-derived slots: the placement perf model's optimum."""
        if session is not None:
            pm = session.placement_perf_model() or session.tuned_perf_model()
            if hasattr(pm, "optimal_batch_size"):
                return max(1, pm.optimal_batch_size(max_batch))
        return max(1, min(4, max_batch))

    def _resolve_step_ms(self) -> float:
        """Modeled decode-step milliseconds for the SLO virtual clock."""
        if self._slo is not None and self._slo.step_time_ms is not None:
            return float(self._slo.step_time_ms)
        if self.session is not None:
            pm = (self.session.placement_perf_model()
                  or self.session.tuned_perf_model())
            if pm is not None and hasattr(pm, "step_seconds"):
                try:
                    fpt = self.session.flops_per_token()
                except Exception:
                    fpt = None
                if fpt:
                    return pm.step_seconds(fpt, self.batch_size) * 1e3
        return DEFAULT_STEP_MS

    def _bucket(self, s: int) -> int:
        """pow2 prompt-length bucket, clamped to the cache length."""
        return min(self.max_len, _next_pow2(max(1, s)))

    # -- jitted inner functions ---------------------------------------------

    def _prefill_fn(self, params, tokens, s):
        del s  # static: distinct prompt lengths trace separately
        self._prefill_traces += 1      # python side effect: trace-time only
        logits, cache = self.model.prefill(params, tokens,
                                           max_len=self.max_len)
        return logits, cache

    def _prefill_bucketed_fn(self, params, tokens, last, sb):
        """Whole prefill over a pow2-padded prompt; logits read at the
        traced true-last-token row, so every length in a bucket shares one
        compiled variant."""
        del sb  # static: one trace per bucket (shape already implies it)
        self._prefill_traces += 1      # python side effect: trace-time only
        logits, cache = self.model.prefill(params, tokens,
                                           max_len=self.max_len,
                                           last_idx=last)
        return logits, cache

    def _chunk_fn(self, params, cache, tokens, start, last, c, kv_len):
        del c  # static chunk length (tokens carries the shape)
        self._chunk_traces += 1        # python side effect: trace-time only
        logits, cache = self.model.prefill_chunk(
            params, tokens, cache, start, kv_len=kv_len, last_idx=last)
        return logits, cache

    def _insert_fn(self, cache, new_cache, slot):
        """Scatter a batch-1 prefill cache into batch lane ``slot``.

        Cache leaves are [L, B, max_len, ...] (batch axis 1).
        """
        return jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_slice_in_dim(
                c, n.astype(c.dtype), slot, axis=1),
            cache, new_cache)

    def _step_fn(self, params, cache, tokens, lens):
        logits, cache = self.model.decode_step(params, cache, tokens, lens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, cache

    # -- queue / scheduler ---------------------------------------------------

    def submit(self, request: Request) -> None:
        if request.prompt_len + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {request.request_id}: prompt_len "
                f"{request.prompt_len} + max_new_tokens "
                f"{request.max_new_tokens} exceeds max_len {self.max_len}")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        deadline = request.deadline_ms
        if deadline is None and self._slo is not None:
            deadline = self._slo.default_deadline_ms
        self._deadlines[request.request_id] = (
            None if deadline is None else self._vtime_ms + float(deadline))
        self._queue.append(request)

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    @property
    def n_pending(self) -> int:
        return len(self._queue)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    @property
    def prefill_trace_count(self) -> int:
        """Compiled prefill variants (whole buckets + chunk shapes)."""
        return self._prefill_traces + self._chunk_traces

    def _zero_cache_like(self, cache1):
        """Full-batch cache pytree from a batch-1 prefill cache."""
        b = self.batch_size
        return jax.tree.map(
            lambda c: jnp.zeros(c.shape[:1] + (b,) + c.shape[2:], c.dtype),
            cache1)

    def _zero_cache1(self):
        """Fresh batch-1 KV pytree for a chunked-prefill slot."""
        defs = self.model.cache_defs(1, self.max_len)
        return jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype), defs)

    @staticmethod
    def _trim_cache1(cache1, length: int):
        """First ``length`` seq rows of a batch-1 cache (leaf axis 2)."""
        return jax.tree.map(lambda c: c[:, :, :length], cache1)

    def _pad_cache1(self, cache1):
        """Zero-pad a trimmed batch-1 cache back to ``max_len`` seq rows."""
        def pad(c):
            w = [(0, 0)] * c.ndim
            w[2] = (0, self.max_len - c.shape[2])
            return jnp.pad(c, w)
        return jax.tree.map(pad, cache1)

    # -- prefix cache --------------------------------------------------------

    def _candidate_lengths(self, s: int) -> list[int]:
        """Reusable prefix lengths for a prompt of ``s`` tokens: the whole
        prompt, then chunk-aligned proper prefixes, longest first (partial
        reuse requires the chunk path to resume the suffix)."""
        lengths = [s]
        if self.chunk_prefill is not None:
            c = self.chunk_prefill
            lengths += [k for k in range((s - 1) // c * c, 0, -c)]
        return lengths

    def prefix_probe(self, tokens) -> int:
        """Longest cached prefix covering ``tokens`` (0 without a cache).

        Non-mutating — ``FleetServingEngine`` uses it to pick a lane by
        cache affinity before falling back to round-robin.
        """
        if self._prefix_cache is None:
            return 0
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        return self._prefix_cache.probe(
            self._params_version, tokens,
            self._candidate_lengths(int(tokens.shape[0])))

    def _prefix_insert(self, tokens_np: np.ndarray, cache1, logits_row):
        """Store the completed prefill: the full prompt (with its logits
        row) plus every chunk-aligned proper prefix, all trimmed to exact
        token counts so bucket-padding garbage can never be reused."""
        if self._prefix_cache is None:
            return
        s = int(tokens_np.shape[0])
        v = self._params_version
        self._prefix_cache.insert(v, tokens_np, self._trim_cache1(cache1, s),
                                  logits_row)
        if self.chunk_prefill is not None:
            for length in range((s - 1) // self.chunk_prefill
                                * self.chunk_prefill, 0,
                                -self.chunk_prefill):
                self._prefix_cache.insert(
                    v, tokens_np[:length],
                    self._trim_cache1(cache1, length), None)

    # -- admission -----------------------------------------------------------

    def _estimate_steps(self, req: Request, resume_from: int = 0) -> int:
        """Modeled scheduling steps to finish ``req`` from admission."""
        if self.chunk_prefill is not None:
            remaining = max(0, req.prompt_len - resume_from)
            prefill_steps = -(-remaining // self.chunk_prefill)
        else:
            prefill_steps = 1
        return prefill_steps + req.max_new_tokens - 1

    def _shed_completion(self, req: Request, slot: int = -1,
                         generated=None, logits=None) -> None:
        self._completions.append(Completion(
            request_id=req.request_id,
            tokens=list(generated or []),
            slot=slot,
            admitted_step=self._step_idx,
            finished_step=self._step_idx,
            logits=logits,
            slo_met=False,
            shed=True))
        self._slo_stats["missed"] += 1

    def _admission_order(self) -> list[Request]:
        """Queue in admission order: FIFO, or earliest-deadline-first with
        a stable FIFO tie-break when the SLO policy is on (no-deadline
        requests sort last — they are the ones being *held* while tighter
        deadlines jump ahead)."""
        q = list(self._queue)
        if self._slo is None:
            return q
        def key(pair):
            i, r = pair
            d = self._deadlines.get(r.request_id)
            return (d if d is not None else float("inf"), i)
        return [r for _, r in sorted(enumerate(q), key=key)]

    def _admit(self) -> int:
        """Fill free slots from the queue. Returns #admitted.

        Per candidate (in admission order): shed if its deadline is
        already unreachable under the modeled step time (``SLOConfig.
        shed_on_admit``), reuse a cached prefix when one covers the
        prompt, otherwise prefill — whole-bucket immediately, or chunked
        across the following steps when ``chunk_prefill`` is set.
        """
        free = self.free_slots
        if not free or not self._queue:
            return 0
        candidates = self._admission_order()
        taken: list[Request] = []      # leaving the queue: admitted or shed
        admitted = 0
        ci = 0
        for slot in free:
            while ci < len(candidates):
                req = candidates[ci]
                ci += 1
                taken.append(req)
                if self._slo is not None and self._slo.shed_on_admit:
                    deadline = self._deadlines.get(req.request_id)
                    resume = self._probe_resume_point(req)
                    eta = (self._vtime_ms
                           + self._estimate_steps(req, resume) * self._step_ms)
                    if deadline is not None and eta > deadline:
                        self._slo_stats["shed_on_admit"] += 1
                        self._shed_completion(req)
                        continue
                self._admit_slot(slot, req)
                admitted += 1
                break
        if taken:
            # identity-based removal: Request holds array prompts, so the
            # dataclass __eq__ deque.remove would use is unsafe
            taken_ids = {id(r) for r in taken}
            self._queue = collections.deque(
                r for r in self._queue if id(r) not in taken_ids)
        return admitted

    def _probe_resume_point(self, req: Request) -> int:
        if self._prefix_cache is None:
            return 0
        return self.prefix_probe(np.asarray(req.tokens, np.int32))

    def _admit_slot(self, slot: int, req: Request) -> None:
        tokens_np = np.ascontiguousarray(
            np.asarray(req.tokens, np.int32).reshape(-1))
        s = req.prompt_len
        entry = None
        if self._prefix_cache is not None:
            entry = self._prefix_cache.lookup(
                self._params_version, tokens_np, self._candidate_lengths(s))

        if entry is not None and entry.n_tokens == s and \
                entry.logits is not None:
            # full hit: the stored cache + logits replace prefill outright
            cache1 = self._pad_cache1(entry.cache)
            self._start_decode(slot, req, cache1,
                               np.asarray(entry.logits).reshape(-1))
            return

        if self.chunk_prefill is not None:
            # chunked path: enter the prefilling phase; a partial hit seeds
            # the private cache and resumes after the shared prefix
            sb = self._bucket(s)
            chunk = min(self.chunk_prefill, sb)
            padded = np.zeros((sb,), np.int32)
            padded[:s] = tokens_np
            resume = 0
            cache1 = self._zero_cache1()
            if entry is not None and entry.n_tokens < s:
                resume = entry.n_tokens
                cache1 = self._pad_cache1(entry.cache)
            st = _Slot(request=req, admitted_step=self._step_idx,
                       generated=[], logits=[], phase="prefill",
                       pf=_PrefillState(tokens=padded, prompt_len=s,
                                        bucket=sb, chunk=chunk, pos=resume,
                                        cache=cache1),
                       deadline_vms=self._deadlines.get(req.request_id))
            self._slots[slot] = st
            return

        # whole prefill: pow2-bucketed for chunk-capable models, legacy
        # exact-length otherwise (MoE / non-transformer protocols)
        if self._bucketed:
            sb = self._bucket(s)
            padded = np.zeros((1, sb), np.int32)
            padded[0, :s] = tokens_np
            logits, cache1 = self._prefill_bucketed(
                self.params, jnp.asarray(padded),
                jnp.asarray(s - 1, jnp.int32), sb)
            self._prefilled_tokens += sb
        else:
            tokens = jnp.asarray(tokens_np)[None, :]
            logits, cache1 = self._prefill(self.params, tokens,
                                           tokens.shape[1])
            self._prefilled_tokens += s
        logits_row = np.asarray(logits[0])
        self._prefix_insert(tokens_np, cache1, logits_row)
        self._start_decode(slot, req, cache1, logits_row)

    def _start_decode(self, slot: int, req: Request, cache1,
                      logits_row: np.ndarray) -> None:
        """Install a completed prefill into a batch lane and begin decode."""
        if self._cache is None:
            self._cache = self._zero_cache_like(cache1)
        self._cache = self._insert(self._cache, cache1, slot)
        first = int(np.argmax(logits_row))
        st = self._slots[slot]
        if st is None:                 # whole-prefill / full-hit admission
            st = _Slot(request=req, admitted_step=self._step_idx,
                       generated=[], logits=[],
                       deadline_vms=self._deadlines.get(req.request_id))
            self._slots[slot] = st
        st.phase = "decode"
        st.pf = None
        st.generated.append(first)
        if self.collect_logits:
            st.logits.append(np.asarray(logits_row))
        self._tokens[slot, 0] = first
        self._lens[slot] = req.prompt_len
        if len(st.generated) >= req.max_new_tokens:
            # degenerate budget: the prefill token already finishes it
            self._evict(slot)

    # -- chunked prefill -----------------------------------------------------

    def _advance_chunks(self) -> int:
        """Run at most one prefill chunk per prefilling slot, bounded by
        ``prefill_budget`` tokens per step. Returns tokens prefilled."""
        if self.chunk_prefill is None:
            return 0
        budget = self.prefill_budget
        progressed = 0
        for slot, st in enumerate(self._slots):
            if st is None or st.phase != "prefill":
                continue
            pf = st.pf
            c = pf.chunk
            if budget is not None and budget - progressed < c:
                continue               # zero-budget chunk: hold, no progress
            start = pf.pos
            chunk_tokens = jnp.asarray(
                pf.tokens[start:start + c][None, :])
            is_last = start + c >= pf.prompt_len
            last_local = (pf.prompt_len - 1 - start) if is_last else (c - 1)
            logits, pf.cache = self._chunk(
                self.params, pf.cache, chunk_tokens,
                jnp.asarray(start, jnp.int32),
                jnp.asarray(last_local, jnp.int32), c, pf.bucket)
            pf.pos = start + c
            progressed += c
            self._prefill_chunks += 1
            self._prefilled_tokens += c
            if is_last:
                tokens_np = pf.tokens[:pf.prompt_len]
                logits_row = np.asarray(logits[0])
                self._prefix_insert(tokens_np, pf.cache, logits_row)
                self._start_decode(slot, st.request, pf.cache, logits_row)
        return progressed

    # -- eviction / shedding -------------------------------------------------

    def _evict(self, slot: int, shed: bool = False) -> None:
        st = self._slots[slot]
        deadline = st.deadline_vms
        slo_met: bool | None = None
        if shed:
            slo_met = False
            self._slo_stats["missed"] += 1
        elif deadline is not None:
            slo_met = self._vtime_ms <= deadline
            self._slo_stats["met" if slo_met else "missed"] += 1
        self._completions.append(Completion(
            request_id=st.request.request_id,
            tokens=list(st.generated),
            slot=slot,
            admitted_step=st.admitted_step,
            finished_step=self._step_idx,
            logits=(np.stack(st.logits) if st.logits else None),
            slo_met=slo_met,
            shed=shed))
        self._slots[slot] = None
        self._lens[slot] = 0

    def _shed_expired(self) -> int:
        """Evict in-flight requests whose deadline has already passed on
        the virtual clock (``SLOConfig.shed_admitted``); a mid-prefill
        shed simply discards the slot's private chunk cache."""
        if self._slo is None or not self._slo.shed_admitted:
            return 0
        shed = 0
        for slot, st in enumerate(self._slots):
            if st is None or st.deadline_vms is None:
                continue
            if self._vtime_ms > st.deadline_vms:
                self._slo_stats["shed_admitted"] += 1
                self._evict(slot, shed=True)
                shed += 1
        return shed

    def shed_request(self, request_id: int) -> bool:
        """Drop a request wherever it is — queued, prefilling, or decoding.

        Returns True when found.  An in-flight request completes with its
        partial tokens and ``shed=True``; a prefilling slot's private
        cache is discarded (nothing was inserted into the batch yet).
        """
        for req in self._queue:
            if req.request_id == request_id:
                self._queue = collections.deque(
                    r for r in self._queue if r is not req)
                self._shed_completion(req)
                return True
        for slot, st in enumerate(self._slots):
            if st is not None and st.request.request_id == request_id:
                self._evict(slot, shed=True)
                return True
        return False

    # -- params hot swap -----------------------------------------------------

    def stage_params(self, params) -> None:
        """Stage a replacement serving tree for a between-steps hot swap.

        The engine keeps decoding on the current tree; the swap happens at
        the top of the next ``step()``, before admission, so every request
        (in-flight and newly admitted) sees a consistent pack and no step
        is ever skipped.  Staging again before the swap replaces the
        previously staged tree (last writer wins).  The swap bumps the
        params version and drops every prefix-cache entry — a KV prefix
        computed under the old pack is stale the moment the new one lands.
        """
        self._staged_params = params

    @property
    def swap_pending(self) -> bool:
        return self._staged_params is not None

    # -- step loop -----------------------------------------------------------

    def step(self) -> list[Completion]:
        """One scheduling step: swap staged params, shed expired requests,
        admit, advance prefill chunks, run one batched decode wave over
        decoding slots, evict finished requests.

        Returns the requests that finished on this step.
        """
        done_before = len(self._completions)
        if self._staged_params is not None:
            self.params = self._staged_params
            self._staged_params = None
            self._swap_steps.append(self._step_idx)
            self._params_version += 1
            if self._prefix_cache is not None:
                self._prefix_invalidated_entries += \
                    self._prefix_cache.invalidate()
        self._shed_expired()
        self._admit()
        chunked = self._advance_chunks()
        live = [i for i, s in enumerate(self._slots)
                if s is not None and s.phase == "decode"]
        if live:
            self._active_slot_steps += len(live)
            self.watchdog.start_step(self._step_idx)
            t0 = time.time()
            nxt, logits, self._cache = self._step(
                self.params, self._cache, jnp.asarray(self._tokens),
                jnp.asarray(self._lens))
            nxt = np.asarray(nxt)
            self._decode_wall_s += time.time() - t0
            self.watchdog.end_step()
            self._step_idx += 1
            logits_np = np.asarray(logits) if self.collect_logits else None
            for i in live:
                st = self._slots[i]
                st.generated.append(int(nxt[i, 0]))
                if self.collect_logits:
                    st.logits.append(logits_np[i])
                self._tokens[i, 0] = nxt[i, 0]
                self._lens[i] += 1
        worked = bool(live) or chunked > 0 or \
            len(self._completions) > done_before
        if worked:
            self._vtime_ms += self._step_ms
        if live:
            for i in live:
                st = self._slots[i]
                if st is not None and \
                        len(st.generated) >= st.request.max_new_tokens:
                    self._evict(i)
        self._last_step_worked = worked
        # beat after evictions so a supervisor reads end-of-step state
        if worked and self.heartbeat is not None:
            self.heartbeat.beat(self._step_idx, active=self.n_active,
                                completed=len(self._completions))
        return self._completions[done_before:]

    def run(self, requests=None) -> list[Completion]:
        """Drain the queue (plus ``requests``, if given) to completion.

        Returns all completions sorted by request_id.  Raises when the
        scheduler stalls (queued/active work but no progress for
        ``_STALL_LIMIT`` consecutive steps — e.g. a ``prefill_budget``
        smaller than the chunk size).
        """
        if requests is not None:
            self.submit_all(requests)
        stalls = 0
        while self._queue or self.n_active:
            self.step()
            stalls = 0 if self._last_step_worked else stalls + 1
            if stalls >= _STALL_LIMIT:
                raise RuntimeError(
                    f"scheduler stalled: {self.n_pending} pending / "
                    f"{self.n_active} active but no progress for "
                    f"{stalls} steps (prefill_budget "
                    f"{self.prefill_budget} < chunk {self.chunk_prefill}?)")
        return sorted(self._completions, key=lambda c: c.request_id)

    # -- reporting -----------------------------------------------------------

    def scheduler_report(self) -> dict:
        """Scheduler counters: slot occupancy, steps, measured decode rate,
        prefill trace/chunk counters, prefix-cache and SLO telemetry."""
        steps = self._step_idx
        gen_tokens = sum(len(c.tokens) for c in self._completions)
        occ = (self._active_slot_steps / (steps * self.batch_size)
               if steps else 0.0)
        rep = {
            "batch_size": self.batch_size,
            "steps": steps,
            "completed": len(self._completions),
            "pending": self.n_pending,
            "active": self.n_active,
            "generated_tokens": gen_tokens,
            "slot_occupancy": occ,
            "decode_wall_s": self._decode_wall_s,
            "wall_tok_s": (gen_tokens / self._decode_wall_s
                           if self._decode_wall_s else 0.0),
            "stragglers": len(self.watchdog.stragglers),
            "step_ema_s": self.watchdog.ema_s,
            "hangs": self._hangs,
            "swaps": len(self._swap_steps),
            "swap_steps": list(self._swap_steps),
            "prefill_traces": self._prefill_traces,
            "chunk_traces": self._chunk_traces,
            "prefill_chunks": self._prefill_chunks,
            "prefilled_tokens": self._prefilled_tokens,
        }
        if self._prefix_cache is not None:
            pc = self._prefix_cache.stats()
            pc["invalidated_entries"] = self._prefix_invalidated_entries
            rep["prefix_cache"] = pc
        if self._slo is not None:
            rep["slo"] = dict(self._slo_stats, step_ms=self._step_ms)
        return rep

    def perf_report(self, flops_per_token: float | None = None) -> dict:
        """Scheduler counters + the session's batch-aware DRAM-side rates."""
        rep = self.scheduler_report()
        if self.session is not None:
            rep.update(self.session.perf_report(
                flops_per_token, batch_size=self.batch_size))
        return rep


class FleetServingEngine:
    """Data-parallel fleet of ``ServingEngine``s over per-lane sharded packs.

    One inner engine per "data"-axis lane of a ``PUDFleetSession``;
    requests partition by prefix-cache affinity (the lane whose LRU holds
    the longest matching prefix wins — repeated system prompts keep
    landing where their KV already lives) with round-robin as the
    fallback, and every lane keeps the single-engine semantics —
    continuous batching, per-request bit-exact decode — so a request's
    tokens (and logits) are identical to running it through a
    single-device ``ServingEngine``.  Scheduler extensions
    (``chunk_prefill`` / ``prefix_cache`` / ``slo``) pass through to every
    lane; ``prefix_cache=True`` builds one *per-lane* cache (entries hold
    lane-sharded KV pytrees, so they must not cross lanes).  The
    model-parallel dimension lives *inside* each lane's params: every
    packed projection is a ``ShardedPackedTensor`` executing via
    ``shard_map`` over the mesh's "model" axis
    (``kernels.ops.pud_matmul_sharded``), so a lane's decode step is one
    jitted program spanning its model shards.
    """

    def __init__(self, model, lane_params, *, max_len: int,
                 fleet=None, sessions=None, batch_size: int | None = None,
                 **kw):
        if not lane_params:
            raise ValueError("need at least one data lane")
        if sessions is None and fleet is not None:
            # lane d's default batch size derives from its shard-0 session
            sessions = [row[0] for row in fleet.sessions]
        if sessions is None:
            sessions = [None] * len(lane_params)
        if isinstance(kw.get("prefix_cache"), PrefixCache) and \
                len(lane_params) > 1:
            raise ValueError(
                "a shared PrefixCache cannot span lanes (entries hold "
                "lane-local KV); pass prefix_cache=True for per-lane caches")
        self.fleet = fleet
        self.lanes = [
            ServingEngine(model, p, session=s, max_len=max_len,
                          batch_size=batch_size, **kw)
            for p, s in zip(lane_params, sessions)]
        self._next_lane = 0

    @property
    def n_lanes(self) -> int:
        return len(self.lanes)

    @property
    def batch_size(self) -> int:
        return self.lanes[0].batch_size

    @property
    def n_pending(self) -> int:
        return sum(lane.n_pending for lane in self.lanes)

    @property
    def n_active(self) -> int:
        return sum(lane.n_active for lane in self.lanes)

    def submit(self, request: Request) -> int:
        """Place the request on the lane with the longest cached prefix of
        its prompt (cache affinity), else round-robin; returns the lane
        index."""
        best, best_len = None, 0
        for i, lane in enumerate(self.lanes):
            n = lane.prefix_probe(np.asarray(request.tokens, np.int32))
            if n > best_len:
                best, best_len = i, n
        if best is None:
            best = self._next_lane
            self._next_lane = (best + 1) % len(self.lanes)
        self.lanes[best].submit(request)
        return best

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    def stage_lane_params(self, lane: int, params) -> None:
        """Per-lane hot-swap hook (drift recovery repacks one lane only)."""
        self.lanes[lane].stage_params(params)

    def step(self) -> list[Completion]:
        """Step every lane that has work; returns this step's completions."""
        done: list[Completion] = []
        for lane in self.lanes:
            if lane._queue or lane.n_active or lane.swap_pending:
                done.extend(lane.step())
        return done

    def run(self, requests=None) -> list[Completion]:
        """Drain every lane; all completions sorted by request_id."""
        if requests is not None:
            self.submit_all(requests)
        while any(lane._queue or lane.n_active for lane in self.lanes):
            self.step()
        comps = [c for lane in self.lanes for c in lane._completions]
        return sorted(comps, key=lambda c: c.request_id)

    # -- reporting -----------------------------------------------------------

    def scheduler_report(self) -> dict:
        """Fleet-merged counters plus the per-lane reports."""
        reps = [lane.scheduler_report() for lane in self.lanes]
        rep = {
            "n_lanes": len(self.lanes),
            "batch_size": self.batch_size,
            "steps": max(r["steps"] for r in reps),
            "completed": sum(r["completed"] for r in reps),
            "pending": sum(r["pending"] for r in reps),
            "active": sum(r["active"] for r in reps),
            "generated_tokens": sum(r["generated_tokens"] for r in reps),
            "slot_occupancy": (sum(r["slot_occupancy"] for r in reps)
                               / len(reps)),
            "lanes": reps,
        }
        pcs = [r["prefix_cache"] for r in reps if "prefix_cache" in r]
        if pcs:
            hits = sum(p["hits"] for p in pcs)
            misses = sum(p["misses"] for p in pcs)
            rep["prefix_cache"] = {
                "entries": sum(p["entries"] for p in pcs),
                "bytes": sum(p["bytes"] for p in pcs),
                "hits": hits,
                "misses": misses,
                "hit_rate": (hits / (hits + misses)
                             if hits + misses else 0.0),
                "inserts": sum(p["inserts"] for p in pcs),
                "evictions": sum(p["evictions"] for p in pcs),
                "invalidations": sum(p["invalidations"] for p in pcs),
            }
        return rep

    def perf_report(self, flops_per_token: float | None = None) -> dict:
        """Merged scheduler counters + the fleet's aggregate rate model."""
        rep = self.scheduler_report()
        if self.fleet is not None:
            rep.update(self.fleet.perf_report(
                flops_per_token, batch_size=self.batch_size))
        return rep
