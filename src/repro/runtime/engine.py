"""ServingEngine: continuous-batching request scheduler over a PUDSession.

The calibrated array only pays off when it is kept saturated: PUDTune's
extra error-free columns buy 1.81x more parallel MACs per wave, but a
serve loop that decodes one request at a time leaves them idle between
requests.  This engine turns the single-vector decode path into a
multi-request system:

  * **Requests** enter a FIFO queue (``submit``); each is one prompt plus a
    token budget.
  * **Slots** — the engine owns a fixed-size padded batch of ``batch_size``
    decode slots and one KV-cache pytree sized ``[L, batch_size, max_len,
    ...]``; every slot holds at most one in-flight request.
  * **Continuous batching** — admission and eviction happen at *step*
    granularity: before every decode step, free slots are filled from the
    queue (per-request prefill, cache scattered into the slot's batch
    lane); after it, finished requests are evicted and their slots freed
    immediately — no waiting for the whole batch to drain.
  * **Per-slot positions** — one jitted decode step serves all slots at
    once with a [B] vector of cache lengths (models/attention.py's
    per-slot decode path), so requests admitted at different times decode
    correctly side by side with no host-side Python loop over slots.

Bit-exactness: every per-slot computation (per-row activation quantization,
the integer bit-plane kernel, per-row attention masks, rmsnorm) is
independent of the other batch lanes, so the tokens a request gets from a
batched engine are bit-identical to running it alone — enforced across
backends and layouts by tests/test_engine.py.

Batch-size selection: with a calibrated + placed ``PUDSession``, the
default ``batch_size`` comes from the placement-derived ``FleetPerfModel``
(``optimal_batch_size`` — weight replicas x operand residency), the point
up to which the DRAM-side aggregate tokens/s grows monotonically.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .watchdog import StepWatchdog

DEFAULT_MAX_BATCH = 32


@dataclasses.dataclass
class Request:
    """One generation request: a prompt and a token budget."""

    request_id: int
    tokens: Any                   # [S] int prompt tokens (array-like)
    max_new_tokens: int

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class Completion:
    """A finished request: generated tokens plus scheduling metadata."""

    request_id: int
    tokens: list[int]             # generated tokens (length = max_new_tokens)
    slot: int
    admitted_step: int            # engine step index at admission
    finished_step: int            # engine step index after the last token
    logits: np.ndarray | None = None   # [gen, V] when collect_logits


@dataclasses.dataclass
class _Slot:
    request: Request
    admitted_step: int
    generated: list[int]
    logits: list[np.ndarray]


class ServingEngine:
    """Continuous-batching decode engine for one model + packed params.

    ``params`` is the serving tree (``PackedModel.params`` for the PUD path
    or a raw bf16 tree); ``session`` is the ``PUDSession`` whose packed
    model is being served — it contributes the default batch size (from
    placement occupancy) and the DRAM-side rate model for ``perf_report``.
    The engine itself is execution-agnostic: the PUD-vs-bf16 choice already
    happened at pack time.

    The model must expose ``prefill(params, tokens, max_len=)`` and a
    ``decode_step(params, cache, tokens, cur_len)`` that accepts a [B]
    vector ``cur_len`` (transformer-family models; see models/attention).
    """

    def __init__(self, model, params, *, max_len: int,
                 session=None, batch_size: int | None = None,
                 max_batch: int = DEFAULT_MAX_BATCH,
                 collect_logits: bool = False,
                 watchdog: StepWatchdog | None = None,
                 heartbeat=None):
        if batch_size is None:
            batch_size = self._default_batch_size(session, max_batch)
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.model = model
        self.params = params
        self.session = session
        self.batch_size = int(batch_size)
        self.max_len = int(max_len)
        self.collect_logits = collect_logits

        self._queue: collections.deque[Request] = collections.deque()
        self._slots: list[_Slot | None] = [None] * self.batch_size
        self._cache = None                       # allocated on first admit
        # host-side slot state, shipped to the device once per step
        self._tokens = np.zeros((self.batch_size, 1), np.int32)
        self._lens = np.zeros((self.batch_size,), np.int32)
        self._completions: list[Completion] = []
        self._step_idx = 0
        self._active_slot_steps = 0              # sum of live slots per step
        self._decode_wall_s = 0.0

        # Step telemetry: every decode step is bracketed by a StepWatchdog
        # (EMA step time, straggler flags, optional hang callback) and
        # optionally announced through a Heartbeat for fleet-level liveness.
        # The default watchdog has no on_hang, so no monitor thread spawns.
        self.watchdog = watchdog if watchdog is not None else StepWatchdog()
        self.heartbeat = heartbeat
        self._hangs = 0
        user_hang = self.watchdog.on_hang
        if user_hang is not None:
            def _counted_hang(waited_s, _cb=user_hang):
                self._hangs += 1
                _cb(waited_s)
            self.watchdog.on_hang = _counted_hang

        # Double-buffered serving tree: ``stage_params`` parks a freshly
        # packed tree here and the NEXT ``step()`` swaps it in at the step
        # boundary, so decode never observes a half-replaced pack and no
        # request stalls (the params argument of the jitted step is not
        # donated — only the KV cache is — so the old tree stays valid
        # through the step that builds its replacement).
        self._staged_params = None
        self._swap_steps: list[int] = []

        # The cache argument is donated: the engine owns the single
        # [L, B, max_len, ...] KV pytree and rebinds it after every call,
        # so XLA updates it in place instead of copying it per token.
        self._prefill = jax.jit(self._prefill_fn, static_argnames=("s",))
        self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))
        self._step = jax.jit(self._step_fn, donate_argnums=(1,))

    # -- construction helpers ------------------------------------------------

    @staticmethod
    def _default_batch_size(session, max_batch: int) -> int:
        """Occupancy-derived slots: the placement perf model's optimum."""
        if session is not None:
            pm = session.placement_perf_model() or session.tuned_perf_model()
            if hasattr(pm, "optimal_batch_size"):
                return max(1, pm.optimal_batch_size(max_batch))
        return max(1, min(4, max_batch))

    # -- jitted inner functions ---------------------------------------------

    def _prefill_fn(self, params, tokens, s):
        del s  # static: distinct prompt lengths trace separately
        logits, cache = self.model.prefill(params, tokens,
                                           max_len=self.max_len)
        return logits, cache

    def _insert_fn(self, cache, new_cache, slot):
        """Scatter a batch-1 prefill cache into batch lane ``slot``.

        Cache leaves are [L, B, max_len, ...] (batch axis 1).
        """
        return jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_slice_in_dim(
                c, n.astype(c.dtype), slot, axis=1),
            cache, new_cache)

    def _step_fn(self, params, cache, tokens, lens):
        logits, cache = self.model.decode_step(params, cache, tokens, lens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, cache

    # -- queue / scheduler ---------------------------------------------------

    def submit(self, request: Request) -> None:
        if request.prompt_len + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {request.request_id}: prompt_len "
                f"{request.prompt_len} + max_new_tokens "
                f"{request.max_new_tokens} exceeds max_len {self.max_len}")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self._queue.append(request)

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    @property
    def n_pending(self) -> int:
        return len(self._queue)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def _zero_cache_like(self, cache1):
        """Full-batch cache pytree from a batch-1 prefill cache."""
        b = self.batch_size
        return jax.tree.map(
            lambda c: jnp.zeros(c.shape[:1] + (b,) + c.shape[2:], c.dtype),
            cache1)

    def _admit(self) -> int:
        """Fill free slots from the queue (FIFO). Returns #admitted."""
        admitted = 0
        for slot in self.free_slots:
            if not self._queue:
                break
            req = self._queue.popleft()
            tokens = jnp.asarray(np.asarray(req.tokens), jnp.int32)[None, :]
            logits, cache1 = self._prefill(self.params, tokens,
                                           tokens.shape[1])
            if self._cache is None:
                self._cache = self._zero_cache_like(cache1)
            self._cache = self._insert(self._cache, cache1, slot)
            first = int(jnp.argmax(logits, axis=-1)[0])
            st = _Slot(request=req, admitted_step=self._step_idx,
                       generated=[first], logits=[])
            if self.collect_logits:
                st.logits.append(np.asarray(logits[0]))
            self._slots[slot] = st
            self._tokens[slot, 0] = first
            self._lens[slot] = req.prompt_len
            admitted += 1
            if len(st.generated) >= req.max_new_tokens:
                # degenerate budget: the prefill token already finishes it
                self._evict(slot)
        return admitted

    def _evict(self, slot: int) -> None:
        st = self._slots[slot]
        self._completions.append(Completion(
            request_id=st.request.request_id,
            tokens=list(st.generated),
            slot=slot,
            admitted_step=st.admitted_step,
            finished_step=self._step_idx,
            logits=(np.stack(st.logits) if st.logits else None)))
        self._slots[slot] = None
        self._lens[slot] = 0

    def stage_params(self, params) -> None:
        """Stage a replacement serving tree for a between-steps hot swap.

        The engine keeps decoding on the current tree; the swap happens at
        the top of the next ``step()``, before admission, so every request
        (in-flight and newly admitted) sees a consistent pack and no step
        is ever skipped.  Staging again before the swap replaces the
        previously staged tree (last writer wins).
        """
        self._staged_params = params

    @property
    def swap_pending(self) -> bool:
        return self._staged_params is not None

    def step(self) -> list[Completion]:
        """Admit, run one batched decode step, evict finished requests.

        Returns the requests that finished on this step.
        """
        if self._staged_params is not None:
            self.params = self._staged_params
            self._staged_params = None
            self._swap_steps.append(self._step_idx)
        self._admit()
        live = [i for i, s in enumerate(self._slots) if s is not None]
        if not live:
            return []
        self._active_slot_steps += len(live)
        self.watchdog.start_step(self._step_idx)
        t0 = time.time()
        nxt, logits, self._cache = self._step(
            self.params, self._cache, jnp.asarray(self._tokens),
            jnp.asarray(self._lens))
        nxt = np.asarray(nxt)
        self._decode_wall_s += time.time() - t0
        self.watchdog.end_step()
        self._step_idx += 1
        done_before = len(self._completions)
        logits_np = np.asarray(logits) if self.collect_logits else None
        for i in live:
            st = self._slots[i]
            st.generated.append(int(nxt[i, 0]))
            if self.collect_logits:
                st.logits.append(logits_np[i])
            self._tokens[i, 0] = nxt[i, 0]
            self._lens[i] += 1
            if len(st.generated) >= st.request.max_new_tokens:
                self._evict(i)
        # beat after evictions so a supervisor reads end-of-step state
        if self.heartbeat is not None:
            self.heartbeat.beat(self._step_idx, active=self.n_active,
                                completed=len(self._completions))
        return self._completions[done_before:]

    def run(self, requests=None) -> list[Completion]:
        """Drain the queue (plus ``requests``, if given) to completion.

        Returns all completions sorted by request_id.
        """
        if requests is not None:
            self.submit_all(requests)
        while self._queue or self.n_active:
            self.step()
        return sorted(self._completions, key=lambda c: c.request_id)

    # -- reporting -----------------------------------------------------------

    def scheduler_report(self) -> dict:
        """Scheduler counters: slot occupancy, steps, measured decode rate."""
        steps = self._step_idx
        gen_tokens = sum(len(c.tokens) for c in self._completions)
        occ = (self._active_slot_steps / (steps * self.batch_size)
               if steps else 0.0)
        return {
            "batch_size": self.batch_size,
            "steps": steps,
            "completed": len(self._completions),
            "pending": self.n_pending,
            "active": self.n_active,
            "generated_tokens": gen_tokens,
            "slot_occupancy": occ,
            "decode_wall_s": self._decode_wall_s,
            "wall_tok_s": (gen_tokens / self._decode_wall_s
                           if self._decode_wall_s else 0.0),
            "stragglers": len(self.watchdog.stragglers),
            "step_ema_s": self.watchdog.ema_s,
            "hangs": self._hangs,
            "swaps": len(self._swap_steps),
            "swap_steps": list(self._swap_steps),
        }

    def perf_report(self, flops_per_token: float | None = None) -> dict:
        """Scheduler counters + the session's batch-aware DRAM-side rates."""
        rep = self.scheduler_report()
        if self.session is not None:
            rep.update(self.session.perf_report(
                flops_per_token, batch_size=self.batch_size))
        return rep


class FleetServingEngine:
    """Data-parallel fleet of ``ServingEngine``s over per-lane sharded packs.

    One inner engine per "data"-axis lane of a ``PUDFleetSession``;
    requests partition round-robin at submit time and every lane keeps the
    single-engine semantics — continuous batching, per-request bit-exact
    decode — so a request's tokens (and logits) are identical to running
    it through a single-device ``ServingEngine``.  The model-parallel
    dimension lives *inside* each lane's params: every packed projection
    is a ``ShardedPackedTensor`` executing via ``shard_map`` over the
    mesh's "model" axis (``kernels.ops.pud_matmul_sharded``), so a lane's
    decode step is one jitted program spanning its model shards.
    """

    def __init__(self, model, lane_params, *, max_len: int,
                 fleet=None, sessions=None, batch_size: int | None = None,
                 **kw):
        if not lane_params:
            raise ValueError("need at least one data lane")
        if sessions is None and fleet is not None:
            # lane d's default batch size derives from its shard-0 session
            sessions = [row[0] for row in fleet.sessions]
        if sessions is None:
            sessions = [None] * len(lane_params)
        self.fleet = fleet
        self.lanes = [
            ServingEngine(model, p, session=s, max_len=max_len,
                          batch_size=batch_size, **kw)
            for p, s in zip(lane_params, sessions)]
        self._next_lane = 0

    @property
    def n_lanes(self) -> int:
        return len(self.lanes)

    @property
    def batch_size(self) -> int:
        return self.lanes[0].batch_size

    @property
    def n_pending(self) -> int:
        return sum(lane.n_pending for lane in self.lanes)

    @property
    def n_active(self) -> int:
        return sum(lane.n_active for lane in self.lanes)

    def submit(self, request: Request) -> int:
        """Round-robin the request onto a lane; returns the lane index."""
        lane = self._next_lane
        self.lanes[lane].submit(request)
        self._next_lane = (lane + 1) % len(self.lanes)
        return lane

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    def stage_lane_params(self, lane: int, params) -> None:
        """Per-lane hot-swap hook (drift recovery repacks one lane only)."""
        self.lanes[lane].stage_params(params)

    def step(self) -> list[Completion]:
        """Step every lane that has work; returns this step's completions."""
        done: list[Completion] = []
        for lane in self.lanes:
            if lane._queue or lane.n_active or lane.swap_pending:
                done.extend(lane.step())
        return done

    def run(self, requests=None) -> list[Completion]:
        """Drain every lane; all completions sorted by request_id."""
        if requests is not None:
            self.submit_all(requests)
        while any(lane._queue or lane.n_active for lane in self.lanes):
            self.step()
        comps = [c for lane in self.lanes for c in lane._completions]
        return sorted(comps, key=lambda c: c.request_id)

    # -- reporting -----------------------------------------------------------

    def scheduler_report(self) -> dict:
        """Fleet-merged counters plus the per-lane reports."""
        reps = [lane.scheduler_report() for lane in self.lanes]
        return {
            "n_lanes": len(self.lanes),
            "batch_size": self.batch_size,
            "steps": max(r["steps"] for r in reps),
            "completed": sum(r["completed"] for r in reps),
            "pending": sum(r["pending"] for r in reps),
            "active": sum(r["active"] for r in reps),
            "generated_tokens": sum(r["generated_tokens"] for r in reps),
            "slot_occupancy": (sum(r["slot_occupancy"] for r in reps)
                               / len(reps)),
            "lanes": reps,
        }

    def perf_report(self, flops_per_token: float | None = None) -> dict:
        """Merged scheduler counters + the fleet's aggregate rate model."""
        rep = self.scheduler_report()
        if self.fleet is not None:
            rep.update(self.fleet.perf_report(
                flops_per_token, batch_size=self.batch_size))
        return rep
