"""Persistent kernel-tuning cache (versioned, atomic, keyed).

The search half of the autotuner (kernels/autotune.py) times candidate tile
plans on real operands — seconds of compile + measurement per (kernel,
layout, format, shape) key.  The winners are static until the kernel source
changes, so serving must never pay the search at startup: this cache stores
one JSON file per tuning key,

    <root>/<safe_key>.json     {"format", "key", "kernels_fingerprint",
                                "plan": {...}, "stats": {...}}

with the same durability discipline as ``runtime/calib_cache.py``: writes
stage to a ``.tmp-<pid>`` file and ``os.replace`` into place (a crash
mid-save can never leave a torn entry), loads verify format version + key +
kernel-source fingerprint and report a miss (None) on any mismatch —
corrupt, torn, stale, or version-skewed entries all read as "re-tune", never
as an exception.  A ``FORMAT`` bump invalidates old entries instead of
misreading them.

``kernels_fingerprint()`` hashes the kernel source files themselves, so a
kernel change (new BlockSpecs, different heuristic) silently invalidates
every persisted plan — and doubles as the CI ``actions/cache`` key, letting
the tuning directory survive exactly as long as the kernels it measured.

Deliberately jax-free at module level (the CLI must run without the
accelerator stack); ``TunedTile`` materializes lazily on load.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import re

FORMAT = "pud-tuning-v1"

#: Kernel source files whose bytes define plan validity: any edit to the
#: tiling, BlockSpecs, or search space invalidates persisted winners.
_KERNEL_SOURCES = ("autotune.py", "backends.py", "bitplane_gemm.py",
                   "bitplane_gemv.py", "ops.py")


def kernels_fingerprint() -> str:
    """Stable hash of the kernel implementation sources (jax-free)."""
    kernels = pathlib.Path(__file__).resolve().parents[1] / "kernels"
    h = hashlib.sha256()
    for name in _KERNEL_SOURCES:
        h.update(name.encode())
        h.update((kernels / name).read_bytes())
    return h.hexdigest()[:16]


def _safe_name(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", name)


class TuningCache:
    """One directory of persisted tuning winners, keyed by
    ``kernels.autotune.tuning_key`` strings."""

    def __init__(self, directory: str | os.PathLike,
                 fingerprint: str | None = None):
        self.directory = pathlib.Path(directory)
        self.fingerprint = fingerprint or kernels_fingerprint()

    def _path(self, key: str) -> pathlib.Path:
        return self.directory / f"{_safe_name(key)}.json"

    # -- save ---------------------------------------------------------------

    def save(self, key: str, plan, stats: dict | None = None) -> pathlib.Path:
        """Persist one winner atomically; ``plan`` is a TunedTile (or any
        object with ``to_dict``) or a plain field dict."""
        final = self._path(key)
        final.parent.mkdir(parents=True, exist_ok=True)
        for stale in final.parent.glob(final.name + ".tmp-*"):
            stale.unlink(missing_ok=True)     # crashed earlier saves
        entry = {
            "format": FORMAT,
            "key": key,
            "kernels_fingerprint": self.fingerprint,
            "plan": plan.to_dict() if hasattr(plan, "to_dict") else dict(plan),
            "stats": stats or {},
        }
        tmp = final.with_name(final.name + f".tmp-{os.getpid()}")
        tmp.write_text(json.dumps(entry, indent=1))
        os.replace(tmp, final)
        return final

    # -- load ---------------------------------------------------------------

    def load_entry(self, key: str) -> dict | None:
        """The raw cache entry, or None (miss) on absence or any mismatch —
        torn/corrupt JSON, format or fingerprint skew, wrong key."""
        path = self._path(key)
        if not path.exists():
            return None
        try:
            entry = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError, UnicodeDecodeError):
            return None
        if not isinstance(entry, dict):
            return None
        if entry.get("format") != FORMAT:
            return None
        if entry.get("key") != key:
            return None
        if entry.get("kernels_fingerprint") != self.fingerprint:
            return None                       # kernels changed: re-tune
        if not isinstance(entry.get("plan"), dict):
            return None
        return entry

    def load(self, key: str):
        """The persisted ``TunedTile`` for ``key``, or None on any miss."""
        entry = self.load_entry(key)
        if entry is None:
            return None
        from repro.kernels.autotune import TunedTile
        try:
            return TunedTile.from_dict(entry["plan"])
        except (TypeError, ValueError):       # unknown fields: stale entry
            return None

    # -- inspection ---------------------------------------------------------

    def entries(self) -> list[dict]:
        """Every valid entry under the cache root (invalid files skipped)."""
        out = []
        if not self.directory.exists():
            return out
        for path in sorted(self.directory.glob("*.json")):
            if ".tmp-" in path.name:
                continue
            try:
                entry = json.loads(path.read_text())
            except (OSError, json.JSONDecodeError, UnicodeDecodeError):
                continue
            if isinstance(entry, dict) and entry.get("format") == FORMAT:
                out.append(entry)
        return out

    def evict(self, key: str | None = None) -> int:
        """Drop one entry (or all of them); returns the number removed."""
        if key is not None:
            path = self._path(key)
            if path.exists():
                path.unlink()
                return 1
            return 0
        n = 0
        if self.directory.exists():
            for path in self.directory.glob("*.json"):
                path.unlink()
                n += 1
        return n

    def stats(self) -> dict:
        entries = self.entries()
        current = [e for e in entries
                   if e.get("kernels_fingerprint") == self.fingerprint]
        size = 0
        if self.directory.exists():
            size = sum(f.stat().st_size
                       for f in self.directory.glob("*.json"))
        return {"entries": len(entries), "current": len(current),
                "stale": len(entries) - len(current), "bytes": size,
                "fingerprint": self.fingerprint}


# ---------------------------------------------------------------------------
# CLI: inspect/evict persisted tuning entries without writing any Python.
#
#     python -m repro.runtime.tune --root DIR --list
#     python -m repro.runtime.tune --root DIR --stats
#     python -m repro.runtime.tune --root DIR --evict KEY
#     python -m repro.runtime.tune --fingerprint
#
# jax-free: CI uses --fingerprint as the actions/cache key before any
# accelerator stack is installed.
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime.tune",
        description="Inspect a persistent kernel-tuning cache.")
    ap.add_argument("--root", metavar="DIR",
                    help="cache root (the --tuning-cache directory)")
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--list", action="store_true",
                   help="one line per persisted tuning entry")
    g.add_argument("--stats", action="store_true",
                   help="aggregate counts and on-disk size")
    g.add_argument("--evict", metavar="KEY",
                   help="drop one tuning key ('all' drops every entry)")
    g.add_argument("--fingerprint", action="store_true",
                   help="print the kernel-source fingerprint and exit")
    args = ap.parse_args(argv)

    if args.fingerprint:
        print(kernels_fingerprint())
        return 0
    if not args.root:
        ap.error("--root is required for --list/--stats/--evict")
    cache = TuningCache(args.root)
    if args.evict:
        n = cache.evict(None if args.evict == "all" else args.evict)
        print(f"evicted {n} tuning entr{'y' if n == 1 else 'ies'}")
        return 0
    if args.list:
        entries = cache.entries()
        if not entries:
            print(f"no tuning entries under {cache.directory}")
            return 0
        for e in entries:
            stale = ("" if e.get("kernels_fingerprint") == cache.fingerprint
                     else "  [stale]")
            stats = e.get("stats", {})
            speed = (f"  {stats['speedup']:.2f}x"
                     if isinstance(stats.get("speedup"), (int, float))
                     else "")
            print(f"{e.get('key', '?'):<48s} {json.dumps(e.get('plan'))}"
                  f"{speed}{stale}")
        return 0
    s = cache.stats()
    print(f"cache root       {cache.directory}")
    print(f"entries          {s['entries']}")
    print(f"current          {s['current']}")
    print(f"stale            {s['stale']}")
    print(f"on-disk size     {s['bytes'] / 1024:.1f} KiB")
    print(f"fingerprint      {s['fingerprint']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
