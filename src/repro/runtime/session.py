"""PUDSession: the one public API for the PUD serving lifecycle.

Everything a workload needs from a calibrated PUD device used to be ~120
lines of hand-wiring per call site: load-or-run fleet calibration, persist
the table, plan column placement from the masks, pack weights into placed
bit-planes, dispatch the kernel, price the serving rate.  ``PUDSession``
owns that chain behind five calls:

    from repro.api import PUDSession

    session = PUDSession.open("qwen3-1.7b", grid=FleetConfig(...),
                              cache_dir="~/.pud-cache", backend="pallas")
    state  = session.calibrate()            # cache hit (ms) or Algorithm 1
    packed = session.pack(params, cfg)      # placement-aware PackedModel
    y      = session.linear(x, "unembed/w") # kernel via the named backend
    rep    = session.perf_report()          # Eq.-1 rates, occupancy, ECR
    extras = session.decode_extras()        # layout/bytes/report diagnostics

The session hides per-device reliability state (which physical columns are
safe) from the workload: callers speak logical tensors, the session speaks
placed physical columns.  Backends (kernels/backends.py) are selectable per
session and per call and are bit-exact against each other, so the same
session code serves the TPU Pallas lowering, the forced interpreter, and the
pure-jnp reference.

A session without ``cache_dir`` still works — calibration runs in memory
and is simply not persisted (the null-cache path); a session that never
calibrates packs onto logical columns, exactly like serving without
``--calib-cache``.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibrate import CalibrationConfig
from repro.core.fleet import FleetConfig, load_or_calibrate, manufacture_fleet
from repro.kernels.backends import DEFAULT_BACKEND, backend_names
from repro.pud.gemv import (ECR_BASELINE_B300, ECR_PUDTUNE_T210,
                            FleetPerfAggregate, FleetPerfModel, PUDGemvConfig,
                            PUDPerfModel, pud_linear)
from repro.pud.packed import PackedModel, packed_bytes
from repro.pud.packer import pack_model, pack_model_sharded, packing_requests
from repro.pud.physics import PhysicsParams
from repro.pud.placement import (Placement, PlacementError, plan_for_grid,
                                 requests_fingerprint, shard_column_slices)
from repro.runtime.calib_cache import CalibrationTableCache


@dataclasses.dataclass
class CalibrationState:
    """One device's reliability state, as loaded or identified."""

    levels: jax.Array          # [G, C] int32 ladder level per column
    ecr: jax.Array             # [G] float32 per-subarray ECR
    masks: jax.Array           # [G, C] bool per-column error-prone mask
    cache_hit: bool
    wall_s: float

    @property
    def mean_ecr(self) -> float:
        return float(np.asarray(self.ecr).mean())


def _restamp_model(pm: PackedModel, stamped: dict) -> PackedModel:
    """Rebuild a ``PackedModel`` with ``stamped[name]`` tensors swapped in
    (same aux metadata — tuning stamps are trace-static pytree aux)."""
    def walk(tree, path):
        out = {}
        for key, sub in tree.items():
            if key.endswith("_pud"):
                name = "/".join(path + (key[: -len("_pud")],))
                out[key] = stamped.get(name, sub)
            elif isinstance(sub, dict):
                out[key] = walk(sub, path + (key,))
            else:
                out[key] = sub
        return out

    return PackedModel(
        params=walk(pm.params, ()),
        packed_names=pm.packed_names,
        skipped_names=pm.skipped_names,
        weight_bits=pm.weight_bits, placed=pm.placed)


class _NullCache:
    """In-memory stand-in when no cache_dir is given: every load misses,
    every save is dropped — calibration still runs, nothing persists."""

    def load(self, *a, **kw):
        return None

    def save(self, *a, **kw):
        return None


class PUDSession:
    """Facade over the calibrate -> cache -> place -> pack -> execute chain.

    Build one with ``PUDSession.open``; the constructor takes the already-
    resolved pieces.
    """

    def __init__(self, *, arch: str | None, fleet_cfg: FleetConfig,
                 cache: CalibrationTableCache | None, device_id: str,
                 backend: str, physics: PhysicsParams,
                 calib: CalibrationConfig, key: jax.Array,
                 placement: bool, method: str, n_trials_ecr: int):
        if backend not in backend_names():
            raise KeyError(f"unknown backend {backend!r}; registered: "
                           f"{backend_names()}")
        self.arch = arch
        self.fleet_cfg = fleet_cfg
        self.cache = cache
        self.device_id = device_id
        self.backend = backend
        self.physics = physics
        self.calib_cfg = calib
        self.key = key
        self.placement_enabled = placement
        self.method = method
        self.n_trials_ecr = n_trials_ecr

        self._state: CalibrationState | None = None
        self._canaries = None                     # core/canary.CanarySet
        self._operating_point: float | None = None
        self._packed: PackedModel | None = None
        self._pack_cfg: PUDGemvConfig | None = None
        self._placement: Placement | None = None
        self._placement_name: str | None = None
        self._placement_status: str | None = None   # hit | planned | skipped
        self._placement_error: str | None = None
        self._tuning_report: dict | None = None

    # -- construction -------------------------------------------------------

    @classmethod
    def open(cls, arch_or_grid: "str | FleetConfig | None" = None, *,
             grid: FleetConfig | None = None,
             cache_dir=None, device_id: str = "dimm0",
             backend: str = DEFAULT_BACKEND,
             physics: PhysicsParams | None = None,
             calib: CalibrationConfig | None = None,
             key: "jax.Array | int" = 0,
             placement: bool = True,
             method: str = "reference",
             n_trials_ecr: int = 1024) -> "PUDSession":
        """Open a session on one device.

        ``arch_or_grid``: either the architecture name this session serves
        (used for perf pricing and placement naming) or the device's
        ``FleetConfig`` grid; pass the other via ``grid``.  ``cache_dir``
        enables persistence (tables + placements survive restarts);
        without it calibration runs in memory.  ``key`` seeds manufacture/
        calibration (an int is wrapped with ``jax.random.key``).
        """
        arch = None
        if isinstance(arch_or_grid, FleetConfig):
            if grid is not None:
                raise ValueError("grid given twice")
            grid = arch_or_grid
        elif arch_or_grid is not None:
            arch = str(arch_or_grid)
        if not isinstance(key, jax.Array):
            key = jax.random.key(int(key))
        return cls(
            arch=arch,
            fleet_cfg=grid or FleetConfig(n_channels=1, n_banks=1,
                                          n_subarrays=16, n_cols=2048),
            cache=(CalibrationTableCache(cache_dir)
                   if cache_dir is not None else None),
            device_id=device_id, backend=backend,
            physics=physics or PhysicsParams(),
            calib=calib or CalibrationConfig(),
            key=key, placement=placement, method=method,
            n_trials_ecr=n_trials_ecr)

    @classmethod
    def at_operating_point(cls, ecr: float, *, arch: str | None = None,
                           n_fracs_cfg: tuple[int, ...] = (2, 1, 0),
                           backend: str = DEFAULT_BACKEND) -> "PUDSession":
        """Session pinned to a fixed mean ECR (e.g. the Table-I operating
        points) instead of a measured device — for pricing/what-if runs."""
        s = cls.open(arch, grid=FleetConfig(frac_counts=n_fracs_cfg),
                     backend=backend)
        s._operating_point = float(ecr)
        return s

    @classmethod
    def open_fleet(cls, arch_or_grid: "str | FleetConfig | None" = None, *,
                   mesh=None, n_data: int | None = None,
                   n_model: int | None = None,
                   grid: FleetConfig | None = None,
                   cache_dir=None, device_id: str = "dimm0",
                   backend: str = DEFAULT_BACKEND,
                   physics: PhysicsParams | None = None,
                   calib: CalibrationConfig | None = None,
                   key: "jax.Array | int" = 0,
                   placement: bool = True,
                   method: str = "reference",
                   n_trials_ecr: int = 1024) -> "PUDFleetSession":
        """Open one logical session per device of a serving mesh.

        ``mesh`` is a ``("data", "model")`` mesh from ``launch/mesh.py``;
        its "model" axis carries tensor-parallel shards of every packable
        projection, its "data" axis independent serving lanes.  Without a
        mesh, pass ``n_data``/``n_model`` explicitly — packing and all
        host-side state management still work (useful for planning tests),
        only sharded *execution* requires the mesh.

        Each of the ``n_data x n_model`` sessions gets its own derived
        ``device_id`` (suffix ``-d{lane}m{shard}``) and its own fold of
        ``key`` — so per-device calibration tables, placements, canaries
        and drift state are fully independent, exactly as physically
        distinct DIMMs would be.
        """
        if mesh is not None:
            if n_data is None:
                n_data = int(mesh.shape["data"])
            if n_model is None:
                n_model = int(mesh.shape["model"])
        if not n_data or not n_model or n_data < 1 or n_model < 1:
            raise ValueError("open_fleet needs a mesh or explicit "
                             "n_data/n_model >= 1")
        if not isinstance(key, jax.Array):
            key = jax.random.key(int(key))
        sessions = [
            [cls.open(arch_or_grid, grid=grid, cache_dir=cache_dir,
                      device_id=f"{device_id}-d{d}m{m}", backend=backend,
                      physics=physics, calib=calib,
                      key=jax.random.fold_in(key, d * n_model + m),
                      placement=placement, method=method,
                      n_trials_ecr=n_trials_ecr)
             for m in range(n_model)]
            for d in range(n_data)]
        return PUDFleetSession(sessions, mesh=mesh,
                               arch=sessions[0][0].arch)

    # -- calibration --------------------------------------------------------

    @property
    def calibration(self) -> CalibrationState | None:
        return self._state

    @property
    def n_fracs(self) -> int:
        return sum(self.fleet_cfg.frac_counts)

    @property
    def ladder(self):
        return self.fleet_cfg.ladder(self.physics)

    def calibrate(self, force: bool = False) -> CalibrationState:
        """Load the device's persisted table, or identify + persist it.

        A cache hit costs a file read; a miss runs the fleet Algorithm 1 +
        ECR/mask measurement and (with a cache) persists the table.
        """
        if self._state is not None and not force:
            return self._state
        t0 = time.time()
        levels, ecr, masks, hit = load_or_calibrate(
            self.cache if self.cache is not None else _NullCache(),
            self.device_id, self.key, self.fleet_cfg, self.physics,
            config=self.calib_cfg, method=self.method,
            n_trials_ecr=self.n_trials_ecr)
        self._state = CalibrationState(
            levels=levels, ecr=ecr, masks=masks,
            cache_hit=bool(hit), wall_s=time.time() - t0)
        return self._state

    def baseline_ecr(self, n_trials: int | None = None) -> float:
        """Mean fleet ECR of the uncalibrated B_{3,0,0} baseline on this
        device's manufactured offsets (the before-picture of Table I)."""
        from repro.core.ecr import measure_ecr_fleet
        from repro.core.offsets import baseline_charges
        cfg = self.fleet_cfg
        offsets = manufacture_fleet(self.key, cfg, self.physics)
        base = jnp.broadcast_to(
            baseline_charges(3, cfg.n_cols, self.physics)[None],
            (cfg.n_subarrays_total, 3, cfg.n_cols))
        ecr, _ = measure_ecr_fleet(
            jax.random.fold_in(self.key, 0x0ECB), offsets, base,
            self.physics, 3, n_trials=n_trials or self.n_trials_ecr)
        return float(np.asarray(ecr).mean())

    # -- canaries + live recalibration --------------------------------------

    @property
    def canaries(self):
        """The reserved ``core/canary.CanarySet``, or None."""
        return self._canaries

    def reserve_canaries(self, n_per_subarray: int = 16):
        """Reserve per-subarray canary columns for the drift monitor.

        Canaries come out of the calibration-time error-free set (evenly
        spread over each subarray) and are OR-ed into the planning masks,
        so no tensor is ever placed on them — the monitor can hammer them
        with probe patterns while decode runs on the rest of the grid.
        Call after ``calibrate`` and before ``pack``; the reservation also
        keys persisted placement names, so a canary-less cached plan is
        never reused for a canary-reserving session.
        """
        if self._state is None:
            raise RuntimeError("reserve_canaries requires calibrate() first")
        from repro.core.canary import CanarySet, reserve_canaries
        cols = reserve_canaries(self._state.masks, n_per_subarray)
        self._canaries = CanarySet(cols=cols, n_cols=self.fleet_cfg.n_cols)
        return self._canaries

    def recalibrate_subarrays(self, subarrays, sense_offsets, *,
                              assumed_temp_c: float | None = None
                              ) -> CalibrationState:
        """Partial live recalibration against the device's *current* offsets.

        The background half of drift recovery: re-runs ladder
        identification for ``subarrays`` only (per-subarray RNG streams,
        so the result is independent of how drift events were batched),
        re-measures their ECR + masks against the drifted offsets, merges
        the refreshed rows into the session state, and persists the merged
        table as a new cache version.  The cache save replaces the whole
        entry directory, which drops its persisted placements — exactly
        right, since plans made from the stale masks may sit on columns
        that went bad; the next ``pack`` re-plans from the merged masks.
        """
        if self._state is None:
            raise RuntimeError(
                "recalibrate_subarrays requires calibrate() first")
        from repro.core.ecr import measure_ecr_fleet
        from repro.core.fleet import fleet_calib_charges, recalibrate_subarrays
        t0 = time.time()
        idx = sorted(int(s) for s in subarrays)
        offs = jnp.asarray(sense_offsets)
        sub_levels = recalibrate_subarrays(
            self.key, offs, idx, self.fleet_cfg, self.physics,
            self.calib_cfg, method=self.method)
        charges = fleet_calib_charges(self.ladder, sub_levels, self.physics)
        sub_ecr, sub_masks = measure_ecr_fleet(
            jax.random.fold_in(self.key, 0x0EC5), offs[jnp.asarray(idx)],
            charges, self.physics, self.n_fracs,
            n_trials=self.n_trials_ecr)
        levels = np.asarray(self._state.levels).copy()
        ecr = np.asarray(self._state.ecr).copy()
        masks = np.asarray(self._state.masks).copy()
        levels[idx] = np.asarray(sub_levels)
        ecr[idx] = np.asarray(sub_ecr)
        masks[idx] = np.asarray(sub_masks)
        self._state = CalibrationState(
            levels=jnp.asarray(levels), ecr=jnp.asarray(ecr),
            masks=jnp.asarray(masks), cache_hit=False,
            wall_s=time.time() - t0)
        if self.cache is not None:
            self.cache.save(
                self.device_id, self.fleet_cfg, self.physics, levels,
                ecr=ecr, masks=masks,
                metadata={"method": self.method,
                          "recalibrated_subarrays": idx},
                assumed_temp_c=(self.physics.temp_nominal_c
                                if assumed_temp_c is None
                                else assumed_temp_c))
        return self._state

    def calibration_age(self) -> dict | None:
        """Age metadata of the persisted table (staleness for the drift
        monitor), or None without a cache / persisted entry."""
        if self.cache is None or isinstance(self.cache, _NullCache):
            return None
        table = self.cache.load(self.device_id, self.fleet_cfg, self.physics)
        if table is None:
            return None
        return {"calibrated_at": table.calibrated_at,
                "age_days": table.age_days(),
                "assumed_temp_c": table.assumed_temp_c,
                "params_fingerprint": table.params_fingerprint}

    # -- placement + packing ------------------------------------------------

    @property
    def placement(self) -> Placement | None:
        return self._placement

    @property
    def placement_status(self) -> str | None:
        """After ``pack``: "hit" | "planned" | "skipped" | None (placement
        not attempted — disabled or uncalibrated)."""
        return self._placement_status

    @property
    def placement_error(self) -> str | None:
        return self._placement_error

    @property
    def placement_name(self) -> str | None:
        return self._placement_name

    @property
    def packed(self) -> PackedModel | None:
        return self._packed

    def _plan_requests(self, reqs, base_name: str) -> Placement | None:
        """Cache-aware placement planning for an explicit request list.

        The shard-slicing entry used by ``PUDFleetSession``: each model
        shard plans its *own column slice* of every request against its own
        masks and persists under its own fingerprinted name. ``_plan``
        feeds it the whole-model requests.
        """
        pname = f"{base_name}-{requests_fingerprint(reqs)}"
        masks = self._state.masks
        if self._canaries is not None:
            # Reserved canaries plan as unusable despite being error-free,
            # and the reservation hash keys the persisted plan.
            masks = np.asarray(masks, bool) | self._canaries.mask()
            pname += f"-c{self._canaries.fingerprint()}"
        self._placement_name = pname
        placement = None
        if self.cache is not None:
            placement = self.cache.load_placement(
                self.device_id, self.fleet_cfg, self.physics, pname)
        if placement is not None:
            self._placement_status = "hit"
            self._placement = placement
            return placement
        try:
            placement = plan_for_grid(
                masks, reqs, self.fleet_cfg.grid_shape)
        except PlacementError as e:
            self._placement_status, self._placement_error = "skipped", str(e)
            return None
        if self.cache is not None:
            self.cache.save_placement(self.device_id, self.fleet_cfg,
                                      self.physics, pname, placement)
        self._placement_status = "planned"
        self._placement = placement
        return placement

    def _plan(self, params: dict, cfg: PUDGemvConfig,
              name: str | None) -> Placement | None:
        return self._plan_requests(packing_requests(params, cfg),
                                   name or self.arch or "model")

    def pack(self, params: dict, cfg: PUDGemvConfig | None = None, *,
             name: str | None = None,
             include_unembed: bool = True) -> PackedModel:
        """Pack a parameter tree for this device.

        With placement enabled and a calibrated session, every packable
        projection's columns are planned onto error-free physical columns
        (loaded from the cache when a plan for the same request fingerprint
        is already persisted, planned + persisted otherwise) and the packs
        come out in the placed physical layout.  ``name`` labels the
        persisted placement (default: the session's arch).

        The packs are stamped with the session backend (unless the config
        names its own), so model forwards dispatch them through it too.
        """
        if cfg is None:
            cfg = PUDGemvConfig(backend=self.backend)
        elif cfg.backend is None:
            cfg = dataclasses.replace(cfg, backend=self.backend)
        self._placement_status = self._placement_error = None
        self._placement = None
        if (self.placement_enabled and self._state is not None
                and self._state.masks is not None):
            self._placement = self._plan(params, cfg, name)
        pm = pack_model(params, cfg, include_unembed=include_unembed,
                        placement=self._placement)
        self._packed, self._pack_cfg = pm, cfg
        return pm

    # -- kernel autotuning ---------------------------------------------------

    def _tuning_cache(self):
        """The persistent tuning cache riding alongside the calibration
        cache (``<cache_dir>/tuning``), or None for cache-less sessions
        (tuning still runs; winners live only in the stamped packs)."""
        if self.cache is None:
            return None
        from repro.runtime.tune import TuningCache
        return TuningCache(self.cache.directory / "tuning")

    def tune(self, names=None, *, batches=(1, 8), force: bool = False,
             warmup: int = 1, reps: int = 3,
             max_candidates: int = 12) -> dict:
        """Autotune the packed projections and stamp the winners.

        For every pack (restricted to ``names`` — report names or unique
        path suffixes — when given) and every batch size in ``batches``
        (1 exercises the decode-shaped GeMV entry, >1 the batch-tiled
        GEMM), the persisted plan is loaded from the tuning cache; on a
        miss (or ``force=True``) the search runs (kernels/autotune.py:
        contract-filtered candidates, warmup + median timing, bit-exactness
        cross-check) and the winner is persisted.  Winning plans are
        stamped onto the packs, so every subsequent ``linear`` /
        ``serving_engine`` call — and any ``save_packed_npz`` — carries
        them; cold-start without plans falls back to the divisor heuristic.

        Returns the tuning report (also via :meth:`tuning_report`).
        """
        if self._packed is None:
            raise RuntimeError("no packed model: call session.pack() first")
        from repro.kernels.autotune import tune_kernel, tuning_key
        cache = self._tuning_cache()
        cfg = self._pack_cfg or PUDGemvConfig()
        mode = cfg.mode
        tensors = self._packed.tensors
        if names is not None:
            wanted = {}
            for name in names:
                hits = ([name] if name in tensors
                        else [k for k in tensors if k.endswith(name)])
                if len(hits) != 1:
                    raise KeyError(f"packed tensor {name!r} "
                                   + ("is ambiguous" if hits
                                      else "not found"))
                wanted[hits[0]] = tensors[hits[0]]
            tensors = wanted

        report: dict = {"fingerprint": (cache.fingerprint if cache
                                        else None),
                        "cache_dir": (str(cache.directory) if cache
                                      else None),
                        "keys": {}}
        stamped: dict[str, object] = {}
        for name, pt in tensors.items():
            planes = pt.planes[0] if pt.planes.ndim == 4 else pt.planes
            col_ids = None
            if pt.col_ids is not None:
                col_ids = (pt.col_ids[0] if pt.col_ids.ndim == 2
                           else pt.col_ids)
            plans: dict[str, object] = {}
            for batch in batches:
                entry = "gemm" if batch > 1 else "gemv"
                key = tuning_key(entry, int(batch), pt.k, pt.n, pt.n_bits,
                                 pt.layout, pt.placed)
                plan = None if (force or cache is None) else cache.load(key)
                row = {"name": name, "entry": entry}
                if plan is not None:
                    row["status"] = "hit"
                else:
                    # Deterministic int8 probe covering the full operand
                    # range; tuning is timing-only, values are irrelevant
                    # beyond exercising the same dtype/shape as serving.
                    x = ((jnp.arange(int(batch) * pt.k) % 255) - 127) \
                        .astype(jnp.int8).reshape(int(batch), pt.k)
                    res = tune_kernel(
                        entry, x, planes, col_ids=col_ids,
                        window_block=pt.window_block, layout=pt.layout,
                        logical_k=pt.logical_k, mode=mode,
                        backend=self.backend, warmup=warmup, reps=reps,
                        max_candidates=max_candidates)
                    plan = res.plan
                    row.update(status="tuned", **res.to_stats())
                    if cache is not None:
                        cache.save(key, plan, res.to_stats())
                row["plan"] = plan.to_dict()
                report["keys"][key] = row
                plans[entry] = plan
            stamped[name] = pt.replace(
                tile_plan=tuple(sorted(plans.items())))
        self._restamp_packs(stamped)
        self._tuning_report = report
        return report

    def _restamp_packs(self, stamped: dict) -> None:
        """Swap tuned packs into the packed tree (new ``PackedModel``,
        same aux metadata — the stamp is trace-static pytree aux)."""
        self._packed = _restamp_model(self._packed, stamped)

    def tuning_report(self) -> dict | None:
        """The last :meth:`tune` report (per-key status, plans, measured
        speedups), or None when the session never tuned."""
        return self._tuning_report

    # -- execution ----------------------------------------------------------

    def linear(self, x: jax.Array, name: str, *,
               backend: str | None = None) -> jax.Array:
        """Run one packed projection: x [..., K] -> [..., N] float32.

        ``name`` is the pack's report name or a unique path suffix
        ("unembed/w", "mixer/wi").  ``backend`` overrides the session
        backend for this call; all backends are bit-exact.
        """
        if self._packed is None:
            raise RuntimeError("no packed model: call session.pack() first")
        pt = self._packed.tensor(name)
        cfg = self._pack_cfg or PUDGemvConfig()
        return pud_linear(x, pt, cfg, backend=backend or self.backend)

    # -- reporting ----------------------------------------------------------

    def baseline_perf_model(self) -> PUDPerfModel:
        """The uncalibrated B_{3,0,0} Table-I operating point."""
        return PUDPerfModel(error_free_frac=1 - ECR_BASELINE_B300)

    def tuned_perf_model(self) -> "FleetPerfModel | PUDPerfModel":
        """The calibrated device's rate model: the measured per-subarray
        table when calibrated, the pinned operating point for
        ``at_operating_point`` sessions, the Table-I T_{2,1,0} constant
        otherwise."""
        if self._operating_point is not None:
            return PUDPerfModel(error_free_frac=1 - self._operating_point)
        if self._state is not None:
            return FleetPerfModel.from_table(
                self._state.ecr, n_fracs=self.n_fracs)
        return PUDPerfModel(error_free_frac=1 - ECR_PUDTUNE_T210)

    def placement_perf_model(self) -> FleetPerfModel | None:
        """Rate from the actual column placement (occupied-subarray waves),
        None when serving on the logical layout.  An *empty* placement
        (a zero-width model shard serving pure padding) also yields None —
        the device executes no placed columns, so the table-derived model
        is the honest rate."""
        if self._placement is None or not self._placement.entries:
            return None
        return FleetPerfModel.from_placement(
            self._placement, n_fracs=self.n_fracs)

    def flops_per_token(self) -> float | None:
        """2 x active params of the session's arch (one MAC = 2 flops)."""
        if self.arch is None:
            return None
        from repro.configs import get
        return 2.0 * get(self.arch).n_active_params

    def tokens_per_second(self, flops_per_token: float | None = None) -> float:
        flops = flops_per_token or self.flops_per_token()
        if flops is None:
            raise ValueError("no arch on this session: pass flops_per_token")
        return self.tuned_perf_model().tokens_per_second(flops)

    def optimal_batch_size(self, max_batch: int | None = None) -> int:
        """Occupancy-derived serving batch: the placement-derived rate
        model's optimum (weight replicas x operand residency), 1 when the
        session has no fleet-shaped model to derive it from."""
        pm = self.placement_perf_model() or self.tuned_perf_model()
        if isinstance(pm, FleetPerfModel):
            return pm.optimal_batch_size(max_batch)
        return 1

    def serving_engine(self, model, *, max_len: int,
                       batch_size: int | None = None,
                       chunk_prefill: int | None = None,
                       prefix_cache=False, slo=None, **kw):
        """A continuous-batching ``ServingEngine`` over this session's
        packed model (``pack`` must have run).  ``batch_size`` defaults to
        ``optimal_batch_size()``.

        Scheduler extensions (see ``runtime/engine.py``): ``chunk_prefill``
        interleaves fixed-size prefill chunks with decode waves,
        ``prefix_cache`` reuses completed prefills across requests
        (invalidated on every drift hot swap), and ``slo`` enables
        deadline-aware admission priced by this session's placement perf
        model (``step_seconds``).
        """
        from repro.runtime.engine import ServingEngine
        if self._packed is None:
            raise RuntimeError("no packed model: call session.pack() first")
        return ServingEngine(model, self._packed.params, session=self,
                             max_len=max_len, batch_size=batch_size,
                             chunk_prefill=chunk_prefill,
                             prefix_cache=prefix_cache, slo=slo, **kw)

    def perf_report(self, flops_per_token: float | None = None,
                    batch_size: int | None = None) -> dict:
        """Everything the serving driver prints: calibration status, Eq.-1
        rate models, the placement occupancy report and — when
        ``batch_size`` is given — the batch-aware aggregate rates."""
        base, tune = self.baseline_perf_model(), self.tuned_perf_model()
        rep: dict = {
            "device_id": self.device_id,
            "backend": self.backend,
            "n_subarrays": self.fleet_cfg.n_subarrays_total,
            "n_fracs": self.n_fracs,
            "calibrated": self._state is not None,
            "cache_hit": (self._state.cache_hit if self._state else None),
            "mean_ecr": (self._state.mean_ecr if self._state
                         else self._operating_point),
            "baseline_model": base,
            "tuned_model": tune,
            "gain": tune.speedup_vs(base),
            "placement": (self._placement.capacity_report()
                          if self._placement is not None else None),
            "placement_status": self._placement_status,
            "placement_model": self.placement_perf_model(),
        }
        flops = flops_per_token or self.flops_per_token()
        if flops is not None:
            rep["flops_per_token"] = flops
            rep["baseline_tok_s"] = base.tokens_per_second(flops)
            rep["tuned_tok_s"] = tune.tokens_per_second(flops)
            if rep["placement_model"] is not None:
                rep["placed_tok_s"] = \
                    rep["placement_model"].tokens_per_second(flops)
            # Weight-traffic terms of the last pack: the staging-bandwidth
            # ceiling and the rate under both (compute + traffic) limits.
            if self._packed is not None and isinstance(tune, FleetPerfModel):
                stored = packed_bytes(self._packed)["stored_bytes"]
                rep["weight_bytes_per_token"] = stored
                rep["staging_bound_tok_s"] = \
                    tune.staging_bound_tokens_per_second(stored)
                rep["traffic_aware_tok_s"] = \
                    tune.traffic_aware_tokens_per_second(flops, stored)
        if batch_size is not None:
            rep["batch_size"] = int(batch_size)
            rep["optimal_batch"] = self.optimal_batch_size()
            pm = self.placement_perf_model() or self.tuned_perf_model()
            if isinstance(pm, FleetPerfModel):
                rep["batch_speedup"] = pm.batch_speedup(batch_size)
                if flops is not None:
                    rep["batched_tok_s"] = pm.batched_tokens_per_second(
                        flops, batch_size)
        return rep

    def decode_extras(self) -> dict:
        """Decode-path diagnostics of the last ``pack``: layout, byte
        accounting (stored vs dense-equivalent — the bit-packing win), the
        per-token weight-traffic terms, and the packing report."""
        if self._packed is None:
            raise RuntimeError("no packed model: call session.pack() first")
        from repro.pud.gemv import weight_traffic
        return {
            "backend": self.backend,
            "layout": ("placed physical" if self._placed_layout
                       else "logical"),
            "weight_bits": self._packed.weight_bits,
            "n_packed": len(self._packed.packed_names),
            "report": self._packed.report,
            **packed_bytes(self._packed),
            **weight_traffic(self._packed),
        }

    @property
    def _placed_layout(self) -> bool:
        return self._packed is not None and self._packed.placed


class PUDFleetSession:
    """A mesh-shaped grid of ``PUDSession``s serving one sharded model.

    ``sessions[d][m]`` is the device at data lane ``d``, model shard ``m``
    — each with its own device id, and therefore its own calibration-cache
    entry, placement plans, canary reservation and drift state.

    The "model" axis carries tensor parallelism: every packable
    projection's N columns split on *full-tensor* window-block boundaries
    (``pud.placement.shard_column_slices``, verified by
    ``analysis.contracts.check_shard_slices``) so shard ``m`` owns whole
    placement windows, plans them on its own masks, and executes its slice
    through ``shard_map`` (``kernels.ops.pud_matmul_sharded`` — bit-exact
    against the unsharded path).  The "data" axis carries independent
    serving lanes: :meth:`pack` builds one ``PackedModel`` of
    ``ShardedPackedTensor``s per lane and :meth:`serving_engine` runs one
    ``ServingEngine`` per lane over a round-robin split of the request
    queue (``runtime.engine.FleetServingEngine``).

    Build one with :meth:`PUDSession.open_fleet`.
    """

    def __init__(self, sessions, *, mesh=None, axis: str = "model",
                 arch: str | None = None):
        if not sessions or not sessions[0]:
            raise ValueError("open_fleet needs at least one session")
        self.sessions = sessions
        self.mesh = mesh
        self.axis = axis
        self.arch = arch
        self.n_data = len(sessions)
        self.n_model = len(sessions[0])
        self._packs: "list[PackedModel] | None" = None
        self._pack_cfg: PUDGemvConfig | None = None
        self._pack_args = None          # (params, name, include_unembed)
        self._shard_widths: tuple[int, ...] | None = None
        self._tuning_report: dict | None = None

    # -- grid views ----------------------------------------------------------

    @property
    def n_devices(self) -> int:
        return self.n_data * self.n_model

    @property
    def device_ids(self) -> list:
        return [[s.device_id for s in row] for row in self.sessions]

    @property
    def shard_widths(self) -> "tuple[int, ...] | None":
        """Total N columns each model shard owns (set by :meth:`pack`)."""
        return self._shard_widths

    @property
    def packs(self) -> "list[PackedModel] | None":
        return self._packs

    def shard(self, data_lane: int, model_shard: int) -> PUDSession:
        return self.sessions[data_lane][model_shard]

    # -- lifecycle -----------------------------------------------------------

    def calibrate(self, force: bool = False) -> list:
        """Calibrate every device; returns the [n_data][n_model] states."""
        return [[s.calibrate(force) for s in row] for row in self.sessions]

    def reserve_canaries(self, n_per_subarray: int = 16) -> list:
        return [[s.reserve_canaries(n_per_subarray) for s in row]
                for row in self.sessions]

    def flops_per_token(self) -> float | None:
        return self.sessions[0][0].flops_per_token()

    def optimal_batch_size(self, max_batch: int | None = None) -> int:
        """Worst-case (min over devices) so every lane's engine sustains it."""
        return min(s.optimal_batch_size(max_batch)
                   for row in self.sessions for s in row)

    # -- placement + packing -------------------------------------------------

    def _shard_requests(self, reqs):
        """Per-model-shard sliced request lists + per-shard total widths.

        Every request splits on the boundaries of its *own* full-tensor
        window block (``shard_column_slices``), so no placement window ever
        straddles a shard — ``check_shard_slices`` enforces it before any
        planning happens.  Zero-width shards (more shards than blocks)
        simply receive no request for that tensor.
        """
        from repro.analysis.contracts import check_shard_slices
        sliced = [[] for _ in range(self.n_model)]
        widths = [0] * self.n_model
        for r in reqs:
            spans, bc = shard_column_slices(r.n_cols, self.n_model)
            check_shard_slices(spans, r.n_cols, bc)
            for m, (lo, hi) in enumerate(spans):
                widths[m] += hi - lo
                if hi > lo:
                    sliced[m].append(dataclasses.replace(
                        r, n_cols=hi - lo, block_cols=bc))
        return sliced, tuple(widths)

    def _plan_shard(self, data_lane: int, model_shard: int, sliced,
                    name: str | None) -> Placement | None:
        s = self.sessions[data_lane][model_shard]
        s._placement_status = s._placement_error = None
        s._placement = None
        if not (s.placement_enabled and s._state is not None):
            return None
        base = (f"{name or self.arch or 'model'}"
                f"-shard{model_shard}of{self.n_model}")
        return s._plan_requests(sliced, base)

    def pack(self, params: dict, cfg: PUDGemvConfig | None = None, *,
             name: str | None = None,
             include_unembed: bool = True) -> "list[PackedModel]":
        """Pack one sharded ``PackedModel`` per data lane.

        Each lane's model shards plan their own column slice of every
        request on their own calibration masks.  If any live shard of a
        lane cannot place (uncalibrated, or planning fails), the whole
        lane falls back to the logical sharded layout — shards of one lane
        always share a layout, which the stacked-children representation
        requires.
        """
        if cfg is None:
            cfg = PUDGemvConfig(backend=self.sessions[0][0].backend)
        elif cfg.backend is None:
            cfg = dataclasses.replace(
                cfg, backend=self.sessions[0][0].backend)
        reqs = packing_requests(params, cfg, include_unembed)
        sliced, self._shard_widths = self._shard_requests(reqs)
        packs = []
        for d in range(self.n_data):
            placements = [self._plan_shard(d, m, sliced[m], name)
                          for m in range(self.n_model)]
            if any(placements[m] is None
                   for m in range(self.n_model) if sliced[m]):
                placements = None     # logical fallback, lane-consistent
            packs.append(pack_model_sharded(
                params, cfg, n_shards=self.n_model, placements=placements,
                include_unembed=include_unembed, mesh=self.mesh,
                axis=self.axis))
        self._packs, self._pack_cfg = packs, cfg
        self._pack_args = (params, name, include_unembed)
        return packs

    def repack_lane(self, data_lane: int, *,
                    changed_model: int | None = None) -> PackedModel:
        """Rebuild one lane's sharded pack from its shards' current state.

        With ``changed_model`` given (the drift-recovery path), only that
        shard re-plans; every other shard of the lane reuses its existing
        ``Placement`` object untouched — the isolation guarantee per-shard
        recalibration rests on.
        """
        if self._packs is None or self._pack_args is None:
            raise RuntimeError("no packed fleet: call pack() first")
        params, name, include_unembed = self._pack_args
        cfg = self._pack_cfg
        reqs = packing_requests(params, cfg, include_unembed)
        sliced, self._shard_widths = self._shard_requests(reqs)
        placements = []
        for m in range(self.n_model):
            s = self.sessions[data_lane][m]
            if (changed_model is not None and m != changed_model
                    and s._placement is not None):
                placements.append(s._placement)   # untouched shard: reuse
            else:
                placements.append(
                    self._plan_shard(data_lane, m, sliced[m], name))
        if any(placements[m] is None
               for m in range(self.n_model) if sliced[m]):
            placements = None
        pm = pack_model_sharded(
            params, cfg, n_shards=self.n_model, placements=placements,
            include_unembed=include_unembed, mesh=self.mesh, axis=self.axis)
        self._packs[data_lane] = pm
        return pm

    def recalibrate_shard(self, model_shard: int, subarrays, sense_offsets,
                          *, data_lane: int = 0,
                          assumed_temp_c: float | None = None):
        """Route a drift event to the owning shard only.

        Re-runs partial recalibration on ``sessions[data_lane]
        [model_shard]``, re-plans that shard's slice of the last pack and
        rebuilds the lane's sharded ``PackedModel``.  Every other shard's
        table, placement and canaries are untouched — their ``PUDSession``
        state objects are not even read.  Returns the refreshed lane pack
        (also swapped into :attr:`packs`), or the refreshed
        ``CalibrationState`` when the fleet has not packed yet.
        """
        s = self.sessions[data_lane][model_shard]
        state = s.recalibrate_subarrays(subarrays, sense_offsets,
                                        assumed_temp_c=assumed_temp_c)
        if self._packs is None:
            return state
        return self.repack_lane(data_lane, changed_model=model_shard)

    # -- kernel autotuning ---------------------------------------------------

    def tune(self, *, batches=(1, 8), force: bool = False, warmup: int = 1,
             reps: int = 3, max_candidates: int = 12) -> dict:
        """Autotune the common per-shard kernel geometry, stamp every lane.

        All shards of a pack share one padded per-device shape by
        construction (``pack_linear_sharded`` pads every shard to the
        widest), so a single search per (pack, batch) — run on shard
        (0, 0)'s slice — covers the whole mesh.  Keys differ from the
        unsharded session's because N is the padded per-shard width.
        Winners persist in shard (0, 0)'s tuning cache.  Never routes
        through ``PUDSession.tune`` (whose stacked-layer slicing would
        mis-read the [S, WB, Kw, R] shard axis as a layer axis).
        """
        if self._packs is None:
            raise RuntimeError("no packed fleet: call pack() first")
        from repro.kernels.autotune import tune_kernel, tuning_key
        s0 = self.sessions[0][0]
        cache = s0._tuning_cache()
        cfg = self._pack_cfg or PUDGemvConfig()
        report: dict = {"fingerprint": (cache.fingerprint if cache
                                        else None),
                        "cache_dir": (str(cache.directory) if cache
                                      else None),
                        "keys": {}}
        ref = self._packs[0]
        tile_plans: dict[str, tuple] = {}
        for pname in ref.packed_names:
            st = ref.tensor(pname)
            if st.planes.ndim == 5:            # stacked layers: [L, S, ...]
                planes = st.planes[0, 0]
                col_ids = (st.col_ids[0, 0] if st.col_ids is not None
                           else None)
            else:                              # [S, WB, Kw, R]
                planes = st.planes[0]
                col_ids = st.col_ids[0] if st.col_ids is not None else None
            plans: dict[str, object] = {}
            for batch in batches:
                entry = "gemm" if batch > 1 else "gemv"
                key = tuning_key(entry, int(batch), st.k, st.padded_n,
                                 st.n_bits, st.layout, st.placed)
                plan = None if (force or cache is None) else cache.load(key)
                row = {"name": pname, "entry": entry}
                if plan is not None:
                    row["status"] = "hit"
                else:
                    x = ((jnp.arange(int(batch) * st.k) % 255) - 127) \
                        .astype(jnp.int8).reshape(int(batch), st.k)
                    res = tune_kernel(
                        entry, x, planes, col_ids=col_ids,
                        window_block=st.window_block, layout=st.layout,
                        logical_k=st.logical_k, mode=cfg.mode,
                        backend=s0.backend, warmup=warmup, reps=reps,
                        max_candidates=max_candidates)
                    plan = res.plan
                    row.update(status="tuned", **res.to_stats())
                    if cache is not None:
                        cache.save(key, plan, res.to_stats())
                row["plan"] = plan.to_dict()
                report["keys"][key] = row
                plans[entry] = plan
            tile_plans[pname] = tuple(sorted(plans.items()))
        for d, pm in enumerate(self._packs):
            stamped = {n: pm.tensor(n).replace(tile_plan=tile_plans[n])
                       for n in tile_plans}
            self._packs[d] = _restamp_model(pm, stamped)
        self._tuning_report = report
        return report

    def tuning_report(self) -> dict | None:
        return self._tuning_report

    # -- execution + reporting -----------------------------------------------

    def serving_engine(self, model, *, max_len: int,
                       batch_size: int | None = None,
                       chunk_prefill: int | None = None,
                       prefix_cache=False, slo=None, **kw):
        """A ``FleetServingEngine``: one continuous-batching lane per
        "data"-axis row, tensor parallelism inside each lane's packs.

        ``chunk_prefill`` / ``prefix_cache`` / ``slo`` pass through to
        every lane (``prefix_cache=True`` builds one per-lane LRU, and
        submit routes by cache affinity before round-robin)."""
        from repro.runtime.engine import FleetServingEngine
        if self._packs is None:
            raise RuntimeError("no packed fleet: call pack() first")
        return FleetServingEngine(
            model, [pm.params for pm in self._packs], fleet=self,
            max_len=max_len, batch_size=batch_size,
            chunk_prefill=chunk_prefill, prefix_cache=prefix_cache,
            slo=slo, **kw)

    def fleet_perf_model(self) -> FleetPerfAggregate:
        """Aggregate Eq.-1 rate model: the slowest device of each model
        shard bounds that shard, the slowest shard bounds every lane, and
        data lanes multiply (``pud.gemv.FleetPerfAggregate``)."""
        shards = []
        for m in range(self.n_model):
            worst = None
            for row in self.sessions:
                s = row[m]
                pm = s.placement_perf_model() or s.tuned_perf_model()
                if not isinstance(pm, FleetPerfModel):
                    pm = FleetPerfModel.from_table(
                        [1.0 - pm.error_free_frac])
                if worst is None or \
                        pm.macs_per_second < worst.macs_per_second:
                    worst = pm
            shards.append(worst)
        return FleetPerfAggregate(shards=tuple(shards), n_data=self.n_data,
                                  shard_widths=self._shard_widths)

    def perf_report(self, flops_per_token: float | None = None,
                    batch_size: int | None = None) -> dict:
        """Mesh shape, per-device reports, and the aggregate rates the
        serving driver prints (tokens/s over the whole fleet + scaling
        efficiency vs ``n_devices`` copies of shard (0, 0))."""
        agg = self.fleet_perf_model()
        flops = flops_per_token or self.flops_per_token()
        rep: dict = {
            "n_data": self.n_data,
            "n_model": self.n_model,
            "n_devices": self.n_devices,
            "device_ids": self.device_ids,
            "shard_widths": self._shard_widths,
            "shard_fraction": agg.shard_fraction,
            "aggregate_model": agg,
            "shards": [[s.perf_report(flops) for s in row]
                       for row in self.sessions],
        }
        if flops is not None:
            rep["flops_per_token"] = flops
            rep["aggregate_tok_s"] = agg.tokens_per_second(flops)
            rep["scaling_efficiency"] = agg.scaling_efficiency(flops)
            if batch_size is not None:
                rep["batch_size"] = int(batch_size)
                rep["aggregate_batched_tok_s"] = \
                    agg.batched_tokens_per_second(flops, batch_size)
        return rep
