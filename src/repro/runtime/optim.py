"""Distributed AdamW: fp32 moments sharded like the parameters (ZeRO),
global-norm clipping, warmup+cosine schedule, optional int8 gradient
compression with error feedback.

The optimizer state pytree mirrors the param tree, so ``param_pspecs`` specs
apply verbatim — every moment shard lives with its parameter shard, giving
ZeRO-1/3 semantics for free under pjit.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False   # int8 error-feedback compression


def lr_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptConfig):
    def f32(p):
        return jnp.zeros(p.shape, jnp.float32)
    state = {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress_grads:
        state["residual"] = jax.tree.map(f32, params)
    return state


def opt_state_pspecs(param_specs, cfg: OptConfig):
    from jax.sharding import PartitionSpec as P
    state = {"mu": param_specs, "nu": param_specs, "step": P()}
    if cfg.compress_grads:
        state["residual"] = param_specs
    return state


def global_norm(tree):
    return jnp.sqrt(sum((g.astype(jnp.float32) ** 2).sum()
                        for g in jax.tree.leaves(tree)))


# --- int8 error-feedback gradient compression -------------------------------
# Models the bandwidth-reduction trick used on slow cross-pod links: gradients
# are quantized to int8 blocks before synchronization; the quantization error
# is fed back into the next step's gradient (EF-SGD), keeping convergence.

def _quantize_int8(g, block=256):
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.maximum(jnp.abs(flat).max(axis=1, keepdims=True), 1e-12) / 127
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _dequantize_int8(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_roundtrip(g):
    """int8 quantize->dequantize; returns (g_hat, error)."""
    q, s, pad = _quantize_int8(g)
    g_hat = _dequantize_int8(q, s, pad, g.shape)
    return g_hat, g - g_hat


def adamw_update(params, grads, state, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1

    if cfg.compress_grads:
        def comp(g, r):
            g_hat, err = compress_roundtrip(g.astype(jnp.float32) + r)
            return g_hat, err
        pairs = jax.tree.map(comp, grads, state["residual"])
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        residual = jax.tree.map(lambda pr: pr[1], pairs,
                                is_leaf=lambda x: isinstance(x, tuple))
    else:
        residual = None

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"mu": mu, "nu": nu, "step": step}
    if residual is not None:
        new_state["residual"] = residual
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
