"""Online drift monitor + zero-downtime live recalibration for serving.

The paper calibrates once and holds the table fixed; PuDGhost-style drift
(temperature, aging — ``pud/physics`` sigma_temp/time_drift) slowly turns
calibration-time error-free columns error-prone, and a placed pack built
from stale masks starts reading stuck values on the columns that went bad.
This module closes the loop while the engine keeps serving:

  1. **Canary probing** (``DriftMonitor``): every ``probe_every`` controller
     ticks, push ``probe_trials`` known MAJ5 patterns through the reserved
     canary columns (``core/canary``) and score per-subarray canary ECR
     against the calibrated baseline.  Canaries were error-free at
     calibration by construction, so the baseline is zero up to the
     re-measurement churn floor; the per-subarray EMA detector
     (``DriftDetector``, ``StepWatchdog`` style — flagged rounds are
     excluded from the baseline EMA) raises ``DriftEvent(subarray,
     new_ecr, severity)`` when the excess clears the thresholds.
  2. **Background recalibration** (``DriftController``): on a critical
     event, re-run ladder identification for *only* the affected subarrays
     (``PUDSession.recalibrate_subarrays`` -> ``core/fleet``), persist the
     refreshed table through ``runtime/calib_cache`` (which drops the
     entry's stale placements), and re-plan + re-pack so tensors move off
     the columns that went bad.
  3. **Hot swap**: the rebuilt pack is parked via
     ``ServingEngine.stage_params`` and swapped in at the next step
     boundary — the engine decodes on the old pack through every recovery
     phase, so no request ever stalls and tokens flow on every step.

The controller runs its state machine *between* engine steps, one phase
per tick (probe / recalibrate / repack+stage), so fleet recalibration never
executes synchronously on the decode path — pinned by the
``no-recal-on-decode-path`` rule in ``analysis/lint.py``.

Detector thresholds vs the churn floor: canary ECR is quantized to 1/N for
N canaries, and re-probing an "error-free" column with a fresh finite trial
campaign flips marginal columns — the shallower the calibration, the more
marginal columns, so at smoke-test calibration depth 1-2 of 16 canaries
flip per round at *nominal* conditions.  The defaults (16 canaries, warn
at +0.15 ~ 3 flips, critical at +0.30 ~ 5 flips above the EMA baseline)
sit well above that floor while a real drift event — a sizeable fraction
of the subarray's columns flipping at once — clears critical in a single
probe round.  After a recovery the affected subarrays *re-baseline*: their
next probe value is absorbed as the new EMA, because recalibrating against
drifted offsets legitimately leaves a higher residual churn level than the
pristine table had.

Probe amortization: a probe round is ``probe_trials`` MAJ5 waves (the
canary columns of every subarray ride the same waves — columns within a
wave are free), priced by the same ``wave_latency_ns`` model serving rates
come from; ``DriftMonitor.probe_overhead()`` reports the modeled fraction
of DRAM time the schedule spends probing.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.canary import probe_ecr
from repro.pud.timing import maj5_counts, wave_latency_ns


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Monitor schedule + detector thresholds (see module docstring)."""

    n_canary: int = 16            # canary columns per subarray
    probe_every: int = 4          # controller ticks between probe rounds
    probe_trials: int = 64        # MAJ5 patterns per probe round
    ema_alpha: float = 0.25       # churn-baseline EMA weight
    warn_new_ecr: float = 0.15    # excess canary ECR -> warn event
    critical_new_ecr: float = 0.30  # excess canary ECR -> recalibrate


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One detector firing: ``new_ecr`` is the canary ECR the probe read."""

    subarray: int
    new_ecr: float
    severity: str                 # "warn" | "critical"
    probe_round: int = 0
    shard: int = 0                # "model"-axis shard that raised it (fleet)


class DriftDetector:
    """Per-subarray EMA detector over canary ECR (``StepWatchdog`` idiom).

    The baseline starts at zero — canaries are error-free at calibration
    by construction — and healthy rounds refine it toward the churn floor;
    rounds that raise an event are excluded so drift cannot poison the
    baseline it is measured against.
    """

    def __init__(self, n_subarrays: int, config: DriftConfig):
        self.config = config
        self.ema = np.zeros(n_subarrays, np.float32)
        self.events: list[DriftEvent] = []
        self._rebaseline: set[int] = set()

    def rebaseline(self, subarrays) -> None:
        """Absorb the next probe of ``subarrays`` directly as their EMA.

        Called after a recovery: a table recalibrated against drifted
        offsets legitimately has a higher residual churn level, and judging
        it against the pristine baseline would re-trigger forever.
        """
        self._rebaseline.update(int(s) for s in subarrays)

    def update(self, canary_ecr, probe_round: int) -> list[DriftEvent]:
        out = []
        a = self.config.ema_alpha
        for g, e in enumerate(np.asarray(canary_ecr, np.float32)):
            if g in self._rebaseline:
                self._rebaseline.discard(g)
                self.ema[g] = float(e)
                continue
            excess = float(e) - float(self.ema[g])
            if excess > self.config.critical_new_ecr:
                out.append(DriftEvent(g, float(e), "critical", probe_round))
            elif excess > self.config.warn_new_ecr:
                out.append(DriftEvent(g, float(e), "warn", probe_round))
            else:
                self.ema[g] = (1 - a) * self.ema[g] + a * float(e)
        self.events.extend(out)
        return out


class DriftMonitor:
    """Canary probing of one device against a session's live table.

    ``device`` is anything with ``sense_offsets() -> [G, n_cols]`` — the
    ``core/reliability.DriftSimulator`` under ``--drift-sim``, or a real-
    hardware adapter.  Probes always measure against the session's
    *current* levels, so post-recovery rounds score the refreshed table.
    """

    def __init__(self, session, device, *, config: DriftConfig = DriftConfig(),
                 key: jax.Array | None = None):
        if session.calibration is None:
            raise RuntimeError("DriftMonitor requires a calibrated session")
        if session.canaries is None:
            session.reserve_canaries(config.n_canary)
        self.session = session
        self.device = device
        self.config = config
        self.key = (key if key is not None
                    else jax.random.fold_in(session.key, 0x0D41F7))
        self.detector = DriftDetector(
            session.fleet_cfg.n_subarrays_total, config)
        self.probe_rounds = 0
        self.last_canary_ecr: np.ndarray | None = None

    def _charges(self):
        from repro.core.fleet import fleet_calib_charges
        return fleet_calib_charges(
            self.session.ladder, self.session.calibration.levels,
            self.session.physics)

    def probe(self) -> list[DriftEvent]:
        """One probe round over the canary columns; returns new events."""
        cs = self.session.canaries
        ecr, _ = probe_ecr(
            jax.random.fold_in(self.key, self.probe_rounds),
            self.device.sense_offsets(), self._charges(),
            self.session.physics, self.session.n_fracs,
            cols=cs.cols, n_trials=self.config.probe_trials)
        self.last_canary_ecr = np.asarray(ecr)
        events = self.detector.update(self.last_canary_ecr,
                                      self.probe_rounds)
        self.probe_rounds += 1
        return events

    def probe_overhead(self, flops_per_token: float | None = None,
                       batch_size: int = 1) -> float | None:
        """Modeled fraction of DRAM time the probe schedule costs.

        One probe round = ``probe_trials`` MAJ5 waves (all subarrays' canary
        columns ride the same wave — columns are the free axis), amortized
        over ``probe_every`` decode steps priced by the session's
        ``FleetPerfModel``.  None when the session cannot price a token.
        """
        pm = (self.session.placement_perf_model()
              or self.session.tuned_perf_model())
        flops = flops_per_token or self.session.flops_per_token()
        if flops is None or not hasattr(pm, "batched_tokens_per_second"):
            return None
        counts = maj5_counts(self.session.fleet_cfg.frac_counts)
        probe_s = (self.config.probe_trials
                   * wave_latency_ns(counts, pm.sys) * 1e-9)
        tok_s = pm.batched_tokens_per_second(flops, batch_size)
        step_s = batch_size / tok_s
        return probe_s / (probe_s + self.config.probe_every * step_s)

    def report(self) -> dict:
        """Monitor telemetry: probe progress, detector state, staleness."""
        return {
            "probe_rounds": self.probe_rounds,
            "n_canary": (self.session.canaries.n_per_subarray
                         if self.session.canaries else 0),
            "last_canary_ecr": (None if self.last_canary_ecr is None
                                else [float(e)
                                      for e in self.last_canary_ecr]),
            "ema": [float(e) for e in self.detector.ema],
            "events": len(self.detector.events),
            "critical_events": sum(e.severity == "critical"
                                   for e in self.detector.events),
            "probe_overhead": self.probe_overhead(),
            "table_age": self.session.calibration_age(),
        }


class FleetDriftMonitor:
    """Per-shard drift monitoring of one data lane of a ``PUDFleetSession``.

    One ``DriftMonitor`` — its own canary reservation, detector and EMA
    baseline — per model shard of the lane, each probing its *own* device
    (``devices[m]``) against its own session's live table.  ``probe()``
    rounds every shard and stamps each event with the owning ``shard``
    index; ``recover()`` routes a critical event through
    ``PUDFleetSession.recalibrate_shard``, so only the raising shard's
    table and placement move — every other shard's state is untouched.
    """

    def __init__(self, fleet, devices, *,
                 config: DriftConfig = DriftConfig(), data_lane: int = 0):
        row = fleet.sessions[data_lane]
        if len(devices) != len(row):
            raise ValueError(
                f"need one probe device per model shard: got {len(devices)} "
                f"for {len(row)} shards")
        self.fleet = fleet
        self.data_lane = data_lane
        self.monitors = [DriftMonitor(s, dev, config=config)
                         for s, dev in zip(row, devices)]

    def probe(self) -> list[DriftEvent]:
        """One probe round per shard; events carry the shard index."""
        events: list[DriftEvent] = []
        for m, mon in enumerate(self.monitors):
            events.extend(dataclasses.replace(e, shard=m)
                          for e in mon.probe())
        return events

    def recover(self, event: DriftEvent):
        """Recalibrate + re-plan only the shard that raised ``event``."""
        mon = self.monitors[event.shard]
        out = self.fleet.recalibrate_shard(
            event.shard, [event.subarray], mon.device.sense_offsets(),
            data_lane=self.data_lane,
            assumed_temp_c=getattr(mon.device, "temp_c", None))
        mon.detector.rebaseline([event.subarray])
        return out

    def report(self) -> dict:
        return {"data_lane": self.data_lane,
                "shards": [m.report() for m in self.monitors]}


class DriftController:
    """Recovery state machine driven between engine steps.

    ``step()`` runs one engine step, then one controller phase:

        monitor      probe on schedule; critical events queue subarrays
        recalibrate  partial fleet recal via the session (background)
        repack       re-plan placement + rebuild the pack, stage the swap

    The swap itself happens inside the *engine* at the top of its next
    step (``stage_params`` double buffer), so decode continues on the old
    pack through every phase and tokens are emitted on every step with
    live requests — zero downtime by construction.

    ``read_faults``: optional ``f(packed_params) -> packed_params`` mapping
    a freshly built pack to what the (possibly faulty) device would serve —
    under ``--drift-sim`` this injects the simulator's stuck-read state, a
    numeric no-op for an ``avoid_faulty`` placement since the refreshed
    plan dodges every drifted column.
    """

    def __init__(self, engine, monitor: DriftMonitor, model_params, *,
                 pack_cfg=None, pack_name: str | None = None,
                 read_faults=None):
        self.engine = engine
        self.monitor = monitor
        self.session = monitor.session
        self.model_params = model_params
        # Default to the config of the pack the engine is serving, so the
        # rebuilt pack differs only by placement.
        self.pack_cfg = (pack_cfg if pack_cfg is not None
                         else self.session._pack_cfg)
        self.pack_name = pack_name
        self.read_faults = read_faults
        self.phase = "monitor"
        self.tokens_per_step: list[int] = []
        self.swap_step_tokens: list[int] = []   # tokens emitted on swap steps
        self.recoveries: list[dict] = []
        self._pending: set[int] = set()
        self._current: dict | None = None
        self._ticks = 0

    # -- loop ----------------------------------------------------------------

    def step(self):
        """One engine step + one controller phase; returns completions."""
        emitted0 = self.engine._active_slot_steps
        swaps0 = len(self.engine._swap_steps)
        completions = self.engine.step()
        emitted = self.engine._active_slot_steps - emitted0
        self.tokens_per_step.append(emitted)
        if len(self.engine._swap_steps) > swaps0:
            self.swap_step_tokens.append(emitted)
        self._tick()
        return completions

    def run(self, requests=None):
        """Drain requests (and any in-flight recovery) to completion."""
        if requests is not None:
            self.engine.submit_all(requests)
        while (self.engine.n_pending or self.engine.n_active
               or self.phase != "monitor" or self.engine.swap_pending):
            self.step()
        return sorted(self.engine._completions,
                      key=lambda c: c.request_id)

    # -- state machine -------------------------------------------------------

    def _tick(self) -> None:
        self._ticks += 1
        if self.phase == "monitor":
            if (self._ticks - 1) % self.monitor.config.probe_every:
                return
            events = self.monitor.probe()
            critical = sorted({e.subarray for e in events
                               if e.severity == "critical"})
            if critical:
                self._pending.update(critical)
                ecr = self.monitor.last_canary_ecr
                self._current = {
                    "detected_step": self.engine._step_idx,
                    "detected_round": self.monitor.probe_rounds - 1,
                    "subarrays": critical,
                    "canary_ecr_at_detection": {
                        g: float(ecr[g]) for g in critical},
                }
                self.phase = "recalibrate"
        elif self.phase == "recalibrate":
            affected = sorted(self._pending)
            self._pending.clear()
            self.session.recalibrate_subarrays(
                affected, self.device.sense_offsets(),
                assumed_temp_c=getattr(self.device, "temp_c", None))
            self.phase = "repack"
        elif self.phase == "repack":
            packed = self.session.pack(self.model_params, self.pack_cfg,
                                       name=self.pack_name)
            params = packed.params
            if self.read_faults is not None:
                params = self.read_faults(params)
            self.engine.stage_params(params)
            self._current["swap_staged_step"] = self.engine._step_idx
            self._current["recalibrated_ecr"] = {
                g: float(np.asarray(self.session.calibration.ecr)[g])
                for g in self._current["subarrays"]}
            self.monitor.detector.rebaseline(self._current["subarrays"])
            self.recoveries.append(self._current)
            self._current = None
            self.phase = "monitor"

    @property
    def device(self):
        return self.monitor.device

    # -- reporting -----------------------------------------------------------

    def report(self) -> dict:
        """Controller + monitor + engine-swap telemetry in one dict."""
        rep = self.monitor.report()
        rep.update({
            "phase": self.phase,
            "ticks": self._ticks,
            "recoveries": list(self.recoveries),
            "swap_steps": list(self.engine._swap_steps),
            "swap_step_tokens": list(self.swap_step_tokens),
            "min_tokens_per_step": (min(self.tokens_per_step)
                                    if self.tokens_per_step else 0),
        })
        # Every hot swap drops the engine's prefix cache (a KV prefix
        # computed under the pre-recalibration pack is stale); surface how
        # many entries each recovery cost so operators see the trade.
        eng_rep = self.engine.scheduler_report()
        if "prefix_cache" in eng_rep:
            rep["prefix_cache"] = eng_rep["prefix_cache"]
        return rep
