"""Persistent per-device calibration tables (versioned, atomic, keyed).

Identifying calibration data for a full device costs minutes of SiMRA trials
(Algorithm 1 per subarray x thousands of subarrays); the resulting table is
static until re-characterization, so serving must never pay that cost at
startup.  This cache stores one entry per (device id, ladder configuration,
physics fingerprint):

  <root>/<device_id>/<table_key>/
      levels.npy        [G, n_cols] int32 ladder level per column
      ecr.npy           [G] float32 measured per-subarray ECR (optional)
      masks.npy         [G, n_cols] bool per-column error-prone mask
                        (optional; what column placement consumes)
      placements/       <name>.npz serialized ``pud.placement.Placement``s,
                        keyed by the packing-request fingerprint — the
                        physical layout serving actually runs on
      manifest.json     format version, grid shape, frac_counts, params
                        fingerprint, crc32, user metadata

Same durability idioms as runtime/checkpoint.py: writes go to a ``.tmp-<pid>``
directory (files: ``.tmp-<pid>`` suffix) and are ``os.rename``/``os.replace``d
into place, so a crash mid-save can never leave a torn table; loads verify
format version + shape + fingerprint and report a miss (None) on any
mismatch, which callers treat as "recalibrate".  A ``format`` bump
invalidates old entries instead of misreading them — v1 tables lacked the
error-prone masks, so they read as misses under v2 and the device is simply
re-characterized once.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import re
import shutil
import time
import zlib

import numpy as np

FORMAT = "fleet-calib-v2"

# Age metadata (drift monitoring) rides in an OPTIONAL "calibration" manifest
# block rather than a format bump: v2 entries written before the drift
# subsystem existed must keep loading as valid — re-characterizing a fleet
# because its manifest lacks a timestamp would be strictly worse than
# serving from it and letting the canary monitor judge its staleness.


def params_fingerprint(params) -> str:
    """Stable hash of every physics constant that shapes the table."""
    blob = json.dumps(dataclasses.asdict(params), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def table_key(cfg, params) -> str:
    """Cache key: ladder configuration + grid shape + physics fingerprint."""
    frac = "".join(str(f) for f in cfg.frac_counts)
    shape = "x".join(str(s) for s in cfg.grid_shape + (cfg.n_cols,))
    return f"T{frac}__{shape}__{params_fingerprint(params)}"


@dataclasses.dataclass
class CalibrationTable:
    """One loaded cache entry."""

    device_id: str
    levels: np.ndarray                # [G, n_cols] int32
    ecr: np.ndarray | None            # [G] float32
    masks: np.ndarray | None          # [G, n_cols] bool (True = error-prone)
    metadata: dict
    # Age metadata — None for entries saved before the drift subsystem.
    calibrated_at: float | None = None       # wall time of identification
    assumed_temp_c: float | None = None      # operating temp the table assumes
    params_fingerprint: str | None = None    # physics fingerprint of the entry

    def age_days(self, now: float | None = None) -> float | None:
        """Days since identification, or None for a pre-age-metadata entry."""
        if self.calibrated_at is None:
            return None
        return max(0.0, ((time.time() if now is None else now)
                         - self.calibrated_at) / 86400.0)


def _safe_name(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", name)


class CalibrationTableCache:
    def __init__(self, directory: str | os.PathLike):
        self.directory = pathlib.Path(directory)

    def _entry_dir(self, device_id: str, cfg, params) -> pathlib.Path:
        return self.directory / device_id / table_key(cfg, params)

    # -- save ---------------------------------------------------------------

    def save(self, device_id: str, cfg, params, levels: np.ndarray,
             ecr: np.ndarray | None = None,
             masks: np.ndarray | None = None,
             metadata: dict | None = None,
             calibrated_at: float | None = None,
             assumed_temp_c: float | None = None) -> pathlib.Path:
        final = self._entry_dir(device_id, cfg, params)
        # sweep staging dirs of crashed earlier saves of this entry
        for stale in final.parent.glob(final.name + ".tmp-*"):
            shutil.rmtree(stale, ignore_errors=True)
        tmp = final.with_name(final.name + f".tmp-{os.getpid()}")
        tmp.mkdir(parents=True)
        levels = np.asarray(levels, np.int32)
        np.save(tmp / "levels.npy", levels)
        crc = zlib.crc32(levels.tobytes())
        manifest = {
            "format": FORMAT,
            "device_id": device_id,
            "frac_counts": list(cfg.frac_counts),
            "grid_shape": list(cfg.grid_shape),
            "n_cols": cfg.n_cols,
            "params_fingerprint": params_fingerprint(params),
            "crc32": crc,
            "metadata": metadata or {},
            "calibration": {
                "calibrated_at": float(time.time() if calibrated_at is None
                                       else calibrated_at),
                "assumed_temp_c": (None if assumed_temp_c is None
                                   else float(assumed_temp_c)),
            },
        }
        if ecr is not None:
            np.save(tmp / "ecr.npy", np.asarray(ecr, np.float32))
        if masks is not None:
            np.save(tmp / "masks.npy", np.asarray(masks, bool))
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        final.parent.mkdir(parents=True, exist_ok=True)
        os.rename(tmp, final)
        return final

    def save_placement(self, device_id: str, cfg, params, name: str,
                       placement) -> pathlib.Path:
        """Persist one ``pud.placement.Placement`` under the table entry.

        ``name`` keys the placement (use the packing-request fingerprint);
        the write is atomic (tmp file + replace).  Requires the table entry
        to exist — a placement without its masks is meaningless.
        """
        from repro.pud.placement import save_placement_npz
        entry = self._entry_dir(device_id, cfg, params)
        if not (entry / "manifest.json").exists():
            raise FileNotFoundError(
                f"no calibration table for {device_id!r} at {entry}; "
                "save the table before its placements")
        d = entry / "placements"
        d.mkdir(exist_ok=True)
        final = d / f"{_safe_name(name)}.npz"
        for stale in d.glob(final.name + ".tmp-*"):   # crashed earlier saves
            stale.unlink(missing_ok=True)
        tmp = final.with_name(final.name + f".tmp-{os.getpid()}")
        save_placement_npz(tmp, placement)
        os.replace(tmp, final)
        return final

    # -- load ---------------------------------------------------------------

    def load(self, device_id: str, cfg, params,
             verify: bool = False) -> CalibrationTable | None:
        """Return the table, or None (miss) on absence or any mismatch."""
        d = self._entry_dir(device_id, cfg, params)
        manifest_path = d / "manifest.json"
        if not manifest_path.exists():
            return None
        try:
            manifest = json.loads(manifest_path.read_text())
        except (OSError, json.JSONDecodeError):
            return None
        if manifest.get("format") != FORMAT:
            return None
        if manifest.get("params_fingerprint") != params_fingerprint(params):
            return None
        if tuple(manifest.get("frac_counts", ())) != tuple(cfg.frac_counts):
            return None
        try:
            levels = np.load(d / "levels.npy")
        except (OSError, ValueError):      # truncated/corrupt payload: miss
            return None
        want_shape = (cfg.n_subarrays_total, cfg.n_cols)
        if tuple(levels.shape) != want_shape:
            return None
        if verify and zlib.crc32(levels.tobytes()) != manifest.get("crc32"):
            return None
        ecr = None
        if (d / "ecr.npy").exists():
            try:
                ecr = np.load(d / "ecr.npy")
            except (OSError, ValueError):
                ecr = None
        masks = None
        if (d / "masks.npy").exists():
            try:
                masks = np.load(d / "masks.npy")
            except (OSError, ValueError):
                masks = None
            if masks is not None and tuple(masks.shape) != want_shape:
                masks = None
        # Version-tolerant age read: entries saved before the drift subsystem
        # have no "calibration" block — they load as valid with None ages.
        calib = manifest.get("calibration") or {}
        return CalibrationTable(device_id=device_id, levels=levels, ecr=ecr,
                                masks=masks,
                                metadata=manifest.get("metadata", {}),
                                calibrated_at=calib.get("calibrated_at"),
                                assumed_temp_c=calib.get("assumed_temp_c"),
                                params_fingerprint=manifest.get(
                                    "params_fingerprint"))

    def load_placement(self, device_id: str, cfg, params, name: str):
        """One persisted Placement, or None on absence/corruption/mismatch."""
        from repro.pud.placement import load_placement_npz
        path = (self._entry_dir(device_id, cfg, params) / "placements"
                / f"{_safe_name(name)}.npz")
        if not path.exists():
            return None
        placement = load_placement_npz(path)
        if placement is None:
            return None
        if (placement.n_cols_per_subarray != cfg.n_cols
                or placement.n_subarrays != cfg.n_subarrays_total):
            return None
        return placement

    # -- inspection ---------------------------------------------------------

    def entries(self) -> list[dict]:
        """Manifests of every valid entry under the cache root."""
        out = []
        if not self.directory.exists():
            return out
        for manifest in sorted(self.directory.glob("*/*/manifest.json")):
            if ".tmp-" in manifest.parent.name:   # crashed/in-flight save
                continue
            try:
                out.append(json.loads(manifest.read_text()))
            except (OSError, json.JSONDecodeError):
                continue
        return out

    def placements(self, device_id: str, cfg, params) -> list[str]:
        """Names of the placements persisted for one table entry."""
        d = self._entry_dir(device_id, cfg, params) / "placements"
        return sorted(p.stem for p in d.glob("*.npz")
                      if ".tmp-" not in p.name) if d.exists() else []

    def evict(self, device_id: str) -> int:
        """Drop every table of one device; returns the number removed."""
        d = self.directory / device_id
        if not d.exists():
            return 0
        n = sum(1 for m in d.glob("*/manifest.json")
                if ".tmp-" not in m.parent.name)
        shutil.rmtree(d)
        return n


# ---------------------------------------------------------------------------
# CLI: inspect/evict persisted device tables without writing any Python.
#
#     python -m repro.runtime.calib_cache --root DIR --list
#     python -m repro.runtime.calib_cache --root DIR --stats
#     python -m repro.runtime.calib_cache --root DIR --evict DEVICE
#
# Deliberately jax-free: operators can poke a serving host's cache from any
# Python without pulling in the accelerator stack.
# ---------------------------------------------------------------------------


def _dir_bytes(path: pathlib.Path) -> int:
    return sum(f.stat().st_size for f in path.rglob("*") if f.is_file())


def _entry_rows(root: pathlib.Path) -> list[dict]:
    rows = []
    for manifest in sorted(root.glob("*/*/manifest.json")):
        entry = manifest.parent
        if ".tmp-" in entry.name:
            continue
        try:
            m = json.loads(manifest.read_text())
        except (OSError, json.JSONDecodeError):
            m = {}
        placements = entry / "placements"
        rows.append({
            "calibrated_at": (m.get("calibration") or {}).get("calibrated_at"),
            "device_id": entry.parent.name,
            "table_key": entry.name,
            "format": m.get("format", "?"),
            "grid_shape": m.get("grid_shape"),
            "n_cols": m.get("n_cols"),
            "frac_counts": m.get("frac_counts"),
            "n_placements": (sum(1 for p in placements.glob("*.npz")
                                 if ".tmp-" not in p.name)
                             if placements.exists() else 0),
            "bytes": _dir_bytes(entry),
        })
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime.calib_cache",
        description="Inspect a persistent calibration-table cache.")
    ap.add_argument("--root", required=True, metavar="DIR",
                    help="cache root (the --calib-cache directory)")
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--list", action="store_true",
                   help="one line per persisted table entry")
    g.add_argument("--stats", action="store_true",
                   help="aggregate counts and on-disk size")
    g.add_argument("--evict", metavar="DEVICE",
                   help="drop every table of one device")
    args = ap.parse_args(argv)

    root = pathlib.Path(args.root)
    if args.evict:
        n = CalibrationTableCache(root).evict(args.evict)
        print(f"evicted {n} table(s) of device {args.evict!r}")
        return 0
    rows = _entry_rows(root) if root.exists() else []
    if args.list:
        if not rows:
            print(f"no cache entries under {root}")
            return 0
        for r in rows:
            grid = "x".join(str(s) for s in (r["grid_shape"] or ["?"]))
            frac = "".join(str(f) for f in (r["frac_counts"] or ["?"]))
            at = r["calibrated_at"]
            age = (f"age {(time.time() - at) / 86400.0:.1f}d"
                   if at else "age unknown")
            print(f"{r['device_id']:<12s} {r['table_key']:<40s} "
                  f"{r['format']:<15s} grid {grid} x {r['n_cols']} cols "
                  f"T{frac}  {r['n_placements']} placement(s)  {age}  "
                  f"{r['bytes'] / 1024:.1f} KiB")
        return 0
    devices = {r["device_id"] for r in rows}
    print(f"cache root       {root}")
    print(f"devices          {len(devices)}")
    print(f"table entries    {len(rows)}")
    print(f"placements       {sum(r['n_placements'] for r in rows)}")
    print("on-disk size     "
          f"{(_dir_bytes(root) if root.exists() else 0) / 1024:.1f} KiB")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
