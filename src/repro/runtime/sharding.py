"""Sharding rules: logical axes -> mesh axes, input/cache/state PartitionSpecs.

Mesh axes (launch/mesh.py): ("data", "model") single-pod, ("pod", "data",
"model") multi-pod.  Policy (DESIGN.md §5):

  batch                 -> ("pod", "data")   (DP across pods, DP/FSDP inside)
  params "embed" dim    -> "data"            (FSDP / ZeRO-3: all-gathered per
                                              layer by XLA SPMD)
  params TP dims        -> "model"           (heads / mlp / experts / vocab)
  optimizer state       -> same as params    (ZeRO)
  sequence dim          -> None by default; "model" for long-prefill SP
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.params import DEFAULT_RULES, param_pspecs


def mesh_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def make_rules(mesh: Mesh, overrides: dict | None = None) -> dict:
    rules = dict(DEFAULT_RULES)
    rules["batch"] = batch_axes(mesh)
    rules.update(overrides or {})
    return rules


def model_param_pspecs(model, mesh: Mesh, overrides: dict | None = None):
    return param_pspecs(model.param_defs(), make_rules(mesh, overrides))


def cache_pspecs(model, mesh: Mesh, batch: int, max_len: int,
                 overrides: dict | None = None):
    return param_pspecs(model.cache_defs(batch, max_len),
                        make_rules(mesh, overrides))


def input_pspecs(specs: dict, mesh: Mesh) -> dict:
    """PartitionSpec per input: leading dim = batch, rest replicated.

    Scalars (cur_len) replicate.
    """
    b = batch_axes(mesh)
    out = {}
    for name, s in specs.items():
        if s.ndim == 0:
            out[name] = P()
        else:
            out[name] = P(*((b,) + (None,) * (s.ndim - 1)))
    return out


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sharded_pack_specs(st) -> dict:
    """PartitionSpecs for a ``pud.packed.ShardedPackedTensor``'s children.

    The stacked shard axis S maps onto the pack's mesh axis; every other
    dimension replicates.  S sits at a fixed offset from the *end* of each
    child (planes [..., S, WB, Kw, R], scale/col_ids [..., S, Np]), which
    keeps the spec correct for both single and stacked-layer packs.
    """
    def spec(arr, s_from_end: int) -> P:
        axes: list = [None] * arr.ndim
        axes[arr.ndim - s_from_end] = st.axis
        return P(*axes)

    fields = [("planes", 4), ("scale", 2)]
    if st.col_ids is not None:
        fields.append(("col_ids", 2))
    return {name: spec(getattr(st, name), off) for name, off in fields}


def put_sharded_pack(st):
    """device_put a sharded pack's children onto its mesh.

    Dispatch (``kernels.ops.pud_matmul_sharded``) shards its inputs per
    call; pre-placing the children with the matching ``NamedSharding``
    makes every call start from device-resident shards instead of
    re-scattering replicated host arrays.  A no-op numerically.
    """
    if st.mesh is None:
        raise ValueError("sharded pack carries no mesh — build it through "
                         "PUDFleetSession.pack / pack_model_sharded(mesh=...)")
    specs = sharded_pack_specs(st)
    kw = {k: jax.device_put(getattr(st, k), NamedSharding(st.mesh, v))
          for k, v in specs.items()}
    return st.replace(**kw)
