"""Jittable train / prefill / decode steps with microbatch gradient
accumulation — the functions the launcher jits with in/out shardings.

train_step: scans over microbatches (activation memory ~ 1/K), accumulates
fp32 gradients sharded like the params, then applies sharded AdamW.  Buffers
are donated by the launcher.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .optim import OptConfig, adamw_update


def _split_microbatches(batch: dict, k: int) -> dict:
    def sp(x):
        if x.ndim == 0:
            return x
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape((k, b // k) + x.shape[1:])
    return jax.tree.map(sp, batch)


def _constrain_batch(tree, batch_axes, lead: int = 0):
    """Pin the batch dim of every leaf to the DP mesh axes.

    Without this, GSPMD loses the batch sharding through the microbatch
    reshape + scan slicing and replicates the whole attention (measured on
    qwen3/train_4k: 6.1x the model flops per device; with the constraint the
    per-device flops drop ~4x — EXPERIMENTS.md §Perf iteration 1).
    """
    if batch_axes is None:
        return tree
    from jax.sharding import PartitionSpec as P

    def c(x):
        if x.ndim <= lead:
            return x
        spec = P(*((None,) * lead + (batch_axes,) + (None,) * (x.ndim - 1 - lead)))
        return jax.lax.with_sharding_constraint(x, spec)

    return jax.tree.map(c, tree)


def make_train_step(model, opt_cfg: OptConfig, microbatches: int = 1,
                    batch_axes=None) -> Callable:
    """Returns train_step(params, opt_state, batch, rng) ->
    (params, opt_state, metrics).

    batch_axes: mesh axis (or tuple) carrying the batch dim; used to pin
    microbatch slices so data parallelism survives the accumulation scan.
    """

    def train_step(params, opt_state, batch, seed):
        rng = jax.random.key(seed)
        mbs = _split_microbatches(batch, microbatches)
        mbs = _constrain_batch(mbs, batch_axes, lead=1)

        def loss_fn(p, mb, key):
            mb = _constrain_batch(mb, batch_axes)
            loss, metrics = model.train_loss(p, mb, key)
            return loss, metrics

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def mb_body(carry, xs):
            gsum, loss_sum = carry
            mb, key = xs
            (loss, _), grads = grad_fn(params, mb, key)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, loss_sum + loss), None

        gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        keys = jax.random.split(rng, microbatches)
        (gsum, loss_sum), _ = jax.lax.scan(mb_body, (gzero, 0.0),
                                           (mbs, keys))
        grads = jax.tree.map(lambda g: g / microbatches, gsum)
        loss = loss_sum / microbatches

        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_eval_loss(model) -> Callable:
    def eval_loss(params, batch):
        loss, metrics = model.train_loss(params, batch)
        return loss
    return eval_loss


def make_prefill_step(model, family: str) -> Callable:
    """Returns prefill(params, tokens[, extras]) -> (last logits, cache).

    ``extras`` is a positional dict (patch/frame embeddings for the stubbed
    vlm/encdec frontends) so the launcher can attach a sharding pytree to it.
    """

    def prefill(params, tokens, extras=None):
        if family == "vlm":
            return model.prefill(params, tokens, extras["patches"])
        if family == "encdec":
            return model.prefill(params, tokens, extras["frames"])
        return model.prefill(params, tokens)

    return prefill


def make_decode_step(model) -> Callable:
    """Returns decode(params, cache, tokens, cur_len) -> (logits, cache)."""

    def decode(params, cache, tokens, cur_len):
        return model.decode_step(params, cache, tokens, cur_len)

    return decode


def make_serve_step(model, greedy: bool = True) -> Callable:
    """Decode + sampling: returns (next_token, logits, cache)."""

    def serve(params, cache, tokens, cur_len, rng):
        logits, cache = model.decode_step(params, cache, tokens, cur_len)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rng, logits).astype(jnp.int32)
        return nxt[:, None], logits, cache

    return serve
