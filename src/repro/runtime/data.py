"""Deterministic, resumable, shard-aware data pipeline.

No external corpus ships with this container, so the pipeline generates
synthetic-but-learnable token streams (a mixture of order-2 Markov chains —
enough structure that a ~100M model's loss visibly drops within a few hundred
steps, see examples/train_lm.py).  The *pipeline machinery* is the deliverable:

  * **Determinism**: batch at step ``s`` for host shard ``h`` is a pure
    function of (seed, s, h) — `jax.random.fold_in` chains, no hidden state.
  * **Resumability**: pipeline state is just ``(seed, next_step)``; it rides
    in the checkpoint metadata and restore continues the exact stream.
  * **Shard-awareness**: each host generates only its ``1/n_hosts`` slice of
    the global batch (the per-host rows of the batch axis), as a real
    multi-host loader must.
  * **Packing**: documents are sampled to a length distribution and packed
    into fixed-length rows with EOS separators; labels are next-token with
    -100 padding masked via ``mask``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    n_chains: int = 8          # Markov mixture components
    order2_frac: float = 0.5   # fraction of order-2 positions
    eos_id: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _chain_tables(cfg: DataConfig) -> jax.Array:
    """[n_chains, vocab] per-chain next-token logit tables (static)."""
    key = jax.random.key(cfg.seed ^ 0x5EED)
    return jax.random.normal(
        key, (cfg.n_chains, min(cfg.vocab, 512)), jnp.float32) * 2.0


class DataPipeline:
    """Iterator with explicit state: ``state()`` / ``from_state``."""

    def __init__(self, cfg: DataConfig, next_step: int = 0):
        self.cfg = cfg
        self.next_step = next_step
        self._tables = _chain_tables(cfg)
        self._sample = jax.jit(self._sample_impl)

    # -- state (rides in checkpoint metadata) --------------------------------

    def state(self) -> dict:
        return {"seed": self.cfg.seed, "next_step": self.next_step}

    @classmethod
    def from_state(cls, cfg: DataConfig, state: dict) -> "DataPipeline":
        assert state["seed"] == cfg.seed, "pipeline seed changed mid-run"
        return cls(cfg, next_step=int(state["next_step"]))

    # -- batch generation -----------------------------------------------------

    def _sample_impl(self, key: jax.Array) -> dict:
        cfg = self.cfg
        b, s = cfg.host_batch, cfg.seq_len
        k_chain, k_tok, k_len = jax.random.split(key, 3)
        v = self._tables.shape[1]

        # per-row mixture component
        chain = jax.random.randint(k_chain, (b,), 0, cfg.n_chains)
        logits = self._tables[chain]                          # [b, v]

        # order-1 sampling with order-2 "echo" structure: with prob
        # order2_frac, token t repeats token t-2 (+1 mod v) — a pattern a
        # transformer learns quickly but a unigram model cannot.
        toks = jax.random.categorical(
            k_tok, logits[:, None, :].repeat(s, axis=1))      # [b, s]
        echo = (jnp.roll(toks, 2, axis=1) + 1) % v
        use_echo = jax.random.bernoulli(
            jax.random.fold_in(k_tok, 1), cfg.order2_frac, (b, s))
        pos = jnp.arange(s)[None, :]
        toks = jnp.where((pos >= 2) & use_echo, echo, toks)

        # document packing: segment rows with EOS every random 64-512 tokens
        doc_len = jax.random.randint(k_len, (b, 1), 64, 512)
        is_eos = (pos % doc_len) == (doc_len - 1)
        toks = jnp.where(is_eos, cfg.eos_id, toks).astype(jnp.int32)

        labels = jnp.roll(toks, -1, axis=1)
        mask = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
        return {"tokens": toks, "labels": labels, "mask": mask}

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step, host) — the determinism contract."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.cfg.seed), step),
            self.cfg.host_id)
        return self._sample(key)

    def __next__(self) -> dict:
        b = self.batch_at(self.next_step)
        self.next_step += 1
        return b

    def __iter__(self):
        return self


def eval_batches(cfg: DataConfig, n: int, seed_offset: int = 10_000):
    """Fixed held-out batches (disjoint fold-in domain from training)."""
    pipe = DataPipeline(
        dataclasses.replace(cfg, seed=cfg.seed + seed_offset))
    return [pipe.batch_at(i) for i in range(n)]
