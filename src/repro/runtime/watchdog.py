"""Straggler / hang mitigation for the training and serving loops.

``launch/train.py`` brackets its optimizer steps with these; ``ServingEngine``
brackets every decode step the same way and surfaces the counters
(stragglers, EMA step time, hangs) through ``perf_report``.

Two cooperating pieces, both host-side (the device program is SPMD and
lock-stepped — detection must happen at the host boundary):

  * ``StepWatchdog`` — per-step wall-time tracker with an EMA baseline.
    A step slower than ``slow_factor`` x EMA is flagged (straggler); a step
    exceeding ``hang_timeout_s`` triggers the ``on_hang`` callback from a
    monitor thread (at fleet scale: report the host to the coordinator so
    the job restarts without it; here: log + raise).
  * ``Heartbeat`` — writes ``heartbeat_<host>.json`` (step, wall time,
    monotonically increasing counter) so an external supervisor
    (launch/train.py --supervise, or the cluster manager) can distinguish
    "slow" from "dead" and act per host.

The counters feed EXPERIMENTS.md's fault-tolerance test: kill -9 mid-run,
restart, verify bit-identical continuation from the atomic checkpoint.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import threading
import time
from typing import Callable


@dataclasses.dataclass
class StepWatchdog:
    slow_factor: float = 2.5
    hang_timeout_s: float = 600.0
    ema_alpha: float = 0.1
    on_hang: Callable[[float], None] | None = None

    def __post_init__(self):
        self.ema_s: float | None = None
        self.stragglers: list[tuple[int, float]] = []
        self._step_start: float | None = None
        self._step_idx = 0
        self._stop = threading.Event()
        self._monitor: threading.Thread | None = None

    # -- step bracketing -------------------------------------------------------

    def start_step(self, step: int) -> None:
        self._step_idx = step
        self._step_start = time.monotonic()
        if self._monitor is None and self.on_hang is not None:
            self._monitor = threading.Thread(target=self._watch, daemon=True)
            self._monitor.start()

    def end_step(self) -> dict:
        assert self._step_start is not None, "end_step before start_step"
        dt = time.monotonic() - self._step_start
        self._step_start = None
        is_straggler = self.ema_s is not None and dt > self.slow_factor * \
            self.ema_s
        if is_straggler:
            self.stragglers.append((self._step_idx, dt))
        # EMA excludes flagged steps so one hiccup doesn't poison the baseline
        if not is_straggler:
            self.ema_s = dt if self.ema_s is None else (
                (1 - self.ema_alpha) * self.ema_s + self.ema_alpha * dt)
        return {"step_time_s": dt, "ema_s": self.ema_s,
                "straggler": is_straggler}

    def _watch(self) -> None:
        while not self._stop.wait(1.0):
            start = self._step_start
            if start is None:
                continue
            waited = time.monotonic() - start
            if waited > self.hang_timeout_s:
                self.on_hang(waited)
                return

    def close(self) -> None:
        self._stop.set()


@dataclasses.dataclass
class Heartbeat:
    directory: str | os.PathLike
    host_id: int = 0

    def __post_init__(self):
        self.directory = pathlib.Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self._count = 0

    @property
    def path(self) -> pathlib.Path:
        return self.directory / f"heartbeat_{self.host_id}.json"

    def beat(self, step: int, **extra) -> None:
        self._count += 1
        tmp = self.path.with_suffix(f".tmp-{os.getpid()}")
        tmp.write_text(json.dumps(
            {"host": self.host_id, "step": step, "count": self._count,
             "time": time.time(), **extra}))
        os.rename(tmp, self.path)

    @staticmethod
    def read_all(directory) -> list[dict]:
        out = []
        for p in pathlib.Path(directory).glob("heartbeat_*.json"):
            try:
                out.append(json.loads(p.read_text()))
            except (json.JSONDecodeError, OSError):
                pass  # torn read: supervisor retries next poll
        return sorted(out, key=lambda h: h["host"])

    @staticmethod
    def stale_hosts(directory, timeout_s: float = 120.0) -> list[int]:
        now = time.time()
        return [h["host"] for h in Heartbeat.read_all(directory)
                if now - h["time"] > timeout_s]
