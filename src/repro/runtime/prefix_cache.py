"""PrefixCache: LRU reuse of prefill KV state across requests.

Serving traffic is heavy on repeated prefixes — the same system prompt
leads hundreds of requests — and prefill is the expensive phase (O(S)
full-width matmuls per layer vs O(1) for a decode step).  This cache lets
the engine skip that work: after a prompt's prefill completes, its KV
pytree (trimmed to the *exact* token count, so no bucket-padding garbage
can ever leak into a reader) is stored under

    (params_version, sha1(prompt_tokens), n_tokens)

and later requests reuse it two ways:

  * **full hit** — an entry covering the entire new prompt: the engine
    scatters the stored cache into a batch lane and starts decoding with
    zero prefill work.
  * **partial hit** — an entry covering a chunk-aligned proper prefix
    (the shared system prompt): the engine seeds the slot's prefill state
    from it and chunked prefill resumes at ``start=len(entry)``, paying
    only for the distinct suffix.

Correctness guards:

  * ``params_version`` is bumped by the engine on every ``stage_params``
    hot swap, and ``invalidate()`` drops all entries — a stale prefix
    computed under a pre-drift-recalibration pack is unreachable.
  * every lookup re-verifies the stored token array against the query
    prefix (hash collisions and longer-cached-than-query prompts both
    fail closed to a miss).
  * entries below ``min_tokens`` are not stored — reusing a 2-token
    prefix costs more in bookkeeping than the prefill it saves.

Bit-exactness: a stored entry holds exactly the rows a whole-bucket
prefill produced for those positions; resuming from them goes through the
same chunked-prefill path as a cold prompt, so tokens and logits are
bit-identical to a cache-miss run (tests/test_chunked_prefill.py).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Any

import jax
import numpy as np

#: Prefixes shorter than this are never cached (bookkeeping > savings).
DEFAULT_MIN_TOKENS = 4

#: Default entry capacity; smoke-scale KV pytrees are KBs each.
DEFAULT_CAPACITY = 32


def _token_key(tokens: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(tokens, np.int32).tobytes()
                        ).hexdigest()


def _nbytes(cache: Any) -> int:
    return sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache))


@dataclasses.dataclass
class PrefixEntry:
    """One cached prefix: the KV pytree plus reuse metadata.

    ``cache`` leaves are [L, 1, n_tokens, ...] — trimmed to the exact
    prefix length.  ``logits`` is the last-position logits row ([V]) and
    is only present for full-prompt entries (a partial prefix's logits
    are useless: the resumed chunk recomputes the real last position).
    """

    tokens: np.ndarray            # [n_tokens] int32, for exact verification
    cache: Any                    # KV pytree, seq axis trimmed to n_tokens
    logits: np.ndarray | None
    nbytes: int

    @property
    def n_tokens(self) -> int:
        return int(self.tokens.shape[0])


class PrefixCache:
    """LRU over ``PrefixEntry``s keyed on (params version, token hash, len).

    Not thread-safe; the engine calls it from its scheduling loop only.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 min_tokens: int = DEFAULT_MIN_TOKENS,
                 max_bytes: int | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.min_tokens = int(min_tokens)
        self.max_bytes = max_bytes
        self._entries: collections.OrderedDict[tuple, PrefixEntry] = \
            collections.OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    # -- lookup --------------------------------------------------------------

    def _get(self, version: int, tokens: np.ndarray,
             length: int) -> PrefixEntry | None:
        """Verified fetch of the entry covering ``tokens[:length]``."""
        if length < self.min_tokens or length > tokens.shape[0]:
            return None
        prefix = np.ascontiguousarray(tokens[:length], np.int32)
        key = (version, _token_key(prefix), length)
        entry = self._entries.get(key)
        if entry is None:
            return None
        # fail closed on hash collision / stale shape
        if entry.n_tokens != length or \
                not np.array_equal(entry.tokens, prefix):
            return None
        return entry

    def lookup(self, version: int, tokens, lengths) -> PrefixEntry | None:
        """Longest verified entry covering a prefix of ``tokens``.

        ``lengths``: candidate prefix lengths to try, best first (the
        engine passes [full prompt, then chunk-aligned lengths
        descending]).  Counts one hit or one miss per call and refreshes
        LRU recency on hit.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        for length in lengths:
            entry = self._get(version, tokens, int(length))
            if entry is not None:
                key = (version, _token_key(entry.tokens), entry.n_tokens)
                self._entries.move_to_end(key)
                self.hits += 1
                return entry
        self.misses += 1
        return None

    def probe(self, version: int, tokens, lengths) -> int:
        """Longest covered prefix length without touching LRU state or
        hit/miss counters — the fleet's lane-affinity check."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        for length in lengths:
            if self._get(version, tokens, int(length)) is not None:
                return int(length)
        return 0

    # -- insert / evict ------------------------------------------------------

    def insert(self, version: int, tokens, cache,
               logits: np.ndarray | None = None) -> bool:
        """Store a prefix; returns False when below ``min_tokens`` or
        already present (first writer wins — the values are identical by
        bit-exactness, so refreshing buys nothing)."""
        tokens = np.ascontiguousarray(
            np.asarray(tokens, np.int32).reshape(-1))
        n = int(tokens.shape[0])
        if n < self.min_tokens:
            return False
        key = (version, _token_key(tokens), n)
        if key in self._entries:
            self._entries.move_to_end(key)
            return False
        entry = PrefixEntry(
            tokens=tokens, cache=cache,
            logits=None if logits is None else np.asarray(logits),
            nbytes=_nbytes(cache))
        self._entries[key] = entry
        self._bytes += entry.nbytes
        self.inserts += 1
        self._shrink()
        return True

    def _shrink(self) -> None:
        while len(self._entries) > self.capacity or (
                self.max_bytes is not None and self._bytes > self.max_bytes
                and len(self._entries) > 1):
            _, old = self._entries.popitem(last=False)
            self._bytes -= old.nbytes
            self.evictions += 1

    def invalidate(self) -> int:
        """Drop every entry (params hot swap); returns #dropped."""
        n = len(self._entries)
        self._entries.clear()
        self._bytes = 0
        if n:
            self.invalidations += 1
        return n

    # -- telemetry -----------------------------------------------------------

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "bytes": self._bytes,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": (self.hits / total) if total else 0.0,
            "inserts": self.inserts,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
        }
