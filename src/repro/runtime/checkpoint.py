"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic restore.

Design (no orbax in this environment — built on numpy + rename atomicity):

  * A checkpoint is a directory ``step_<N>/`` holding one ``.npy`` per pytree
    leaf (keyed by its flattened path) plus ``manifest.json`` (paths, shapes,
    dtypes, step, user metadata, and a payload checksum).
  * **Atomicity**: writes go to ``step_<N>.tmp-<pid>/`` and are ``os.rename``d
    into place; the ``LATEST`` pointer file is likewise written-then-renamed.
    A crash mid-save leaves only a ``.tmp-*`` directory, which restore ignores
    and the next save garbage-collects — a restart can never see a torn
    checkpoint.
  * **Async**: ``save_async`` snapshots to host memory (``jax.device_get``)
    synchronously — cheap relative to a step — then serializes on a
    background thread so training overlaps the disk write. ``wait()`` joins;
    a second save while one is in flight joins the first (back-pressure).
  * **Keep-k**: after a successful save, only the newest ``keep`` checkpoints
    are retained (the LATEST pointer is updated before any deletion).
  * **Elastic restore**: leaves are stored as full (unsharded) global arrays;
    ``restore`` accepts an optional sharding pytree and ``jax.device_put``s
    onto it, so a checkpoint written on a 512-chip mesh restores onto 256 or
    1024 chips (device-count changes re-shard transparently).  At true
    1000+-node scale you would write per-host shards instead; the manifest
    carries a ``format`` field so that layout can be added without breaking
    old checkpoints (see DESIGN.md §5).

Multi-host protocol: only process 0 writes (``should_write``); all processes
restore.  On this single-process container that's the identity.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import re
import shutil
import threading
import zlib
from typing import Any

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np

# numpy can't serialize ml_dtypes (bfloat16, fp8) natively; store a bit-view
# in a same-width integer dtype and record the true dtype in the manifest.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}

_STEP_RE = re.compile(r"^step_(\d+)$")
_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_part(p) for p in path)
        out[key] = leaf
    return out


def _path_part(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    return str(p)


def _unflatten_into(template, leaves: dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    vals = []
    for path, tmpl in flat:
        key = _SEP.join(_path_part(p) for p in path)
        if key not in leaves:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        v = leaves[key]
        want = getattr(tmpl, "shape", None)
        if want is not None and tuple(v.shape) != tuple(want):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {v.shape} != model {want}")
        vals.append(v)
    return jax.tree_util.tree_unflatten(treedef, vals)


@dataclasses.dataclass
class CheckpointManager:
    directory: str | os.PathLike
    keep: int = 3
    should_write: bool = True          # False on non-zero hosts

    def __post_init__(self):
        self.directory = pathlib.Path(self.directory)
        self._thread: threading.Thread | None = None
        self._error: list[BaseException] = []
        if self.should_write:
            self.directory.mkdir(parents=True, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, metadata: dict | None = None) -> None:
        """Synchronous atomic save."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._write(step, host, metadata or {})

    def save_async(self, step: int, tree, metadata: dict | None = None
                   ) -> None:
        """Snapshot now, write on a background thread."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                self._write(step, host, metadata or {})
            except BaseException as e:  # surfaced by wait()
                self._error.append(e)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def _write(self, step: int, host_tree, metadata: dict) -> None:
        if not self.should_write:
            return
        final = self.directory / f"step_{step}"
        tmp = self.directory / f"step_{step}.tmp-{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        manifest = {"format": "full-v1", "step": step, "metadata": metadata,
                    "leaves": {}}
        crc = 0
        for key, arr in flat.items():
            arr = np.asarray(arr)
            fname = key.replace(_SEP, "__") + ".npy"
            true_dtype = str(arr.dtype)
            if true_dtype in _VIEW_AS:
                arr = arr.view(_VIEW_AS[true_dtype])
            np.save(tmp / fname, arr)
            crc = zlib.crc32(arr.tobytes(), crc)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": true_dtype}
        manifest["crc32"] = crc
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._point_latest(step)
        self._gc()

    def _point_latest(self, step: int) -> None:
        ptr = self.directory / "LATEST"
        tmp = self.directory / f"LATEST.tmp-{os.getpid()}"
        tmp.write_text(str(step))
        os.rename(tmp, ptr)

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.directory / f"step_{s}", ignore_errors=True)
        for p in self.directory.glob("*.tmp-*"):
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        if not pathlib.Path(self.directory).exists():
            return []
        out = []
        for p in self.directory.iterdir():
            m = _STEP_RE.match(p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        ptr = self.directory / "LATEST"
        if ptr.exists():
            s = int(ptr.read_text().strip())
            if (self.directory / f"step_{s}" / "manifest.json").exists():
                return s
        steps = self.all_steps()   # LATEST lost/torn: fall back to scan
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None,
                shardings=None, verify: bool = False):
        """Restore into the structure of ``template``.

        shardings: optional pytree of jax.sharding.Sharding — leaves are
        device_put onto it (elastic re-shard). verify: recompute the crc.
        Returns (tree, step, metadata).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.directory}")
        d = self.directory / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = {}
        crc = 0
        for key, info in manifest["leaves"].items():
            arr = np.load(d / info["file"])
            if verify:
                crc = zlib.crc32(arr.tobytes(), crc)
            if info["dtype"] in _VIEW_AS:
                arr = arr.view(np.dtype(info["dtype"]))
            leaves[key] = arr
        if verify and crc != manifest.get("crc32"):
            raise IOError(f"checkpoint step_{step} failed crc verification")
        tree = _unflatten_into(template, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, step, manifest.get("metadata", {})
