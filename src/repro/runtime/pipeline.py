"""GPipe-style pipeline parallelism over a "stage" mesh axis.

The framework's default distribution is 2-D FSDP x TP (sharding.py); this
module adds the third option for very deep archs (deepseek-67b: 95 layers)
or cross-pod scaling where DCN bandwidth makes FSDP all-gathers expensive:
split the layer stack into S stages, shard microbatches through them with
``jax.lax.ppermute`` inside a ``shard_map``, and overlap stage compute with
the point-to-point transfers (XLA's latency-hiding scheduler handles the
async pairs; the schedule below is the standard GPipe fill-drain with
B microbatches -> pipeline bubble S-1 / (B + S - 1)).

Layout contract: stage-stacked parameters [S, ...] sharded over "stage";
inputs [B_micro, ...] replicated along "stage" (each stage computes every
microbatch but only its own layer slice — activations flow, weights stay).

``pipelined_apply`` is deliberately model-agnostic: it takes
``stage_fn(stage_params, h) -> h`` (one stage's layer run, e.g. the scanned
transformer block group) and composes the schedule around it.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipelined_apply(
    stage_fn: Callable,
    stage_params,
    h: jax.Array,              # [n_micro, micro_batch, ...] microbatched input
    mesh: Mesh,
    stage_axis: str = "stage",
):
    """Run h through S pipeline stages with the GPipe fill-drain schedule.

    Returns the output of the LAST stage for every microbatch, in order.
    Inside shard_map each device holds stage s's params and, at tick t,
    works on microbatch (t - s); ppermute shifts activations s -> s+1.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = h.shape[0]
    n_ticks = n_micro + n_stages - 1

    def body(params_s, h_all):
        # params_s: this stage's slice [1, ...] (shard_map strips nothing —
        # leading stage dim becomes size 1); h_all: [n_micro, mb, ...]
        params_local = jax.tree.map(lambda x: x[0], params_s)
        sid = jax.lax.axis_index(stage_axis)

        def tick(carry, t):
            acc, inflight = carry
            # stage 0 injects microbatch t; others take the permuted input
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            injected = h_all[mb_idx]
            x_in = jnp.where(sid == 0, injected, inflight)
            y = stage_fn(params_local, x_in)
            # last stage banks its result for microbatch (t - (S-1))
            out_idx = t - (n_stages - 1)
            valid = (out_idx >= 0) & (sid == n_stages - 1)
            acc = jax.lax.cond(
                valid,
                lambda a: jax.lax.dynamic_update_index_in_dim(
                    a, y, jnp.maximum(out_idx, 0), 0),
                lambda a: a, acc)
            # shift activations to the next stage (ring; last->first unused)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(y, stage_axis, perm)
            return (acc, nxt), None

        acc0 = jnp.zeros((n_micro,) + h_all.shape[1:], h_all.dtype)
        (acc, _), _ = jax.lax.scan(
            tick, (acc0, jnp.zeros_like(h_all[0])), jnp.arange(n_ticks))
        # every device returns the full acc; only the last stage's is real —
        # zero the others and psum to replicate it along the stage axis.
        acc = jnp.where(sid == n_stages - 1, acc, jnp.zeros_like(acc))
        return jax.lax.psum(acc, stage_axis)

    spec_params = jax.tree.map(lambda _: P(stage_axis), stage_params)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_params, P()),          # params stage-sharded, h replicated
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, h)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: idle ticks / total ticks."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def stage_split(defs_or_params, n_stages: int):
    """Split a layer-stacked pytree [L, ...] into [S, L/S, ...]."""
    def one(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape((n_stages, l // n_stages) + x.shape[1:])
    return jax.tree.map(one, defs_or_params)
