"""Public API surface of the PUDTune reproduction.

One import site for the serving stack — the session facade, the typed pack
pytrees, the execution-backend registry, and the placement/config types they
speak.  Workloads should depend on this module; the deeper layers
(``repro.pud``, ``repro.kernels``, ``repro.core``) stay free to refactor.

    from repro.api import PUDSession, PUDGemvConfig

    session = PUDSession.open("qwen3-1.7b", grid=FleetConfig(...),
                              cache_dir="~/.pud-cache")
    session.calibrate()
    packed = session.pack(params, PUDGemvConfig(weight_bits=4))
    y = session.linear(x, "unembed/w", backend="reference")

See docs/api.md for the lifecycle and the old->new call-site migration
table.
"""
from __future__ import annotations

from repro.core.calibrate import CalibrationConfig
from repro.core.canary import CanarySet, drifted_offsets, probe_ecr
from repro.core.fleet import (FleetConfig, load_or_calibrate,
                              recalibrate_subarrays)
from repro.core.reliability import DriftSimulator
from repro.analysis.contracts import check_shard_slices
from repro.kernels.backends import (Backend, backend_names, get_backend,
                                    register_backend)
from repro.pud.gemv import (ATTN_PACKABLE, ECR_BASELINE_B300,
                            ECR_PUDTUNE_T210, FFN_PACKABLE,
                            FleetPerfAggregate, FleetPerfModel,
                            PUDGemvConfig, PUDPerfModel, pack_linear,
                            pud_linear, weight_traffic)
from repro.pud.packed import (LAYOUT_BITPACK, LAYOUT_DENSE, PackedModel,
                              PackedTensor, ShardedPackedTensor,
                              as_packed_tensor, load_packed_npz,
                              packed_bytes, save_packed_npz, to_bitpacked,
                              to_dense)
from repro.pud.packer import (pack_for_serving, pack_model,
                              pack_model_sharded, packing_requests)
from repro.pud.physics import PhysicsParams
from repro.pud.placement import (Placement, PlacementError, PlacementRequest,
                                 TensorPlacement, inject_read_faults,
                                 refresh_fault_state, shard_column_slices)
from repro.runtime.calib_cache import CalibrationTableCache
from repro.runtime.drift import (DriftConfig, DriftController, DriftDetector,
                                 DriftEvent, DriftMonitor, FleetDriftMonitor)
from repro.runtime.engine import (Completion, FleetServingEngine, Request,
                                  ServingEngine, SLOConfig)
from repro.runtime.prefix_cache import PrefixCache
from repro.runtime.session import (CalibrationState, PUDFleetSession,
                                   PUDSession)
from repro.runtime.watchdog import Heartbeat, StepWatchdog

__all__ = [
    # session lifecycle
    "PUDSession", "CalibrationState",
    # sharded multi-device serving fleet
    "PUDFleetSession", "FleetServingEngine", "FleetDriftMonitor",
    "FleetPerfAggregate", "ShardedPackedTensor", "pack_model_sharded",
    "shard_column_slices", "check_shard_slices",
    # batched serving
    "ServingEngine", "Request", "Completion",
    "PrefixCache", "SLOConfig",
    "StepWatchdog", "Heartbeat",
    # drift monitoring + live recalibration
    "DriftMonitor", "DriftController", "DriftDetector", "DriftConfig",
    "DriftEvent", "DriftSimulator", "CanarySet", "probe_ecr",
    "drifted_offsets", "recalibrate_subarrays", "refresh_fault_state",
    # configs
    "PUDGemvConfig", "FleetConfig", "CalibrationConfig", "PhysicsParams",
    "FFN_PACKABLE", "ATTN_PACKABLE",
    # typed packs + storage layouts
    "PackedTensor", "PackedModel", "as_packed_tensor", "packed_bytes",
    "pack_model", "packing_requests",
    "LAYOUT_BITPACK", "LAYOUT_DENSE", "to_bitpacked", "to_dense",
    "save_packed_npz", "load_packed_npz", "weight_traffic",
    # backends
    "Backend", "register_backend", "get_backend", "backend_names",
    # placement
    "Placement", "TensorPlacement", "PlacementRequest", "PlacementError",
    "inject_read_faults",
    # perf models + Table-I operating points
    "PUDPerfModel", "FleetPerfModel",
    "ECR_BASELINE_B300", "ECR_PUDTUNE_T210",
    # persistence + legacy shims
    "CalibrationTableCache", "load_or_calibrate",
    "pack_for_serving", "pack_linear", "pud_linear",
]
