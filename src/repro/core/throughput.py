"""End-to-end method evaluation: ECR + Eq.-1 throughput (paper Table I).

``evaluate_method`` runs the full pipeline for one MAJ5 implementation
(baseline B_{x,0,0} or PUDTune T_{x,y,z}):

    manufacture subarray -> [identify calibration data (Alg. 1)] ->
    measure MAJ5 ECR (Monte-Carlo, paper protocol) ->
    measure ADD8/MUL8 compound ECR on the MAJ graphs ->
    price command sequences on the DDR4-2133 model -> Eq. 1 throughput.

MAJ5 TOPS uses the standalone MAJ5 sequence; ADD/MUL use the staged
arithmetic sequences (see pud/bitserial.py docstring).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.pud.bitserial import (MajContext, add8_counts,
                                 maj5_standalone_counts, mul8_counts)
from repro.pud.physics import PhysicsParams
from repro.pud.timing import SystemConfig, throughput_ops, wave_latency_ns
from .calibrate import CalibrationConfig, identify_calibration
from .ecr import measure_ecr_graph, measure_ecr_maj5
from .offsets import baseline_charges, levels_to_charges, make_ladder


@dataclasses.dataclass
class MethodResult:
    name: str
    ecr: float                    # MAJ5 error-prone column ratio
    ecr_add: float
    ecr_mul: float
    maj5_tops: float
    add8_gops: float
    mul8_gops: float
    maj5_latency_us: float
    levels: jax.Array | None = None
    error_free_mask: jax.Array | None = None   # per measured column

    def row(self) -> str:
        return (f"{self.name},{self.ecr:.4f},{self.maj5_tops / 1e12:.3f},"
                f"{self.add8_gops / 1e9:.1f},{self.mul8_gops / 1e9:.2f}")


def _parse_method(name: str) -> tuple[str, tuple[int, int, int]]:
    """'B300' -> ('baseline', (3,0,0)); 'T210' -> ('pudtune', (2,1,0))."""
    kind = "baseline" if name[0] == "B" else "pudtune"
    fc = tuple(int(c) for c in name[1:4])
    return kind, fc


def evaluate_method(
    key: jax.Array,
    name: str,
    params: PhysicsParams = PhysicsParams(),
    sys: SystemConfig = SystemConfig(),
    n_cols: int = 65536,
    n_trials_maj5: int = 8192,
    n_cols_arith: int = 4096,
    n_trials_arith: int = 512,
    calib_config: CalibrationConfig = CalibrationConfig(),
    with_arith: bool = True,
) -> MethodResult:
    kind, fc = _parse_method(name)
    k_mfg, k_cal, k_ecr, k_add, k_mul = jax.random.split(key, 5)
    sense_offset = params.sigma_static * jax.random.normal(
        k_mfg, (n_cols,), jnp.float32)

    levels = None
    if kind == "baseline":
        calib_charge = baseline_charges(fc[0], n_cols, params)
        n_fracs = fc[0]
    else:
        ladder = make_ladder(fc, params)
        levels = identify_calibration(
            k_cal, sense_offset, ladder, params, calib_config)
        calib_charge = levels_to_charges(ladder, levels, params)
        n_fracs = ladder.n_fracs

    ecr5, err_mask = measure_ecr_maj5(
        k_ecr, sense_offset, calib_charge, params, n_fracs,
        n_trials=n_trials_maj5)
    ef5 = (1.0 - ecr5) * sys.n_cols_per_subarray
    maj5_cnt = maj5_standalone_counts(n_fracs)
    maj5_tput = throughput_ops(maj5_cnt, ef5, sys)

    ecr_add = ecr_mul = float("nan")
    add_tput = mul_tput = float("nan")
    if with_arith:
        # Compound-graph ECR on a column subsample (the graphs are ~100x the
        # MAJ count of a single MAJ5; same protocol, fewer columns/trials).
        sub = slice(0, n_cols_arith)
        ctx = MajContext(
            params=params,
            sense_offset=sense_offset[sub],
            calib_charge=calib_charge[:, sub],
            n_fracs=n_fracs,
        )
        ecr_add, _ = measure_ecr_graph(
            k_add, ctx, "add8", n_trials=n_trials_arith)
        ecr_mul, _ = measure_ecr_graph(
            k_mul, ctx, "mul8", n_trials=max(64, n_trials_arith // 4))
        add_tput = throughput_ops(
            add8_counts(n_fracs),
            (1.0 - ecr_add) * sys.n_cols_per_subarray, sys)
        mul_tput = throughput_ops(
            mul8_counts(n_fracs),
            (1.0 - ecr_mul) * sys.n_cols_per_subarray, sys)

    return MethodResult(
        name=name,
        ecr=ecr5,
        ecr_add=ecr_add,
        ecr_mul=ecr_mul,
        maj5_tops=maj5_tput,
        add8_gops=add_tput,
        mul8_gops=mul_tput,
        maj5_latency_us=wave_latency_ns(maj5_cnt, sys) / 1e3,
        levels=levels,
        error_free_mask=~err_mask,
    )


# ---------------------------------------------------------------------------
# Fleet-aggregate throughput: Table I's numbers as distributions over
# subarrays instead of one point estimate.
# ---------------------------------------------------------------------------

_OP_COUNTS = {"maj5": maj5_standalone_counts, "add8": add8_counts,
              "mul8": mul8_counts}


@dataclasses.dataclass
class FleetThroughput:
    """Per-subarray and device-aggregate ops/s for one PUD op graph."""

    name: str
    op: str                            # "maj5" | "add8" | "mul8"
    per_subarray: np.ndarray           # ops/s at each subarray's ECR
    aggregate: float                   # ops/s at the fleet-mean ECR
    mean_ecr: float

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.per_subarray, q))

    def speedup_vs(self, baseline: "FleetThroughput") -> float:
        return self.aggregate / baseline.aggregate

    def row(self) -> str:
        return (f"{self.name},{self.op},{self.mean_ecr:.4f},"
                f"{self.aggregate:.4g},{self.percentile(10):.4g},"
                f"{self.percentile(90):.4g}")


def fleet_throughput(
    name: str,
    op: str,
    ecr_per_subarray,                  # [G] error-prone column ratios
    n_fracs: int,
    sys: SystemConfig = SystemConfig(),
) -> FleetThroughput:
    """Eq. 1 evaluated per subarray and at the fleet mean.

    ``per_subarray[g]`` is the rate the full system would sustain were every
    bank wave served at subarray g's error-free fraction — the distribution
    shows how much of the device a worst-case placement would cost;
    ``aggregate`` prices the realistic schedule where waves rotate uniformly
    over the grid (mean error-free fraction).
    """
    counts = _OP_COUNTS[op](n_fracs)
    ecr = np.asarray(ecr_per_subarray, np.float64)
    per = np.array([
        throughput_ops(counts, (1.0 - e) * sys.n_cols_per_subarray, sys)
        for e in ecr])
    agg = throughput_ops(
        counts, float((1.0 - ecr).mean()) * sys.n_cols_per_subarray, sys)
    return FleetThroughput(name=name, op=op, per_subarray=per,
                           aggregate=agg, mean_ecr=float(ecr.mean()))
