"""Canary-column drift primitives shared by Fig. 6 and the live monitor.

The paper calibrates once at nominal conditions and *holds the table fixed*;
its reliability story (Fig. 6) is an offline sweep.  Production serving needs
the same measurement online, so this module factors drift sampling and probe
measurement out of ``core/reliability`` into primitives both consumers share:

  * ``drifted_offsets``   — the physics drift model (sigma_temp_drift /
    sigma_time_drift legs) applied to any offset array.  Fig. 6's sweep and
    the ``DriftSimulator`` behind ``serve --drift-sim`` call exactly this.
  * ``reserve_canaries`` / ``CanarySet`` — per-subarray columns, chosen from
    the calibration-time error-free set and withheld from placement, whose
    only job is to be probed.
  * ``probe_ecr`` — push random known bit-patterns through the majority-X
    path on a column subset and score per-subarray ECR, i.e. the paper's
    test campaign (Sec. IV-A) restricted to canaries so a probe round is
    cheap enough to interleave with decode.

Why canaries work: drift is a *column-independent* threshold shift (the
physics legs draw i.i.d. per column), so the flip probability of a reserved
error-free column equals that of any placed error-free column.  A handful of
canaries per subarray is therefore an unbiased — just coarse — estimator of
the fraction of placed columns that silently went bad; the detector on top
(runtime/drift.py) only has to resolve "a few canaries flipped" against the
re-measurement churn floor (~0.5-0.7 % per trial campaign), not the paper's
0.1 %-scale drift tails.
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.pud.physics import PhysicsParams
from .ecr import measure_ecr_fleet


def drifted_offsets(
    key: jax.Array,
    sense_offset: jax.Array,
    params: PhysicsParams,
    temp_c: float | None = None,
    days: float | None = None,
) -> jax.Array:
    """Apply the paper's temperature/time drift model to sense offsets.

    Each leg adds an i.i.d. normal shift per column: temperature scales as
    ``sigma_temp_drift * |T - T_nominal|``, aging as
    ``sigma_time_drift * sqrt(days)``.  Works on any offset shape (single
    subarray ``[C]`` or fleet ``[G, C]``).
    """
    drift = jnp.zeros_like(sense_offset)
    if temp_c is not None:
        scale = params.sigma_temp_drift * jnp.abs(temp_c - params.temp_nominal_c)
        drift = drift + scale * jax.random.normal(
            key, sense_offset.shape, jnp.float32)
    if days is not None:
        scale = params.sigma_time_drift * jnp.sqrt(jnp.float32(days))
        drift = drift + scale * jax.random.normal(
            jax.random.fold_in(key, 1), sense_offset.shape, jnp.float32)
    return sense_offset + drift


def reserve_canaries(masks, n_per_subarray: int) -> np.ndarray:
    """Pick ``n_per_subarray`` calibration-time error-free columns per subarray.

    Columns are spread evenly across each subarray's error-free set so a
    spatially-correlated failure (one bad mat) cannot hide between canaries.
    Deterministic given the masks — no RNG, so the same calibration always
    reserves the same columns.  Raises if a subarray lacks enough error-free
    columns to sacrifice.
    """
    masks = np.asarray(masks, bool)
    g, _ = masks.shape
    cols = np.zeros((g, n_per_subarray), np.int32)
    for gi in range(g):
        free = np.nonzero(~masks[gi])[0]
        if free.size < n_per_subarray:
            raise ValueError(
                f"subarray {gi}: only {free.size} error-free columns, "
                f"cannot reserve {n_per_subarray} canaries")
        idx = np.linspace(0, free.size - 1, n_per_subarray).round().astype(int)
        cols[gi] = free[idx]
    return cols


@dataclasses.dataclass(frozen=True)
class CanarySet:
    """Reserved canary columns for one fleet: ``cols[g, i]`` is the i-th
    canary's column index within subarray ``g``."""

    cols: np.ndarray              # [G, n_per_subarray] int32
    n_cols: int                   # columns per subarray (mask width)

    @property
    def n_per_subarray(self) -> int:
        return int(self.cols.shape[1])

    def mask(self) -> np.ndarray:
        """[G, n_cols] bool, True at canary columns — OR into planning masks
        so placement treats canaries as unusable despite being error-free."""
        g = self.cols.shape[0]
        out = np.zeros((g, self.n_cols), bool)
        out[np.arange(g)[:, None], self.cols] = True
        return out

    def fingerprint(self) -> str:
        """Short stable hash of the reservation — keyed into persisted
        placement names so a canary-less cached plan can never be reused
        for a canary-reserving session (it might occupy canary columns)."""
        h = hashlib.sha256(np.ascontiguousarray(self.cols).tobytes())
        return h.hexdigest()[:10]


def probe_ecr(
    key: jax.Array,
    sense_offsets: jax.Array,     # [G, n_cols] current (possibly drifted)
    calib_charges: jax.Array,     # [G, n_calib, n_cols] from the live table
    params: PhysicsParams,
    n_fracs: int,
    *,
    cols: np.ndarray | None = None,   # [G, n] canary columns; None = all
    n_trials: int = 64,
    chunk: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """One probe round: per-subarray ECR of a column subset (paper protocol).

    With ``cols`` this is the monitor's canary probe; with ``cols=None`` it
    measures every column (Fig. 6's sweep, and the drift-sim's ground-truth
    fault masks).  Returns (ecr [G] float32, error masks [G, n] bool) where
    n follows the probed subset.
    """
    offs = jnp.asarray(sense_offsets)
    charges = jnp.asarray(calib_charges)
    if cols is not None:
        idx = jnp.asarray(cols)
        offs = jnp.take_along_axis(offs, idx, axis=1)
        charges = jnp.take_along_axis(charges, idx[:, None, :], axis=2)
    return measure_ecr_fleet(
        key, offs, charges, params, n_fracs,
        n_trials=n_trials, chunk=min(chunk, n_trials))
