"""One-time fit of the physics noise constants to the paper's silicon.

The paper measures real SK Hynix DDR4 chips; we have a physics model with
five free constants (sigma_static, sigma_dynamic, sigma_frac, sigma_transfer,
frac_alpha).  This module fits them ONCE against four measured operating
points, all taken from the paper:

    ECR(B_{3,0,0}) = 46.6 %                       (Table I)
    ECR(T_{2,1,0}) =  3.3 %                       (Table I)
    ECR(T_{0,0,0}) = 20.9 %   <- backed out of Fig. 5's "T210 = 1.03x T000"
    ECR(T_{2,2,2}) = 24.4 %   <- backed out of Fig. 5's "T210 = 1.48x T222"

(The Fig. 5 back-outs divide the throughput ratios by the command-count
latency ratios 16/19 and 22/19 of the T_{x,y,z} Frac configurations.)

Everything else reported in EXPERIMENTS.md — the 1.81x/1.88x/1.89x gains,
ADD/MUL absolute throughput, the Fig. 5 orderings at other configurations,
Fig. 6 — is a *prediction* of the fitted model.

The fit uses the smooth closed-form ECR expectation (ecr.expected_ecr_maj5's
per-trial failure model) integrated over the threshold-deviation distribution
on a grid, with nearest-ladder-level assignment; the Monte-Carlo pipeline then
validates the fitted constants end-to-end (benchmarks/table1.py).

Run:  PYTHONPATH=src python -m repro.core.fit
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.pud.physics import PhysicsParams
from .offsets import make_ladder

N_TRIALS = 8192
TARGETS = {
    "B300": 0.466,
    "T210": 0.033,
    "T000": 0.209,
    "T222": 0.244,
}
INPUT_SWING_SQ = 5.0  # five full-swing operand rows


def _config_geometry(name: str, p: PhysicsParams):
    """(candidate offsets volts, n_fracs, sum_swing_sq) for a MAJ5 config."""
    if name.startswith("B"):
        x = int(name[1])
        off = np.array([0.5 * p.frac_alpha**x * p.cell_weight])
        swing = INPUT_SWING_SQ + 2.0 + p.frac_alpha ** (2 * x)
        return off, x, swing
    fc = tuple(int(c) for c in name[1:])
    ladder = make_ladder(fc, p)
    offs = np.asarray(ladder.offsets_units) * p.cell_weight
    swing = INPUT_SWING_SQ + sum(p.frac_alpha ** (2 * f) for f in fc)
    return offs, sum(fc), swing


_ERF = np.vectorize(__import__("math").erf)


def _phi(z):
    """Standard normal CDF (no scipy in this environment)."""
    return 0.5 * (1.0 + _ERF(np.asarray(z) / np.sqrt(2.0)))


def trial_fail_prob(residual, sigma_eff, margin):
    ncdf = _phi
    p_hi = ncdf(-(margin - residual) / sigma_eff)
    p_lo = ncdf(-(margin + residual) / sigma_eff)
    p_hi2 = ncdf(-(3 * margin - residual) / sigma_eff)
    p_lo2 = ncdf(-(3 * margin + residual) / sigma_eff)
    return (10 / 32) * (p_hi + p_lo) + (5 / 32) * (p_hi2 + p_lo2)


def expected_ecr(name: str, p: PhysicsParams, n_dev: int = 4001) -> float:
    """E[ECR] over dev ~ N(0, sigma_static), nearest-level calibration."""
    offs, n_fracs, swing = _config_geometry(name, p)
    dev = np.linspace(-6, 6, n_dev) * p.sigma_static
    w = np.exp(-0.5 * (dev / p.sigma_static) ** 2)
    w /= w.sum()
    resid = dev[:, None] - offs[None, :]
    best = resid[np.arange(n_dev), np.abs(resid).argmin(axis=1)]
    sig = np.sqrt(
        p.sigma_dynamic**2
        + p.sigma_frac**2 * n_fracs
        + p.sigma_transfer**2 * swing
    )
    pfail = trial_fail_prob(best, sig, p.maj_margin)
    return float((w * (1.0 - (1.0 - pfail) ** N_TRIALS)).sum())


# Paper Fig. 5 shows T_{2,1,0} as the globally OPTIMAL configuration.  If the
# x=1 point of that figure is T100, the paper's numbers imply
# ECR(T100) >= ~10% (else T100's 17-ACT latency would beat T210's 19).  An
# optional hinge (ordering_weight > 0) imposes throughput(T210) >= every
# other T config.  FINDING (documented in EXPERIMENTS.md §Paper): this hinge
# is UNSATISFIABLE jointly with the four ECR targets under any column-global
# noise model — T000 = 20.9% forces central-gap failures at residual ~= the
# MAJ5 margin, which bounds the granularity cutoff m - z*sigma_d from below,
# and T100's 0.5*alpha central level (0.013 V) then always clears it.  The
# silicon must have a failure mode outside this model (most plausibly the
# wide per-cell spread of intermediate charge states that FracDRAM reports,
# hitting T100's single fine level hardest).  We therefore ship the 4-target
# fit (ordering_weight = 0) and report the T100 ordering as a known
# model-vs-silicon deviation rather than distorting the validated Table-I
# operating points.
ORDER_VS_T210 = ("T100", "T110", "T111", "T211", "T221", "T000", "T222")


def _throughput_au(name: str, ecr: float) -> float:
    n_fracs = sum(int(c) for c in name[1:4])
    return (1.0 - ecr) / (16 + n_fracs)


def loss(p: PhysicsParams, ordering_weight: float = 0.0) -> float:
    err = 0.0
    for name, tgt in TARGETS.items():
        err += ((expected_ecr(name, p) - tgt) / max(tgt, 0.05)) ** 2
    if ordering_weight > 0.0:
        tp210 = _throughput_au("T210", expected_ecr("T210", p))
        for name in ORDER_VS_T210:
            tp = _throughput_au(name, expected_ecr(name, p))
            # hinge: any config beating T210 (with 3% slack) is penalized
            err += ordering_weight * max(0.0, tp / tp210 - 1.03) ** 2
    return err


def fit(verbose: bool = True, ordering_weight: float = 0.0) -> PhysicsParams:
    """Coordinate-descent grid refinement over the five constants."""
    best = PhysicsParams(
        sigma_static=0.036, sigma_dynamic=0.0008, sigma_frac=0.0006,
        sigma_transfer=0.0004, frac_alpha=0.47)
    best_loss = loss(best, ordering_weight)
    grids = {
        "sigma_static": np.linspace(0.024, 0.048, 25),
        "frac_alpha": np.linspace(0.34, 0.60, 27),
        "sigma_dynamic": np.linspace(0.0002, 0.0080, 27),
        "sigma_frac": np.linspace(0.0, 0.0030, 16),
        "sigma_transfer": np.linspace(0.0, 0.0020, 11),
    }
    for sweep in range(6):
        improved = False
        for field, grid in grids.items():
            for v in grid:
                cand = dataclasses.replace(best, **{field: float(v)})
                l = loss(cand, ordering_weight)
                if l < best_loss - 1e-9:
                    best, best_loss, improved = cand, l, True
        # refine grids around current best
        for field in grids:
            c = getattr(best, field)
            span = (grids[field][-1] - grids[field][0]) / 4
            grids[field] = np.linspace(max(0.0, c - span), c + span, 17)
        if verbose:
            print(f"sweep {sweep}: loss={best_loss:.5f} "
                  + " ".join(f"{f}={getattr(best, f):.5f}" for f in grids))
        if not improved:
            break
    return best


def main() -> None:
    p = fit()
    print("\nFitted constants:")
    for f in ("sigma_static", "sigma_dynamic", "sigma_frac",
              "sigma_transfer", "frac_alpha"):
        print(f"  {f} = {getattr(p, f):.6f}")
    print("\nPredicted vs target ECR:")
    for name, tgt in TARGETS.items():
        print(f"  {name}: model={expected_ecr(name, p):.4f} target={tgt:.4f}")
    for name in ("T100", "T110", "T211", "T221", "T321", "B000", "B600"):
        print(f"  {name}: model={expected_ecr(name, p):.4f} (prediction)")

    # The Fig.-5 ordering experiment (see module comment at ORDER_VS_T210):
    # rerun with the hinge active and show the residual tension.
    print("\nOrdering-hinge experiment (throughput(T210) >= all T configs):")
    ph = fit(verbose=False, ordering_weight=25.0)
    print("  hinged fit:", {f: round(getattr(ph, f), 5) for f in (
        "sigma_static", "sigma_dynamic", "frac_alpha")})
    tp210 = _throughput_au("T210", expected_ecr("T210", ph))
    for name in ORDER_VS_T210:
        r = _throughput_au(name, expected_ecr(name, ph)) / tp210
        flag = "VIOLATED" if r > 1.03 else "ok"
        print(f"  tput({name})/tput(T210) = {r:.3f}  [{flag}]")
    print("  -> hinge remains violated at the optimum: the four ECR targets "
          "and the T100 ordering\n     are jointly unsatisfiable in a "
          "column-global noise model (see EXPERIMENTS.md §Paper).")


if __name__ == "__main__":
    main()
