"""Reliability analysis under temperature and time drift (paper Fig. 6).

Calibration data is identified once at nominal conditions (50 C, day 0) and
then *held fixed* (the paper stores it in non-volatile memory).  The sense-amp
thresholds drift with temperature and age; the metric is **new ECR** — the
fraction of columns that were error-free at calibration time but become
error-prone under the shifted condition.  The paper measures < 0.14 % across
40-100 C and < 0.27 % over one week.

Both the drift sampling and the probe measurement live in ``core/canary``
(``drifted_offsets`` / ``probe_ecr``) so Fig. 6's offline sweep and the live
monitor (``runtime/drift.py``) score drift with the same code.  This module
keeps the sweep itself plus ``DriftSimulator`` — the stand-in device behind
``serve --drift-sim`` and the recovery tests, which ages a fleet's offsets
with the same physics legs the sweep uses.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.pud.physics import PhysicsParams
from .calibrate import CalibrationConfig, identify_calibration
from .canary import drifted_offsets, probe_ecr
from .offsets import levels_to_charges, make_ladder


@dataclasses.dataclass
class ReliabilityPoint:
    condition: float          # degC or days
    ecr: float                # total ECR at the condition
    new_ecr: float            # newly error-prone among calibration-time EF


def reliability_sweep(
    key: jax.Array,
    method: str = "T210",
    temps_c: tuple[float, ...] = (40, 50, 60, 70, 80, 90, 100),
    days: tuple[float, ...] = (1, 2, 3, 4, 5, 6, 7),
    params: PhysicsParams = PhysicsParams(),
    n_cols: int = 65536,
    n_trials: int = 8192,
    calib_config: CalibrationConfig = CalibrationConfig(),
) -> tuple[list[ReliabilityPoint], list[ReliabilityPoint]]:
    """Returns (temperature sweep, time sweep) for a PUDTune configuration."""
    fc = tuple(int(c) for c in method[1:4])
    k_mfg, k_cal, k_base, k_t, k_d = jax.random.split(key, 5)
    sense_offset = params.sigma_static * jax.random.normal(
        k_mfg, (n_cols,), jnp.float32)
    ladder = make_ladder(fc, params)
    levels = identify_calibration(
        k_cal, sense_offset, ladder, params, calib_config)
    calib = levels_to_charges(ladder, levels, params)

    # Probe through the canary primitives as a 1-subarray fleet, so the
    # sweep exercises the exact measurement path the live monitor runs.
    offs_fleet = sense_offset[None]
    charges_fleet = calib[None]

    _, base_err = probe_ecr(
        k_base, offs_fleet, charges_fleet, params, ladder.n_fracs,
        n_trials=n_trials)
    base_ef = ~base_err[0]

    def eval_at(k, offs):
        ecr, err = probe_ecr(
            k, offs[None], charges_fleet, params, ladder.n_fracs,
            n_trials=n_trials)
        new_ecr = float((err[0] & base_ef).mean())
        return float(ecr[0]), new_ecr

    temp_points, time_points = [], []
    for t in temps_c:
        k_t, k = jax.random.split(k_t)
        offs = drifted_offsets(jax.random.fold_in(k, int(t)), sense_offset,
                               params, temp_c=float(t))
        ecr, new = eval_at(k, offs)
        temp_points.append(ReliabilityPoint(float(t), ecr, new))
    for d in days:
        k_d, k = jax.random.split(k_d)
        offs = drifted_offsets(jax.random.fold_in(k, int(d * 100)),
                               sense_offset, params, days=float(d))
        ecr, new = eval_at(k, offs)
        time_points.append(ReliabilityPoint(float(d), ecr, new))
    return temp_points, time_points


class DriftSimulator:
    """A PUD fleet whose sense offsets age — the device behind ``--drift-sim``.

    Holds the fleet's manufactured (calibration-time) offsets and exposes
    ``sense_offsets()``, the one method the drift monitor needs from a
    device.  ``advance`` moves the simulated condition; offsets are then
    resampled through ``canary.drifted_offsets`` under a per-epoch folded
    key, so they are *stable within an epoch* — the monitor's probe, the
    ground-truth fault masks, and the recalibration pass all see the same
    drifted device until the next ``advance``.

    ``subarrays`` restricts an advance to a localized hot spot (rows of the
    grid); other subarrays keep their base offsets, which is what makes
    "only affected subarrays recalibrate" a sharp, testable claim.
    """

    def __init__(self, key: jax.Array, base_offsets: jax.Array,
                 params: PhysicsParams):
        self.key = key
        self.base = jnp.asarray(base_offsets)
        self.params = params
        self.temp_c = float(params.temp_nominal_c)
        self.days = 0.0
        self._epoch = 0
        self._subarrays: list[int] | None = None

    @classmethod
    def for_session(cls, session) -> "DriftSimulator":
        """Simulator over the same manufactured fleet a session calibrated —
        epoch 0 reproduces the offsets its table was identified against."""
        from .fleet import manufacture_fleet
        base = manufacture_fleet(session.key, session.fleet_cfg,
                                 session.physics)
        return cls(jax.random.fold_in(session.key, 0x0D21F7), base,
                   session.physics)

    def advance(self, temp_c: float | None = None, days: float | None = None,
                subarrays=None) -> None:
        """Age the device: set operating temperature and/or add elapsed days,
        optionally confined to ``subarrays`` (a localized hot spot)."""
        if temp_c is not None:
            self.temp_c = float(temp_c)
        if days is not None:
            self.days += float(days)
        self._subarrays = (None if subarrays is None
                           else sorted(int(s) for s in subarrays))
        self._epoch += 1

    @property
    def drifted(self) -> bool:
        return (self._epoch > 0
                and (self.temp_c != self.params.temp_nominal_c
                     or self.days > 0.0))

    def sense_offsets(self) -> jax.Array:
        """Current [G, n_cols] offsets under the simulated condition."""
        if not self.drifted:
            return self.base
        temp = self.temp_c if self.temp_c != self.params.temp_nominal_c else None
        days = self.days if self.days > 0.0 else None
        offs = drifted_offsets(
            jax.random.fold_in(self.key, self._epoch), self.base,
            self.params, temp_c=temp, days=days)
        if self._subarrays is None:
            return offs
        sel = jnp.zeros((self.base.shape[0], 1), bool)
        sel = sel.at[jnp.asarray(self._subarrays)].set(True)
        return jnp.where(sel, offs, self.base)
