"""Reliability analysis under temperature and time drift (paper Fig. 6).

Calibration data is identified once at nominal conditions (50 C, day 0) and
then *held fixed* (the paper stores it in non-volatile memory).  The sense-amp
thresholds drift with temperature and age; the metric is **new ECR** — the
fraction of columns that were error-free at calibration time but become
error-prone under the shifted condition.  The paper measures < 0.14 % across
40-100 C and < 0.27 % over one week.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.pud.physics import PhysicsParams
from .calibrate import CalibrationConfig, identify_calibration
from .ecr import measure_ecr_maj5
from .offsets import levels_to_charges, make_ladder


@dataclasses.dataclass
class ReliabilityPoint:
    condition: float          # degC or days
    ecr: float                # total ECR at the condition
    new_ecr: float            # newly error-prone among calibration-time EF


def _drifted_offsets(key, sense_offset, params, temp_c=None, days=None):
    drift = jnp.zeros_like(sense_offset)
    if temp_c is not None:
        scale = params.sigma_temp_drift * jnp.abs(temp_c - params.temp_nominal_c)
        drift = drift + scale * jax.random.normal(
            key, sense_offset.shape, jnp.float32)
    if days is not None:
        scale = params.sigma_time_drift * jnp.sqrt(jnp.float32(days))
        drift = drift + scale * jax.random.normal(
            jax.random.fold_in(key, 1), sense_offset.shape, jnp.float32)
    return sense_offset + drift


def reliability_sweep(
    key: jax.Array,
    method: str = "T210",
    temps_c: tuple[float, ...] = (40, 50, 60, 70, 80, 90, 100),
    days: tuple[float, ...] = (1, 2, 3, 4, 5, 6, 7),
    params: PhysicsParams = PhysicsParams(),
    n_cols: int = 65536,
    n_trials: int = 8192,
    calib_config: CalibrationConfig = CalibrationConfig(),
) -> tuple[list[ReliabilityPoint], list[ReliabilityPoint]]:
    """Returns (temperature sweep, time sweep) for a PUDTune configuration."""
    fc = tuple(int(c) for c in method[1:4])
    k_mfg, k_cal, k_base, k_t, k_d = jax.random.split(key, 5)
    sense_offset = params.sigma_static * jax.random.normal(
        k_mfg, (n_cols,), jnp.float32)
    ladder = make_ladder(fc, params)
    levels = identify_calibration(
        k_cal, sense_offset, ladder, params, calib_config)
    calib = levels_to_charges(ladder, levels, params)

    _, base_err = measure_ecr_maj5(
        k_base, sense_offset, calib, params, ladder.n_fracs, n_trials=n_trials)
    base_ef = ~base_err

    def eval_at(k, offs):
        ecr, err = measure_ecr_maj5(
            k, offs, calib, params, ladder.n_fracs, n_trials=n_trials)
        new_ecr = float((err & base_ef).mean())
        return ecr, new_ecr

    temp_points, time_points = [], []
    for t in temps_c:
        k_t, k = jax.random.split(k_t)
        offs = _drifted_offsets(jax.random.fold_in(k, int(t)), sense_offset,
                                params, temp_c=float(t))
        ecr, new = eval_at(k, offs)
        temp_points.append(ReliabilityPoint(float(t), ecr, new))
    for d in days:
        k_d, k = jax.random.split(k_d)
        offs = _drifted_offsets(jax.random.fold_in(k, int(d * 100)),
                                sense_offset, params, days=float(d))
        ecr, new = eval_at(k, offs)
        time_points.append(ReliabilityPoint(float(d), ecr, new))
    return temp_points, time_points
