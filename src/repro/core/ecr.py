"""Error-prone column ratio (ECR) measurement (paper Sec. IV-A).

A column is *error-free* iff it produces zero errors across the whole test
campaign (the paper uses 8 192 random inputs per bank).  We provide:

  * ``measure_ecr_maj5``  — Monte-Carlo, chunked over trials (paper protocol).
  * ``measure_ecr_graph`` — same protocol over a compound MAJ graph
    (ADD8 / MUL8), whose error-free set is the intersection over every MAJX
    in the graph — this is what makes arithmetic gains exceed the bare
    column gain.
  * ``expected_ecr_maj5`` — smooth closed-form E[1-(1-p)^N] used by the
    one-time noise-constant fit (repro/core/fit.py); not used for reporting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm

from repro.pud.bitserial import (MajContext, add_n, bits_to_int, int_to_bits,
                                 mul8_truncated)
from repro.pud.device import maj_outputs
from repro.pud.physics import PhysicsParams

N_TRIALS_PAPER = 8192


@functools.partial(
    jax.jit, static_argnames=("params", "n_fracs", "n_trials", "chunk",
                              "n_inputs", "const_charge_sum",
                              "const_swing_sq"))
def _majx_error_mask(key, sense_offset, calib_charge, params, n_fracs,
                     n_trials, chunk, n_inputs=5, const_charge_sum=0.0,
                     const_swing_sq=0.0):
    n_cols = sense_offset.shape[0]
    # n_trials < chunk would otherwise scan zero chunks and report a
    # perfect (all-False) mask without measuring anything
    chunk = min(chunk, n_trials)

    def body(any_err, k):
        k_in, k_noise = jax.random.split(k)
        inputs = jax.random.bernoulli(
            k_in, 0.5, (chunk, n_inputs, n_cols)).astype(jnp.float32)
        out = maj_outputs(
            inputs, calib_charge, sense_offset, k_noise, params, n_fracs,
            const_charge_sum=const_charge_sum,
            const_swing_sq=const_swing_sq)
        truth = (inputs.sum(axis=-2) > n_inputs // 2).astype(jnp.float32)
        err = (out != truth).any(axis=0)
        return any_err | err, None

    keys = jax.random.split(key, n_trials // chunk)
    any_err, _ = jax.lax.scan(body, jnp.zeros((n_cols,), bool), keys)
    return any_err


def measure_ecr_maj5(
    key: jax.Array,
    sense_offset: jax.Array,
    calib_charge: jax.Array,
    params: PhysicsParams,
    n_fracs: int,
    n_trials: int = N_TRIALS_PAPER,
    chunk: int = 256,
) -> tuple[float, jax.Array]:
    """Returns (ECR in [0,1], per-column error-prone mask)."""
    mask = _majx_error_mask(
        key, sense_offset, calib_charge, params, n_fracs, n_trials, chunk)
    return float(mask.mean()), mask


def measure_ecr_majx(
    key: jax.Array,
    sense_offset: jax.Array,
    calib_charge: jax.Array,
    params: PhysicsParams,
    n_fracs: int,
    n_inputs: int,
    const_charge_sum: float = 0.0,
    const_swing_sq: float = 0.0,
    n_trials: int = N_TRIALS_PAPER,
    chunk: int = 256,
) -> tuple[float, jax.Array]:
    """MAJX ECR for any input count (paper Sec. III-D extension).

    MAJ3 = 3 inputs + 0/1 constant pair (const_charge_sum=1, swing_sq=2)
    + 3 calibration rows; MAJ7 = 7 inputs + 1 calibration row.  Opened rows
    must total params.n_simra_rows.
    """
    mask = _majx_error_mask(
        key, sense_offset, calib_charge, params, n_fracs, n_trials, chunk,
        n_inputs=n_inputs, const_charge_sum=const_charge_sum,
        const_swing_sq=const_swing_sq)
    return float(mask.mean()), mask


def measure_ecr_graph(
    key: jax.Array,
    ctx: MajContext,
    op: str,                       # "add8" | "mul8"
    n_trials: int = 1024,
    chunk: int = 64,
) -> tuple[float, jax.Array]:
    """ECR of a compound arithmetic graph under the paper's protocol.

    Random 8-bit operand pairs per column per trial; a column is error-prone
    if any trial's full result deviates from exact integer arithmetic.
    """
    n_cols = ctx.sense_offset.shape[0]

    def run_chunk(k):
        k_a, k_b, k_g = jax.random.split(k, 3)
        a = jax.random.randint(k_a, (chunk, n_cols), 0, 256, jnp.int32)
        b = jax.random.randint(k_b, (chunk, n_cols), 0, 256, jnp.int32)
        ab_, bb_ = int_to_bits(a, 8), int_to_bits(b, 8)
        abar, bbar = 1.0 - ab_, 1.0 - bb_
        if op == "add8":
            s, _, cout, _ = add_n(ctx, ab_, abar, bb_, bbar, k_g)
            got = bits_to_int(s) + (cout.astype(jnp.int32) << 8)
            want = a + b
        elif op == "mul8":
            s = mul8_truncated(ctx, ab_, abar, bb_, bbar, k_g)
            got = bits_to_int(s)
            want = (a * b) & 0xFF
        else:
            raise ValueError(op)
        return (got != want).any(axis=0)

    run_chunk = jax.jit(run_chunk)
    any_err = jnp.zeros((n_cols,), bool)
    for k in jax.random.split(key, max(1, n_trials // chunk)):
        any_err = any_err | run_chunk(k)
    return float(any_err.mean()), any_err


# ---------------------------------------------------------------------------
# Fleet-scale measurement (per-subarray grid, paper protocol per subarray).
# ---------------------------------------------------------------------------


def measure_ecr_fleet(
    key: jax.Array,
    sense_offsets: jax.Array,     # [G, n_cols] per-subarray offsets
    calib_charges: jax.Array,     # [G, n_calib, n_cols] per-subarray charges
    params: PhysicsParams,
    n_fracs: int,
    n_trials: int = N_TRIALS_PAPER,
    chunk: int = 256,
    n_inputs: int = 5,
) -> tuple[jax.Array, jax.Array]:
    """Per-subarray MAJX ECR over a fleet grid.

    Returns (ecr [G] float32, error-prone masks [G, n_cols] bool).  Each
    subarray gets its own fold_in'd trial stream, so a row reproduces the
    single-subarray ``measure_ecr_maj5`` measurement with that folded key.
    """
    g = sense_offsets.shape[0]
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(g))
    masks = jax.vmap(
        lambda k, so, cc: _majx_error_mask(
            k, so, cc, params, n_fracs, n_trials, chunk, n_inputs=n_inputs)
    )(keys, sense_offsets, calib_charges)
    return masks.mean(axis=1).astype(jnp.float32), masks


def fleet_ecr_summary(masks: jax.Array) -> dict:
    """Aggregate statistics of per-subarray error-prone masks [G, n_cols]."""
    import numpy as np
    per = np.asarray(masks).mean(axis=1)
    return {
        "n_subarrays": int(masks.shape[0]),
        "cols_per_subarray": int(masks.shape[1]),
        "mean_ecr": float(per.mean()),
        "std_ecr": float(per.std()),
        "min_ecr": float(per.min()),
        "max_ecr": float(per.max()),
        "p90_ecr": float(np.percentile(per, 90)),
        "error_free_cols_total": int((~np.asarray(masks)).sum()),
        "cols_total": int(masks.size),
    }


# ---------------------------------------------------------------------------
# Closed-form expectation for fitting.
# ---------------------------------------------------------------------------


def _trial_fail_prob(residual, sigma_eff, margin):
    """P(one random-MAJ5 trial errs | signed offset residual).

    Pattern probabilities for 5 uniform bits: the two margin-critical charge
    sums (3-of-5 / 2-of-5) each occur w.p. 10/32; patterns two margins out
    (4-of-5 / 1-of-5) w.p. 5/32 each; extremes are safe.
    """
    m = margin
    p_hi = norm.cdf(-(m - residual) / sigma_eff)     # true-1 read as 0
    p_lo = norm.cdf(-(m + residual) / sigma_eff)     # true-0 read as 1
    p_hi2 = norm.cdf(-(3 * m - residual) / sigma_eff)
    p_lo2 = norm.cdf(-(3 * m + residual) / sigma_eff)
    return (10 / 32) * (p_hi + p_lo) + (5 / 32) * (p_hi2 + p_lo2)


def expected_ecr_maj5(
    sense_offset: jax.Array,
    calib_offset_units: jax.Array,   # per-column applied offset, charge units
    params: PhysicsParams,
    n_fracs: int,
    sum_swing_sq: float,
    n_trials: int = N_TRIALS_PAPER,
) -> jax.Array:
    """E[ECR] under the analytic per-trial failure model (smooth in params)."""
    residual = sense_offset - calib_offset_units * params.cell_weight
    sigma_eff = params.sensing_sigma(
        jnp.float32(n_fracs), jnp.float32(sum_swing_sq))
    p = _trial_fail_prob(residual, sigma_eff, params.maj_margin)
    return (1.0 - (1.0 - p) ** n_trials).mean()
