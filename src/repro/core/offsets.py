"""Multi-level charging offset ladders (paper Sec. III-C/D, Fig. 3).

A MAJ5 with 8-row SiMRA leaves 3 non-operand rows.  PUDTune stores a
per-column bit b_i in each and applies f_i Frac ops to row i, so row i's
charge is 0.5 + (b_i - 0.5) * alpha^f_i — an offset of +-0.5 * alpha^f_i
cell-charge units around neutral.  The 2^3 sign patterns give the *offset
ladder* of configuration T_{f1,f2,f3}:

    T_{0,0,0}: +-0.5 +-0.5 +-0.5   -> 4 distinct levels, coarse (step 1.0)
    T_{2,2,2}: +-.125 x3           -> 4 levels, fine (step 0.25) but narrow
    T_{2,1,0}: +-.125 +-.25 +-.5   -> 8 levels, fine (step 0.25) AND wide

Baseline B_{x,0,0} stores a constant 1 Frac'd x times plus a 0/1 constant
pair — a single fixed (near-zero) offset, no per-column freedom.

Conversion to volts: one cell-charge unit shifts the 8-row SiMRA bitline by
C_cell / (8 C_cell + C_bl) = 1/17 V_DD (physics.cell_weight * 2... the ladder
is stored in charge units; multiply by ``params.cell_weight`` for volts).
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np
import jax
import jax.numpy as jnp

from repro.pud.physics import NEUTRAL, PhysicsParams


@dataclasses.dataclass(frozen=True)
class OffsetLadder:
    """Static description of a T_{x,y,z,...} configuration's offset ladder.

    Generic in the number of calibration rows (paper Sec. III-D: "PUDTune
    can be naturally extended to MAJX operations with different input
    sizes"): MAJ3/MAJ5 leave 3 non-operand rows in an 8-row SiMRA, MAJ7
    leaves 1 — the ladder then has 2^1 = 2 levels, which is exactly why
    calibration buys less there (benchmarks/majx_general.py).
    """

    frac_counts: tuple[int, ...]
    offsets_units: tuple[float, ...]     # sorted distinct offsets, charge units
    bits_table: tuple[tuple[int, ...], ...]  # bit pattern per level
    n_fracs: int

    @property
    def n_levels(self) -> int:
        return len(self.offsets_units)

    @property
    def n_rows(self) -> int:
        return len(self.frac_counts)

    def offsets_volts(self, params: PhysicsParams) -> np.ndarray:
        return np.asarray(self.offsets_units) * params.cell_weight

    def row_charges(self, params: PhysicsParams) -> np.ndarray:
        """[n_levels, n_rows] cell charge per calibration row per level."""
        out = np.zeros((self.n_levels, self.n_rows), np.float32)
        for lvl, bits in enumerate(self.bits_table):
            for i, (b, f) in enumerate(zip(bits, self.frac_counts)):
                out[lvl, i] = NEUTRAL + (b - NEUTRAL) * params.frac_alpha**f
        return out


def make_ladder(
    frac_counts: tuple[int, ...], params: PhysicsParams
) -> OffsetLadder:
    """Enumerate the 2^n_rows sign patterns, dedupe, sort by offset."""
    deltas = [0.5 * params.frac_alpha**f for f in frac_counts]
    entries: dict[float, tuple[int, ...]] = {}
    for bits in itertools.product((0, 1), repeat=len(frac_counts)):
        off = sum((b - 0.5) * 2 * d for b, d in zip(bits, deltas))
        off = round(off, 9)
        entries.setdefault(off, bits)
    offs = sorted(entries)
    return OffsetLadder(
        frac_counts=tuple(frac_counts),
        offsets_units=tuple(offs),
        bits_table=tuple(entries[o] for o in offs),
        n_fracs=sum(frac_counts),
    )


def levels_to_charges(
    ladder: OffsetLadder, levels: jax.Array, params: PhysicsParams
) -> jax.Array:
    """Per-column levels [n_cols] -> calibration row charges [n_rows, n_cols]."""
    table = jnp.asarray(ladder.row_charges(params))  # [L, n_rows]
    return table[levels].T                            # [n_rows, n_cols]


def baseline_charges(
    x_fracs: int, n_cols: int, params: PhysicsParams
) -> jax.Array:
    """B_{x,0,0}: one constant-1 row Frac'd x times, plus constants 0 and 1."""
    neutralish = NEUTRAL + 0.5 * params.frac_alpha**x_fracs
    col = jnp.array([neutralish, 0.0, 1.0], jnp.float32)
    return jnp.broadcast_to(col[:, None], (3, n_cols))


def neutral_level(ladder: OffsetLadder) -> int:
    """Ladder index whose offset is closest to zero (calibration start)."""
    return int(np.argmin(np.abs(np.asarray(ladder.offsets_units))))
