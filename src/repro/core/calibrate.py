"""Calibration data identification — the paper's Algorithm 1.

For each iteration: run MAJ5 on random input patterns, compute the per-column
*bias* (proportion of '1' outputs minus the true-majority proportion), and
step the column one level down/up the offset ladder when the bias exceeds
+-threshold.  A positive bias means the column reads '1' too often (its sense
threshold sits low), so the calibration offset must move DOWN — i.e.
``decrement_level`` — and vice versa, exactly as in Algorithm 1.

The loop is a ``lax.scan`` over iterations; each iteration vmaps over sample
chunks, so identifying a 65 536-column subarray takes seconds on CPU (the
paper's Python-on-DRAM-Bender implementation takes ~1 minute per subarray).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.pud.device import maj_outputs
from repro.pud.physics import PhysicsParams
from .offsets import OffsetLadder, levels_to_charges, neutral_level


@dataclasses.dataclass(frozen=True)
class CalibrationConfig:
    n_iterations: int = 20      # paper Sec. IV-A
    n_samples: int = 512        # random samples per iteration (paper Sec. IV-A)
    # Bias threshold of Algorithm 1.  Must sit below 1/n_samples so that a
    # single observed error already triggers a level step: near convergence
    # the residual error rates are ~1e-3/trial, and a threshold of several
    # errors per iteration stalls the walk one level short (measured: ECR
    # 14% -> 4% by lowering tau; see EXPERIMENTS.md §Paper).
    threshold: float = 0.0009
    maj_inputs: int = 5
    # constant (non-operand, non-calibration) rows: MAJ3 uses a 0/1 pair
    const_charge_sum: float = 0.0
    const_swing_sq: float = 0.0


def identify_calibration_fn(
    key: jax.Array,
    sense_offset: jax.Array,          # [n_cols]
    ladder: OffsetLadder,
    params: PhysicsParams,
    config: CalibrationConfig = CalibrationConfig(),
) -> jax.Array:
    """Run Algorithm 1; returns per-column ladder level indices [n_cols] int32.

    Unjitted implementation — the fleet engine (repro/core/fleet.py) vmaps
    this over a subarray grid; ``identify_calibration`` is the jitted
    single-subarray entry point.
    """
    n_cols = sense_offset.shape[0]
    init_levels = jnp.full((n_cols,), neutral_level(ladder), jnp.int32)

    def iteration(levels, it_key):
        k_in, k_noise = jax.random.split(it_key)
        inputs = jax.random.bernoulli(
            k_in, 0.5, (config.n_samples, config.maj_inputs, n_cols)
        ).astype(jnp.float32)
        calib = levels_to_charges(ladder, levels, params)
        out = maj_outputs(
            inputs, calib, sense_offset, k_noise, params, ladder.n_fracs,
            const_charge_sum=config.const_charge_sum,
            const_swing_sq=config.const_swing_sq,
        )
        truth = (inputs.sum(axis=-2) > config.maj_inputs // 2).astype(jnp.float32)
        bias = (out - truth).mean(axis=0)  # [n_cols]
        step = jnp.where(bias > config.threshold, -1, 0) + jnp.where(
            bias < -config.threshold, 1, 0
        )
        levels = jnp.clip(levels + step, 0, ladder.n_levels - 1)
        return levels, bias

    keys = jax.random.split(key, config.n_iterations)
    levels, biases = jax.lax.scan(iteration, init_levels, keys)
    return levels


identify_calibration = jax.jit(
    identify_calibration_fn, static_argnames=("ladder", "params", "config"))


def calibration_history(
    key: jax.Array,
    sense_offset: jax.Array,
    ladder: OffsetLadder,
    params: PhysicsParams,
    config: CalibrationConfig = CalibrationConfig(),
):
    """Like identify_calibration but also returns per-iteration mean |bias|
    (for the convergence benchmark)."""
    n_cols = sense_offset.shape[0]
    levels = jnp.full((n_cols,), neutral_level(ladder), jnp.int32)
    history = []
    for it_key in jax.random.split(key, config.n_iterations):
        k_in, k_noise = jax.random.split(it_key)
        inputs = jax.random.bernoulli(
            k_in, 0.5, (config.n_samples, config.maj_inputs, n_cols)
        ).astype(jnp.float32)
        calib = levels_to_charges(ladder, levels, params)
        out = maj_outputs(
            inputs, calib, sense_offset, k_noise, params, ladder.n_fracs
        )
        truth = (inputs.sum(axis=-2) > config.maj_inputs // 2).astype(
            jnp.float32)
        bias = (out - truth).mean(axis=0)
        history.append(float(jnp.abs(bias).mean()))
        step = jnp.where(bias > config.threshold, -1, 0) + jnp.where(
            bias < -config.threshold, 1, 0
        )
        levels = jnp.clip(levels + step, 0, ladder.n_levels - 1)
    return levels, history
