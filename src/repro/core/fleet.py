"""Fleet-scale calibration engine: Algorithm 1 across a whole device.

A real module is not one 65 536-column subarray — it exposes a
``(channels, banks, subarrays)`` grid of them, and sense-amp offsets (hence
error patterns and the calibration data that fixes them) vary per subarray.
This module runs the paper's Algorithm 1 over the whole grid in ONE jitted
call:

  * ``manufacture_fleet``      — per-subarray sense offsets [G, C], derived
    by ``fold_in(key, subarray_index)`` so any single subarray of the fleet
    is bit-identical to manufacturing it alone with that folded key.
  * ``calibrate_fleet``        — three interchangeable engines:
      - ``per_subarray``: ``vmap`` of the unjitted single-subarray
        Algorithm 1 (bit-identical to N independent ``identify_calibration``
        calls — the equivalence oracle);
      - ``reference``:    vmapped pure-jnp fused iteration (kernels/ref.py);
      - ``fused``:        vmapped Pallas kernel (kernels/majx.calib_iter_fused)
        that does SiMRA sensing + bias accumulation + ladder level-step in a
        single pass instead of three jitted stages.
    With a ``mesh``, the subarray axis is ``shard_map``-ped over every mesh
    axis (launch/mesh.py meshes compose directly), one RNG stream per shard.
  * ``fleet_calib_charges``    — levels -> per-subarray calibration-row
    charges for downstream ECR / arithmetic measurement.

Persistence lives in ``repro.runtime.calib_cache`` (versioned per-device
tables); ``load_or_calibrate`` glues the two so serving starts from a cached
table instead of recalibrating.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.kernels.ops import calib_iter_fused
from repro.kernels.ref import calib_iter_ref
from repro.pud.physics import NEUTRAL, PhysicsParams
from .calibrate import CalibrationConfig, identify_calibration_fn
from .offsets import (OffsetLadder, levels_to_charges, make_ladder,
                      neutral_level)

METHODS = ("fused", "reference", "per_subarray")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Shape of one device's subarray grid."""

    n_channels: int = 1
    n_banks: int = 4
    n_subarrays: int = 4          # per bank
    n_cols: int = 4096            # per subarray (65 536 on real DDR4)
    frac_counts: tuple[int, ...] = (2, 1, 0)

    @property
    def grid_shape(self) -> tuple[int, int, int]:
        return (self.n_channels, self.n_banks, self.n_subarrays)

    @property
    def n_subarrays_total(self) -> int:
        return self.n_channels * self.n_banks * self.n_subarrays

    @property
    def n_cols_total(self) -> int:
        return self.n_subarrays_total * self.n_cols

    def ladder(self, params: PhysicsParams) -> OffsetLadder:
        return make_ladder(self.frac_counts, params)


@dataclasses.dataclass
class FleetCalibration:
    """Result of one fleet calibration run."""

    levels: jax.Array                  # [G, C] int32 ladder level per column
    mean_abs_bias: jax.Array | None    # [n_iterations] (None: per_subarray)
    config: FleetConfig
    method: str

    @property
    def levels_grid(self) -> jax.Array:
        """[channels, banks, subarrays, cols] view."""
        return self.levels.reshape(self.config.grid_shape
                                   + (self.config.n_cols,))


def subarray_key(key: jax.Array, index: int | jax.Array) -> jax.Array:
    """RNG key of subarray ``index`` — the fleet/single-subarray contract."""
    return jax.random.fold_in(key, index)


def manufacture_fleet(
    key: jax.Array, cfg: FleetConfig, params: PhysicsParams
) -> jax.Array:
    """Per-subarray sense offsets [G, C]; row g == single-subarray draw g."""
    def one(g):
        return params.sigma_static * jax.random.normal(
            subarray_key(key, g), (cfg.n_cols,), jnp.float32)
    return jax.vmap(one)(jnp.arange(cfg.n_subarrays_total))


def ladder_tables(
    ladder: OffsetLadder, params: PhysicsParams
) -> tuple[tuple[float, ...], tuple[float, ...]]:
    """Static per-level (charge sum, swing^2 sum) of the calibration rows."""
    rc = ladder.row_charges(params)                        # [L, n_rows]
    qsum = tuple(float(x) for x in rc.sum(axis=1))
    swing = tuple(float(x) for x in ((2.0 * (rc - NEUTRAL)) ** 2).sum(axis=1))
    return qsum, swing


def _block_calibrate(ladder: OffsetLadder, params: PhysicsParams,
                     config: CalibrationConfig, method: str, interpret: bool):
    """Returns f(key, offsets [Gl, C]) -> (levels [Gl, C], |bias| history)."""
    qsum, swing = ladder_tables(ladder, params)

    def one_iter(inputs, noise, levels, offs):
        if method == "fused":
            return calib_iter_fused(
                inputs, noise, levels, offs, params, ladder.n_fracs,
                qsum, swing, config.threshold, config.maj_inputs,
                config.const_charge_sum, config.const_swing_sq, interpret)
        return calib_iter_ref(
            inputs, noise, levels, offs, params, ladder.n_fracs,
            qsum, swing, config.threshold, config.maj_inputs,
            config.const_charge_sum, config.const_swing_sq)

    def run(key, offs):
        gl, c = offs.shape
        init = jnp.full((gl, c), neutral_level(ladder), jnp.int32)

        def iteration(levels, it_key):
            k_in, k_noise = jax.random.split(it_key)
            inputs = jax.random.bernoulli(
                k_in, 0.5, (gl, config.n_samples, config.maj_inputs, c)
            ).astype(jnp.float32)
            noise = jax.random.normal(
                k_noise, (gl, config.n_samples, c), jnp.float32)
            new, bias = jax.vmap(one_iter)(inputs, noise, levels, offs)
            return new, jnp.abs(bias).mean()

        keys = jax.random.split(key, config.n_iterations)
        return jax.lax.scan(iteration, init, keys)

    return run


def calibrate_fleet(
    key: jax.Array,
    sense_offsets: jax.Array,             # [G, C]
    cfg: FleetConfig,
    params: PhysicsParams,
    config: CalibrationConfig = CalibrationConfig(),
    *,
    mesh: Mesh | None = None,
    method: str = "fused",
    interpret: bool = True,
) -> FleetCalibration:
    """Run Algorithm 1 over the whole subarray grid.

    ``mesh``: shard the subarray axis over every mesh axis (G must divide
    the device count evenly); without one, the grid runs vmapped on the
    local device.  ``method="per_subarray"`` is the bit-exact oracle.
    """
    if method not in METHODS:
        raise ValueError(f"method {method!r} not in {METHODS}")
    g, _ = sense_offsets.shape
    ladder = cfg.ladder(params)

    if method == "per_subarray":
        def one(idx, offs):
            return identify_calibration_fn(
                subarray_key(key, idx), offs, ladder, params, config)
        levels = jax.jit(jax.vmap(one))(
            jnp.arange(g), sense_offsets)
        return FleetCalibration(levels, None, cfg, method)

    run = _block_calibrate(ladder, params, config, method, interpret)

    if mesh is None or mesh.size == 1:
        levels, hist = jax.jit(run)(key, sense_offsets)
        return FleetCalibration(levels, hist, cfg, method)

    if g % mesh.size != 0:
        raise ValueError(
            f"{g} subarrays not divisible over {mesh.size} devices")
    axes = tuple(mesh.axis_names)
    spec = P(axes)

    def sharded(key_block, offs):
        idx = jnp.int32(0)
        for name in axes:
            idx = idx * mesh.shape[name] + jax.lax.axis_index(name)
        levels, hist = run(jax.random.fold_in(key_block[0], idx), offs)
        return levels, jax.lax.pmean(hist, axes)

    levels, hist = jax.jit(shard_map(
        sharded, mesh=mesh, in_specs=(P(), spec), out_specs=(spec, P()),
        check_rep=False))(key[None], sense_offsets)
    return FleetCalibration(levels, hist, cfg, method)


def recalibrate_subarrays(
    key: jax.Array,
    sense_offsets: jax.Array,             # [G, C] full fleet, as sensed NOW
    subarrays,                            # iterable of subarray indices
    cfg: FleetConfig,
    params: PhysicsParams,
    config: CalibrationConfig = CalibrationConfig(),
    *,
    method: str = "reference",
    interpret: bool = True,
) -> jax.Array:
    """Re-run Algorithm 1 for a subset of subarrays against current offsets.

    The background-recalibration primitive behind the drift monitor: on a
    drift event only the flagged subarrays re-identify, against the fleet's
    *currently sensed* (drifted) offsets, while the rest of the table is
    left untouched.  Every subarray keeps its own RNG stream
    (``subarray_key(key, g)``), so the result is independent of how drift
    events were batched — recalibrating {3} then {5} yields exactly the rows
    a joint {3, 5} pass would.  (Block methods run per subarray here rather
    than sharing one iteration stream across the block like
    ``calibrate_fleet``; for a *partial* pass, batching-independence is the
    contract that matters.)

    Returns refreshed levels ``[len(subarrays), C]`` in ascending-index
    order; merging them into the full table is the caller's job
    (``PUDSession.recalibrate_subarrays``).
    """
    if method not in METHODS:
        raise ValueError(f"method {method!r} not in {METHODS}")
    idx = jnp.asarray(sorted(int(s) for s in subarrays), jnp.int32)
    offs = jnp.asarray(sense_offsets)[idx]
    ladder = cfg.ladder(params)

    if method == "per_subarray":
        def one(g, o):
            return identify_calibration_fn(
                subarray_key(key, g), o, ladder, params, config)
        return jax.jit(jax.vmap(one))(idx, offs)

    run = _block_calibrate(ladder, params, config, method, interpret)

    def one(g, o):
        levels, _ = run(subarray_key(key, g), o[None])
        return levels[0]
    return jax.jit(jax.vmap(one))(idx, offs)


def fleet_calib_charges(
    ladder: OffsetLadder, levels: jax.Array, params: PhysicsParams
) -> jax.Array:
    """[G, C] levels -> [G, n_rows, C] calibration-row charges."""
    return jax.vmap(lambda lv: levels_to_charges(ladder, lv, params))(levels)


# ---------------------------------------------------------------------------
# Cache glue: serve/gemv start from a table instead of recalibrating.
# ---------------------------------------------------------------------------


def load_or_calibrate(
    cache,                               # runtime.calib_cache.CalibrationTableCache
    device_id: str,
    key: jax.Array,
    cfg: FleetConfig,
    params: PhysicsParams = PhysicsParams(),
    config: CalibrationConfig = CalibrationConfig(),
    *,
    mesh: Mesh | None = None,
    # "reference" is bit-identical to the fused Pallas kernel (enforced by
    # tests/test_fleet.py) and much faster under the CPU interpreter; pass
    # method="fused" with interpret=False on real TPU serving hosts.
    method: str = "reference",
    n_trials_ecr: int = 1024,
    interpret: bool = True,
):
    """Return (levels [G, C], ecr [G], masks [G, C], cache_hit).

    ``masks`` is the per-column error-prone mask (True = faulty) that
    column placement (repro/pud/placement.py) consumes.  On a cache hit
    nothing is recalibrated or re-measured; on a miss the fleet is
    manufactured from ``fold_in(key, .)``, calibrated, its ECR + masks
    measured, and the table persisted for the next startup.
    """
    from .ecr import measure_ecr_fleet

    hit = cache.load(device_id, cfg, params)
    # A table without its ECR measurement or masks can't drive the perf
    # model / placement — treat it as a miss and re-identify rather than
    # hand back None.
    if hit is not None and hit.ecr is not None and hit.masks is not None:
        return hit.levels, hit.ecr, hit.masks, True

    offsets = manufacture_fleet(key, cfg, params)
    cal = calibrate_fleet(key, offsets, cfg, params, config,
                          mesh=mesh, method=method, interpret=interpret)
    ladder = cfg.ladder(params)
    charges = fleet_calib_charges(ladder, cal.levels, params)
    ecr, masks = measure_ecr_fleet(
        jax.random.fold_in(key, 0x0ECD), offsets, charges, params,
        ladder.n_fracs, n_trials=n_trials_ecr)
    cache.save(device_id, cfg, params, np.asarray(cal.levels),
               ecr=np.asarray(ecr), masks=np.asarray(masks),
               metadata={"method": cal.method,
                         "n_iterations": config.n_iterations},
               assumed_temp_c=params.temp_nominal_c)
    return cal.levels, ecr, masks, False
