"""PUD device-plane tests: physics matches the paper's worked examples,
commands behave per Sec. II-B, bit-serial arithmetic is exact on an ideal
(noise-free) device."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.pud import bitserial, device, timing
from repro.pud.physics import NEUTRAL, PhysicsParams

IDEAL = PhysicsParams(sigma_static=0.0, sigma_dynamic=0.0, sigma_frac=0.0,
                      sigma_transfer=0.0)


def test_paper_voltage_examples():
    """Reproduce the two voltages the paper computes in Sec. II-C."""
    p = PhysicsParams()
    # single-cell read of a '1': 0.55 V_DD
    v1 = p.bitline_voltage(jnp.float32(1.0), 1)
    assert abs(float(v1) - 0.55) < 1e-6
    # MAJ5(1,1,1,0,0) with three neutral rows under 8-row SiMRA: ~0.529 V_DD
    v2 = p.bitline_voltage(jnp.float32(3 + 3 * NEUTRAL), 8)
    assert abs(float(v2) - 0.5294) < 1e-3


def test_frac_multi_level_convergence():
    """Repeated Fracs approach neutral (FracDRAM: 6-10 ops to neutral)."""
    p = PhysicsParams()
    q = jnp.float32(1.0)
    levels = [float(p.frac_charge(q, n)) for n in range(8)]
    assert levels[0] == 1.0
    diffs = np.abs(np.diff(levels))
    assert (diffs[1:] < diffs[:-1]).all()          # monotone convergence
    assert abs(levels[6] - NEUTRAL) < 0.005         # ~neutral by 6 Fracs


def test_simra_majority_and_restore():
    device.set_params(IDEAL)
    key = jax.random.key(0)
    state = device.make_subarray(key, 16, 256, IDEAL)
    bits = [1, 1, 1, 0, 0]
    for r, b in enumerate(bits):
        state = device.write_row(state, r, jnp.full((256,), float(b)))
    for r in (5, 6, 7):
        state = device.write_row(state, r, jnp.full((256,), 0.5))
    state, out = device.simra(state, range(8), jax.random.key(1))
    np.testing.assert_allclose(np.asarray(out), 1.0)     # MAJ5 = 1
    # result restored into all 8 opened rows (paper Fig. 1 step 4)
    np.testing.assert_allclose(np.asarray(state.charge[:8]), 1.0)


def test_rowcopy_multi_destination():
    device.set_params(IDEAL)
    state = device.make_subarray(jax.random.key(0), 8, 128, IDEAL)
    src = jnp.arange(128) % 2
    state = device.write_row(state, 0, src.astype(jnp.float32))
    state = device.rowcopy(state, 0, (3, 5))
    np.testing.assert_allclose(np.asarray(state.charge[3]),
                               np.asarray(src, dtype=np.float32))
    np.testing.assert_allclose(np.asarray(state.charge[5]),
                               np.asarray(src, dtype=np.float32))


def _ideal_ctx(n_cols):
    return bitserial.MajContext(
        params=IDEAL,
        sense_offset=jnp.zeros((n_cols,)),
        calib_charge=jnp.full((3, n_cols), NEUTRAL),
        n_fracs=0,
    )


def test_full_adder_truth_table():
    ctx = _ideal_ctx(8)
    key = jax.random.key(0)
    for a in (0, 1):
        for b in (0, 1):
            for c in (0, 1):
                av = jnp.full((8,), float(a))
                bv = jnp.full((8,), float(b))
                cv = jnp.full((8,), float(c))
                s, sb, cout, coutb = bitserial.full_adder(
                    ctx, av, 1 - av, bv, 1 - bv, cv, 1 - cv, key)
                assert int(s[0]) == (a + b + c) % 2, (a, b, c)
                assert int(cout[0]) == (a + b + c) // 2, (a, b, c)
                assert int(sb[0]) == 1 - (a + b + c) % 2
                assert int(coutb[0]) == 1 - (a + b + c) // 2


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_add8_exact_on_ideal_device(seed):
    n = 64
    ctx = _ideal_ctx(n)
    key = jax.random.key(seed)
    ka, kb, kg = jax.random.split(key, 3)
    a = jax.random.randint(ka, (1, n), 0, 256, jnp.int32)
    b = jax.random.randint(kb, (1, n), 0, 256, jnp.int32)
    ab_, bb_ = bitserial.int_to_bits(a, 8), bitserial.int_to_bits(b, 8)
    s, _, cout, _ = bitserial.add_n(ctx, ab_, 1 - ab_, bb_, 1 - bb_, kg)
    got = bitserial.bits_to_int(s) + (cout.astype(jnp.int32) << 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(a + b))


def test_mul8_truncated_exact_on_ideal_device():
    n = 32
    ctx = _ideal_ctx(n)
    key = jax.random.key(123)
    ka, kb, kg = jax.random.split(key, 3)
    a = jax.random.randint(ka, (1, n), 0, 256, jnp.int32)
    b = jax.random.randint(kb, (1, n), 0, 256, jnp.int32)
    ab_, bb_ = bitserial.int_to_bits(a, 8), bitserial.int_to_bits(b, 8)
    s = bitserial.mul8_truncated(ctx, ab_, 1 - ab_, bb_, 1 - bb_, kg)
    np.testing.assert_array_equal(np.asarray(bitserial.bits_to_int(s)),
                                  np.asarray((a * b) & 0xFF))


def test_int_bits_roundtrip():
    x = jnp.arange(256, dtype=jnp.int32)
    bits = bitserial.int_to_bits(x, 8)
    np.testing.assert_array_equal(np.asarray(bitserial.bits_to_int(bits)),
                                  np.asarray(x))


# ---------------------------------------------------------------------------
# timing model
# ---------------------------------------------------------------------------

def test_latency_model_matches_paper_operating_point():
    """MAJ5 wave latency lands at the paper's implied ~2.5 us and the
    ACT-count ratios between T-configs match Sec. IV-B.2's structure."""
    sys = timing.SystemConfig()
    lat210 = timing.wave_latency_ns(bitserial.maj5_standalone_counts(3), sys)
    assert 2200 < lat210 < 2800, lat210      # paper-implied 2.52 us
    lat000 = timing.wave_latency_ns(bitserial.maj5_standalone_counts(0), sys)
    lat222 = timing.wave_latency_ns(bitserial.maj5_standalone_counts(6), sys)
    assert lat000 < lat210 < lat222          # fewer Fracs -> lower latency


def test_throughput_eq1_proportional_to_error_free_columns():
    sys = timing.SystemConfig()
    cnt = bitserial.maj5_standalone_counts(3)
    t1 = timing.throughput_ops(cnt, 1000.0, sys)
    t2 = timing.throughput_ops(cnt, 2000.0, sys)
    assert abs(t2 / t1 - 2.0) < 1e-9
