"""Fleet session (host-side, no mesh needed): per-device state is fully
independent, drift recalibration touches only the owning shard's table and
placement, and the fleet monitor routes events to the shard that raised
them."""
import jax
import numpy as np
import pytest

from repro.api import (CalibrationConfig, DriftConfig, DriftSimulator,
                       FleetConfig, FleetDriftMonitor, PUDGemvConfig,
                       PUDSession)
from repro.models.params import init_params
from repro.models.transformer import LMConfig, TransformerLM

GRID = FleetConfig(n_channels=1, n_banks=1, n_subarrays=8, n_cols=1024)
CAL = CalibrationConfig(n_iterations=4, n_samples=64)
DRIFT_TEMP_C = 3000.0        # see tests/test_drift.py: certainty, not realism


@pytest.fixture(scope="module")
def smoke():
    """Wider than the arch smokes on purpose: every projection must span
    >= 2 window blocks so both model shards of a 2-way split own columns
    (single-block tensors park their second shard on pure padding, which
    tests/test_sharded_placement.py covers separately)."""
    model = TransformerLM(LMConfig(
        name="fleet-smoke", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512, head_dim=16, loss_chunk=32))
    params = init_params(model.param_defs(), jax.random.key(0))
    return model, params


@pytest.fixture(scope="module")
def fleet(smoke):
    """A calibrated 1x2 fleet (one lane, two model shards) with canaries
    and a placed sharded pack — shared by the read-only tests; the
    mutation test below recalibrates a subarray no other test reads."""
    _, params = smoke
    f = PUDSession.open_fleet("qwen3-1.7b", n_data=1, n_model=2, grid=GRID,
                              calib=CAL, key=7, n_trials_ecr=128,
                              backend="reference")
    f.calibrate()
    f.reserve_canaries(16)
    f.pack(params, PUDGemvConfig(weight_bits=4), name="fleet-shared")
    return f


def test_fleet_devices_are_independent(fleet):
    assert fleet.n_data == 1 and fleet.n_model == 2 and fleet.n_devices == 2
    (s0, s1), = fleet.sessions
    assert fleet.shard(0, 0) is s0 and fleet.shard(0, 1) is s1
    assert s0.device_id != s1.device_id
    # distinct key folds -> distinct manufactured offsets -> distinct tables
    assert (np.asarray(s0.calibration.levels)
            != np.asarray(s1.calibration.levels)).any()
    # each shard planned its own slice under its own placement namespace
    assert s0._placement is not None and s1._placement is not None
    assert s0._placement is not s1._placement


def test_pack_splits_every_projection_on_block_boundaries(fleet):
    pm = fleet.packs[0]
    widths = fleet.shard_widths
    assert widths is not None and len(widths) == 2 and min(widths) > 0
    for n in pm.packed_names:
        st = pm.tensor(n)
        assert len(st.shard_widths) == 2
        assert all(w % st.block_cols == 0 for w in st.shard_widths)
        assert st.planes.shape[-4] == 2          # stacked shard axis
    assert pm.placed


def test_fleet_monitor_routes_events_to_owning_shard(fleet):
    s0, s1 = fleet.sessions[0]
    sims = [DriftSimulator.for_session(s) for s in (s0, s1)]
    mon = FleetDriftMonitor(fleet, sims,
                            config=DriftConfig(probe_every=1))
    # clean fleet: no critical events anywhere
    assert not [e for e in mon.probe() if e.severity == "critical"]

    sims[1].advance(temp_c=DRIFT_TEMP_C, subarrays=[2])
    events = [e for e in mon.probe() if e.severity == "critical"]
    assert events and {e.shard for e in events} == {1}
    assert {e.subarray for e in events} == {2}

    state0 = s0._state
    pm = mon.recover(events[0])
    assert s0._state is state0               # untouched neighbour
    assert fleet.packs[0] is pm
    rep = mon.report()
    assert rep["data_lane"] == 0 and len(rep["shards"]) == 2


def test_fleet_monitor_needs_one_device_per_shard(fleet):
    sim = DriftSimulator.for_session(fleet.shard(0, 0))
    with pytest.raises(ValueError):
        FleetDriftMonitor(fleet, [sim])


def test_recalibrate_shard_leaves_other_shard_untouched(fleet):
    s0, s1 = fleet.sessions[0]
    state0, plc0 = s0._state, s0._placement
    levels1 = np.asarray(s1.calibration.levels).copy()
    pack_before = fleet.packs[0]

    sim = DriftSimulator.for_session(s1)
    sim.advance(temp_c=DRIFT_TEMP_C, subarrays=[5])
    pm = fleet.recalibrate_shard(1, [5], sim.sense_offsets(),
                                 assumed_temp_c=DRIFT_TEMP_C)

    # shard 0: state and placement are the very same objects — not re-read,
    # not re-planned, not re-identified
    assert s0._state is state0
    assert s0._placement is plc0
    # shard 1: only subarray 5's ladder moved
    levels1b = np.asarray(s1.calibration.levels)
    assert (levels1b[5] != levels1[5]).any()
    for g in range(GRID.n_subarrays):
        if g != 5:
            np.testing.assert_array_equal(levels1b[g], levels1[g])
    # the lane's pack was rebuilt and swapped in
    assert fleet.packs[0] is pm and pm is not pack_before
    assert pm.placed
