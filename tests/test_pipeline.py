"""Pipeline parallelism: schedule correctness in a subprocess with forced
multi-device CPU (the stage axis needs >= 2 real devices)."""
import pytest

from repro.runtime.pipeline import bubble_fraction, stage_split


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(2, 14) == pytest.approx(1 / 15)


def test_stage_split_shapes():
    import jax.numpy as jnp
    tree = {"w": jnp.zeros((8, 3, 5)), "b": jnp.zeros((8, 5))}
    out = stage_split(tree, 4)
    assert out["w"].shape == (4, 2, 3, 5)
    assert out["b"].shape == (4, 2, 5)


PIPE_PROG = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.runtime.pipeline import pipelined_apply, stage_split

    mesh = jax.make_mesh((4,), ("stage",))
    S, L, D, MB, NM = 4, 8, 16, 2, 6

    key = jax.random.key(0)
    # L layers of y = tanh(x @ W_l); stage s runs layers [2s, 2s+2)
    ws = 0.5 * jax.random.normal(key, (L, D, D), jnp.float32)

    def stage_fn(params, h):          # params: [L/S, D, D]
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, h, params)
        return h

    h = jax.random.normal(jax.random.key(1), (NM, MB, D), jnp.float32)
    staged = stage_split(ws, S)
    got = pipelined_apply(stage_fn, staged, h, mesh)

    # reference: plain sequential application of all L layers
    def ref_one(x):
        for i in range(L):
            x = jnp.tanh(x @ ws[i])
        return x
    want = jax.vmap(ref_one)(h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    print("PIPELINE_OK", float(jnp.abs(got - want).max()))
"""


def test_pipelined_apply_matches_sequential(forced_devices):
    forced_devices(PIPE_PROG, marker="PIPELINE_OK", devices=4, timeout=300)
