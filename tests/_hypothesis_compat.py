"""Degrade gracefully when ``hypothesis`` is not installed.

The property-test modules import ``given/settings/strategies`` from here
instead of from ``hypothesis`` directly.  With hypothesis installed (it is
declared in requirements-dev.txt) the real library is used unchanged.
Without it, a minimal deterministic fallback runs each ``@given`` test on a
fixed-seed sample of the strategy space — weaker than real property testing
(no shrinking, no coverage-guided search) but the whole suite still collects
and every test still exercises its code path, instead of six modules erroring
at collection.

Only the strategy combinators these tests actually use are implemented
(``integers``, ``sampled_from``, ``booleans``, ``floats``); extend as needed.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    # Cap fallback examples well below typical max_examples settings: each
    # example of a JAX property test can trigger a fresh trace/compile, and
    # the fallback's fixed seed gains nothing from more repeats.
    _MAX_FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

    class strategies:  # noqa: N801 - mimics `hypothesis.strategies` module
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def settings(max_examples=None, **_kw):
        def deco(fn):
            fn._max_examples = min(max_examples or _MAX_FALLBACK_EXAMPLES,
                                   _MAX_FALLBACK_EXAMPLES)
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            _MAX_FALLBACK_EXAMPLES)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    drawn = [s.sample(rng) for s in arg_strategies]
                    drawn_kw = {k: s.sample(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **drawn_kw, **kwargs)
            # pytest must not mistake the strategy-filled parameters for
            # fixtures: hide the wrapped signature.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
