"""ServingEngine: continuous-batching scheduler invariants and the
batched-vs-sequential bit-exactness guarantee, placed + logical layouts,
across all execution backends.  Also covers the per-slot decode path in
models/attention.py and the batch-aware FleetPerfModel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CalibrationConfig, FleetConfig, FleetPerfModel,
                       PUDGemvConfig, PUDSession, Request, ServingEngine,
                       backend_names)
from repro.configs import get
from repro.launch.serve import greedy_generate
from repro.models.params import init_params

MAX_LEN = 16
GEN = 4
PROMPT = 8


@pytest.fixture(scope="module")
def smoke():
    spec = get("qwen3-1.7b")
    model = spec.make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    return model, params


def _prompts(model, n, lens=None, key=1):
    lens = lens or [PROMPT] * n
    k = jax.random.key(key)
    return [jax.random.randint(jax.random.fold_in(k, i), (lens[i],), 0,
                               model.cfg.vocab, jnp.int32)
            for i in range(n)]


def _requests(prompts, gen=GEN):
    return [Request(request_id=i, tokens=p, max_new_tokens=gen)
            for i, p in enumerate(prompts)]


def _session(backend="pallas", calibrate=True):
    s = PUDSession.open(
        "qwen3-1.7b",
        grid=FleetConfig(n_channels=1, n_banks=1, n_subarrays=8,
                         n_cols=1024),
        calib=CalibrationConfig(n_iterations=4, n_samples=64),
        key=7, n_trials_ecr=128, backend=backend)
    if calibrate:
        s.calibrate()
    return s


# ---------------------------------------------------------------------------
# Per-slot decode path (models/attention.py vector cur_len)
# ---------------------------------------------------------------------------

def test_vector_cur_len_matches_scalar(smoke):
    model, params = smoke
    toks = jnp.stack(_prompts(model, 3))
    logits, cache = model.prefill(params, toks, max_len=MAX_LEN)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    l_s, c_s = model.decode_step(params, cache, nxt, jnp.int32(PROMPT))
    l_v, c_v = model.decode_step(params, cache, nxt,
                                 jnp.full((3,), PROMPT, jnp.int32))
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_v))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staggered_rows_independent(smoke):
    """A row decoding at its own position gets exactly the result it would
    get alone — the property continuous batching rests on."""
    model, params = smoke
    toks = jnp.stack(_prompts(model, 3))
    logits, cache = model.prefill(params, toks, max_len=MAX_LEN)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    lens = jnp.array([PROMPT, PROMPT + 1, PROMPT + 2], jnp.int32)
    l_g, _ = model.decode_step(params, cache, nxt, lens)
    l_1, _ = model.decode_step(
        params, jax.tree.map(lambda c: c[:, :1], cache), nxt[:1],
        jnp.int32(PROMPT))
    np.testing.assert_array_equal(np.asarray(l_g[0]), np.asarray(l_1[0]))


def test_mla_vector_cur_len(smoke):
    """Per-slot lengths also hold for the MLA (latent-attention) decode."""
    spec = get("deepseek-v2-lite-16b")
    model = spec.make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    toks = jnp.stack(_prompts(model, 2))
    logits, cache = model.prefill(params, toks, max_len=MAX_LEN)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    l_s, _ = model.decode_step(params, cache, nxt, jnp.int32(PROMPT))
    l_v, _ = model.decode_step(params, cache, nxt,
                               jnp.full((2,), PROMPT, jnp.int32))
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_v))


# ---------------------------------------------------------------------------
# Batched-vs-sequential bit-exactness (the acceptance criterion)
# ---------------------------------------------------------------------------

def _run_engine(model, params, prompts, session=None, batch_size=2,
                collect_logits=False):
    eng = ServingEngine(model, params, session=session, max_len=MAX_LEN,
                        batch_size=batch_size, collect_logits=collect_logits)
    return eng, eng.run(_requests(prompts))


@pytest.mark.parametrize("backend", sorted(backend_names()))
def test_batched_equals_sequential_placed(smoke, backend):
    """Placed physical layout, every backend: tokens AND logits of the
    batched engine are bit-identical to per-request sequential decode."""
    model, params = smoke
    session = _session(backend=backend)
    packed = session.pack(params, PUDGemvConfig(weight_bits=4),
                          name=f"eng-{backend}")
    assert packed.placed
    prompts = _prompts(model, 4)
    eng, comps = _run_engine(model, packed.params, prompts, session=session,
                             collect_logits=True)
    assert len(comps) == 4
    for c in comps:
        toks, logits = greedy_generate(
            model, packed.params, prompts[c.request_id][None], GEN, MAX_LEN)
        assert c.tokens == list(np.asarray(toks)[0])
        np.testing.assert_array_equal(
            c.logits, np.asarray(logits)[0, :GEN],
            err_msg=f"backend {backend}, request {c.request_id}")


def test_batched_equals_sequential_logical(smoke):
    """Logical (unplaced) layout: same guarantee without calibration."""
    model, params = smoke
    session = _session(calibrate=False)
    packed = session.pack(params, PUDGemvConfig(weight_bits=4))
    assert not packed.placed
    prompts = _prompts(model, 3)
    _, comps = _run_engine(model, packed.params, prompts, session=session)
    for c in comps:
        toks, _ = greedy_generate(
            model, packed.params, prompts[c.request_id][None], GEN, MAX_LEN)
        assert c.tokens == list(np.asarray(toks)[0])


def test_batched_equals_sequential_ragged_prompts(smoke):
    """Mixed prompt lengths force genuinely staggered slot positions."""
    model, params = smoke
    prompts = _prompts(model, 4, lens=[4, 8, 6, 10])
    _, comps = _run_engine(model, params, prompts, batch_size=3)
    for c in comps:
        toks, _ = greedy_generate(
            model, params, prompts[c.request_id][None], GEN, MAX_LEN)
        assert c.tokens == list(np.asarray(toks)[0]), c.request_id


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------

def test_scheduler_no_slot_leaks_and_fifo(smoke):
    model, params = smoke
    prompts = _prompts(model, 7)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=3)
    eng.submit_all(_requests(prompts))
    assert eng.n_pending == 7 and eng.n_active == 0
    seen_active = []
    while eng.n_pending or eng.n_active:
        eng.step()
        assert eng.n_active <= eng.batch_size
        assert len(eng.free_slots) + eng.n_active == eng.batch_size
        seen_active.append(eng.n_active)
    comps = sorted(eng._completions, key=lambda c: c.request_id)
    # every request completed exactly once, with its full budget
    assert [c.request_id for c in comps] == list(range(7))
    assert all(len(c.tokens) == GEN for c in comps)
    # all slots free at drain; no request left behind
    assert eng.n_active == 0 and eng.n_pending == 0
    assert eng.free_slots == [0, 1, 2]
    # FIFO admission: request k is never admitted before request k-1
    admits = [c.admitted_step for c in comps]
    assert admits == sorted(admits)
    # the batch was actually used (more than one slot live at once)
    assert max(seen_active) == 3
    rep = eng.scheduler_report()
    assert rep["completed"] == 7 and rep["generated_tokens"] == 7 * GEN
    # every live slot-step decoded exactly one token — no lost work
    # (the first token of each request comes from its prefill, not a step)
    assert rep["slot_occupancy"] * rep["steps"] * 3 == 7 * (GEN - 1)
    # 7 requests on 3 slots cannot tile evenly: the ragged tail ran
    # under-occupied instead of being dropped
    assert 0 < rep["slot_occupancy"] < 1


def test_scheduler_eviction_order_and_reuse(smoke):
    """Shorter budgets finish first; their slots are re-used immediately."""
    model, params = smoke
    prompts = _prompts(model, 4)
    reqs = [Request(request_id=i, tokens=p, max_new_tokens=g)
            for i, (p, g) in enumerate(zip(prompts, [6, 2, 2, 3]))]
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2)
    eng.submit_all(reqs)
    order = []
    while eng.n_pending or eng.n_active:
        order += [c.request_id for c in eng.step()]
    # 1 (budget 2) evicts before 0 (budget 6); its slot admits 2, then 3
    assert order.index(1) < order.index(0)
    assert order.index(2) < order.index(0)
    comps = {c.request_id: c for c in eng._completions}
    assert comps[2].slot == comps[1].slot      # freed slot re-used
    assert comps[1].finished_step <= comps[2].admitted_step
    for i, g in enumerate([6, 2, 2, 3]):
        assert len(comps[i].tokens) == g


def test_engine_rejects_oversized_request(smoke):
    model, params = smoke
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(Request(request_id=0,
                           tokens=jnp.zeros((PROMPT,), jnp.int32),
                           max_new_tokens=MAX_LEN))
    with pytest.raises(ValueError, match="batch_size"):
        ServingEngine(model, params, max_len=MAX_LEN, batch_size=0)


def test_engine_default_batch_from_session_occupancy(smoke):
    model, params = smoke
    session = _session()
    session.pack(params, PUDGemvConfig(weight_bits=4), name="defbatch")
    eng = session.serving_engine(model, max_len=MAX_LEN)
    assert eng.batch_size == session.optimal_batch_size(32)
    assert eng.batch_size > 1
    # no session -> small fixed default
    assert ServingEngine(model, params, max_len=MAX_LEN).batch_size >= 1


# ---------------------------------------------------------------------------
# Batch-aware perf model + reporting
# ---------------------------------------------------------------------------

def test_fleet_perf_model_monotone_to_optimum():
    m = FleetPerfModel(error_free_fracs=(0.9, 0.95),
                       occupied_subarrays=2, total_subarrays=8)
    opt = m.optimal_batch_size()
    assert opt == m.n_replicas * m.operand_slots == 16
    rates = [m.batched_tokens_per_second(2e9, b) for b in range(1, opt + 4)]
    assert all(a < b for a, b in zip(rates[:opt - 1], rates[1:opt]))
    assert rates[opt - 1] == pytest.approx(rates[-1])       # flat past opt
    assert m.batched_tokens_per_second(2e9, 1) == pytest.approx(
        m.tokens_per_second(2e9))
    assert m.optimal_batch_size(max_batch=5) == 5


def test_perf_report_batch_aware(smoke):
    model, params = smoke
    session = _session()
    session.pack(params, PUDGemvConfig(weight_bits=4), name="rep")
    rep = session.perf_report(2e9, batch_size=4)
    assert rep["batch_size"] == 4
    assert rep["optimal_batch"] >= 1
    assert rep["batched_tok_s"] >= rep["placed_tok_s"]
    assert rep["batch_speedup"] == pytest.approx(
        rep["batched_tok_s"] / rep["placed_tok_s"])
    # engine perf_report merges scheduler + session views
    eng = session.serving_engine(model, max_len=MAX_LEN, batch_size=2)
    eng.run(_requests(_prompts(model, 2)))
    merged = eng.perf_report(2e9)
    assert merged["completed"] == 2 and "batched_tok_s" in merged


def test_greedy_generate_threads_key(smoke):
    """Explicit seed satellite: same key -> same trace, and the default
    stays the legacy key(0) behavior."""
    model, params = smoke
    toks = jnp.stack(_prompts(model, 2))
    a = greedy_generate(model, params, toks, GEN, MAX_LEN)
    b = greedy_generate(model, params, toks, GEN, MAX_LEN,
                        key=jax.random.key(0))
    c = greedy_generate(model, params, toks, GEN, MAX_LEN,
                        key=jax.random.key(123))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    # greedy decode: key changes must not change tokens
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(c[0]))


# ---------------------------------------------------------------------------
# Benchmark harness exit-code satellite
# ---------------------------------------------------------------------------

def test_benchmarks_run_propagates_failures(capsys):
    import benchmarks.run as run_mod
    ok = {"called": False}

    def _ok(scale):
        ok["called"] = True

    def _boom(scale):
        raise RuntimeError("kaboom")

    saved = dict(run_mod.BENCHES)
    try:
        run_mod.BENCHES.clear()
        run_mod.BENCHES["boom"] = _boom
        run_mod.BENCHES["fine"] = _ok
        rc = run_mod.main([])
        out = capsys.readouterr().out
        assert rc == 1
        assert ok["called"], "later benchmarks must still run"
        assert "1 FAILED (boom)" in out
        assert run_mod.main(["--only", "fine"]) == 0
    finally:
        run_mod.BENCHES.clear()
        run_mod.BENCHES.update(saved)
