"""PUDTune core tests: offset ladders (Fig. 3), Algorithm 1, ECR reduction,
throughput model (Table I structure), reliability (Fig. 6 structure)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.calibrate import CalibrationConfig, identify_calibration
from repro.core.ecr import measure_ecr_maj5
from repro.core.offsets import baseline_charges, levels_to_charges, make_ladder
from repro.pud.physics import PhysicsParams

P = PhysicsParams()
CALIB_FAST = CalibrationConfig(n_iterations=20, n_samples=256)


# ---------------------------------------------------------------------------
# Offset ladders (paper Fig. 3)
# ---------------------------------------------------------------------------

def test_ladder_fig3_structure():
    """T000 coarse+wide; T222 fine+narrow; T210 fine AND wide."""
    t000 = make_ladder((0, 0, 0), P)
    t222 = make_ladder((2, 2, 2), P)
    t210 = make_ladder((2, 1, 0), P)

    def span(l): return l.offsets_units[-1] - l.offsets_units[0]
    def min_step(l): return min(np.diff(l.offsets_units))

    assert t000.n_levels == 4 and t222.n_levels == 4 and t210.n_levels == 8
    assert span(t000) > span(t222)            # wide vs narrow
    assert min_step(t222) < min_step(t000)    # fine vs coarse
    assert span(t210) > 2.5 * span(t222)      # wide range despite fine grain
    assert min_step(t210) <= min_step(t222) + 1e-9


@settings(max_examples=30, deadline=None)
@given(x=st.integers(0, 4), y=st.integers(0, 4), z=st.integers(0, 4))
def test_ladder_invariants(x, y, z):
    ladder = make_ladder((x, y, z), P)
    o = np.asarray(ladder.offsets_units)
    assert (np.diff(o) > 0).all()                     # strictly sorted
    np.testing.assert_allclose(o, -o[::-1], atol=1e-9)  # symmetric
    assert 2 <= ladder.n_levels <= 8
    # bits_table regenerates exactly the advertised offsets
    charges = ladder.row_charges(P)
    regen = (charges - 0.5).sum(axis=1)               # charge units
    np.testing.assert_allclose(regen, o, atol=1e-6)


def test_levels_to_charges_shapes():
    ladder = make_ladder((2, 1, 0), P)
    levels = jnp.array([0, 3, 7, 4], jnp.int32)
    ch = levels_to_charges(ladder, levels, P)
    assert ch.shape == (3, 4)
    assert ((ch >= 0.0) & (ch <= 1.0)).all()


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

def test_calibration_moves_levels_toward_offsets():
    """Columns with strongly positive sense offset need positive calibration
    offset (increment); negative need negative.  Algorithm 1 stops at the
    FIRST level whose residual clears the MAJ5 margin (the bias signal
    vanishes there), so assert direction + margin coverage, not nearness."""
    ladder = make_ladder((2, 1, 0), P)
    n = 2048
    key = jax.random.key(0)
    sense = jnp.where(jnp.arange(n) < n // 2, 0.03, -0.03).astype(jnp.float32)
    levels = identify_calibration(key, sense, ladder, P, CALIB_FAST)
    offs = jnp.asarray(ladder.offsets_volts(P))[levels]
    assert float(offs[: n // 2].mean()) > 0.008
    assert float(offs[n // 2:].mean()) < -0.008
    # residual after calibration sits inside the MAJ5 margin for every column
    assert float(jnp.abs(sense - offs).max()) < P.maj_margin


def test_calibration_reduces_ecr_massively():
    """The paper's headline: ECR drops from ~47% to a few percent."""
    n = 8192
    k1, k2, k3, k4 = jax.random.split(jax.random.key(1), 4)
    sense = P.sigma_static * jax.random.normal(k1, (n,), jnp.float32)

    base_ecr, _ = measure_ecr_maj5(
        k2, sense, baseline_charges(3, n, P), P, 3, n_trials=2048)

    ladder = make_ladder((2, 1, 0), P)
    levels = identify_calibration(k3, sense, ladder, P, CALIB_FAST)
    tune_ecr, _ = measure_ecr_maj5(
        k4, sense, levels_to_charges(ladder, levels, P), P, 3, n_trials=2048)

    assert 0.35 < base_ecr < 0.60, base_ecr
    assert tune_ecr < 0.08, tune_ecr
    assert base_ecr / max(tune_ecr, 1e-3) > 5.0


def test_calibration_is_deterministic_given_key():
    ladder = make_ladder((2, 1, 0), P)
    key = jax.random.key(5)
    sense = P.sigma_static * jax.random.normal(
        jax.random.key(6), (512,), jnp.float32)
    l1 = identify_calibration(key, sense, ladder, P, CALIB_FAST)
    l2 = identify_calibration(key, sense, ladder, P, CALIB_FAST)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_calibrated_levels_in_range(seed):
    ladder = make_ladder((2, 1, 0), P)
    sense = P.sigma_static * jax.random.normal(
        jax.random.key(seed), (256,), jnp.float32)
    levels = identify_calibration(
        jax.random.fold_in(jax.random.key(seed), 1), sense, ladder, P,
        CalibrationConfig(n_iterations=5, n_samples=64))
    arr = np.asarray(levels)
    assert ((arr >= 0) & (arr < ladder.n_levels)).all()


# ---------------------------------------------------------------------------
# Baseline structure
# ---------------------------------------------------------------------------

def test_baseline_charges_neutral_equivalent():
    """B_{x,0,0}: 0/1 constant pair sums to one, frac'd row near neutral."""
    ch = baseline_charges(3, 16, P)
    assert ch.shape == (3, 16)
    total = float(ch[:, 0].sum())
    assert abs(total - 1.5) < 0.05   # ~3 neutral rows' worth of charge
