"""Sharding-hint machinery: no-op without rules, exactness of activation
head padding under a real (forced multi-device) mesh."""
import jax.numpy as jnp

from repro.models import sharding_ctx


def test_hint_noop_without_rules():
    sharding_ctx.set_rules(None)
    x = jnp.ones((2, 3))
    assert sharding_ctx.hint(x, "batch", None) is x


def test_padded_head_count_without_rules():
    sharding_ctx.set_rules(None)
    assert sharding_ctx.padded_head_count(40) == 40


def test_padded_head_count_with_rules():
    sharding_ctx.set_rules({"heads": "model", "heads_act": "model",
                            "_mesh_sizes": {"data": 16, "model": 16}})
    try:
        assert sharding_ctx.padded_head_count(40) == 48
        assert sharding_ctx.padded_head_count(20) == 32
        assert sharding_ctx.padded_head_count(16) == 16
        assert sharding_ctx.padded_head_count(64) == 64
    finally:
        sharding_ctx.set_rules(None)


PAD_PROG = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.models import sharding_ctx
    from repro.models.attention import AttnConfig, gqa_attention, gqa_defs
    from repro.models.params import init_params

    # h=6 heads on a model=4 axis -> pads to 8; kv=3 does not divide 8 -> kv pads
    cfg = AttnConfig(d_model=32, n_heads=6, n_kv_heads=3, head_dim=8,
                     kv_chunk=16)
    params = init_params(gqa_defs(cfg, jnp.float32), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 32, 32), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(32), (4, 32))

    # reference: no rules -> no padding, single device semantics
    sharding_ctx.set_rules(None)
    ref, (rk, rv) = gqa_attention(params, cfg, x, positions)

    from repro.launch.mesh import make_host_mesh, use_mesh
    mesh = make_host_mesh(1, 4)
    with use_mesh(mesh):
        sharding_ctx.set_rules({"batch": "data", "heads": None,
                                "heads_act": "model",
                                "_mesh_sizes": dict(mesh.shape)})
        got, (gk, gv) = jax.jit(
            lambda p, xx: gqa_attention(p, cfg, xx, positions))(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(rk),
                               rtol=2e-5, atol=2e-5)
    assert gk.shape[2] == cfg.n_kv_heads, gk.shape
    print("PAD_OK", float(jnp.abs(got - ref).max()))
"""


def test_head_padding_exact_on_mesh(forced_devices):
    forced_devices(PAD_PROG, marker="PAD_OK", devices=4, timeout=300)
