"""Property tests: MAJ-based dual-rail arithmetic is EXACT integer math on
an ideal (noise-free, offset-free) device — the algorithmic layer is
separated from the error model, so any failure here is a graph bug, not
noise. Also: self-duality invariants of the MAJ primitives."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.pud.bitserial import (MajContext, add_n, bits_to_int, int_to_bits,
                                 mul8_truncated)
from repro.pud.physics import PhysicsParams

IDEAL = PhysicsParams(sigma_static=0.0, sigma_dynamic=0.0, sigma_frac=0.0,
                      sigma_transfer=0.0)


def _ctx(n_cols: int, fc=(2, 1, 0)) -> MajContext:
    from repro.core.offsets import levels_to_charges, make_ladder, neutral_level
    ladder = make_ladder(fc, IDEAL)
    levels = jnp.full((n_cols,), neutral_level(ladder), jnp.int32)
    return MajContext(
        params=IDEAL,
        sense_offset=jnp.zeros((n_cols,), jnp.float32),
        calib_charge=levels_to_charges(ladder, levels, IDEAL),
        n_fracs=ladder.n_fracs)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nbits=st.sampled_from([4, 8, 12]))
def test_addn_exact_on_ideal_device(seed, nbits):
    n_cols = 64
    k1, k2, kg = jax.random.split(jax.random.key(seed), 3)
    hi = 1 << nbits
    a = jax.random.randint(k1, (n_cols,), 0, hi, jnp.int32)
    b = jax.random.randint(k2, (n_cols,), 0, hi, jnp.int32)
    ab, bb = int_to_bits(a, nbits), int_to_bits(b, nbits)
    s, _, cout, _ = _run_add(_ctx(n_cols), ab, bb, kg)
    got = bits_to_int(s) + (cout.astype(jnp.int32) << nbits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(a + b))


def _run_add(ctx, ab, bb, kg):
    return add_n(ctx, ab, 1.0 - ab, bb, 1.0 - bb, kg)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mul8_exact_on_ideal_device(seed):
    n_cols = 32
    k1, k2, kg = jax.random.split(jax.random.key(seed), 3)
    a = jax.random.randint(k1, (n_cols,), 0, 256, jnp.int32)
    b = jax.random.randint(k2, (n_cols,), 0, 256, jnp.int32)
    ab, bb = int_to_bits(a, 8), int_to_bits(b, 8)
    s = mul8_truncated(_ctx(n_cols), ab, 1.0 - ab, bb, 1.0 - bb, kg)
    got = bits_to_int(s)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray((a * b) & 0xFF))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_maj_primitive_identities(seed):
    """AND/OR/MAJ3 truth tables + self-duality MAJ(~x) = ~MAJ(x)."""
    ctx = _ctx(8)
    key = jax.random.key(seed)
    bits = jax.random.bernoulli(key, 0.5, (3, 8)).astype(jnp.float32)
    x, y, z = bits
    k1, k2, k3, k4 = jax.random.split(jax.random.key(seed + 1), 4)
    np.testing.assert_array_equal(np.asarray(ctx.and_(x, y, k1)),
                                  np.asarray(x * y))
    np.testing.assert_array_equal(np.asarray(ctx.or_(x, y, k2)),
                                  np.asarray(jnp.maximum(x, y)))
    maj = np.asarray(ctx.maj3(x, y, z, k3))
    np.testing.assert_array_equal(maj, np.asarray(
        ((x + y + z) > 1.5).astype(jnp.float32)))
    dual = np.asarray(ctx.maj3(1 - x, 1 - y, 1 - z, k4))
    np.testing.assert_array_equal(dual, 1.0 - maj)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nbits=st.integers(1, 16))
def test_bits_roundtrip(seed, nbits):
    x = jax.random.randint(jax.random.key(seed), (37,), 0, 1 << nbits,
                           jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(bits_to_int(int_to_bits(x, nbits))), np.asarray(x))
