"""``PUDGemvConfig.packable`` matching edge cases: scoped vs bare entries,
non-packable shapes, and the FFN/attention packing overlap."""
import jax
import jax.numpy as jnp
import pytest

from repro.pud.gemv import PUDGemvConfig
from repro.pud.packer import pack_for_serving, packing_requests


def _w(key, *shape):
    return 0.05 * jax.random.normal(jax.random.key(key), shape, jnp.float32)


def _names(params, cfg):
    return {r.name for r in packing_requests(params, cfg,
                                             include_unembed=False)}


def test_scoped_entry_requires_scope_on_path():
    params = {
        "layers_0": {"mixer": {"wi": _w(0, 16, 32)}},
        "adapter": {"wi": _w(1, 16, 32)},        # same key, wrong scope
    }
    cfg = PUDGemvConfig(packable=("mixer.wi",))
    assert _names(params, cfg) == {"layers_0/mixer/wi"}
    packed, report = pack_for_serving(params, cfg, include_unembed=False)
    assert report["packed"] == ["layers_0/mixer/wi"]
    assert "wi_pud" in packed["layers_0"]["mixer"]
    assert "wi" in packed["adapter"]             # untouched
    assert "wi_pud" not in packed["adapter"]


def test_scope_matches_any_path_component():
    # "mixer" may sit anywhere on the path, not just the direct parent.
    params = {"mixer": {"inner": {"wi": _w(0, 16, 32)}}}
    cfg = PUDGemvConfig(packable=("mixer.wi",))
    assert _names(params, cfg) == {"mixer/inner/wi"}


def test_bare_entry_matches_in_any_context():
    params = {
        "layers_0": {"mixer": {"wi": _w(0, 16, 32)}},
        "adapter": {"wi": _w(1, 16, 32)},
    }
    cfg = PUDGemvConfig(packable=("wi",))
    assert _names(params, cfg) == {"layers_0/mixer/wi", "adapter/wi"}


def test_non_packable_shapes_are_reported_skipped():
    params = {"layers_0": {"mixer": {
        "wi": _w(0, 2, 3, 16, 32),     # 4-D non-attn (e.g. MoE expert bank)
    }}}
    cfg = PUDGemvConfig(packable=("mixer.wi",))
    assert _names(params, cfg) == set()
    packed, report = pack_for_serving(params, cfg, include_unembed=False)
    assert report["packed"] == []
    assert report["skipped"] == ["layers_0/mixer/wi"]
    assert "wi" in packed["layers_0"]["mixer"]   # kept on the bf16 path


def test_attn_2d_weight_is_not_packable():
    # attention keys demand the explicit-head-axis layout; a pre-flattened
    # 2-D wq under attn is ambiguous and stays unpacked.
    params = {"layers_0": {"attn": {"wq": _w(0, 16, 32)}}}
    cfg = PUDGemvConfig(packable=("attn.wq",))
    packed, report = pack_for_serving(params, cfg, include_unembed=False)
    assert report["skipped"] == ["layers_0/attn/wq"]
    assert "wq_pud" not in packed["layers_0"]["attn"]


def test_attention_heads_flatten_to_gemv_columns():
    d, h, dh, n_layers = 16, 4, 8, 2
    params = {"layers_0": {"attn": {
        "wq": _w(0, d, h, dh),                   # [D, H, Dh]
        "wo": _w(1, h, dh, d),                   # [H, Dh, D]
    }, "stacked_attn": {}}}
    params["layers_1"] = {"attn": {
        "wq": _w(2, n_layers, d, h, dh),         # [L, D, H, Dh]
        "wo": _w(3, n_layers, h, dh, d),         # [L, H, Dh, D]
    }}
    cfg = PUDGemvConfig(packable=("attn.wq", "attn.wo"))
    reqs = {r.name: r for r in packing_requests(params, cfg,
                                                include_unembed=False)}
    assert reqs["layers_0/attn/wq"].n_cols == h * dh
    assert reqs["layers_0/attn/wq"].n_slices == 0
    assert reqs["layers_0/attn/wo"].n_cols == d
    assert reqs["layers_1/attn/wq"].n_cols == h * dh
    assert reqs["layers_1/attn/wq"].n_slices == n_layers
    packed, report = pack_for_serving(params, cfg, include_unembed=False)
    # bit-packed words: the K (=D) axis folds 8 rows per byte
    assert packed["layers_0"]["attn"]["wq_pud"].planes.shape == \
        (4, d // 8, h * dh)
    assert packed["layers_0"]["attn"]["wq_pud"].k == d
    assert packed["layers_1"]["attn"]["wq_pud"].planes.shape == \
        (n_layers, 4, d // 8, h * dh)


def test_ffn_and_attention_packing_overlap_via_bare_key():
    # A bare "wo" entry claims both the FFN wo and the attention wo; each
    # resolves through its own canonicalization.
    params = {"layers_0": {
        "mixer": {"wo": _w(0, 32, 16)},
        "attn": {"wo": _w(1, 4, 8, 16)},
    }}
    cfg = PUDGemvConfig(packable=("wo",))
    assert _names(params, cfg) == {"layers_0/mixer/wo", "layers_0/attn/wo"}
    packed, report = pack_for_serving(params, cfg, include_unembed=False)
    assert sorted(report["packed"]) == ["layers_0/attn/wo",
                                       "layers_0/mixer/wo"]
    assert packed["layers_0"]["attn"]["wo_pud"].planes.shape == (4, 4, 16)
    assert packed["layers_0"]["mixer"]["wo_pud"].planes.shape == (4, 4, 16)
    assert packed["layers_0"]["attn"]["wo_pud"].k == 32


def test_requests_match_report_names():
    # the placement contract: packing_requests names == pack report names
    params = {
        "layers_0": {"mixer": {"wi": _w(0, 16, 32), "wg": _w(1, 16, 32)},
                     "attn": {"wq": _w(2, 16, 4, 8)}},
        "unembed": {"w": _w(3, 16, 64)},
    }
    cfg = PUDGemvConfig(packable=("mixer.wi", "mixer.wg", "attn.wq"))
    reqs = {r.name for r in packing_requests(params, cfg)}
    _, report = pack_for_serving(params, cfg)
    assert reqs == set(report["packed"])


@pytest.mark.parametrize("entry,key,should", [
    ("mixer.wi", "wi", True), ("mixer.wi", "wig", False),
    ("wi", "wi", True), ("wi", "wo", False),
])
def test_match_is_exact_on_key_names(entry, key, should):
    params = {"mixer": {key: _w(0, 16, 32)}}
    got = _names(params, PUDGemvConfig(packable=(entry,)))
    assert bool(got) == should
