"""Batch-tiled bit-plane GEMM kernel: bit-exactness vs the row-vmapped GeMV
reference, ragged-batch padding, backend registry entries, and the
rank-dispatching ``pud_gemv`` shim over ``pud_matmul``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.backends import backend_names, get_backend
from repro.kernels.bitplane_gemm import (B_BLOCK, bitplane_gemm,
                                         bitplane_gemm_placed)
from repro.kernels.bitplane_gemv import bitplane_gemv, bitplane_gemv_placed
from repro.kernels.ops import pud_gemv, pud_matmul
from repro.kernels.ref import pack_bitplanes

K, N, P, WB = 64, 256, 320, 4


def _planes(key=0):
    w = jax.random.randint(jax.random.key(key), (K, N), -8, 8, jnp.int32)
    return pack_bitplanes(w, WB)


def _placed(key=0):
    planes = _planes(key)
    col_ids = jax.random.permutation(jax.random.key(key + 50), P)[:N]
    window = jnp.zeros((WB, K, P), jnp.int8).at[:, :, col_ids].set(planes)
    return window, col_ids.astype(jnp.int32)


def _x(b, key=1):
    return jax.random.randint(jax.random.key(key), (b, K), -127, 128,
                              jnp.int32).astype(jnp.int8)


@pytest.mark.parametrize("mode", ["planes", "folded"])
@pytest.mark.parametrize("b", [1, 3, 8])
def test_gemm_matches_vmapped_gemv(mode, b):
    """The acceptance oracle: row r of the batched GEMM == the GeMV kernel
    run on row r alone (vmap over singleton batches)."""
    planes, x = _planes(), _x(b)
    got = bitplane_gemm(x, planes, mode=mode)
    want = jax.vmap(
        lambda row: bitplane_gemv(row[None], planes, mode=mode)[0])(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32 and got.shape == (b, N)


@pytest.mark.parametrize("mode", ["planes", "folded"])
@pytest.mark.parametrize("b", [1, 5, 8])
def test_gemm_placed_matches_vmapped_gemv_placed(mode, b):
    window, col_ids = _placed()
    x = _x(b)
    got = bitplane_gemm_placed(x, window, col_ids, mode=mode)
    want = jax.vmap(lambda row: bitplane_gemv_placed(
        row[None], window, col_ids, mode=mode)[0])(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gemm_ragged_batch_pads_transparently():
    """B that is not a tile multiple pads with zero rows inside the kernel
    wrapper; real rows are unaffected and the pad is sliced off."""
    planes = _planes()
    big = _x(B_BLOCK + 3, key=9)        # forces bb=B_BLOCK, pad 125 rows
    got = bitplane_gemm(big, planes, mode="folded")
    assert got.shape == (B_BLOCK + 3, N)
    ref = get_backend("reference").gemm(big, planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("b", [1, 2, 6])
def test_all_backends_gemm_parity(b):
    planes, x = _planes(), _x(b)
    window, col_ids = _placed()
    ref_be = get_backend("reference")
    want = np.asarray(ref_be.matmul(x, planes))
    want_placed = np.asarray(ref_be.matmul_placed(x, window, col_ids))
    for name in backend_names():
        be = get_backend(name)
        np.testing.assert_array_equal(
            np.asarray(be.matmul(x, planes)), want,
            err_msg=f"{name} gemm != reference")
        np.testing.assert_array_equal(
            np.asarray(be.matmul_placed(x, window, col_ids)), want_placed,
            err_msg=f"{name} gemm_placed != reference")


def test_backend_matmul_falls_back_to_gemv():
    from repro.kernels.backends import Backend
    be = get_backend("reference")
    stripped = Backend(name="stripped", gemv=be.gemv,
                       gemv_placed=be.gemv_placed)
    planes, x = _planes(), _x(4)
    np.testing.assert_array_equal(
        np.asarray(stripped.matmul(x, planes)),
        np.asarray(be.matmul(x, planes)))


def test_pud_gemv_rank_dispatch():
    """1-D x -> [N]; 2-D x -> [B, N]; numerics identical to pud_matmul."""
    planes = _planes()
    scale = jnp.float32(0.5)
    x1 = jax.random.normal(jax.random.key(2), (K,), jnp.float32)
    y1 = pud_gemv(x1, planes, scale)
    y2 = pud_gemv(x1[None], planes, scale)
    ym = pud_matmul(x1[None], planes, scale)
    assert y1.shape == (N,) and y2.shape == (1, N)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2[0]))
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(ym))


@pytest.mark.parametrize("backend", ["pallas", "interpret", "reference"])
def test_pud_matmul_batched_equals_per_row(backend):
    """The serving guarantee at the op level: each row of a batched
    pud_matmul is bit-identical to running that row alone (B=1 takes the
    GeMV kernel path, B>1 the GEMM path — the dispatch must not change
    numerics)."""
    planes = _planes()
    w_scale = jnp.abs(jax.random.normal(jax.random.key(4), (N,))) + 0.1
    x = jax.random.normal(jax.random.key(3), (5, K), jnp.float32)
    batched = np.asarray(pud_matmul(x, planes, w_scale, backend=backend))
    for r in range(x.shape[0]):
        alone = np.asarray(pud_matmul(x[r:r + 1], planes, w_scale,
                                      backend=backend))
        np.testing.assert_array_equal(batched[r], alone[0])
