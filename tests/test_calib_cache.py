"""Calibration-cache failure paths: every corruption/mismatch mode must read
as a miss and fall back to recalibration — never raise, never serve a stale
or torn table."""
import json

import jax
import numpy as np
import pytest

from repro.core.calibrate import CalibrationConfig
from repro.core.fleet import FleetConfig, load_or_calibrate
from repro.pud.physics import PhysicsParams
from repro.runtime.calib_cache import (FORMAT, CalibrationTableCache,
                                       params_fingerprint, table_key)

P = PhysicsParams()
CFG = FleetConfig(n_channels=1, n_banks=1, n_subarrays=2, n_cols=128)
CAL = CalibrationConfig(n_iterations=4, n_samples=64)
KEY = jax.random.key(41)


@pytest.fixture
def warm(tmp_path):
    """A cache warmed by one real load_or_calibrate miss."""
    cache = CalibrationTableCache(tmp_path)
    levels, ecr, masks, hit = load_or_calibrate(
        cache, "dev", KEY, CFG, P, CAL, n_trials_ecr=128)
    assert not hit
    entry = tmp_path / "dev" / table_key(CFG, P)
    assert (entry / "manifest.json").exists()
    return cache, entry, (np.asarray(levels), np.asarray(ecr),
                          np.asarray(masks))


def _reload(cache):
    return load_or_calibrate(cache, "dev", KEY, CFG, P, CAL,
                             n_trials_ecr=128)


def test_warm_hit_is_deterministic(warm):
    cache, _, (levels, ecr, masks) = warm
    lv, e, m, hit = _reload(cache)
    assert hit
    np.testing.assert_array_equal(np.asarray(lv), levels)
    np.testing.assert_allclose(np.asarray(e), ecr)
    np.testing.assert_array_equal(np.asarray(m), masks)


def test_torn_levels_fall_back_to_recalibration(warm):
    cache, entry, (levels, _, _) = warm
    payload = entry / "levels.npy"
    payload.write_bytes(payload.read_bytes()[:40])    # truncated mid-write
    assert cache.load("dev", CFG, P) is None          # miss, not a raise
    lv, _, _, hit = _reload(cache)
    assert not hit                                    # recalibrated ...
    np.testing.assert_array_equal(np.asarray(lv), levels)  # ... same result
    assert cache.load("dev", CFG, P) is not None      # and re-persisted


def test_corrupt_manifest_falls_back(warm):
    cache, entry, _ = warm
    (entry / "manifest.json").write_text("{not json")
    assert cache.load("dev", CFG, P) is None
    _, _, _, hit = _reload(cache)
    assert not hit


def test_version_mismatch_falls_back(warm):
    """A format bump must invalidate old entries instead of misreading."""
    cache, entry, _ = warm
    manifest = json.loads((entry / "manifest.json").read_text())
    assert manifest["format"] == FORMAT
    manifest["format"] = "fleet-calib-v1"             # pre-masks era
    (entry / "manifest.json").write_text(json.dumps(manifest))
    assert cache.load("dev", CFG, P) is None
    _, _, _, hit = _reload(cache)
    assert not hit
    # the recalibration re-saved under the current format
    got = json.loads((entry / "manifest.json").read_text())
    assert got["format"] == FORMAT


def test_fingerprint_mismatch_falls_back(warm):
    """Changed physics constants can never silently reuse a stale table."""
    cache, entry, _ = warm
    manifest = json.loads((entry / "manifest.json").read_text())
    manifest["params_fingerprint"] = "0" * 12
    (entry / "manifest.json").write_text(json.dumps(manifest))
    assert manifest["params_fingerprint"] != params_fingerprint(P)
    assert cache.load("dev", CFG, P) is None
    _, _, _, hit = _reload(cache)
    assert not hit


def test_missing_masks_treated_as_miss(warm):
    """v2 tables without masks can't drive placement: re-identify."""
    cache, entry, _ = warm
    (entry / "masks.npy").unlink()
    table = cache.load("dev", CFG, P)
    assert table is not None and table.masks is None  # load is lenient ...
    _, _, masks, hit = _reload(cache)
    assert not hit and masks is not None              # ... the glue is not


def test_wrong_shape_masks_treated_as_missing(warm):
    cache, entry, _ = warm
    np.save(entry / "masks.npy", np.zeros((1, 3), bool))
    table = cache.load("dev", CFG, P)
    assert table is not None and table.masks is None


def test_evict_then_recalibrate(warm):
    cache, entry, _ = warm
    assert cache.evict("dev") == 1
    assert cache.load("dev", CFG, P) is None
    assert cache.evict("dev") == 0                    # idempotent
    _, _, _, hit = _reload(cache)
    assert not hit
    assert len(cache.entries()) == 1


def test_crashed_staging_dir_swept_on_save(warm, tmp_path):
    cache, entry, _ = warm
    torn = entry.with_name(entry.name + ".tmp-9999")
    torn.mkdir()
    (torn / "levels.npy").write_bytes(b"garbage")
    assert len(cache.entries()) == 1                  # staging is invisible
    lv, ecr, masks, hit = _reload(cache)
    assert hit                                        # real entry untouched
    cache.save("dev", CFG, P, np.asarray(lv), ecr=np.asarray(ecr),
               masks=np.asarray(masks))
    assert not torn.exists()                          # gc on the next save


# ---------------------------------------------------------------------------
# Calibration age metadata (drift monitoring): stamped on save, version-
# tolerant on load — entries written before the metadata existed still read
# as valid tables with unknown age.
# ---------------------------------------------------------------------------

def test_fresh_save_stamps_calibration_age(warm):
    cache, entry, _ = warm
    manifest = json.loads((entry / "manifest.json").read_text())
    calib = manifest["calibration"]
    assert calib["calibrated_at"] > 0
    table = cache.load("dev", CFG, P)
    assert table.calibrated_at == calib["calibrated_at"]
    assert table.params_fingerprint == params_fingerprint(P)
    # load_or_calibrate stamps the physics' nominal temperature
    assert table.assumed_temp_c == P.temp_nominal_c
    assert table.age_days() >= 0.0
    assert table.age_days(now=table.calibrated_at + 86400.0) == \
        pytest.approx(1.0)
    # clock skew can't produce negative ages
    assert table.age_days(now=table.calibrated_at - 60.0) == 0.0


def test_entry_without_calibration_block_loads_with_unknown_age(warm):
    """Pre-metadata entries (same format version) must stay readable."""
    cache, entry, (levels, _, _) = warm
    manifest = json.loads((entry / "manifest.json").read_text())
    del manifest["calibration"]
    (entry / "manifest.json").write_text(json.dumps(manifest))
    table = cache.load("dev", CFG, P)
    assert table is not None                          # still a hit ...
    np.testing.assert_array_equal(table.levels, levels)
    assert table.calibrated_at is None                # ... age unknown
    assert table.assumed_temp_c is None
    assert table.age_days() is None


def test_explicit_calibrated_at_roundtrips(warm):
    cache, entry, (levels, ecr, masks) = warm
    cache.save("dev", CFG, P, levels, ecr=ecr, masks=masks,
               calibrated_at=123456.0, assumed_temp_c=62.5)
    table = cache.load("dev", CFG, P)
    assert table.calibrated_at == 123456.0
    assert table.assumed_temp_c == 62.5


def test_cli_list_shows_age(warm, tmp_path, capsys):
    from repro.runtime.calib_cache import main as cli
    assert cli(["--root", str(tmp_path), "--list"]) == 0
    assert "age " in capsys.readouterr().out


# ---------------------------------------------------------------------------
# CLI (python -m repro.runtime.calib_cache)
# ---------------------------------------------------------------------------

def test_cli_list_and_stats(warm, tmp_path, capsys):
    from repro.runtime.calib_cache import main as cli
    assert cli(["--root", str(tmp_path), "--list"]) == 0
    out = capsys.readouterr().out
    assert "dev" in out and table_key(CFG, P) in out and FORMAT in out
    assert cli(["--root", str(tmp_path), "--stats"]) == 0
    out = capsys.readouterr().out
    assert "devices          1" in out
    assert "table entries    1" in out


def test_cli_evict_and_empty(warm, tmp_path, capsys):
    from repro.runtime.calib_cache import main as cli
    assert cli(["--root", str(tmp_path), "--evict", "dev"]) == 0
    assert "evicted 1 table(s)" in capsys.readouterr().out
    assert cli(["--root", str(tmp_path), "--list"]) == 0
    assert "no cache entries" in capsys.readouterr().out
    # missing root reads as empty, not an error
    assert cli(["--root", str(tmp_path / "nope"), "--stats"]) == 0
    assert "table entries    0" in capsys.readouterr().out


def test_cli_requires_exactly_one_action(tmp_path):
    from repro.runtime.calib_cache import main as cli
    with pytest.raises(SystemExit):
        cli(["--root", str(tmp_path)])
    with pytest.raises(SystemExit):
        cli(["--root", str(tmp_path), "--list", "--stats"])


# ---------------------------------------------------------------------------
# Placement serialization versions (the bit-packed refactor bumped the
# placement format to v2 — block-aligned windows; PR-2/PR-3-era v1 archives
# must keep loading through the upgrade path, unknown versions miss)
# ---------------------------------------------------------------------------

def _v1_placement_npz(path, plan, masks):
    """Re-serialize a Placement in the PR-2 (pud-placement-v1) archive
    layout: one physical span per slice, ``region_start``/``region_size``
    instead of block structure."""
    flat = np.asarray(masks, bool).reshape(-1)
    arrays = {"used": np.asarray(plan.used_per_subarray, np.int32),
              "usable": np.asarray(plan.usable_per_subarray, np.int32)}
    region_sizes = []
    for i, name in enumerate(plan.entries):
        tp = plan.entries[name]
        phys = np.atleast_2d(np.asarray(tp.phys_cols, np.int64))
        starts = phys[:, 0]
        region = int((phys[:, -1] - phys[:, 0] + 1).max())
        region_sizes.append(region)
        faulty = np.zeros((phys.shape[0], region), bool)
        stuck = np.zeros((phys.shape[0], region), np.int8)
        for s, r0 in enumerate(starts):
            window = np.arange(r0, r0 + region)
            in_dev = window < flat.size
            faulty[s, in_dev] = flat[window[in_dev]]
            stuck[s, in_dev] = (window[in_dev] % 2).astype(np.int8)
        if np.asarray(tp.phys_cols).ndim == 1:
            arrays[f"e{i}_phys"] = np.asarray(tp.phys_cols, np.int32)
            arrays[f"e{i}_start"] = np.int32(starts[0])
            arrays[f"e{i}_faulty"] = faulty[0]
            arrays[f"e{i}_stuck"] = stuck[0]
        else:
            arrays[f"e{i}_phys"] = np.asarray(tp.phys_cols, np.int32)
            arrays[f"e{i}_start"] = starts.astype(np.int32)
            arrays[f"e{i}_faulty"] = faulty
            arrays[f"e{i}_stuck"] = stuck
    meta = {"format": "pud-placement-v1",
            "names": list(plan.entries),
            "region_sizes": region_sizes,
            "grid_shape": list(plan.grid_shape),
            "n_cols_per_subarray": plan.n_cols_per_subarray,
            "avoid_faulty": plan.avoid_faulty}
    arrays["meta"] = np.array(json.dumps(meta))
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def test_v1_placement_archive_upgrades_on_load(tmp_path):
    """A PR-2/PR-3 placement .npz loads into the block-aligned v2 layout
    and serves bit-identically to a freshly planned placement."""
    import jax.numpy as jnp
    from repro.pud.gemv import PUDGemvConfig, pud_linear
    from repro.pud.packer import pack_model, packing_requests
    from repro.pud.placement import (PlacementRequest, load_placement_npz,
                                     plan_placement, save_placement_npz)
    rng = np.random.default_rng(3)
    masks = rng.random((4, 512)) < 0.25
    params = {"m": {"wi": 0.05 * np.asarray(
        rng.standard_normal((64, 96)), np.float32)},
        "s": {"wi": 0.05 * np.asarray(
            rng.standard_normal((2, 64, 96)), np.float32)}}
    params = jax.tree_util.tree_map(jnp.asarray, params)
    cfg = PUDGemvConfig(packable=("wi",))
    reqs = packing_requests(params, cfg, include_unembed=False)
    plan = plan_placement(masks, reqs)

    v1 = tmp_path / "m0_v1.npz"
    _v1_placement_npz(v1, plan, masks)
    up = load_placement_npz(v1)
    assert up is not None
    for name in plan.entries:
        tp, utp = plan.entries[name], up.entries[name]
        np.testing.assert_array_equal(np.asarray(utp.phys_cols),
                                      np.asarray(tp.phys_cols))
        assert utp.block_cols == tp.block_cols
        assert utp.window_block == tp.window_block
        np.testing.assert_array_equal(utp.block_starts, tp.block_starts)
        np.testing.assert_array_equal(utp.faulty, tp.faulty)
    assert up.capacity_report() == plan.capacity_report()

    # packs built from the upgraded placement serve bit-identically
    placed = pack_model(params, cfg, include_unembed=False, placement=up)
    logical = pack_model(params, cfg, include_unembed=False)
    x = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(pud_linear(x, placed.tensor("m/wi"))),
        np.asarray(pud_linear(x, logical.tensor("m/wi"))))

    # v2 round-trip and unknown-version miss
    v2 = tmp_path / "m0_v2.npz"
    save_placement_npz(v2, plan)
    got = load_placement_npz(v2)
    assert got is not None
    assert got.entries["m/wi"].window_block == plan.entries["m/wi"].window_block
    bad = tmp_path / "bad.npz"
    meta = {"format": "pud-placement-v99", "names": []}
    np.savez(bad, meta=np.array(json.dumps(meta)))
    assert load_placement_npz(bad) is None


def test_v1_placement_in_cache_reads_as_hit(warm, tmp_path):
    """The cache's load_placement path accepts a v1 archive sitting in a
    warm table's placements/ dir (old caches keep their plans)."""
    from repro.pud.placement import PlacementRequest, plan_placement
    cache, entry, (_, _, masks) = warm
    plan = plan_placement(masks, [PlacementRequest("unembed/w", 48, 0)])
    d = entry / "placements"
    d.mkdir(exist_ok=True)
    _v1_placement_npz(d / "legacy.npz", plan, masks)
    got = cache.load_placement("dev", CFG, P, "legacy")
    assert got is not None
    np.testing.assert_array_equal(
        np.asarray(got.entries["unembed/w"].phys_cols),
        np.asarray(plan.entries["unembed/w"].phys_cols))
