"""Calibration-cache failure paths: every corruption/mismatch mode must read
as a miss and fall back to recalibration — never raise, never serve a stale
or torn table."""
import json

import jax
import numpy as np
import pytest

from repro.core.calibrate import CalibrationConfig
from repro.core.fleet import FleetConfig, load_or_calibrate
from repro.pud.physics import PhysicsParams
from repro.runtime.calib_cache import (FORMAT, CalibrationTableCache,
                                       params_fingerprint, table_key)

P = PhysicsParams()
CFG = FleetConfig(n_channels=1, n_banks=1, n_subarrays=2, n_cols=128)
CAL = CalibrationConfig(n_iterations=4, n_samples=64)
KEY = jax.random.key(41)


@pytest.fixture
def warm(tmp_path):
    """A cache warmed by one real load_or_calibrate miss."""
    cache = CalibrationTableCache(tmp_path)
    levels, ecr, masks, hit = load_or_calibrate(
        cache, "dev", KEY, CFG, P, CAL, n_trials_ecr=128)
    assert not hit
    entry = tmp_path / "dev" / table_key(CFG, P)
    assert (entry / "manifest.json").exists()
    return cache, entry, (np.asarray(levels), np.asarray(ecr),
                          np.asarray(masks))


def _reload(cache):
    return load_or_calibrate(cache, "dev", KEY, CFG, P, CAL,
                             n_trials_ecr=128)


def test_warm_hit_is_deterministic(warm):
    cache, _, (levels, ecr, masks) = warm
    lv, e, m, hit = _reload(cache)
    assert hit
    np.testing.assert_array_equal(np.asarray(lv), levels)
    np.testing.assert_allclose(np.asarray(e), ecr)
    np.testing.assert_array_equal(np.asarray(m), masks)


def test_torn_levels_fall_back_to_recalibration(warm):
    cache, entry, (levels, _, _) = warm
    payload = entry / "levels.npy"
    payload.write_bytes(payload.read_bytes()[:40])    # truncated mid-write
    assert cache.load("dev", CFG, P) is None          # miss, not a raise
    lv, _, _, hit = _reload(cache)
    assert not hit                                    # recalibrated ...
    np.testing.assert_array_equal(np.asarray(lv), levels)  # ... same result
    assert cache.load("dev", CFG, P) is not None      # and re-persisted


def test_corrupt_manifest_falls_back(warm):
    cache, entry, _ = warm
    (entry / "manifest.json").write_text("{not json")
    assert cache.load("dev", CFG, P) is None
    _, _, _, hit = _reload(cache)
    assert not hit


def test_version_mismatch_falls_back(warm):
    """A format bump must invalidate old entries instead of misreading."""
    cache, entry, _ = warm
    manifest = json.loads((entry / "manifest.json").read_text())
    assert manifest["format"] == FORMAT
    manifest["format"] = "fleet-calib-v1"             # pre-masks era
    (entry / "manifest.json").write_text(json.dumps(manifest))
    assert cache.load("dev", CFG, P) is None
    _, _, _, hit = _reload(cache)
    assert not hit
    # the recalibration re-saved under the current format
    got = json.loads((entry / "manifest.json").read_text())
    assert got["format"] == FORMAT


def test_fingerprint_mismatch_falls_back(warm):
    """Changed physics constants can never silently reuse a stale table."""
    cache, entry, _ = warm
    manifest = json.loads((entry / "manifest.json").read_text())
    manifest["params_fingerprint"] = "0" * 12
    (entry / "manifest.json").write_text(json.dumps(manifest))
    assert manifest["params_fingerprint"] != params_fingerprint(P)
    assert cache.load("dev", CFG, P) is None
    _, _, _, hit = _reload(cache)
    assert not hit


def test_missing_masks_treated_as_miss(warm):
    """v2 tables without masks can't drive placement: re-identify."""
    cache, entry, _ = warm
    (entry / "masks.npy").unlink()
    table = cache.load("dev", CFG, P)
    assert table is not None and table.masks is None  # load is lenient ...
    _, _, masks, hit = _reload(cache)
    assert not hit and masks is not None              # ... the glue is not


def test_wrong_shape_masks_treated_as_missing(warm):
    cache, entry, _ = warm
    np.save(entry / "masks.npy", np.zeros((1, 3), bool))
    table = cache.load("dev", CFG, P)
    assert table is not None and table.masks is None


def test_evict_then_recalibrate(warm):
    cache, entry, _ = warm
    assert cache.evict("dev") == 1
    assert cache.load("dev", CFG, P) is None
    assert cache.evict("dev") == 0                    # idempotent
    _, _, _, hit = _reload(cache)
    assert not hit
    assert len(cache.entries()) == 1


def test_crashed_staging_dir_swept_on_save(warm, tmp_path):
    cache, entry, _ = warm
    torn = entry.with_name(entry.name + ".tmp-9999")
    torn.mkdir()
    (torn / "levels.npy").write_bytes(b"garbage")
    assert len(cache.entries()) == 1                  # staging is invisible
    lv, ecr, masks, hit = _reload(cache)
    assert hit                                        # real entry untouched
    cache.save("dev", CFG, P, np.asarray(lv), ecr=np.asarray(ecr),
               masks=np.asarray(masks))
    assert not torn.exists()                          # gc on the next save


# ---------------------------------------------------------------------------
# CLI (python -m repro.runtime.calib_cache)
# ---------------------------------------------------------------------------

def test_cli_list_and_stats(warm, tmp_path, capsys):
    from repro.runtime.calib_cache import main as cli
    assert cli(["--root", str(tmp_path), "--list"]) == 0
    out = capsys.readouterr().out
    assert "dev" in out and table_key(CFG, P) in out and FORMAT in out
    assert cli(["--root", str(tmp_path), "--stats"]) == 0
    out = capsys.readouterr().out
    assert "devices          1" in out
    assert "table entries    1" in out


def test_cli_evict_and_empty(warm, tmp_path, capsys):
    from repro.runtime.calib_cache import main as cli
    assert cli(["--root", str(tmp_path), "--evict", "dev"]) == 0
    assert "evicted 1 table(s)" in capsys.readouterr().out
    assert cli(["--root", str(tmp_path), "--list"]) == 0
    assert "no cache entries" in capsys.readouterr().out
    # missing root reads as empty, not an error
    assert cli(["--root", str(tmp_path / "nope"), "--stats"]) == 0
    assert "table entries    0" in capsys.readouterr().out


def test_cli_requires_exactly_one_action(tmp_path):
    from repro.runtime.calib_cache import main as cli
    with pytest.raises(SystemExit):
        cli(["--root", str(tmp_path)])
    with pytest.raises(SystemExit):
        cli(["--root", str(tmp_path), "--list", "--stats"])
