"""PUDSession facade + typed packs + backend registry: the public API that
owns the calibrate -> cache -> place -> pack -> execute chain."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CalibrationConfig, FleetConfig, PackedModel,
                       PackedTensor, PUDGemvConfig, PUDSession,
                       as_packed_tensor, backend_names, get_backend,
                       pack_model, packed_bytes)

SMALL_CALIB = CalibrationConfig(n_iterations=4, n_samples=64)


def _params(key=0, k=64, n=128, n_unembed=256, stacked=0):
    kw = jax.random.split(jax.random.key(key), 4)
    shape = (stacked, k, n) if stacked else (k, n)

    def w(i, s):
        return 0.05 * jax.random.normal(kw[i], s, jnp.float32)

    return {
        "layers_0": {"mixer": {"wi": w(0, shape),
                               "wo": w(1, shape[:-2] + (n, k))}},
        "unembed": {"w": w(2, (k, n_unembed))},
        "embed": {"w": w(3, (8, k))},
    }


CFG = PUDGemvConfig(weight_bits=4, packable=("mixer.wi", "mixer.wo"))


def _session(tmp_path=None, **kw):
    kw.setdefault("grid", FleetConfig(n_channels=1, n_banks=1,
                                      n_subarrays=4, n_cols=256))
    kw.setdefault("calib", SMALL_CALIB)
    kw.setdefault("n_trials_ecr", 128)
    kw.setdefault("key", 7)
    return PUDSession.open(
        cache_dir=None if tmp_path is None else tmp_path, **kw)


# ---------------------------------------------------------------------------
# Typed packs
# ---------------------------------------------------------------------------

def test_packed_tensor_mapping_protocol_and_pytree():
    pt = PackedTensor(planes=jnp.zeros((4, 8, 16), jnp.int8),
                      scale=jnp.ones((16,), jnp.float32))
    assert not pt.placed
    assert pt["planes"].shape == (4, 8, 16)
    assert pt.get("col_ids") is None
    assert "col_ids" not in pt and "scale" in pt
    assert set(pt.keys()) == {"planes", "scale"}
    with pytest.raises(KeyError):
        pt["col_ids"]
    with pytest.raises(KeyError):
        pt["planes_typo"]
    # pytree: jit/tree_map round-trip, None col_ids preserved
    mapped = jax.tree_util.tree_map(lambda x: x + 0, pt)
    assert isinstance(mapped, PackedTensor) and mapped.col_ids is None
    out = jax.jit(lambda p: p.planes.sum() + p.scale.sum())(pt)
    assert float(out) == 16.0
    # legacy dict coercion
    legacy = {"planes": pt.planes, "scale": pt.scale}
    assert isinstance(as_packed_tensor(legacy), PackedTensor)
    assert as_packed_tensor(pt) is pt


def test_packed_tensor_scan_slices_like_dict_packs():
    pt = PackedTensor(planes=jnp.arange(2 * 4 * 8 * 16, dtype=jnp.int8)
                      .reshape(2, 4, 8, 16),
                      scale=jnp.ones((2, 16), jnp.float32),
                      col_ids=jnp.tile(jnp.arange(16, dtype=jnp.int32),
                                       (2, 1)))

    def body(carry, p):
        return carry + p.planes.astype(jnp.int32).sum(), p.col_ids.sum()

    total, ys = jax.lax.scan(body, jnp.int32(0), pt)
    assert int(total) == int(pt.planes.astype(jnp.int32).sum())
    assert ys.shape == (2,)


def test_pack_model_typed_and_legacy_views():
    pm = pack_model(_params(), CFG)
    assert isinstance(pm, PackedModel)
    assert set(pm.packed_names) == {"layers_0/mixer/wi", "layers_0/mixer/wo",
                                    "unembed/w"}
    assert pm.report["packed"] == list(pm.packed_names)
    assert not pm.placed
    # flat tensor view + suffix lookup
    assert set(pm.tensors) == set(pm.packed_names)
    assert pm.tensor("unembed/w") is not None
    # default pack output is bit-packed: 8 K rows per uint8 word
    wi = pm.tensor("mixer/wi")
    assert wi.planes.shape == (4, 8, 128) and wi.planes.dtype == jnp.uint8
    assert wi.bitpacked and wi.k == 64 and wi.n == 128
    assert wi.stored_bytes < wi.dense_equiv_bytes / 4
    with pytest.raises(KeyError, match="not found"):
        pm.tensor("nope/w")
    # embed untouched, fp weight dropped from packed projections
    assert "w" in pm.params["embed"]
    assert "wi" not in pm.params["layers_0"]["mixer"]
    sizes = packed_bytes(pm)
    assert sizes["pud_bytes"] > 0
    # PackedModel is a pytree: metadata rides aux, params are leaves
    mapped = jax.tree_util.tree_map(lambda x: x, pm)
    assert isinstance(mapped, PackedModel)
    assert mapped.packed_names == pm.packed_names


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

def test_registry_contains_required_backends():
    names = backend_names()
    for required in ("pallas", "reference", "interpret"):
        assert required in names
    with pytest.raises(KeyError, match="unknown backend"):
        get_backend("cuda")


def test_session_rejects_unknown_backend():
    with pytest.raises(KeyError, match="unknown backend"):
        PUDSession.open(backend="not-a-backend")


# ---------------------------------------------------------------------------
# Session lifecycle
# ---------------------------------------------------------------------------

def test_session_calibrate_miss_then_hit(tmp_path):
    s1 = _session(tmp_path)
    st1 = s1.calibrate()
    assert not st1.cache_hit
    assert st1.masks.shape == (4, 256)
    assert s1.calibrate() is st1          # memoized
    # a fresh session on the same cache dir hits the persisted table
    s2 = _session(tmp_path)
    st2 = s2.calibrate()
    assert st2.cache_hit
    np.testing.assert_array_equal(np.asarray(st2.levels),
                                  np.asarray(st1.levels))
    assert st2.mean_ecr == pytest.approx(st1.mean_ecr)


def test_session_pack_places_and_persists(tmp_path):
    s1 = _session(tmp_path)
    s1.calibrate()
    pm = s1.pack(_params(), CFG, name="toy")
    assert pm.placed and s1.placement_status == "planned"
    assert s1.placement_name.startswith("toy-")
    for pt in pm.tensors.values():
        assert pt.placed
    # second session: placement comes back from the cache, packs identical
    s2 = _session(tmp_path)
    s2.calibrate()
    pm2 = s2.pack(_params(), CFG, name="toy")
    assert s2.placement_status == "hit"
    np.testing.assert_array_equal(
        np.asarray(pm2.tensor("unembed/w").col_ids),
        np.asarray(pm.tensor("unembed/w").col_ids))


def test_session_uncalibrated_packs_logical():
    s = _session()
    pm = s.pack(_params(), CFG)
    assert not pm.placed and s.placement_status is None
    assert s.placement is None


def test_session_capacity_overflow_skips_placement(tmp_path):
    s = _session(tmp_path, grid=FleetConfig(n_channels=1, n_banks=1,
                                            n_subarrays=1, n_cols=128))
    s.calibrate()
    pm = s.pack(_params(), CFG)            # demand 512 > 128 cols
    assert s.placement_status == "skipped"
    assert "exceeds usable capacity" in s.placement_error
    assert not pm.placed                   # served on logical columns


def test_session_linear_requires_pack():
    s = _session()
    with pytest.raises(RuntimeError, match="pack"):
        s.linear(jnp.zeros((2, 64)), "unembed/w")
    with pytest.raises(RuntimeError, match="pack"):
        s.decode_extras()


# ---------------------------------------------------------------------------
# Backend parity (acceptance: bit-exact through the session API, placed
# and logical layouts)
# ---------------------------------------------------------------------------

def _assert_parity(session):
    for name in session.packed.packed_names:
        k = session.packed.tensor(name).k
        x = jax.random.normal(jax.random.key(3), (5, k), jnp.float32)
        outs = {be: np.asarray(session.linear(x, name, backend=be))
                for be in backend_names()}
        ref = outs.pop("reference")
        assert ref.shape == (5, session.packed.tensor(name).scale.shape[-1])
        for be, got in outs.items():
            np.testing.assert_array_equal(
                got, ref, err_msg=f"{be} != reference on {name}")


def test_backend_parity_logical_layout():
    s = _session()
    s.pack(_params(), CFG)
    _assert_parity(s)


def test_backend_parity_placed_layout(tmp_path):
    s = _session(tmp_path)
    s.calibrate()
    s.pack(_params(), CFG)
    assert s.packed.placed
    _assert_parity(s)


def test_session_backend_reaches_model_dispatch(monkeypatch):
    """Model forwards call pud_linear(x, pack) with the default config; the
    session's backend choice must still win there, via the pack stamp."""
    import repro.kernels.ops as ops
    from repro.pud.gemv import pud_linear
    s = _session(backend="reference")
    pm = s.pack(_params(), CFG)
    assert pm.tensor("unembed/w").backend == "reference"
    # the stamp survives pytree ops (it is aux data, not a leaf)
    mapped = jax.tree_util.tree_map(lambda x: x, pm.tensor("unembed/w"))
    assert mapped.backend == "reference"
    seen = []
    real = ops.get_backend
    monkeypatch.setattr(ops, "get_backend",
                        lambda name: (seen.append(name), real(name))[1])
    x = jnp.zeros((2, 64), jnp.float32)
    pud_linear(x, pm.tensor("unembed/w"))          # model-dispatch shape
    assert seen == ["reference"]
    pud_linear(x, pm.tensor("unembed/w"), backend="interpret")
    assert seen[-1] == "interpret"                 # per-call override wins


def test_placed_linear_matches_logical_linear(tmp_path):
    placed = _session(tmp_path)
    placed.calibrate()
    placed.pack(_params(), CFG)
    logical = _session()
    logical.pack(_params(), CFG)
    for name in placed.packed.packed_names:
        k = placed.packed.tensor(name).k
        x = jax.random.normal(jax.random.key(5), (3, k), jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(placed.linear(x, name)),
            np.asarray(logical.linear(x, name)))


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

def test_perf_report_and_decode_extras(tmp_path):
    s = _session(tmp_path)
    s.calibrate()
    s.pack(_params(), CFG)
    rep = s.perf_report(flops_per_token=2e9)
    assert rep["calibrated"] and rep["cache_hit"] is False
    assert 0 <= rep["mean_ecr"] < 0.5
    assert rep["tuned_tok_s"] > rep["baseline_tok_s"] > 0
    assert rep["gain"] == pytest.approx(
        rep["tuned_tok_s"] / rep["baseline_tok_s"])
    assert rep["placement"]["occupancy"] > 0
    assert rep["placed_tok_s"] > 0
    # traffic terms: staging ceiling from the actual stored (bit-packed)
    # bytes, and the combined-limit rate never exceeds either bound
    assert rep["weight_bytes_per_token"] == packed_bytes(s.packed)[
        "stored_bytes"]
    assert rep["staging_bound_tok_s"] > 0
    assert rep["traffic_aware_tok_s"] == pytest.approx(
        min(rep["tuned_tok_s"], rep["staging_bound_tok_s"]))
    extras = s.decode_extras()
    assert extras["layout"] == "placed physical"
    assert extras["n_packed"] == 3
    assert extras["pud_bytes"] > 0
    assert extras["report"] == s.packed.report


def test_perf_report_uncalibrated_falls_back_to_table1():
    s = _session()
    rep = s.perf_report(flops_per_token=2e9)
    assert not rep["calibrated"] and rep["mean_ecr"] is None
    # T210 vs B300 Table-I points -> the paper's headline gain
    assert rep["gain"] == pytest.approx(1.81, abs=0.01)


def test_at_operating_point_matches_perf_model():
    from repro.pud.gemv import PUDPerfModel
    s = PUDSession.at_operating_point(0.033)
    want = PUDPerfModel(error_free_frac=1 - 0.033).tokens_per_second(2e9)
    assert s.tokens_per_second(2e9) == pytest.approx(want)


def test_session_arch_gives_flops():
    s = PUDSession.open("qwen3-1.7b", grid=FleetConfig())
    assert s.flops_per_token() > 1e9
    assert "tuned_tok_s" in s.perf_report()
    with pytest.raises(ValueError, match="flops_per_token"):
        _session().tokens_per_second()
