"""Shard-boundary invariant: model-shard column splits land on window-block
boundaries (no placement window ever straddles two devices), the contract
checker trips on every adversarial split, and the cross-shard perf
aggregate prices imbalance the way the split creates it."""
import numpy as np
import pytest

from repro.analysis import ContractViolation, contracts
from repro.pud.gemv import FleetPerfAggregate, FleetPerfModel
from repro.pud.packer import pack_linear_sharded
from repro.pud.placement import (PLACE_BLOCK, PlacementError,
                                 PlacementRequest, plan_placement,
                                 shard_column_slices)


# ---------------------------------------------------------------------------
# shard_column_slices: block-aligned spans for divisible and ragged N.
# ---------------------------------------------------------------------------


def test_even_split_on_place_block():
    spans, bc = shard_column_slices(1024, 4)
    assert bc == PLACE_BLOCK
    assert spans == ((0, 256), (256, 512), (512, 768), (768, 1024))
    contracts.check_shard_slices(spans, 1024, bc)


def test_non_divisible_n_uses_full_tensor_block_width():
    # 384 has no 256 divisor: the unsharded allocator picks block_cols=192,
    # and the shard split must respect the same width (2 blocks, 3 shards
    # -> the last shard serves pure padding).
    spans, bc = shard_column_slices(384, 3)
    assert bc == 192
    assert spans == ((0, 192), (192, 384), (384, 384))
    contracts.check_shard_slices(spans, 384, bc)


def test_remainder_blocks_go_to_earlier_shards():
    spans, bc = shard_column_slices(1536, 4)
    assert bc == PLACE_BLOCK                    # 6 blocks over 4 shards
    widths = tuple(hi - lo for lo, hi in spans)
    assert widths == (512, 512, 256, 256)
    contracts.check_shard_slices(spans, 1536, bc)


def test_more_shards_than_blocks_yields_zero_width_tails():
    spans, bc = shard_column_slices(256, 4)
    assert bc == 256
    assert spans == ((0, 256), (256, 256), (256, 256), (256, 256))
    contracts.check_shard_slices(spans, 256, bc)


def test_rejects_nonpositive_inputs():
    with pytest.raises(PlacementError):
        shard_column_slices(0, 2)
    with pytest.raises(PlacementError):
        shard_column_slices(512, 0)


# ---------------------------------------------------------------------------
# check_shard_slices: every adversarial split trips "shard-straddle".
# ---------------------------------------------------------------------------


ADVERSARIAL = [
    ("mid-block boundary", ((0, 200), (200, 512)), 512, 256),
    ("gap between shards", ((0, 256), (512, 1024)), 1024, 256),
    ("short coverage", ((0, 256), (256, 512)), 1024, 256),
    ("overshoot", ((0, 256), (256, 1280)), 1024, 256),
    ("negative span", ((0, 256), (256, 128)), 512, 256),
    ("block does not tile n", ((0, 300), (300, 600)), 600, 256),
]


@pytest.mark.parametrize("name,spans,n,bc", ADVERSARIAL,
                         ids=[a[0].replace(" ", "-") for a in ADVERSARIAL])
def test_adversarial_split_trips_shard_straddle(name, spans, n, bc):
    with pytest.raises(ContractViolation) as exc:
        contracts.check_shard_slices(spans, n, bc)
    assert exc.value.invariant == "shard-straddle", name
    assert exc.value.kernel == "sharded_gemm"


# ---------------------------------------------------------------------------
# The planner rejects a forced block width that would straddle, and the
# sharded packer's per-shard geometry matches the split it came from.
# ---------------------------------------------------------------------------


def test_forced_block_cols_must_divide_n_cols():
    masks = np.zeros((4, 1024), bool)
    bad = PlacementRequest("w", n_cols=384, block_cols=256)
    with pytest.raises(PlacementError):
        plan_placement(masks, [bad])
    # the width shard_column_slices derives is accepted
    _, bc = shard_column_slices(384, 2)
    plan_placement(masks, [PlacementRequest("w", n_cols=192,
                                            block_cols=bc)])


def test_pack_linear_sharded_geometry_matches_split():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(64, 1536)).astype(np.float32)
    st = pack_linear_sharded(w, 4, backend="reference")
    spans, bc = shard_column_slices(1536, 4)
    assert st.block_cols == bc
    assert st.shard_widths == tuple(hi - lo for lo, hi in spans)
    assert sum(st.shard_widths) == 1536
    # common per-device width = the widest shard; planes/scale stack on S
    n_max = max(st.shard_widths)
    assert st.planes.shape[0] == 4 and st.planes.shape[-1] == n_max
    assert st.scale.shape == (4, n_max)
    # padding columns carry neutral scale so they decode to exact zeros
    np.testing.assert_array_equal(
        np.asarray(st.scale[2, st.shard_widths[2]:]), 1.0)


# ---------------------------------------------------------------------------
# FleetPerfAggregate: the slowest/widest shard bounds the lane rate.
# ---------------------------------------------------------------------------


def _shard(ecr=0.03):
    return FleetPerfModel.from_table([ecr, ecr])


def test_even_split_scales_linearly():
    agg = FleetPerfAggregate((_shard(), _shard()), n_data=2,
                             shard_widths=(512, 512))
    assert agg.n_devices == 4
    assert agg.shard_fraction == pytest.approx(0.5)
    f = 2.0e9
    assert agg.tokens_per_second(f) == pytest.approx(
        4 * _shard().tokens_per_second(f), rel=1e-9)
    assert agg.scaling_efficiency(f) == pytest.approx(1.0, rel=1e-9)


def test_imbalanced_split_prices_widest_shard():
    agg = FleetPerfAggregate((_shard(), _shard()), n_data=1,
                             shard_widths=(768, 256))
    assert agg.shard_fraction == pytest.approx(0.75)
    # the 0.75-share shard bounds the lane: 2 devices deliver 4/3x, not 2x
    assert agg.scaling_efficiency(2.0e9) == pytest.approx(2 / 3, rel=1e-9)


def test_zero_width_tail_shard_is_pure_overhead():
    agg = FleetPerfAggregate((_shard(), _shard()), n_data=1,
                             shard_widths=(256, 0))
    assert agg.shard_fraction == pytest.approx(1.0)
    assert agg.scaling_efficiency(2.0e9) == pytest.approx(0.5, rel=1e-9)


def test_slowest_shard_binds_the_lane():
    fast, slow = _shard(0.01), _shard(0.20)
    agg = FleetPerfAggregate((fast, slow), n_data=1,
                             shard_widths=(512, 512))
    f = 2.0e9
    assert agg.tokens_per_second(f) == pytest.approx(
        slow.tokens_per_second(f * 0.5), rel=1e-9)
