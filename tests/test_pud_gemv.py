"""PUD GeMV serving path: packing, kernel numerics, model integration,
performance model coupling (Eq. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import bitplane_gemv, quantize_activations
from repro.pud.gemv import (PUDGemvConfig, PUDPerfModel, pack_linear,
                            pud_linear, pud_linear_ref)
from repro.pud.packer import pack_for_serving, packed_bytes


# ---------------------------------------------------------------------------
# Bit-plane packing + kernel numerics (shape/dtype sweeps vs ref oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,n,wb", [
    (1, 64, 128, 2), (4, 256, 256, 4), (8, 512, 256, 4), (2, 128, 512, 8),
    (3, 64, 64, 3),
])
@pytest.mark.parametrize("mode", ["planes", "folded"])
def test_bitplane_gemv_matches_ref(b, k, n, wb, mode):
    kx, kw = jax.random.split(jax.random.key(b * 1000 + k + n + wb))
    x = jax.random.randint(kx, (b, k), -127, 128, jnp.int32).astype(jnp.int8)
    w = jax.random.randint(kw, (k, n), -(1 << (wb - 1)), 1 << (wb - 1),
                           jnp.int32)
    planes = ref.pack_bitplanes(w, wb)
    got = bitplane_gemv(x, planes, mode=mode)
    want = ref.bitplane_gemv_ref(x, planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the oracle equals the plain integer matmul
    direct = x.astype(jnp.int32) @ w
    np.testing.assert_array_equal(np.asarray(want), np.asarray(direct))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), wb=st.integers(2, 8))
def test_pack_bitplanes_roundtrip(seed, wb):
    w = jax.random.randint(jax.random.key(seed), (32, 64),
                           -(1 << (wb - 1)), 1 << (wb - 1), jnp.int32)
    planes = ref.pack_bitplanes(w, wb)
    assert planes.shape == (wb, 32, 64)
    assert set(np.unique(np.asarray(planes))) <= {0, 1}
    recon = sum((planes[b].astype(np.int32) << b) for b in range(wb)) \
        - (1 << (wb - 1))
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(w))


def test_pud_gemv_dequant_close_to_float():
    """Float-in/float-out wrapper: error bounded by int8 x int4 quantization."""
    kx, kw = jax.random.split(jax.random.key(0))
    x = jax.random.normal(kx, (4, 256), jnp.float32)
    w = 0.05 * jax.random.normal(kw, (256, 128), jnp.float32)
    packed = pack_linear(w, 4)
    y = pud_linear(x, packed)
    y_ref = pud_linear_ref(x, w, 4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # against the exact float matmul: bounded relative error
    exact = x @ w
    rel = float(jnp.abs(y - exact).mean() / jnp.abs(exact).mean())
    assert rel < 0.2, rel


def test_quantize_activations_bounds():
    x = jax.random.normal(jax.random.key(1), (8, 64)) * 5
    q, scale = quantize_activations(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(
        np.asarray(q.astype(jnp.float32) * scale), np.asarray(x),
        atol=float(scale.max()) * 0.51)


# ---------------------------------------------------------------------------
# Model integration: pack_for_serving + layers.linear dispatch
# ---------------------------------------------------------------------------

def test_pack_for_serving_swaps_ffn_and_unembed():
    from repro.configs import get
    from repro.models.params import init_params
    model = get("granite-8b").make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    packed, report = pack_for_serving(params, PUDGemvConfig(weight_bits=4))
    assert "unembed/w" in report["packed"]
    assert any("mixer" in p for p in report["packed"])
    layer_key = next(k for k in packed if k.startswith("layers_"))
    assert "wi_pud" in packed[layer_key]["mixer"]
    assert "wi" not in packed[layer_key]["mixer"]
    sizes = packed_bytes(packed)
    assert sizes["pud_bytes"] > 0

    # decode through the packed path stays close to the bf16 path
    toks = jax.random.randint(jax.random.key(2), (2, 8), 0,
                              model.cfg.vocab, jnp.int32)
    logits_ref, cache_ref = model.prefill(params, toks, max_len=12)
    logits_pud, cache_pud = model.prefill(packed, toks, max_len=12)
    assert logits_pud.shape == logits_ref.shape
    assert not bool(jnp.isnan(logits_pud).any())
    # greedy tokens mostly agree (4-bit quantization of random weights)
    agree = float((jnp.argmax(logits_pud, -1)
                   == jnp.argmax(logits_ref, -1)).mean())
    assert agree >= 0.5, agree

    nxt = jnp.argmax(logits_pud, -1).astype(jnp.int32)[:, None]
    step_logits, _ = model.decode_step(packed, cache_pud, nxt, jnp.int32(8))
    assert step_logits.shape == (2, model.cfg.vocab)
    assert not bool(jnp.isnan(step_logits).any())


def test_moe_experts_left_unpacked():
    from repro.configs import get
    from repro.models.params import init_params
    model = get("deepseek-v2-lite-16b").make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    packed, report = pack_for_serving(params)
    moe_key = next(k for k in packed if k.endswith("_moe"))
    # routed expert banks keep the bf16 path (documented scope)
    assert "wi" in packed[moe_key]["mixer"]
    assert any("mixer/shared" in p or "mixer" in p for p in report["packed"])


# ---------------------------------------------------------------------------
# Performance model (Eq. 1 coupling)
# ---------------------------------------------------------------------------

def test_perf_model_scales_with_error_free_fraction():
    base = PUDPerfModel(error_free_frac=0.534)
    tune = PUDPerfModel(error_free_frac=0.967)
    assert tune.speedup_vs(base) == pytest.approx(0.967 / 0.534)
    assert tune.gemv_latency_s(4096, 4096) > 0
    # tokens/s inversely proportional to model size
    assert (tune.tokens_per_second(2 * 1e9)
            == pytest.approx(10 * tune.tokens_per_second(2 * 1e10)))
