"""MAJX generalization (paper Sec. III-D): ladders/calibration/ECR for
arbitrary input counts under the 8-row SiMRA budget."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibrate import CalibrationConfig, identify_calibration
from repro.core.ecr import measure_ecr_majx
from repro.core.offsets import levels_to_charges, make_ladder
from repro.pud.physics import PhysicsParams

P = PhysicsParams()


def test_single_row_ladder_structure():
    lad = make_ladder((1,), P)
    assert lad.n_rows == 1
    assert lad.n_levels == 2
    o = np.asarray(lad.offsets_units)
    np.testing.assert_allclose(o, [-0.5 * P.frac_alpha, 0.5 * P.frac_alpha])
    ch = levels_to_charges(lad, jnp.array([0, 1, 1], jnp.int32), P)
    assert ch.shape == (1, 3)


def test_four_row_ladder():
    lad = make_ladder((3, 2, 1, 0), P)
    assert lad.n_rows == 4 and lad.n_levels == 16
    o = np.asarray(lad.offsets_units)
    assert (np.diff(o) > 0).all()
    np.testing.assert_allclose(o, -o[::-1], atol=1e-9)


@pytest.mark.parametrize("x,fc,const", [
    (3, (2, 1, 0), (1.0, 2.0)),
    (7, (1,), (0.0, 0.0)),
])
def test_majx_calibration_reduces_ecr(x, fc, const):
    n = 4096
    k_m, k_c, k_b, k_t = jax.random.split(jax.random.key(x), 4)
    sense = P.sigma_static * jax.random.normal(k_m, (n,), jnp.float32)
    lad = make_ladder(fc, P)
    from benchmarks.majx_general import _neutral_charges
    base, _ = measure_ecr_majx(
        k_b, sense, _neutral_charges(fc, n, P), P, sum(fc), x, *const,
        n_trials=2048)
    levels = identify_calibration(
        k_c, sense, lad, P,
        CalibrationConfig(n_iterations=20, n_samples=256, maj_inputs=x,
                          const_charge_sum=const[0],
                          const_swing_sq=const[1]))
    tuned, _ = measure_ecr_majx(
        k_t, sense, levels_to_charges(lad, levels, P), P, lad.n_fracs, x,
        *const, n_trials=2048)
    assert tuned < base                 # calibration always helps
    if lad.n_levels >= 8:
        assert tuned < 0.10             # fine ladder: near-full recovery
    else:
        assert tuned > 0.15             # 2-level ladder: capped recovery
