"""Tuning-cache failure paths + session/serving integration: every
corruption/mismatch mode must read as a miss and fall back to re-tuning —
never raise, never serve a stale or torn plan — and tuned packs must stay
bit-exact against untuned ones end to end."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.autotune import TunedTile, tuning_key
from repro.runtime.tune import (FORMAT, TuningCache, kernels_fingerprint,
                                main as cli)

KEY = tuning_key("gemv", 1, 64, 96, 4, "dense", placed=False)
PLAN = TunedTile(n_block=48, k_block=32, mode="folded")


@pytest.fixture
def warm(tmp_path):
    """A cache warmed with one persisted winner."""
    cache = TuningCache(tmp_path)
    path = cache.save(KEY, PLAN, {"speedup": 1.25, "tuned_s": 1e-3,
                                  "heuristic_s": 1.25e-3})
    assert path.exists()
    return cache, path


def test_warm_hit_round_trips(warm):
    cache, _ = warm
    assert cache.load(KEY) == PLAN
    entry = cache.load_entry(KEY)
    assert entry["format"] == FORMAT
    assert entry["kernels_fingerprint"] == kernels_fingerprint()
    assert entry["stats"]["speedup"] == 1.25


def test_absent_key_is_miss(warm):
    cache, _ = warm
    assert cache.load(tuning_key("gemm", 8, 64, 96, 4, "dense",
                                 placed=False)) is None


def test_torn_file_is_miss_not_raise(warm):
    cache, path = warm
    path.write_text(path.read_text()[:25])            # truncated mid-write
    assert cache.load(KEY) is None
    assert cache.load_entry(KEY) is None


def test_corrupt_json_is_miss(warm):
    cache, path = warm
    path.write_text("{not json")
    assert cache.load(KEY) is None
    path.write_text(json.dumps(["not", "a", "dict"]))
    assert cache.load(KEY) is None


def test_version_mismatch_is_miss(warm):
    """A format bump must invalidate old entries instead of misreading."""
    cache, path = warm
    entry = json.loads(path.read_text())
    entry["format"] = "pud-tuning-v0"
    path.write_text(json.dumps(entry))
    assert cache.load(KEY) is None
    # re-saving restores the current format
    cache.save(KEY, PLAN)
    assert json.loads(path.read_text())["format"] == FORMAT
    assert cache.load(KEY) == PLAN


def test_fingerprint_mismatch_is_miss(warm, tmp_path):
    """A kernel-source change can never silently reuse stale plans."""
    cache, path = warm
    entry = json.loads(path.read_text())
    entry["kernels_fingerprint"] = "0" * 16
    path.write_text(json.dumps(entry))
    assert cache.load(KEY) is None
    # equivalently: a cache pinned to a different fingerprint misses
    skewed = TuningCache(tmp_path, fingerprint="f" * 16)
    skewed.save(KEY, PLAN)
    assert skewed.load(KEY) == PLAN
    assert TuningCache(tmp_path).load(KEY) is None


def test_wrong_key_in_entry_is_miss(warm):
    cache, path = warm
    entry = json.loads(path.read_text())
    entry["key"] = "gemv__logical__dense__1x999x999@4b"
    path.write_text(json.dumps(entry))
    assert cache.load(KEY) is None


def test_unknown_plan_fields_are_miss(warm):
    """Plans from a future TunedTile shape read as re-tune, not a crash."""
    cache, path = warm
    entry = json.loads(path.read_text())
    entry["plan"] = {"n_block": 48, "warp_count": 4}
    path.write_text(json.dumps(entry))
    assert cache.load_entry(KEY) is not None          # envelope is fine ...
    assert cache.load(KEY) is None                    # ... the plan is not
    entry["plan"] = "heuristic"
    path.write_text(json.dumps(entry))
    assert cache.load_entry(KEY) is None


def test_evict_one_and_all(warm):
    cache, _ = warm
    other = tuning_key("gemm", 8, 64, 96, 4, "dense", placed=False)
    cache.save(other, TunedTile())
    assert cache.evict(KEY) == 1
    assert cache.evict(KEY) == 0                      # idempotent
    assert cache.load(KEY) is None and cache.load(other) is not None
    assert cache.evict() == 1                         # drops the rest
    assert cache.entries() == []


def test_stale_tmp_files_invisible_and_swept(warm):
    cache, path = warm
    torn = path.with_name(path.name + ".tmp-9999")
    torn.write_text("garbage")
    assert len(cache.entries()) == 1                  # staging is invisible
    assert cache.load(KEY) == PLAN
    cache.save(KEY, PLAN)                             # gc on the next save
    assert not torn.exists()


def test_stats_counts_stale_entries(warm, tmp_path):
    cache, _ = warm
    TuningCache(tmp_path, fingerprint="a" * 16).save("old__key", TunedTile())
    s = cache.stats()
    assert s["entries"] == 2 and s["current"] == 1 and s["stale"] == 1
    assert s["bytes"] > 0 and s["fingerprint"] == kernels_fingerprint()


def test_save_accepts_plain_dict(warm):
    cache, _ = warm
    key = tuning_key("gemm", 8, 64, 96, 4, "bitpack8", placed=True)
    cache.save(key, {"k_block": 64, "mode": "planes"})
    assert cache.load(key) == TunedTile(k_block=64, mode="planes")


def test_fingerprint_is_stable_and_source_sensitive():
    assert kernels_fingerprint() == kernels_fingerprint()
    assert len(kernels_fingerprint()) == 16
    int(kernels_fingerprint(), 16)                    # hex


# ---------------------------------------------------------------------------
# CLI (python -m repro.runtime.tune) — jax-free; CI keys actions/cache on
# the --fingerprint output before installing the accelerator stack.
# ---------------------------------------------------------------------------

def test_cli_fingerprint(capsys):
    assert cli(["--fingerprint"]) == 0
    assert capsys.readouterr().out.strip() == kernels_fingerprint()


def test_cli_list_and_stats(warm, tmp_path, capsys):
    assert cli(["--root", str(tmp_path), "--list"]) == 0
    out = capsys.readouterr().out
    assert KEY in out and "1.25x" in out
    assert cli(["--root", str(tmp_path), "--stats"]) == 0
    out = capsys.readouterr().out
    assert "entries          1" in out
    assert kernels_fingerprint() in out


def test_cli_evict_and_empty(warm, tmp_path, capsys):
    assert cli(["--root", str(tmp_path), "--evict", KEY]) == 0
    assert "evicted 1" in capsys.readouterr().out
    assert cli(["--root", str(tmp_path), "--list"]) == 0
    assert "no tuning entries" in capsys.readouterr().out
    assert cli(["--root", str(tmp_path / "nope"), "--stats"]) == 0
    assert "entries          0" in capsys.readouterr().out


def test_cli_evict_all(warm, tmp_path, capsys):
    cache, _ = warm
    cache.save("second__key", TunedTile())
    assert cli(["--root", str(tmp_path), "--evict", "all"]) == 0
    assert "evicted 2" in capsys.readouterr().out
    assert cache.entries() == []


def test_cli_requires_root_and_one_action(tmp_path):
    with pytest.raises(SystemExit):
        cli(["--list"])                               # --root required
    with pytest.raises(SystemExit):
        cli(["--root", str(tmp_path)])                # an action required
    with pytest.raises(SystemExit):
        cli(["--root", str(tmp_path), "--list", "--stats"])


# ---------------------------------------------------------------------------
# Session integration: tune -> persist -> hit, stamped packs stay bit-exact
# ---------------------------------------------------------------------------

def _session(tmp_path):
    from repro.api import (CalibrationConfig, FleetConfig, PUDGemvConfig,
                           PUDSession)
    sess = PUDSession.open(
        grid=FleetConfig(n_channels=1, n_banks=1, n_subarrays=4,
                         n_cols=256),
        calib=CalibrationConfig(n_iterations=4, n_samples=64),
        n_trials_ecr=128, key=7, cache_dir=tmp_path)
    kw = jax.random.split(jax.random.key(0), 2)
    params = {"mixer": {"wi": 0.05 * jax.random.normal(
        kw[0], (64, 96), jnp.float32)}}
    sess.pack(params, PUDGemvConfig(weight_bits=4, packable=("mixer.wi",)),
              include_unembed=False)
    return sess


def test_session_tune_persists_and_hits(tmp_path):
    sess = _session(tmp_path)
    x = jax.random.normal(jax.random.key(1), (64,), jnp.float32)
    before = np.asarray(sess.linear(x, "mixer/wi"))

    rep = sess.tune(reps=1, max_candidates=4)
    assert sess.tuning_report() is rep
    assert rep["fingerprint"] == kernels_fingerprint()
    assert rep["keys"] and all(r["status"] == "tuned"
                               for r in rep["keys"].values())
    # winners are stamped onto the pack and persisted on disk
    pt = sess.packed.tensor("mixer/wi")
    assert pt.tile_plan is not None
    cache = TuningCache(tmp_path / "tuning")
    for key in rep["keys"]:
        assert cache.load(key) is not None
    # tuned execution is bit-exact vs the pre-tune pack
    np.testing.assert_array_equal(np.asarray(sess.linear(x, "mixer/wi")),
                                  before)

    # a second tune is all cache hits and re-stamps identically
    rep2 = sess.tune(reps=1, max_candidates=4)
    assert all(r["status"] == "hit" for r in rep2["keys"].values())
    assert {k: r["plan"] for k, r in rep2["keys"].items()} == \
        {k: r["plan"] for k, r in rep["keys"].items()}


def test_session_tune_name_filter(tmp_path):
    sess = _session(tmp_path)
    rep = sess.tune(names=["wi"], batches=(1,), reps=1, max_candidates=3)
    assert len(rep["keys"]) == 1
    with pytest.raises(KeyError, match="not found"):
        sess.tune(names=["nope"], reps=1)


def test_tile_plan_survives_npz_round_trip(tmp_path):
    from repro.pud.packed import load_packed_npz, save_packed_npz
    sess = _session(tmp_path)
    sess.tune(reps=1, max_candidates=4)
    pm = sess.packed
    stamp = pm.tensor("mixer/wi").tile_plan
    assert stamp is not None
    path = tmp_path / "packs.npz"
    save_packed_npz(path, pm)
    loaded = load_packed_npz(path)
    assert loaded["mixer/wi"].tile_plan == stamp
    x = jax.random.normal(jax.random.key(2), (3, 64), jnp.float32)
    from repro.pud.gemv import pud_linear
    np.testing.assert_array_equal(
        np.asarray(pud_linear(x, loaded["mixer/wi"])),
        np.asarray(pud_linear(x, pm.tensor("mixer/wi"))))
