"""Drift subsystem: canary reservation/probing, the EMA detector, partial
recalibration, placement fault refresh, and the full detect -> recalibrate ->
repack -> hot-swap recovery loop on the serving engine (all backends), with
post-swap decode bit-identical to a fresh decode on the recovered table."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CalibrationConfig, DriftConfig, DriftController,
                       DriftDetector, DriftMonitor, DriftSimulator,
                       FleetConfig, Heartbeat, PUDGemvConfig, PUDSession,
                       Request, ServingEngine, backend_names,
                       inject_read_faults, probe_ecr, refresh_fault_state)
from repro.configs import get
from repro.core.canary import CanarySet, reserve_canaries
from repro.launch.serve import greedy_generate
from repro.models.params import init_params

MAX_LEN = 16
GEN = 4
PROMPT = 8
GRID = FleetConfig(n_channels=1, n_banks=1, n_subarrays=8, n_cols=1024)

#: Far beyond the paper's envelope on purpose: the drift shift is ~2x the
#: majority margin, flipping ~half the affected subarrays' columns so one
#: probe round detects with certainty (the realistic ~0.1% tails are a
#: statistics question, not a plumbing one).
DRIFT_TEMP_C = 3000.0


@pytest.fixture(scope="module")
def smoke():
    spec = get("qwen3-1.7b")
    model = spec.make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    return model, params


def _prompts(model, n, key=1):
    k = jax.random.key(key)
    return [jax.random.randint(jax.random.fold_in(k, i), (PROMPT,), 0,
                               model.cfg.vocab, jnp.int32)
            for i in range(n)]


def _requests(prompts, base_id=0, gen=GEN):
    return [Request(request_id=base_id + i, tokens=p, max_new_tokens=gen)
            for i, p in enumerate(prompts)]


def _session(backend="reference", **kw):
    return PUDSession.open(
        "qwen3-1.7b", grid=GRID,
        calib=CalibrationConfig(n_iterations=4, n_samples=64),
        key=7, n_trials_ecr=128, backend=backend, **kw)


@pytest.fixture(scope="module")
def monitored(smoke):
    """A calibrated reference session with canaries reserved and a placed
    pack — shared by the read-only tests."""
    model, params = smoke
    s = _session()
    s.calibrate()
    s.reserve_canaries(16)
    s.pack(params, PUDGemvConfig(weight_bits=4), name="drift-shared")
    return s


# ---------------------------------------------------------------------------
# Canary reservation
# ---------------------------------------------------------------------------

def test_reserve_canaries_error_free_and_deterministic():
    rng = np.random.default_rng(5)
    masks = rng.random((3, 256)) < 0.3
    cols = reserve_canaries(masks, 8)
    assert cols.shape == (3, 8) and cols.dtype == np.int32
    for g in range(3):
        assert not masks[g, cols[g]].any()          # error-free only
        assert len(set(cols[g].tolist())) == 8      # distinct
        # evenly spread: both ends of the error-free set are represented
        free = np.nonzero(~masks[g])[0]
        assert cols[g, 0] == free[0] and cols[g, -1] == free[-1]
    np.testing.assert_array_equal(cols, reserve_canaries(masks, 8))
    cs = CanarySet(cols=cols, n_cols=256)
    assert cs.n_per_subarray == 8
    m = cs.mask()
    assert m.shape == (3, 256) and m.sum() == 24
    assert not (m & masks).any()
    assert len(cs.fingerprint()) == 10


def test_reserve_canaries_insufficient_columns_raises():
    masks = np.ones((1, 32), bool)
    masks[0, :3] = False
    with pytest.raises(ValueError, match="only 3 error-free"):
        reserve_canaries(masks, 4)


def test_canaries_excluded_from_placement(monitored):
    s = monitored
    cs = s.canaries
    n_cols = s.fleet_cfg.n_cols
    canary_flat = {g * n_cols + int(c)
                   for g in range(cs.cols.shape[0]) for c in cs.cols[g]}
    placed = set()
    for tp in s.placement.entries.values():
        placed.update(int(c) for c in np.asarray(tp.phys_cols).ravel())
    assert placed and not (placed & canary_flat)
    # the reservation keys the persisted placement name
    assert cs.fingerprint() in s.placement_name


# ---------------------------------------------------------------------------
# Detector
# ---------------------------------------------------------------------------

def test_detector_thresholds_ema_and_rebaseline():
    det = DriftDetector(3, DriftConfig(ema_alpha=0.25, warn_new_ecr=0.15,
                                       critical_new_ecr=0.30))
    assert det.update([0.05, 0.0, 0.0], 0) == []       # churn floor absorbed
    assert det.ema[0] == pytest.approx(0.0125)
    evs = det.update([0.2, 0.5, 0.1], 1)
    assert [(e.subarray, e.severity) for e in evs] == [(0, "warn"),
                                                       (1, "critical")]
    assert evs[1].new_ecr == pytest.approx(0.5)
    assert evs[1].probe_round == 1
    # flagged rounds do not poison the baseline; healthy rows keep updating
    assert det.ema[0] == pytest.approx(0.0125)
    assert det.ema[1] == 0.0
    assert det.ema[2] == pytest.approx(0.025)
    # after recovery, the next probe of a re-baselined row is absorbed
    det.rebaseline([1])
    assert det.update([0.0, 0.45, 0.0], 2) == []
    assert det.ema[1] == pytest.approx(0.45)
    # ... and only the one following probe; later excursions still fire
    assert det.update([0.0, 0.9, 0.0], 3)[0].severity == "critical"
    assert det.events and len(det.events) == 3


# ---------------------------------------------------------------------------
# Drift simulator + canary probe
# ---------------------------------------------------------------------------

def test_simulator_targets_subarrays_and_probe_detects(monitored):
    s = monitored
    sim = DriftSimulator.for_session(s)
    base = np.asarray(sim.sense_offsets())
    mon = DriftMonitor(s, sim, config=DriftConfig(probe_every=1))

    # clean device: canary churn stays below the critical threshold
    evs = mon.probe()
    assert not [e for e in evs if e.severity == "critical"]

    sim.advance(temp_c=DRIFT_TEMP_C, subarrays=[2, 6])
    offs = np.asarray(sim.sense_offsets())
    assert (offs[2] != base[2]).any() and (offs[6] != base[6]).any()
    for g in (0, 1, 3, 4, 5, 7):
        np.testing.assert_array_equal(offs[g], base[g])

    evs = mon.probe()
    hot = {e.subarray for e in evs if e.severity == "critical"}
    assert hot == {2, 6}
    assert all(e.new_ecr > 0.3 for e in evs if e.subarray in hot)
    rep = mon.report()
    assert rep["probe_rounds"] == 2 and rep["critical_events"] >= 2
    assert 0.0 < rep["probe_overhead"] < 0.05   # amortized, not dominant

    # back at nominal conditions the device reads its base offsets again
    sim.advance(temp_c=s.physics.temp_nominal_c)
    np.testing.assert_array_equal(np.asarray(sim.sense_offsets()), base)


# ---------------------------------------------------------------------------
# Placement fault refresh
# ---------------------------------------------------------------------------

def test_refresh_fault_state_tracks_new_masks(monitored):
    s = monitored
    sim = DriftSimulator.for_session(s)
    offs = np.asarray(sim.sense_offsets())
    masks = np.asarray(s.calibration.masks, bool)
    packed = s.packed

    # refreshing against the planner's own masks (calibration | canaries,
    # no offsets -> the same deterministic stuck fallback) reproduces the
    # pack-time fault state bit for bit, and injection is idempotent:
    # re-reading an already-corrupted pack changes nothing
    planned = masks | s.canaries.mask()
    same = refresh_fault_state(s.placement, planned)
    for name, tp in s.placement.entries.items():
        np.testing.assert_array_equal(np.asarray(same.entries[name].faulty),
                                      np.asarray(tp.faulty))
        np.testing.assert_array_equal(np.asarray(same.entries[name].stuck),
                                      np.asarray(tp.stuck))
    once = inject_read_faults(packed.params, same)
    twice = inject_read_faults(once, same)
    for a, b in zip(jax.tree.leaves(once), jax.tree.leaves(twice)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # declare every column of an occupied subarray bad: injection must bite
    g = int(np.argmax(np.asarray(s.placement.used_per_subarray)))
    hot_masks = masks.copy()
    hot_masks[g, :] = True
    hot = refresh_fault_state(s.placement, hot_masks, offs)
    assert any(tp.faulty.any() for tp in hot.entries.values())
    corrupted = inject_read_faults(packed.params, hot)
    diffs = [not np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(packed.params),
                             jax.tree.leaves(corrupted))]
    assert any(diffs)
    # the plan itself (columns, capacity) is untouched — re-planning is the
    # recovery path's job, refresh only re-derives fault state
    for name, tp in s.placement.entries.items():
        np.testing.assert_array_equal(np.asarray(hot.entries[name].phys_cols),
                                      np.asarray(tp.phys_cols))


# ---------------------------------------------------------------------------
# Engine: hot swap + watchdog/heartbeat wiring
# ---------------------------------------------------------------------------

def test_stage_params_swaps_between_steps_last_writer_wins(smoke):
    model, params = smoke
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2)
    eng.submit_all(_requests(_prompts(model, 2)))
    eng.step()
    p1 = jax.tree.map(lambda x: x, params)
    p2 = jax.tree.map(lambda x: x, params)
    eng.stage_params(p1)
    assert eng.swap_pending
    eng.stage_params(p2)                      # replaces the staged tree
    before = eng.scheduler_report()["steps"]
    eng.step()
    assert eng.params is p2 and not eng.swap_pending
    rep = eng.scheduler_report()
    assert rep["swaps"] == 1 and rep["swap_steps"] == [before]
    # swapping an identical tree is a numeric no-op: drain + oracle check
    prompts = _prompts(model, 2)
    for c in eng.run():
        want, _ = greedy_generate(
            model, params,
            jnp.asarray(prompts[c.request_id], jnp.int32)[None, :],
            GEN, MAX_LEN)
        assert c.tokens == list(np.asarray(want[0]))


def test_watchdog_and_heartbeat_wiring(smoke, tmp_path):
    model, params = smoke
    hb = Heartbeat(tmp_path)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2,
                        heartbeat=hb)
    eng.run(_requests(_prompts(model, 2)))
    rep = eng.scheduler_report()
    assert rep["hangs"] == 0 and rep["swaps"] == 0
    assert rep["step_ema_s"] is not None and rep["step_ema_s"] > 0
    assert isinstance(rep["stragglers"], int)
    beats = Heartbeat.read_all(tmp_path)
    assert len(beats) == 1 and beats[0]["step"] == rep["steps"]
    assert beats[0]["completed"] == rep["completed"]
    # a user on_hang is wrapped so fired hangs are counted in the report
    from repro.api import StepWatchdog
    seen = []
    eng2 = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                         watchdog=StepWatchdog(on_hang=seen.append))
    eng2.watchdog.on_hang(12.3)
    assert seen == [12.3]
    assert eng2.scheduler_report()["hangs"] == 1


# ---------------------------------------------------------------------------
# Partial recalibration + cache integration
# ---------------------------------------------------------------------------

def test_recalibration_persists_and_drops_stale_placements(smoke, tmp_path):
    from repro.runtime.calib_cache import table_key
    model, params = smoke
    s = _session(cache_dir=tmp_path, device_id="drifty")
    s.calibrate()
    s.reserve_canaries(8)
    s.pack(params, PUDGemvConfig(weight_bits=4), name="persisted")
    entry = tmp_path / "drifty" / table_key(s.fleet_cfg, s.physics)
    assert list((entry / "placements").glob("*.npz"))
    age = s.calibration_age()
    assert age["age_days"] >= 0.0
    assert age["assumed_temp_c"] == s.physics.temp_nominal_c
    levels0 = np.asarray(s.calibration.levels).copy()

    masks0 = np.asarray(s.calibration.masks, bool).copy()
    sim = DriftSimulator.for_session(s)
    sim.advance(temp_c=DRIFT_TEMP_C, subarrays=[3])
    s.recalibrate_subarrays([3], sim.sense_offsets(),
                            assumed_temp_c=DRIFT_TEMP_C)
    # only the affected subarray's ladder moved
    levels1 = np.asarray(s.calibration.levels)
    for g in range(GRID.n_subarrays):
        if g != 3:
            np.testing.assert_array_equal(levels1[g], levels0[g])
    # the merged masks now describe the drifted device: at this stress
    # level many of subarray 3's columns are beyond any ladder and stay
    # masked (placement's job), far more than calibration-time churn
    masks1 = np.asarray(s.calibration.masks, bool)
    assert masks1[3].sum() > masks0[3].sum()
    np.testing.assert_array_equal(masks1[:3], masks0[:3])
    np.testing.assert_array_equal(masks1[4:], masks0[4:])
    # the merged table was re-persisted with recovery metadata ...
    table = s.cache.load("drifty", s.fleet_cfg, s.physics)
    assert table.metadata["recalibrated_subarrays"] == [3]
    assert table.assumed_temp_c == DRIFT_TEMP_C
    np.testing.assert_array_equal(table.levels, levels1)
    # ... and the save dropped the entry's now-stale placements
    assert not list((entry / "placements").glob("*.npz"))


# ---------------------------------------------------------------------------
# The full recovery loop (the acceptance criterion), every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", sorted(backend_names()))
def test_full_recovery_loop(smoke, backend):
    model, params = smoke
    s = _session(backend=backend)
    s.calibrate()
    s.reserve_canaries(16)
    s.pack(params, PUDGemvConfig(weight_bits=4), name=f"drift-{backend}")
    levels0 = np.asarray(s.calibration.levels).copy()

    eng = s.serving_engine(model, max_len=MAX_LEN, batch_size=2)
    sim = DriftSimulator.for_session(s)
    mon = DriftMonitor(s, sim, config=DriftConfig(probe_every=2))

    def read_faults(packed_params):
        pl = refresh_fault_state(s.placement,
                                 np.asarray(s.calibration.masks, bool),
                                 np.asarray(sim.sense_offsets()))
        return inject_read_faults(packed_params, pl)

    ctl = DriftController(eng, mon, params, pack_name=f"drift-{backend}",
                          read_faults=read_faults)

    prompts = _prompts(model, 8)
    eng.submit_all(_requests(prompts[:6]))
    for _ in range(3):
        ctl.step()

    # mid-serve drift: subarray 0 holds placed data, 5 is detection-only;
    # corrupt the live pack to what the drifted device would actually read
    hot = [int(np.argmax(np.asarray(s.placement.used_per_subarray))), 5]
    sim.advance(temp_c=DRIFT_TEMP_C, subarrays=hot)
    _, gt_masks = probe_ecr(jax.random.fold_in(jax.random.key(7), 0xF0),
                            sim.sense_offsets(), mon._charges(), s.physics,
                            s.n_fracs, n_trials=128)
    eng.params = inject_read_faults(
        eng.params, refresh_fault_state(s.placement,
                                        np.asarray(gt_masks, bool),
                                        np.asarray(sim.sense_offsets())))

    guard = 0
    while (eng.n_pending or eng.n_active or ctl.phase != "monitor"
           or eng.swap_pending):
        ctl.step()
        guard += 1
        assert guard < 200, "recovery loop did not converge"

    rep = ctl.report()
    assert len(rep["recoveries"]) == 1
    rec = rep["recoveries"][0]
    # detection named exactly the drifted subarrays, nothing else moved
    assert rec["subarrays"] == sorted(hot)
    levels1 = np.asarray(s.calibration.levels)
    for g in range(GRID.n_subarrays):
        if g not in hot:
            np.testing.assert_array_equal(levels1[g], levels0[g])
    for e in rec["canary_ecr_at_detection"].values():
        assert e > 0.3
    # zero downtime: the swap step (and every step) emitted tokens
    assert rep["swap_steps"] and rep["swap_step_tokens"]
    assert all(t > 0 for t in rep["swap_step_tokens"])
    assert rep["min_tokens_per_step"] > 0

    # post-swap decode is bit-identical to a fresh decode on the recovered
    # pack — the engine fully healed, no residue of the corrupted epoch
    post = _requests(prompts[6:], base_id=100)
    comps = {c.request_id: c for c in ctl.run(post)}
    fresh = s.packed.params
    for r in post:
        want, _ = greedy_generate(model, fresh,
                                  jnp.asarray(r.tokens, jnp.int32)[None, :],
                                  GEN, MAX_LEN)
        assert comps[r.request_id].tokens == list(np.asarray(want[0])), \
            f"backend {backend}, request {r.request_id}"


def test_recovered_tokens_match_independent_fresh_session(smoke):
    """The recovered session's decode equals that of a session calibrated
    from scratch (different key) — recovery restored the exact-integer
    serving contract, not just self-consistency."""
    model, params = smoke
    s = _session()
    s.calibrate()
    s.reserve_canaries(16)
    s.pack(params, PUDGemvConfig(weight_bits=4), name="recovered")
    sim = DriftSimulator.for_session(s)
    sim.advance(temp_c=DRIFT_TEMP_C, subarrays=[1])
    s.recalibrate_subarrays([1], sim.sense_offsets())
    s.pack(params, PUDGemvConfig(weight_bits=4), name="recovered")

    ref = PUDSession.open(
        "qwen3-1.7b", grid=GRID,
        calib=CalibrationConfig(n_iterations=4, n_samples=64),
        key=11, n_trials_ecr=128, backend="reference")
    ref.calibrate()
    ref.pack(params, PUDGemvConfig(weight_bits=4), name="fresh")

    toks = jnp.stack(_prompts(model, 2))
    got, _ = greedy_generate(model, s.packed.params, toks, GEN, MAX_LEN)
    want, _ = greedy_generate(model, ref.packed.params, toks, GEN, MAX_LEN)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
