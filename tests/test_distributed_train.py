"""SPMD correctness: the sharded train step computes the SAME numbers as the
single-device step — run in a subprocess with 4 forced host devices on a
(data=2, model=2) mesh, qwen3-family smoke config, real data pipeline."""

PROG = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import get
    from repro.models.params import init_params, param_pspecs
    from repro.models import sharding_ctx
    from repro.runtime import sharding as shd
    from repro.runtime.data import DataConfig, DataPipeline
    from repro.runtime.optim import OptConfig, init_opt_state, opt_state_pspecs
    from repro.runtime.steps import make_train_step

    model = get("qwen3-1.7b").make_smoke()
    opt_cfg = OptConfig(total_steps=100, warmup_steps=2)
    data = DataPipeline(DataConfig(vocab=256, seq_len=64, global_batch=4,
                                   seed=3))
    batches = [next(data) for _ in range(3)]

    def run(mesh_shape, axes, use_rules):
        from repro.launch.mesh import make_host_mesh, use_mesh
        mesh = make_host_mesh(*mesh_shape)
        with use_mesh(mesh):
            rules = shd.make_rules(mesh)
            sharding_ctx.set_rules(
                {**rules, "_mesh_sizes": dict(mesh.shape)}
                if use_rules else None)
            pspecs = param_pspecs(model.param_defs(), rules)
            opt_ps = opt_state_pspecs(pspecs, opt_cfg)
            params = init_params(model.param_defs(), jax.random.key(0))
            params = jax.device_put(params, shd.named(mesh, pspecs))
            opt = init_opt_state(params, opt_cfg)
            opt = jax.device_put(opt, shd.named(mesh, opt_ps))
            bspec = {k: P("data") for k in batches[0]}
            step = jax.jit(make_train_step(model, opt_cfg, microbatches=2,
                                           batch_axes="data"),
                           in_shardings=(shd.named(mesh, pspecs),
                                         shd.named(mesh, opt_ps),
                                         shd.named(mesh, bspec),
                                         shd.named(mesh, P())),
                           out_shardings=(shd.named(mesh, pspecs),
                                          shd.named(mesh, opt_ps),
                                          shd.named(mesh, P())))
            losses = []
            for i, b in enumerate(batches):
                params, opt, m = step(params, opt, b, jnp.uint32(i))
                losses.append(float(m["loss"]))
            sharding_ctx.set_rules(None)
            return losses, params

    l1, p1 = run((1, 1), ("data", "model"), use_rules=False)
    l4, p4 = run((2, 2), ("data", "model"), use_rules=True)
    np.testing.assert_allclose(l1, l4, rtol=2e-4, atol=2e-4)
    h1 = [np.asarray(jax.device_get(x), np.float32)
          for x in jax.tree.leaves(p1)]
    h4 = [np.asarray(jax.device_get(x), np.float32)
          for x in jax.tree.leaves(p4)]
    d = max(float(np.abs(a - b).max()) for a, b in zip(h1, h4))
    assert d < 2e-2, d   # bf16 params, fp32 math reordering across shards
    print("DIST_OK", l1, l4, d)
"""


def test_sharded_step_matches_single_device(forced_devices):
    forced_devices(PROG, marker="DIST_OK", devices=4)
