"""End-to-end system tests: the public launchers run whole workflows on the
smoke configs — train (with checkpoint/resume continuity), serve (bf16 and
PUD bit-plane paths), and the device-plane quickstart pipeline."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod
from repro.models import sharding_ctx


@pytest.fixture(autouse=True)
def _clean_rules():
    yield
    sharding_ctx.set_rules(None)


def test_train_end_to_end_with_resume(tmp_path):
    common = ["--arch", "qwen3-1.7b", "--preset", "smoke",
              "--ckpt-dir", str(tmp_path), "--save-every", "5",
              "--global-batch", "4", "--seq-len", "64",
              "--microbatches", "2", "--log-every", "100"]
    assert train_mod.main(common + ["--steps", "12"]) == 0
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert 12 in steps
    # resume continues from the saved step and still improves
    assert train_mod.main(common + ["--steps", "24", "--resume"]) == 0


def test_train_with_grad_compression(tmp_path):
    rc = train_mod.main([
        "--arch", "granite-8b", "--preset", "smoke", "--steps", "40",
        "--global-batch", "4", "--seq-len", "64", "--compress-grads",
        "--log-every", "100"])
    assert rc == 0


def test_serve_end_to_end_pud(capsys):
    rc = serve_mod.main([
        "--arch", "qwen3-1.7b", "--preset", "smoke", "--batch", "2",
        "--prompt-len", "8", "--gen", "4", "--pud-gemv"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "token agreement" in out
    assert "1.81x" in out or "1.8" in out   # Eq.-1 serving gain reported


def test_serve_vlm_family():
    rc = serve_mod.main([
        "--arch", "pixtral-12b", "--preset", "smoke", "--batch", "2",
        "--prompt-len", "8", "--gen", "2"])
    assert rc == 0


def test_quickstart_pipeline_device_plane():
    """Manufacture -> calibrate -> ECR drop -> Eq.-1 gain, end to end."""
    from repro.core.calibrate import CalibrationConfig, identify_calibration
    from repro.core.ecr import measure_ecr_maj5
    from repro.core.offsets import (baseline_charges, levels_to_charges,
                                    make_ladder)
    from repro.pud.bitserial import maj5_standalone_counts
    from repro.pud.physics import PhysicsParams
    from repro.pud.timing import SystemConfig, throughput_ops

    params, system = PhysicsParams(), SystemConfig()
    k_m, k_c, k_b, k_t = jax.random.split(jax.random.key(3), 4)
    sense = params.sigma_static * jax.random.normal(k_m, (4096,), jnp.float32)
    ecr_b, _ = measure_ecr_maj5(
        k_b, sense, baseline_charges(3, 4096, params), params, 3,
        n_trials=2048)
    lad = make_ladder((2, 1, 0), params)
    lv = identify_calibration(k_c, sense, lad, params,
                              CalibrationConfig(n_iterations=20,
                                                n_samples=256))
    ecr_t, _ = measure_ecr_maj5(
        k_t, sense, levels_to_charges(lad, lv, params), params,
        lad.n_fracs, n_trials=2048)
    def tp(e):
        return throughput_ops(
            maj5_standalone_counts(3), (1 - e) * system.n_cols_per_subarray,
            system)
    assert ecr_t < ecr_b / 4
    assert 1.4 < tp(ecr_t) / tp(ecr_b) < 2.4   # paper: 1.81x
