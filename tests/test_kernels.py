"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.bitplane_gemv import bitplane_gemv
from repro.kernels.majx import majx_sense
from repro.kernels.ops import pud_gemv, pud_gemv_ref
from repro.pud.physics import PhysicsParams


# ---------------------------------------------------------------------------
# majx kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,r,c", [(8, 8, 1024), (16, 8, 2048), (8, 4, 1024),
                                   (32, 8, 1024)])
@pytest.mark.parametrize("n_fracs", [0, 3])
def test_majx_matches_ref(t, r, c, n_fracs):
    key = jax.random.key(42)
    k1, k2, k3 = jax.random.split(key, 3)
    charge = jax.random.uniform(k1, (t, r, c), jnp.float32)
    offs = 0.03 * jax.random.normal(k2, (c,), jnp.float32)
    noise = jax.random.normal(k3, (t, c), jnp.float32)
    params = PhysicsParams()
    got = majx_sense(charge, offs, noise, params, n_fracs, interpret=True)
    want = ref.majx_sense_ref(charge, offs, noise, params, n_fracs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_majx_majority_semantics():
    """With zero offsets/noise, SiMRA of k full + (8-k) neutral rows is a
    majority vote over the 5 'data' positions."""
    c = 1024
    params = PhysicsParams(sigma_dynamic=0.0, sigma_frac=0.0,
                           sigma_transfer=0.0)
    rows = []
    for k in range(6):
        data = [1.0] * k + [0.0] * (5 - k)
        rows.append(data + [0.5] * 3)
    charge = jnp.tile(jnp.array(rows, jnp.float32)[:, :, None], (1, 1, c))
    charge = jnp.concatenate([charge] * 2, axis=0)[:8]  # pad trials to block
    out = majx_sense(charge, jnp.zeros((c,)), jnp.zeros((8, c)), params, 0)
    expect = jnp.array([0, 0, 0, 1, 1, 1, 0, 0], jnp.float32)  # k>=3 -> 1
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(expect[:8]))


# ---------------------------------------------------------------------------
# bitplane gemv kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,n", [(1, 256, 256), (4, 512, 256),
                                   (8, 256, 512), (2, 1024, 1024)])
@pytest.mark.parametrize("wb", [2, 4, 8])
@pytest.mark.parametrize("mode", ["planes", "folded"])
def test_bitplane_gemv_matches_ref(b, k, n, wb, mode):
    key = jax.random.key(0)
    k1, k2 = jax.random.split(key)
    x = jax.random.randint(k1, (b, k), -127, 128, jnp.int32).astype(jnp.int8)
    w = jax.random.randint(k2, (k, n), -(1 << (wb - 1)), 1 << (wb - 1),
                           jnp.int32)
    planes = ref.pack_bitplanes(w, wb)
    got = bitplane_gemv(x, planes, mode=mode, interpret=True)
    want = ref.bitplane_gemv_ref(x, planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the oracle itself must equal the plain integer matmul
    direct = x.astype(jnp.int32) @ w
    np.testing.assert_array_equal(np.asarray(want), np.asarray(direct))


def test_modes_bit_identical():
    key = jax.random.key(7)
    x = jax.random.randint(key, (4, 512), -127, 128, jnp.int32).astype(jnp.int8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (512, 256), -8, 8,
                           jnp.int32)
    planes = ref.pack_bitplanes(w, 4)
    a = bitplane_gemv(x, planes, mode="planes", interpret=True)
    b = bitplane_gemv(x, planes, mode="folded", interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pud_gemv_dequant_close_to_float():
    key = jax.random.key(3)
    x = jax.random.normal(key, (2, 512), jnp.float32)
    w = jax.random.randint(jax.random.fold_in(key, 1), (512, 256), -8, 8,
                           jnp.int32)
    planes = ref.pack_bitplanes(w, 4)
    got = pud_gemv(x, planes, w_scale=jnp.float32(1.0))
    want = pud_gemv_ref(x, planes, w_scale=jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    # dequantized result approximates the float matmul
    exact = x @ w.astype(jnp.float32)
    err = np.abs(np.asarray(got) - np.asarray(exact))
    assert err.mean() < 0.05 * np.abs(np.asarray(exact)).mean()


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(wb=st.integers(2, 8), seed=st.integers(0, 2**31 - 1))
def test_pack_bitplanes_roundtrip(wb, seed):
    key = jax.random.key(seed)
    w = jax.random.randint(key, (32, 16), -(1 << (wb - 1)), 1 << (wb - 1),
                           jnp.int32)
    planes = ref.pack_bitplanes(w, wb)
    assert set(np.unique(np.asarray(planes))) <= {0, 1}
    rebuilt = sum((planes[b].astype(jnp.int32) << b) for b in range(wb))
    rebuilt = rebuilt - (1 << (wb - 1))
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(w))
