"""Shared pytest config.

NOTE: deliberately does NOT set XLA_FLAGS / device counts — smoke tests must
see the single real CPU device; only launch/dryrun.py forces 512 host devices.
Enables the persistent compilation cache so the big unrolled MAJ-graph
compiles (MUL8 ~ 250 MAJX ops) are paid once per machine, not per run.

Crash-loop guard: a process killed mid-compile can leave a torn cache entry,
and XLA's native deserializer segfaults on it — every later run then dies at
the same test.  A sentinel marks the suite as running; if it is still there
at startup, the previous run died hard and the cache is purged (one-time
recompile instead of a persistent crash loop).
"""
import os
import pathlib
import shutil

import jax

_CACHE = pathlib.Path(os.environ.get("JAX_COMPILATION_CACHE_DIR",
                                     "/tmp/jax_compilation_cache"))

jax.config.update("jax_compilation_cache_dir", str(_CACHE))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 2.0)


def _sentinel() -> pathlib.Path:
    return _CACHE / f".suite-running-{os.getpid()}"


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except OSError:
        pass  # e.g. EPERM: exists but not ours
    return True


def pytest_sessionstart(session):
    # One sentinel per session (pid-stamped): a sentinel whose process is
    # gone means that run died hard, possibly mid-compile — purge.  A live
    # pid is a concurrent session, not a crash; leave its cache alone.
    stale = [p for p in _CACHE.glob(".suite-running-*")
             if not _pid_alive(int(p.name.rsplit("-", 1)[-1]))]
    if stale:
        shutil.rmtree(_CACHE, ignore_errors=True)
    _CACHE.mkdir(parents=True, exist_ok=True)
    _sentinel().write_text("")


def pytest_sessionfinish(session, exitstatus):
    try:
        _sentinel().unlink()
    except OSError:
        pass
