"""Shared pytest config.

NOTE: deliberately does NOT set XLA_FLAGS / device counts — smoke tests must
see the single real CPU device; only launch/dryrun.py forces 512 host devices.
Multi-device tests go through the :func:`forced_devices` fixture below, which
runs their program text in a subprocess with the flag in its environment.
Enables the persistent compilation cache so the big unrolled MAJ-graph
compiles (MUL8 ~ 250 MAJX ops) are paid once per machine, not per run.

Crash-loop guard: a process killed mid-compile can leave a torn cache entry,
and XLA's native deserializer segfaults on it — every later run then dies at
the same test.  A sentinel marks the suite as running; if it is still there
at startup, the previous run died hard and the cache is purged (one-time
recompile instead of a persistent crash loop).
"""
import os
import pathlib
import shutil
import subprocess
import sys
import textwrap

import jax
import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def run_forced_devices(prog: str, *, marker: str, devices: int = 4,
                       timeout: int = 600) -> subprocess.CompletedProcess:
    """Run ``prog`` in a fresh interpreter with ``devices`` forced host CPUs.

    XLA only honors ``--xla_force_host_platform_device_count`` if it is set
    before jax initializes, and this process's jax is already live on the
    single real CPU device — so multi-device tests ship their program text
    to a subprocess with the flag in its environment.  Asserts that
    ``marker`` (the program's success print) appears on stdout and returns
    the completed process for extra assertions.
    """
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
           "HOME": os.environ.get("HOME", "/tmp"),
           "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(prog)],
                       capture_output=True, text=True, env=env,
                       cwd=str(REPO_ROOT), timeout=timeout)
    assert marker in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
    return r


@pytest.fixture
def forced_devices():
    """The :func:`run_forced_devices` subprocess runner, as a fixture."""
    return run_forced_devices

_CACHE = pathlib.Path(os.environ.get("JAX_COMPILATION_CACHE_DIR",
                                     "/tmp/jax_compilation_cache"))

jax.config.update("jax_compilation_cache_dir", str(_CACHE))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 2.0)


def _sentinel() -> pathlib.Path:
    return _CACHE / f".suite-running-{os.getpid()}"


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except OSError:
        pass  # e.g. EPERM: exists but not ours
    return True


def pytest_sessionstart(session):
    # One sentinel per session (pid-stamped): a sentinel whose process is
    # gone means that run died hard, possibly mid-compile — purge.  A live
    # pid is a concurrent session, not a crash; leave its cache alone.
    stale = [p for p in _CACHE.glob(".suite-running-*")
             if not _pid_alive(int(p.name.rsplit("-", 1)[-1]))]
    if stale:
        shutil.rmtree(_CACHE, ignore_errors=True)
    _CACHE.mkdir(parents=True, exist_ok=True)
    _sentinel().write_text("")


def pytest_sessionfinish(session, exitstatus):
    try:
        _sentinel().unlink()
    except OSError:
        pass
