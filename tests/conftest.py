"""Shared pytest config.

NOTE: deliberately does NOT set XLA_FLAGS / device counts — smoke tests must
see the single real CPU device; only launch/dryrun.py forces 512 host devices.
Enables the persistent compilation cache so the big unrolled MAJ-graph
compiles (MUL8 ~ 250 MAJX ops) are paid once per machine, not per run.
"""
import os

import jax

_CACHE = os.environ.get("JAX_COMPILATION_CACHE_DIR",
                        "/tmp/jax_compilation_cache")
jax.config.update("jax_compilation_cache_dir", _CACHE)
jax.config.update("jax_persistent_cache_min_compile_time_secs", 2.0)
