"""Fleet calibration engine: grid == per-subarray equivalence, fused Pallas
kernel vs oracle, shard_map path, cache round-trip, fleet ECR/throughput."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibrate import CalibrationConfig, identify_calibration
from repro.core.ecr import fleet_ecr_summary, measure_ecr_fleet
from repro.core.fleet import (FleetConfig, calibrate_fleet,
                              fleet_calib_charges, ladder_tables,
                              load_or_calibrate, manufacture_fleet,
                              subarray_key)
from repro.core.throughput import fleet_throughput
from repro.kernels.majx import calib_iter_fused
from repro.kernels.ref import calib_iter_ref
from repro.pud.gemv import FleetPerfModel, PUDPerfModel
from repro.pud.physics import PhysicsParams
from repro.runtime.calib_cache import CalibrationTableCache

P = PhysicsParams()
CFG = FleetConfig(n_channels=1, n_banks=2, n_subarrays=2, n_cols=256)
CAL = CalibrationConfig(n_iterations=6, n_samples=128)


def test_manufacture_matches_single_subarray():
    key = jax.random.key(3)
    offs = manufacture_fleet(key, CFG, P)
    assert offs.shape == (CFG.n_subarrays_total, CFG.n_cols)
    for g in (0, 3):
        single = P.sigma_static * jax.random.normal(
            subarray_key(key, g), (CFG.n_cols,), jnp.float32)
        np.testing.assert_array_equal(np.asarray(offs[g]), np.asarray(single))


def test_grid_calibration_matches_per_subarray():
    """vmapped fleet Algorithm 1 == N independent identify_calibration."""
    key = jax.random.key(5)
    offs = manufacture_fleet(key, CFG, P)
    cal = calibrate_fleet(key, offs, CFG, P, CAL, method="per_subarray")
    ladder = CFG.ladder(P)
    for g in range(CFG.n_subarrays_total):
        single = identify_calibration(
            subarray_key(key, g), offs[g], ladder, P, CAL)
        np.testing.assert_array_equal(
            np.asarray(cal.levels[g]), np.asarray(single))


def test_fused_kernel_matches_ref():
    """Fused Pallas calibration iteration vs kernels/ref.py, interpret mode."""
    ladder = CFG.ladder(P)
    qsum, swing = ladder_tables(ladder, P)
    key = jax.random.key(11)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s, c = 64, 512
    inputs = jax.random.bernoulli(k1, 0.5, (s, 5, c)).astype(jnp.float32)
    noise = jax.random.normal(k2, (s, c), jnp.float32)
    levels = jax.random.randint(k3, (c,), 0, ladder.n_levels, jnp.int32)
    offs = 0.03 * jax.random.normal(k4, (c,), jnp.float32)
    args = (inputs, noise, levels, offs, P, ladder.n_fracs, qsum, swing,
            0.0009, 5)
    got_l, got_b = calib_iter_fused(*args, interpret=True)
    want_l, want_b = calib_iter_ref(*args)
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(want_l))
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))
    # levels must actually move off their inputs somewhere (non-trivial step)
    assert (np.asarray(got_l) != np.asarray(levels)).any()


def test_fused_fleet_matches_reference_fleet():
    key = jax.random.key(7)
    offs = manufacture_fleet(key, CFG, P)
    fused = calibrate_fleet(key, offs, CFG, P, CAL, method="fused")
    ref = calibrate_fleet(key, offs, CFG, P, CAL, method="reference")
    np.testing.assert_array_equal(np.asarray(fused.levels),
                                  np.asarray(ref.levels))
    np.testing.assert_allclose(np.asarray(fused.mean_abs_bias),
                               np.asarray(ref.mean_abs_bias), atol=1e-7)
    # the bias walk converges
    hist = np.asarray(fused.mean_abs_bias)
    assert hist[-1] < 0.3 * hist[0]
    assert fused.levels_grid.shape == CFG.grid_shape + (CFG.n_cols,)


def test_fleet_ecr_improves_and_summary():
    key = jax.random.key(13)
    offs = manufacture_fleet(key, CFG, P)
    ladder = CFG.ladder(P)
    cal = calibrate_fleet(key, offs, CFG, P, CAL, method="fused")
    charges = fleet_calib_charges(ladder, cal.levels, P)
    k_ecr = jax.random.key(99)
    ecr, masks = measure_ecr_fleet(k_ecr, offs, charges, P, ladder.n_fracs,
                                   n_trials=1024, chunk=128)
    # uncalibrated (neutral level) fleet for comparison
    from repro.core.offsets import neutral_level
    neutral = jnp.full_like(cal.levels, neutral_level(ladder))
    ecr0, _ = measure_ecr_fleet(
        k_ecr, offs, fleet_calib_charges(ladder, neutral, P), P,
        ladder.n_fracs, n_trials=1024, chunk=128)
    assert float(ecr.mean()) < 0.5 * float(ecr0.mean())
    s = fleet_ecr_summary(masks)
    assert s["n_subarrays"] == CFG.n_subarrays_total
    assert s["cols_total"] == CFG.n_cols_total
    assert 0.0 <= s["min_ecr"] <= s["mean_ecr"] <= s["max_ecr"] <= 1.0
    assert s["error_free_cols_total"] == int((~np.asarray(masks)).sum())


def test_fleet_ecr_row_matches_single_subarray_protocol():
    """Row g of the fleet measurement == single-subarray run w/ folded key."""
    from repro.core.ecr import measure_ecr_maj5
    key = jax.random.key(17)
    offs = manufacture_fleet(key, CFG, P)
    ladder = CFG.ladder(P)
    cal = calibrate_fleet(key, offs, CFG, P, CAL, method="fused")
    charges = fleet_calib_charges(ladder, cal.levels, P)
    k_ecr = jax.random.key(23)
    ecr, masks = measure_ecr_fleet(k_ecr, offs, charges, P, ladder.n_fracs,
                                   n_trials=512, chunk=128)
    g = 1
    single_ecr, single_mask = measure_ecr_maj5(
        jax.random.fold_in(k_ecr, g), offs[g], charges[g], P, ladder.n_fracs,
        n_trials=512, chunk=128)
    np.testing.assert_array_equal(np.asarray(masks[g]),
                                  np.asarray(single_mask))
    assert abs(float(ecr[g]) - single_ecr) < 1e-9


def test_cache_round_trip(tmp_path):
    cache = CalibrationTableCache(tmp_path)
    rng = np.random.default_rng(0)
    levels = rng.integers(
        0, 8, (CFG.n_subarrays_total, CFG.n_cols)).astype(np.int32)
    ecr = np.linspace(0.01, 0.05, CFG.n_subarrays_total).astype(np.float32)
    masks = rng.random((CFG.n_subarrays_total, CFG.n_cols)) < 0.05
    cache.save("dimm7", CFG, P, levels, ecr=ecr, masks=masks,
               metadata={"method": "fused"})
    hit = cache.load("dimm7", CFG, P, verify=True)
    assert hit is not None
    np.testing.assert_array_equal(hit.levels, levels)
    np.testing.assert_array_equal(hit.ecr, ecr)
    np.testing.assert_array_equal(hit.masks, masks)
    assert hit.metadata["method"] == "fused"
    # keyed misses: unknown device, different ladder, different physics
    assert cache.load("other", CFG, P) is None
    import dataclasses
    cfg2 = dataclasses.replace(CFG, frac_counts=(0, 0, 0))
    assert cache.load("dimm7", cfg2, P) is None
    p2 = dataclasses.replace(P, sigma_static=0.05)
    assert cache.load("dimm7", CFG, p2) is None
    assert len(cache.entries()) == 1
    # torn payload (crash mid-write, disk corruption): miss, not crash
    entry = next(iter((tmp_path / "dimm7").glob("*/levels.npy")))
    entry.write_bytes(entry.read_bytes()[:40])
    assert cache.load("dimm7", CFG, P) is None
    assert cache.evict("dimm7") == 1
    assert cache.load("dimm7", CFG, P) is None


def test_load_or_calibrate_hits_without_recalibrating(tmp_path):
    cache = CalibrationTableCache(tmp_path)
    key = jax.random.key(29)
    small = FleetConfig(n_channels=1, n_banks=1, n_subarrays=2, n_cols=256)
    lv1, ecr1, masks1, hit1 = load_or_calibrate(
        cache, "d0", key, small, P, CAL, n_trials_ecr=256)
    assert not hit1
    lv2, ecr2, masks2, hit2 = load_or_calibrate(
        cache, "d0", key, small, P, CAL, n_trials_ecr=256)
    assert hit2
    np.testing.assert_array_equal(np.asarray(lv1), np.asarray(lv2))
    np.testing.assert_allclose(np.asarray(ecr1), np.asarray(ecr2))
    np.testing.assert_array_equal(np.asarray(masks1), np.asarray(masks2))
    # the persisted masks are the ECR measurement's error-prone columns
    np.testing.assert_allclose(np.asarray(masks1).mean(axis=1),
                               np.asarray(ecr1), atol=1e-6)


def test_fleet_throughput_and_perf_model():
    ecr = np.array([0.02, 0.04, 0.03, 0.05])
    add = fleet_throughput("T210", "add8", ecr, n_fracs=3)
    mul = fleet_throughput("T210", "mul8", ecr, n_fracs=3)
    base = fleet_throughput("B300", "add8", np.full(4, 0.466), n_fracs=3)
    assert add.per_subarray.shape == (4,)
    # monotone: lower ECR -> higher rate; aggregate sits inside the envelope
    order = np.argsort(ecr)
    assert (np.diff(add.per_subarray[order]) < 0).all()
    assert add.percentile(0) <= add.aggregate <= add.percentile(100)
    assert add.speedup_vs(base) > 1.5
    assert mul.aggregate != add.aggregate
    # serving model built from the same table
    fleet = FleetPerfModel.from_table(ecr, n_fracs=3)
    point = PUDPerfModel(error_free_frac=1 - float(ecr.mean()), n_fracs=3)
    assert abs(fleet.macs_per_second - point.macs_per_second) < 1e-6 * \
        point.macs_per_second
    assert fleet.worst_subarray_macs_per_second < fleet.macs_per_second


SHARD_PROG = """
    import jax, numpy as np
    from repro.core.calibrate import CalibrationConfig
    from repro.core.fleet import FleetConfig, calibrate_fleet, \\
        manufacture_fleet
    from repro.launch.mesh import make_host_mesh
    from repro.pud.physics import PhysicsParams

    params = PhysicsParams()
    cfg = FleetConfig(n_channels=1, n_banks=2, n_subarrays=4, n_cols=256)
    cal = CalibrationConfig(n_iterations=3, n_samples=64)
    key = jax.random.key(1)
    offs = manufacture_fleet(key, cfg, params)
    mesh = make_host_mesh(2, 2)
    fused = calibrate_fleet(key, offs, cfg, params, cal, mesh=mesh,
                            method="fused")
    ref = calibrate_fleet(key, offs, cfg, params, cal, mesh=mesh,
                          method="reference")
    assert fused.levels.shape == (8, 256)
    np.testing.assert_array_equal(np.asarray(fused.levels),
                                  np.asarray(ref.levels))
    hist = np.asarray(fused.mean_abs_bias)
    assert hist[-1] < hist[0]
    print("SHARD_OK", hist.tolist())
"""


def test_fleet_calibration_shard_map(forced_devices):
    forced_devices(SHARD_PROG, marker="SHARD_OK", devices=4)
