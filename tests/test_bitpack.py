"""Bit-packed plane words: round-trip properties (both candidate word
axes), legacy-pack coercion, layout conversions, kernel bit-exactness on
odd (non-tile-multiple) projection shapes, and pack serialization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.backends import backend_names
from repro.kernels.bitplane_gemm import bitplane_gemm
from repro.kernels.bitplane_gemv import _largest_divisor, bitplane_gemv
from repro.pud.gemv import PUDGemvConfig, pack_linear, pud_linear
from repro.pud.packed import (LAYOUT_BITPACK, LAYOUT_DENSE, PackedTensor,
                              as_packed_tensor, load_packed_npz,
                              packed_bytes, save_packed_npz, to_bitpacked,
                              to_dense)
from repro.pud.packer import pack_model


def _planes(seed, wb, k, n):
    w = jax.random.randint(jax.random.key(seed), (k, n),
                           -(1 << (wb - 1)), 1 << (wb - 1), jnp.int32)
    return w, ref.pack_bitplanes(w, wb)


# ---------------------------------------------------------------------------
# Word round-trip properties — both candidate axes
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), wb=st.integers(2, 8),
       k=st.integers(1, 70), n=st.integers(1, 40))
def test_k_axis_words_roundtrip(seed, wb, k, n):
    """The shipped format: [WB, K, N] -> [WB, ceil(K/8), N] uint8 -> back,
    for every K including non-byte-multiples (zero-bit padding)."""
    _, planes = _planes(seed, wb, k, n)
    words = ref.pack_plane_words(planes)
    assert words.dtype == jnp.uint8
    assert words.shape == (wb, -(-k // 8), n)
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_plane_words(words, k)), np.asarray(planes))
    # pad rows beyond K are zero bits
    full = np.asarray(ref.unpack_plane_words(words))
    assert not full[:, k:, :].any()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), wb=st.integers(2, 8),
       k=st.integers(1, 20), n=st.integers(1, 70))
def test_n_axis_words_roundtrip(seed, wb, k, n):
    """The rejected candidate axis ([WB, K, ceil(N/32)] uint32) must also
    round-trip exactly — the choice between the two is about TPU lane
    layout and placement addressability, not information content."""
    _, planes = _planes(seed, wb, k, n)
    words = ref.pack_plane_words_n(planes)
    assert words.dtype == jnp.uint32
    assert words.shape == (wb, k, -(-n // 32))
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_plane_words_n(words, n)), np.asarray(planes))


def test_word_axes_store_identical_bit_counts():
    _, planes = _planes(3, 4, 64, 64)
    k_words = ref.pack_plane_words(planes)
    n_words = ref.pack_plane_words_n(planes)
    assert k_words.size * 1 == n_words.size * 4 == planes.size // 8 * 1


# ---------------------------------------------------------------------------
# Layout conversions + legacy coercion
# ---------------------------------------------------------------------------

def test_to_bitpacked_to_dense_roundtrip():
    w = 0.05 * jax.random.normal(jax.random.key(0), (60, 48), jnp.float32)
    pt = pack_linear(w, 4)
    assert pt.bitpacked and pt.layout == LAYOUT_BITPACK
    assert pt.k == 60 and pt.planes.shape == (4, 8, 48)
    dense = to_dense(pt)
    assert dense.layout == LAYOUT_DENSE
    assert dense.planes.shape == (4, 60, 48)
    back = to_bitpacked(dense)
    np.testing.assert_array_equal(np.asarray(back.planes),
                                  np.asarray(pt.planes))
    # stacked conversion
    ws = jnp.stack([w, 2 * w])
    pm = pack_model({"m": {"wi": ws}}, PUDGemvConfig(packable=("wi",)),
                    include_unembed=False)
    st_pt = pm.tensor("m/wi")
    assert st_pt.planes.shape == (2, 4, 8, 48)
    st_dense = to_dense(st_pt)
    assert st_dense.planes.shape == (2, 4, 60, 48)
    np.testing.assert_array_equal(
        np.asarray(to_bitpacked(st_dense).planes), np.asarray(st_pt.planes))


def test_legacy_dict_coercion_infers_layout_from_dtype():
    _, planes = _planes(1, 4, 64, 32)
    words = ref.pack_plane_words(planes)
    scale = jnp.ones((32,), jnp.float32)
    dense_pt = as_packed_tensor({"planes": planes, "scale": scale})
    assert dense_pt.layout == LAYOUT_DENSE and dense_pt.k == 64
    word_pt = as_packed_tensor({"planes": words, "scale": scale})
    assert word_pt.layout == LAYOUT_BITPACK and word_pt.k == 64
    # both dispatch through pud_linear to identical results
    x = jax.random.normal(jax.random.key(2), (3, 64), jnp.float32)
    np.testing.assert_array_equal(np.asarray(pud_linear(x, dense_pt)),
                                  np.asarray(pud_linear(x, word_pt)))


def test_legacy_three_arg_custom_backend_serves_dense_packs():
    """The documented extension point: a custom backend registered with the
    pre-bitpack 3-arg entry signature still serves legacy dense packs —
    layout kwargs only travel when a pack actually carries layout info."""
    import repro.kernels.backends as bk
    be = bk.Backend(
        name="legacy3arg",
        gemv=lambda x, planes, mode="folded": ref.bitplane_gemv_ref(
            x, planes),
        gemv_placed=lambda x, planes, col_ids, mode="folded":
            ref.bitplane_gemv_placed_ref(x, planes, col_ids))
    bk.register_backend(be)
    try:
        w = 0.05 * jax.random.normal(jax.random.key(8), (64, 32), jnp.float32)
        x = jax.random.normal(jax.random.key(9), (2, 64), jnp.float32)
        dense = pack_linear(w, 4, bitpack=False)
        np.testing.assert_array_equal(
            np.asarray(pud_linear(x, dense, backend="legacy3arg")),
            np.asarray(pud_linear(x, dense, backend="reference")))
        # dense *placed* pack (no window_block) dispatches legacy too
        idx = jax.random.permutation(jax.random.key(10), 40)[:32]
        phys = jnp.zeros((4, 64, 40), jnp.int8).at[:, :, idx].set(
            dense.planes)
        placed = {"planes": phys, "scale": dense.scale,
                  "col_ids": idx.astype(jnp.int32)}
        np.testing.assert_array_equal(
            np.asarray(pud_linear(x, placed, backend="legacy3arg")),
            np.asarray(pud_linear(x, dense, backend="reference")))
        # bit-packed packs genuinely need the layout-aware signature
        with pytest.raises(TypeError):
            pud_linear(x, pack_linear(w, 4), backend="legacy3arg")
    finally:
        bk._REGISTRY.pop("legacy3arg", None)


def test_dense_and_bitpacked_packs_bit_identical_all_backends():
    w = 0.05 * jax.random.normal(jax.random.key(5), (128, 96), jnp.float32)
    x = jax.random.normal(jax.random.key(6), (4, 128), jnp.float32)
    packed = pack_linear(w, 4)
    dense = pack_linear(w, 4, bitpack=False)
    for be in backend_names():
        np.testing.assert_array_equal(
            np.asarray(pud_linear(x, packed, backend=be)),
            np.asarray(pud_linear(x, dense, backend=be)),
            err_msg=f"backend {be}: bitpacked != dense")


# ---------------------------------------------------------------------------
# Odd (non-tile-multiple) projection shapes — the largest-divisor fallback
# ---------------------------------------------------------------------------

def test_largest_divisor_block_selection():
    assert _largest_divisor(256, 256) == 256
    assert _largest_divisor(300, 256) == 150
    assert _largest_divisor(257, 256) == 1       # prime: degenerate but legal
    assert _largest_divisor(64, 256) == 64


@pytest.mark.parametrize("b,k,n,wb", [(2, 300, 172, 4), (1, 257, 96, 4),
                                      (3, 100, 257, 3)])
@pytest.mark.parametrize("mode", ["planes", "folded"])
def test_odd_shapes_do_not_crash_kernel_wrappers(b, k, n, wb, mode):
    """Non-multiple-of-256 projections pick the largest divisor block
    (mirroring the GEMM batch-pad path) instead of tripping an assert."""
    kx, kw = jax.random.split(jax.random.key(k + n))
    x = jax.random.randint(kx, (b, k), -127, 128, jnp.int32).astype(jnp.int8)
    w = jax.random.randint(kw, (k, n), -(1 << (wb - 1)), 1 << (wb - 1),
                           jnp.int32)
    planes = ref.pack_bitplanes(w, wb)
    want = np.asarray(x.astype(jnp.int32) @ w)
    np.testing.assert_array_equal(
        np.asarray(bitplane_gemv(x, planes, mode=mode)), want)
    np.testing.assert_array_equal(
        np.asarray(bitplane_gemm(x, planes, mode=mode)), want)
    words = ref.pack_plane_words(planes)
    np.testing.assert_array_equal(
        np.asarray(bitplane_gemv(x, words, mode=mode, layout="bitpack8",
                                 logical_k=k)), want)
    np.testing.assert_array_equal(
        np.asarray(bitplane_gemm(x, words, mode=mode, layout="bitpack8",
                                 logical_k=k)), want)


def test_odd_shaped_projection_through_pack_linear():
    """An odd [300, 172] projection packs (K byte-pads to 304) and serves
    bit-identically to its dense-layout pack."""
    w = 0.05 * jax.random.normal(jax.random.key(9), (300, 172), jnp.float32)
    x = jax.random.normal(jax.random.key(10), (2, 300), jnp.float32)
    pt = pack_linear(w, 4)
    assert pt.planes.shape == (4, 38, 172) and pt.k == 300
    np.testing.assert_array_equal(
        np.asarray(pud_linear(x, pt)),
        np.asarray(pud_linear(x, pack_linear(w, 4, bitpack=False))))


# ---------------------------------------------------------------------------
# Byte accounting + serialization
# ---------------------------------------------------------------------------

def test_packed_bytes_reports_actual_and_dense_equiv():
    w = 0.05 * jax.random.normal(jax.random.key(1), (64, 128), jnp.float32)
    pm = pack_model({"m": {"wi": w}}, PUDGemvConfig(packable=("wi",)),
                    include_unembed=False)
    stats = packed_bytes(pm)
    pt = pm.tensor("m/wi")
    # stored_bytes is the true array footprint (words + fp32 scale)
    assert stats["stored_bytes"] == pt.planes.nbytes + pt.scale.nbytes
    assert stats["stored_bytes"] == 4 * 8 * 128 + 128 * 4
    # dense equivalent restores one byte per bit
    assert stats["dense_equiv_bytes"] == 4 * 64 * 128 + 128 * 4
    assert stats["pud_bytes"] == stats["stored_bytes"]   # legacy alias
    # scale bytes follow the actual dtype, not a hardcoded 4
    half = pm.tensor("m/wi").replace(scale=pt.scale.astype(jnp.float16))
    assert half.stored_bytes == pt.planes.nbytes + 128 * 2


def test_pack_npz_roundtrip_and_version_fallback(tmp_path):
    w = 0.05 * jax.random.normal(jax.random.key(4), (64, 96), jnp.float32)
    pm = pack_model({"m": {"wi": w}}, PUDGemvConfig(packable=("wi",)),
                    include_unembed=False)
    path = tmp_path / "packs.npz"
    save_packed_npz(path, pm)
    loaded = load_packed_npz(path)
    assert sorted(loaded) == ["m/wi"]
    pt = loaded["m/wi"]
    assert pt.layout == LAYOUT_BITPACK and pt.k == 64
    np.testing.assert_array_equal(np.asarray(pt.planes),
                                  np.asarray(pm.tensor("m/wi").planes))
    # v1-style archive (dense arrays, no entries metadata) still loads
    import json
    dense = to_dense(pm.tensor("m/wi"))
    v1 = tmp_path / "packs_v1.npz"
    np.savez(v1, meta=np.array(json.dumps(
        {"format": "pud-pack-v1", "names": ["m/wi"]})),
        t0_planes=np.asarray(dense.planes), t0_scale=np.asarray(dense.scale))
    old = load_packed_npz(v1)
    assert old["m/wi"].layout == LAYOUT_DENSE
    x = jax.random.normal(jax.random.key(7), (2, 64), jnp.float32)
    np.testing.assert_array_equal(np.asarray(pud_linear(x, old["m/wi"])),
                                  np.asarray(pud_linear(x, pt)))
    # unknown format and torn payload read as misses
    bad = tmp_path / "bad.npz"
    np.savez(bad, meta=np.array(json.dumps({"format": "pud-pack-v99",
                                            "names": []})))
    assert load_packed_npz(bad) is None
    torn = tmp_path / "torn.npz"
    torn.write_bytes(path.read_bytes()[:40])
    assert load_packed_npz(torn) is None


def test_window_block_survives_pytree_and_scan():
    pt = PackedTensor(planes=jnp.zeros((2, 4, 8, 32), jnp.uint8),
                      scale=jnp.ones((2, 32), jnp.float32),
                      col_ids=jnp.tile(jnp.arange(32, dtype=jnp.int32),
                                       (2, 1)),
                      layout=LAYOUT_BITPACK, logical_k=64, window_block=40)
    mapped = jax.tree_util.tree_map(lambda x: x, pt)
    assert (mapped.layout, mapped.logical_k, mapped.window_block) == \
        (LAYOUT_BITPACK, 64, 40)

    def body(carry, p):
        assert p.window_block == 40 and p.layout == LAYOUT_BITPACK
        return carry, p.planes.astype(jnp.int32).sum()

    _, ys = jax.lax.scan(body, 0, pt)
    assert ys.shape == (2,)
