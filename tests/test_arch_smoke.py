"""Per-architecture smoke tests: REDUCED config of the same family runs one
forward/train step (and a prefill->decode consistency check) on CPU,
asserting output shapes and no NaNs. Full configs are dry-run-only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get
from repro.models.params import init_params, param_count

BATCH, SEQ = 2, 64


def _smoke_batch(spec, key):
    kt, kl = jax.random.split(key)
    model = spec.make_smoke()
    extras = {}
    text_len = SEQ
    if spec.family == "vlm":
        c = model.cfg
        extras["patches"] = jax.random.normal(
            key, (BATCH, c.n_patches, c.d_vit), jnp.bfloat16)
        text_len = SEQ - c.n_patches
    if spec.family == "encdec":
        c = model.cfg
        extras["frames"] = jax.random.normal(
            key, (BATCH, c.n_frames, c.d_model), jnp.bfloat16)
    vocab = _vocab(model)
    tokens = jax.random.randint(kt, (BATCH, text_len), 0, vocab, jnp.int32)
    labels = jax.random.randint(kl, (BATCH, text_len), 0, vocab, jnp.int32)
    return model, {"tokens": tokens, "labels": labels, **extras}


def _vocab(model):
    cfg = getattr(model, "cfg")
    if hasattr(cfg, "vocab"):
        return cfg.vocab
    return cfg.lm.vocab  # VLM


@pytest.mark.parametrize("arch", all_archs())
def test_forward_and_train_step(arch):
    spec = get(arch)
    model, batch = _smoke_batch(spec, jax.random.key(0))
    params = init_params(model.param_defs(), jax.random.key(1))
    assert param_count(model.param_defs()) > 0

    def loss_fn(p):
        loss, metrics = model.train_loss(p, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # loss should be near ln(vocab) for random init
    vocab = _vocab(model)
    assert 0.2 * np.log(vocab) < float(loss) < 3.0 * np.log(vocab) + 1.0
    gnorm = jnp.sqrt(sum((g.astype(jnp.float32) ** 2).sum()
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", all_archs())
def test_prefill_decode_consistency(arch):
    """decode_step(prefill(t[:n]), t[n]) must match prefill(t[:n+1]) logits."""
    spec = get(arch)
    model, batch = _smoke_batch(spec, jax.random.key(2))
    params = init_params(model.param_defs(), jax.random.key(3))
    tokens = batch["tokens"]
    n = tokens.shape[1] - 1
    max_len = tokens.shape[1] + 8

    def prefill(toks, **kw):
        if spec.family == "vlm":
            return model.prefill(params, toks, batch["patches"],
                                 max_len=max_len + 256)
        if spec.family == "encdec":
            return model.prefill(params, toks, batch["frames"],
                                 max_len=max_len)
        return model.prefill(params, toks, max_len=max_len)

    logits_full, _ = prefill(tokens)
    logits_pre, cache = prefill(tokens[:, :n])
    prefix = 0 if spec.family != "vlm" else model.cfg.n_patches
    logits_step, _ = model.decode_step(params, cache, tokens[:, n:],
                                       jnp.int32(n + prefix))
    np.testing.assert_allclose(
        np.asarray(logits_step), np.asarray(logits_full),
        rtol=0.15, atol=0.25)  # bf16 cache + different contraction orders


@pytest.mark.parametrize("arch", all_archs())
def test_full_config_metadata(arch):
    """Full-size configs build their ParamDef tree (no allocation) and the
    declared param counts are within 15% of the registry's estimate."""
    spec = get(arch)
    model = spec.make_model()
    n = param_count(model.param_defs())
    assert abs(n - spec.n_params) / spec.n_params < 0.15, (n, spec.n_params)
    for shape in spec.shapes:
        specs = spec.input_specs(shape)
        assert all(hasattr(v, "shape") for v in specs.values())
