"""Static-analysis gate: lint rules fire on fixtures and stay clean on the
real tree; the contract checker accepts every tier-1 config, rejects every
seeded violation, and pre-flights real kernel calls; the CLI exits 0 on the
repo as committed (what CI runs)."""
import pathlib
import shutil
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import ContractViolation, contracts, lint
from repro.kernels.backends import get_backend
from repro.kernels.ops import pud_matmul
from repro.kernels.ref import pack_plane_words

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = REPO_ROOT / "src"


# ---------------------------------------------------------------------------
# Lint rules: each fires on an inline fixture...
# ---------------------------------------------------------------------------

LIB = "src/repro/models/somewhere.py"      # virtual non-kernel library path
KERNEL = "src/repro/kernels/somewhere.py"  # virtual kernel path

FIXTURES = [
    ("no-pallas-outside-kernels", LIB, """
        import jax.experimental.pallas as pl
        out = pl.pallas_call(kernel, out_shape=shape)(x)
        """),
    ("no-direct-kernel-imports", LIB, """
        from repro.kernels.bitplane_gemv import bitplane_gemv
        """),
    ("no-direct-kernel-imports", LIB, """
        from repro.kernels import majx
        """),
    ("no-direct-kernel-imports", LIB, """
        import repro.kernels.bitplane_gemm
        """),
    ("no-raw-pack-dicts", LIB, """
        pack = {"planes": planes, "scale": scale, "col_ids": None}
        """),
    ("no-raw-pack-dicts", LIB, """
        pack = dict(planes=planes, scale=scale)
        """),
    ("no-assert-in-kernels", KERNEL, """
        def kernel_wrapper(x):
            assert x.shape[0] % 8 == 0
        """),
    ("no-constant-prng-key", LIB, """
        import jax
        key = jax.random.PRNGKey(0)
        """),
    ("no-constant-prng-key", LIB, """
        import jax
        key = jax.random.key(42)
        """),
    ("no-removed-jax-api", LIB, """
        import jax
        jax.set_mesh(mesh)
        """),
    ("no-recal-on-decode-path", "src/repro/runtime/engine.py", """
        from repro.core.fleet import recalibrate_subarrays
        """),
    ("no-recal-on-decode-path", LIB, """
        levels = calibrate_fleet(key, offsets, cfg, params)
        """),
    ("no-mesh-outside-launch-mesh", LIB, """
        import jax
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        """),
    ("no-mesh-outside-launch-mesh", LIB, """
        from jax.sharding import Mesh
        mesh = Mesh(devices, ("data", "model"))
        """),
    ("no-prefill-on-decode-wave", "src/repro/runtime/engine.py", """
        def _advance_chunks(self):
            logits, cache = self._prefill_fn(params, tokens, last)
        """),
    ("no-prefill-on-decode-wave", "src/repro/runtime/engine.py", """
        def _chunk_step(self, slot):
            out = model.prefill(params, tokens)
        """),
]


@pytest.mark.parametrize("rule,path,snippet",
                         FIXTURES, ids=[f"{r}-{i}" for i, (r, _, _)
                                        in enumerate(FIXTURES)])
def test_rule_fires_on_fixture(rule, path, snippet):
    findings = lint.lint_source(textwrap.dedent(snippet), path)
    assert [f.rule for f in findings] == [rule], findings


def test_every_rule_has_a_fixture():
    assert {r for r, _, _ in FIXTURES} == set(lint.RULES)
    assert len(lint.RULES) >= 6


def test_rules_are_path_scoped():
    # The same constructs are legal in their home locations.
    ok = [
        ("src/repro/kernels/new_kernel.py",
         "out = pl.pallas_call(kernel, out_shape=shape)(x)"),
        ("src/repro/kernels/backends.py",
         "from repro.kernels.bitplane_gemv import bitplane_gemv"),
        ("src/repro/pud/packed.py",
         'pack = {"planes": planes, "scale": scale}'),
        ("src/repro/launch/mesh.py", "import jax\njax.set_mesh(mesh)"),
        # threaded keys and non-literal seeds are fine anywhere
        (LIB, "import jax\nkey = jax.random.key(seed)"),
        (LIB, "import jax\nkey = jax.random.fold_in(key, 3)"),
        # assert outside kernel code is pytest's job, not the lint's
        ("tests/test_x.py", "assert x == 1"),
        # recalibration is legal off the decode path: the drift controller
        # and session run it between steps and hand the engine a pack
        ("src/repro/runtime/drift.py",
         "from repro.core.fleet import recalibrate_subarrays"),
        ("src/repro/runtime/session.py",
         "levels = calibrate_fleet(key, offsets, cfg, params)"),
        # mesh construction is legal only in the launch/mesh.py factories
        ("src/repro/launch/mesh.py",
         'import jax\nmesh = jax.make_mesh((2, 2), ("data", "model"))'),
        # whole-request prefill is fine from admission (not a chunk helper)
        ("src/repro/runtime/engine.py",
         "def _admit_slot(self, req):\n"
         "    out = self._prefill_bucketed(p, t, last, sb)"),
        # chunk helpers advance via prefill_chunk — that is the point
        ("src/repro/runtime/engine.py",
         "def _advance_chunks(self):\n"
         "    out = model.prefill_chunk(p, t, cache, start)"),
        # whole prefill named 'prefill' off the decode path is not our rule
        ("src/repro/launch/serve.py",
         "def warm_chunks(engine):\n    engine.model.prefill(p, t)"),
        # importing Mesh for a type annotation is fine — only calls count
        (LIB, "from jax.sharding import Mesh\ndef f(m: Mesh): return m"),
    ]
    for path, snippet in ok:
        assert lint.lint_source(snippet, path) == [], (path, snippet)


def test_real_tree_is_clean():
    findings = lint.lint_paths([SRC])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_syntax_error_is_reported_not_raised():
    findings = lint.lint_source("def broken(:\n", LIB)
    assert [f.rule for f in findings] == ["syntax-error"]


# ---------------------------------------------------------------------------
# Contract checker: valid matrix accepted, seeded violations rejected.
# ---------------------------------------------------------------------------


def test_default_matrix_all_valid():
    for call, ids in contracts.default_matrix():
        plan = contracts.plan_kernel(call)       # must not raise
        assert plan.grid[-2] * plan.nb == call.n
        if ids is not None:
            contracts.check_col_ids(ids, call.n, call.window,
                                    call.window_block, plan.block_cols,
                                    call.kernel)


def test_adversarial_fixtures_each_trip_expected_invariant():
    fixtures = contracts.adversarial_fixtures()
    assert len(fixtures) >= 3
    for name, invariant, call, ids in fixtures:
        with pytest.raises(ContractViolation) as exc:
            plan = contracts.plan_kernel(call)
            if ids is not None:
                contracts.check_col_ids(ids, call.n, call.window,
                                        call.window_block, plan.block_cols,
                                        call.kernel)
        assert exc.value.invariant == invariant, name
        assert exc.value.kernel == call.kernel, name


def test_run_contracts_green_on_shipped_matrix():
    assert contracts.run_contracts() == []


def test_plan_matches_kernel_tiling_rules():
    # dense odd shape: the checker must pick the same divisor tiles the
    # wrapper picks (K=300 -> Kb=150, N=172 -> Nb=172).
    plan = contracts.plan_kernel(contracts.KernelCall(
        entry="gemv", b=4, k=300, n=172))
    assert plan.x_kb == 150 and plan.grid == (1, 2)
    # bitpack8: divisor chosen on the word axis (K=300 -> Kw=38 -> 19 words).
    plan = contracts.plan_kernel(contracts.KernelCall(
        entry="gemv", b=4, k=300, n=172, layout="bitpack8", logical_k=300))
    assert plan.plane_kb == 19 and plan.x_kb == 152
    # gemm pads the batch to a B_BLOCK multiple before gridding.
    plan = contracts.plan_kernel(contracts.KernelCall(
        entry="gemm", b=300, k=256, n=256))
    assert plan.bb == 128 and plan.grid[0] == 3


def test_contract_violation_names_kernel_and_invariant():
    err = ContractViolation("bitplane_gemv", "vmem-budget", "too big",
                            tile=3)
    assert err.kernel == "bitplane_gemv"
    assert err.invariant == "vmem-budget"
    assert err.tile == 3
    assert isinstance(err, ValueError)         # legacy call sites catch this
    assert "vmem-budget" in str(err) and "tile 3" in str(err)


# ---------------------------------------------------------------------------
# Integration: kernels raise ContractViolation; the interpret backend and
# pud_matmul(check_contracts=True) pre-flight through the checker.
# ---------------------------------------------------------------------------


def _pack(k=64, n=64, wb=4):
    planes = np.ones((wb, k, n), np.int8)
    return jnp.asarray(pack_plane_words(planes))


def test_kernel_wrappers_raise_contract_violation():
    be = get_backend("pallas")
    x = jnp.ones((2, 60), jnp.int8)
    planes = jnp.ones((4, 64, 64), jnp.int8)
    with pytest.raises(ContractViolation) as exc:
        be.gemv(x, planes, "folded")
    assert exc.value.invariant == "k-mismatch"


def test_interpret_backend_checks_unconditionally():
    be = get_backend("interpret")
    x = jnp.ones((1, 64), jnp.int8)
    planes = jnp.ones((4, 64, 64), jnp.int8)
    ids = jnp.arange(64, dtype=jnp.int32)
    # window_block=63 does not tile the 64-wide window: the *checker* (not
    # the kernel wrapper's own runtime check) sees it first.
    with pytest.raises(ContractViolation) as exc:
        be.gemv_placed(x, planes, ids, "folded", window_block=63)
    assert exc.value.invariant == "window-tiling"
    # an oversized whole-window placed layout trips the VMEM budget, which
    # only exists in the checker
    big = jnp.zeros((4, 2048, 1 << 15), jnp.int8)
    big_ids = jnp.arange(256, dtype=jnp.int32)
    with pytest.raises(ContractViolation) as exc:
        be.gemv_placed(jnp.ones((8, 2048), jnp.int8), big, big_ids, "folded")
    assert exc.value.invariant == "vmem-budget"


def test_pud_matmul_preflight_opt_in():
    words = _pack()
    scale = jnp.ones((64,), jnp.float32)
    bad_x = jnp.ones((2, 60), jnp.int8)
    # without the flag the reference backend just densifies and pads
    pud_matmul(bad_x, words, scale, mode="folded", layout="bitpack8",
               logical_k=64, backend="reference")
    with pytest.raises(ContractViolation) as exc:
        pud_matmul(bad_x, words, scale, mode="folded", layout="bitpack8",
                   logical_k=64, backend="reference", check_contracts=True)
    assert exc.value.invariant == "bitpack8-logical-k"
    out = pud_matmul(jnp.ones((2, 64), jnp.int8), words, scale,
                     mode="folded", layout="bitpack8", logical_k=64,
                     backend="reference", check_contracts=True)
    assert out.shape == (2, 64)


def test_check_pack_accepts_session_built_pack():
    from repro.pud.gemv import pack_linear
    pt = pack_linear(np.random.default_rng(0).normal(size=(48, 32)))
    plans = contracts.check_pack(pt, batch=4)
    assert len(plans) == 2                     # gemv + gemm


# ---------------------------------------------------------------------------
# CLI + generated docs: what the CI job runs must pass on the repo as
# committed.
# ---------------------------------------------------------------------------


def test_cli_exits_zero_on_repo():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis"],
        cwd=REPO_ROOT, capture_output=True, text=True,
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_flags_and_exit_codes(tmp_path):
    from repro.analysis.__main__ import main
    assert main(["--contracts-only"]) == 0
    assert main(["--lint-only"]) == 0
    # a file violating a rule drives the exit code nonzero
    bad = tmp_path / "src" / "repro" / "models" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import jax\nkey = jax.random.key(7)\n")
    assert main(["--lint-only", str(bad)]) == 1


def test_doc_table_in_sync():
    assert contracts.check_doc_table(REPO_ROOT / "docs" / "kernels.md") == []


def test_doc_drift_detected(tmp_path):
    doc = tmp_path / "kernels.md"
    doc.write_text("# x\n" + contracts.doc_table_block().replace(
        "2.0 KiB", "3.0 KiB") + "\n")
    assert contracts.check_doc_table(doc) != []
    contracts.write_doc_table(doc)             # --write-docs repairs it
    assert contracts.check_doc_table(doc) == []


@pytest.mark.skipif(shutil.which("ruff") is None,
                    reason="ruff not installed in this environment")
def test_ruff_clean():
    proc = subprocess.run(["ruff", "check", "src", "tests"],
                          cwd=REPO_ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
