"""Column-placement subsystem: allocator invariants, placed Pallas kernel
bit-exactness, placement-aware packing, fault injection (the proof that
placement matters), persistence, and the occupancy-derived perf model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.kernels import ref
from repro.kernels.bitplane_gemv import bitplane_gemv, bitplane_gemv_placed
from repro.models.params import init_params
from repro.pud.gemv import (ATTN_PACKABLE, FFN_PACKABLE, FleetPerfModel,
                            PUDGemvConfig, pack_linear, pud_linear)
from repro.pud.packer import pack_for_serving, packing_requests
from repro.pud.placement import (Placement, PlacementError, PlacementRequest,
                                 inject_read_faults, plan_for_grid,
                                 plan_placement, requests_fingerprint)

PUD_ATTN = PUDGemvConfig(weight_bits=4,
                         packable=FFN_PACKABLE + ATTN_PACKABLE)


def _masks(g=4, c=128, p=0.3, seed=0):
    return np.random.default_rng(seed).random((g, c)) < p


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

def test_allocator_avoids_faulty_unique_and_spills():
    masks = _masks()
    reqs = [PlacementRequest("a", 64, 0), PlacementRequest("b", 100, 2)]
    p = plan_placement(masks, reqs)
    flat = masks.reshape(-1)
    seen = set()
    for name, tp in p.entries.items():
        cols = np.asarray(tp.phys_cols).reshape(-1)
        assert not flat[cols].any(), f"{name} placed on faulty columns"
        assert not (seen & set(cols.tolist())), f"{name} overlaps"
        seen |= set(cols.tolist())
        # local maps address inside the window
        assert (np.asarray(tp.local_cols) >= 0).all()
        assert (np.asarray(tp.local_cols) < tp.region_size).all()
    assert p.used_total == 64 + 2 * 100 == len(seen)
    assert p.usable_total == int((~masks).sum())
    np.testing.assert_array_equal(p.usable_per_subarray,
                                  (~masks).sum(axis=1))
    assert p.used_per_subarray.sum() == p.used_total
    # 264 demanded > ~90 free cols/subarray: something must spill
    assert p.spilled_tensors
    rep = p.capacity_report()
    assert rep["occupancy"] == pytest.approx(p.used_total / p.usable_total)


def test_allocator_identity_layout_is_sequential():
    masks = _masks()
    p = plan_placement(masks, [PlacementRequest("t", 96, 0)],
                       avoid_faulty=False)
    np.testing.assert_array_equal(np.asarray(p.entries["t"].phys_cols),
                                  np.arange(96))
    assert not p.avoid_faulty
    # the identity layout does land on faulty silicon here
    assert masks.reshape(-1)[:96].any()


def test_allocator_capacity_error():
    masks = _masks()
    with pytest.raises(PlacementError, match="exceeds usable capacity"):
        plan_placement(masks, [PlacementRequest("huge", 10**5, 0)])


def test_requests_fingerprint_stable():
    reqs = [PlacementRequest("a", 64, 0), PlacementRequest("b", 32, 2)]
    assert requests_fingerprint(reqs) == requests_fingerprint(list(reqs))
    assert requests_fingerprint(reqs) != requests_fingerprint(reqs[:1])


# ---------------------------------------------------------------------------
# Placed kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,n,wb,p", [
    (2, 64, 64, 4, 97), (4, 256, 256, 4, 400), (3, 128, 256, 2, 300),
])
@pytest.mark.parametrize("mode", ["planes", "folded"])
def test_placed_kernel_bit_exact(b, k, n, wb, p, mode):
    kx, kw = jax.random.split(jax.random.key(b + k + n + wb))
    x = jax.random.randint(kx, (b, k), -127, 128, jnp.int32).astype(jnp.int8)
    w = jax.random.randint(kw, (k, n), -(1 << (wb - 1)), 1 << (wb - 1),
                           jnp.int32)
    planes = ref.pack_bitplanes(w, wb)
    cols = np.random.default_rng(p).choice(p, n, replace=False)
    col_ids = jnp.asarray(cols, jnp.int32)
    phys = jnp.zeros((wb, k, p), jnp.int8).at[:, :, col_ids].set(planes)
    got = bitplane_gemv_placed(x, phys, col_ids, mode=mode)
    want = ref.bitplane_gemv_placed_ref(x, phys, col_ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # placed result == unplaced kernel on the logical planes
    direct = bitplane_gemv(x, planes, mode=mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(direct))


def test_pud_linear_placed_matches_unplaced():
    """Scattering *bit-words* along the window axis == packing the scattered
    dense window: the column axis is untouched by K-axis bit-packing, so a
    hand-built placed pack can be assembled directly from word packs."""
    masks = _masks(g=2, c=256, p=0.2, seed=7)
    kx, kw = jax.random.split(jax.random.key(5))
    x = jax.random.normal(kx, (3, 64), jnp.float32)
    w = 0.05 * jax.random.normal(kw, (64, 128), jnp.float32)
    p = plan_placement(masks, [PlacementRequest("t", 128, 0)])
    tp = p.entries["t"]
    pk = pack_linear(w, 4)                      # bit-packed words by default
    idx = jnp.asarray(np.asarray(tp.local_cols), jnp.int32)
    phys = jnp.zeros(pk["planes"].shape[:2] + (tp.region_size,),
                     jnp.uint8).at[:, :, idx].set(pk["planes"])
    placed_pack = {"planes": phys, "scale": pk["scale"], "col_ids": idx}
    np.testing.assert_array_equal(np.asarray(pud_linear(x, placed_pack)),
                                  np.asarray(pud_linear(x, pk)))
    # and the dense (legacy-layout) hand-built pack agrees bit-for-bit
    dk = pack_linear(w, 4, bitpack=False)
    dense = jnp.zeros(dk["planes"].shape[:2] + (tp.region_size,),
                      jnp.int8).at[:, :, idx].set(dk["planes"])
    np.testing.assert_array_equal(
        np.asarray(pud_linear(x, {"planes": dense, "scale": dk["scale"],
                                  "col_ids": idx})),
        np.asarray(pud_linear(x, pk)))


def test_block_aligned_window_blocks_over_p():
    """The tentpole layout guarantee: a placed tensor with N > PLACE_BLOCK
    gets a multi-block window — every logical block's columns sit inside
    its own window slice (the kernel streams one slice per N-tile instead
    of the whole physical region), and the placed pack is bit-exact."""
    from repro.pud.gemv import pack_linear, pud_linear
    from repro.pud.packer import pack_model
    from repro.pud.placement import PLACE_BLOCK
    n, k = 2 * PLACE_BLOCK, 64
    masks = _masks(g=4, c=512, p=0.15, seed=11)
    plan = plan_placement(masks, [PlacementRequest("m/wi", n, 0)])
    tp = plan.entries["m/wi"]
    assert tp.block_cols == PLACE_BLOCK and tp.n_blocks == 2
    # window stride is bounded by the faulty interleave, not the region span
    assert tp.window_block < tp.phys_cols.max() - tp.phys_cols.min() + 1
    local = np.asarray(tp.local_cols)
    blk = np.arange(n) // tp.block_cols
    assert (local // tp.window_block == blk).all(), \
        "logical block j's columns must live inside window block j"

    w = 0.05 * np.random.default_rng(1).standard_normal((k, n))
    params = {"m": {"wi": jnp.asarray(w, jnp.float32)}}
    pm = pack_model(params, PUDGemvConfig(packable=("wi",)),
                    include_unembed=False, placement=plan)
    pt = pm.tensor("m/wi")
    assert pt.window_block == tp.window_block
    assert pt.planes.shape[-1] == tp.region_size       # blocked window axis
    x = jax.random.normal(jax.random.key(3), (3, k), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(pud_linear(x, pt)),
        np.asarray(pud_linear(x, pack_linear(jnp.asarray(w, jnp.float32),
                                             4))))


# ---------------------------------------------------------------------------
# Packing + model integration
# ---------------------------------------------------------------------------

def test_packing_requests_cover_attention_and_unembed():
    model = get("qwen3-1.7b").make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    names = {r.name for r in packing_requests(params, PUD_ATTN)}
    assert "unembed/w" in names
    assert any(n.endswith("attn/wq") for n in names)
    assert any(n.endswith("mixer/wi") for n in names)
    # default config: FFN only, no attention
    ffn_names = {r.name for r in packing_requests(params, PUDGemvConfig())}
    assert not any("attn" in n for n in ffn_names)


def test_attention_packing_decodes():
    model = get("qwen3-1.7b").make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    packed, report = pack_for_serving(params, PUD_ATTN)
    assert any(p.endswith("attn/wq") for p in report["packed"])
    layer_key = next(k for k in packed if k.startswith("layers_"))
    assert "wq_pud" in packed[layer_key]["attn"]
    assert "wq" not in packed[layer_key]["attn"]
    toks = jax.random.randint(jax.random.key(2), (2, 8), 0,
                              model.cfg.vocab, jnp.int32)
    logits_ref, _ = model.prefill(params, toks, max_len=12)
    logits_pud, cache = model.prefill(packed, toks, max_len=12)
    assert not bool(jnp.isnan(logits_pud).any())
    agree = float((jnp.argmax(logits_pud, -1)
                   == jnp.argmax(logits_ref, -1)).mean())
    assert agree >= 0.5, agree
    nxt = jnp.argmax(logits_pud, -1).astype(jnp.int32)[:, None]
    step_logits, _ = model.decode_step(packed, cache, nxt, jnp.int32(8))
    assert not bool(jnp.isnan(step_logits).any())


def test_mla_attention_packing_runs():
    model = get("deepseek-v2-lite-16b").make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    packed, report = pack_for_serving(params, PUD_ATTN)
    assert any(p.endswith("attn/wq") for p in report["packed"])
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0,
                              model.cfg.vocab, jnp.int32)
    logits, cache = model.prefill(packed, toks, max_len=12)
    assert not bool(jnp.isnan(logits).any())
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    step_logits, _ = model.decode_step(packed, cache, nxt, jnp.int32(8))
    assert not bool(jnp.isnan(step_logits).any())


def test_placed_pack_bit_identical_to_logical_pack():
    model = get("qwen3-1.7b").make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    reqs = packing_requests(params, PUD_ATTN)
    placement = plan_placement(_masks(g=8, c=512, p=0.25, seed=3), reqs)
    plain, _ = pack_for_serving(params, PUD_ATTN)
    placed, report = pack_for_serving(params, PUD_ATTN, placement=placement)
    assert report["placed"]
    toks = jax.random.randint(jax.random.key(2), (2, 8), 0,
                              model.cfg.vocab, jnp.int32)
    lg_plain, _ = model.prefill(plain, toks, max_len=12)
    lg_placed, _ = model.prefill(placed, toks, max_len=12)
    np.testing.assert_array_equal(np.asarray(lg_placed), np.asarray(lg_plain))


def test_pack_with_incomplete_placement_raises():
    model = get("qwen3-1.7b").make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    placement = plan_placement(
        _masks(g=8, c=512, seed=1), [PlacementRequest("unembed/w", 256, 0)])
    with pytest.raises(KeyError, match="no entry"):
        pack_for_serving(params, PUD_ATTN, placement=placement)


# ---------------------------------------------------------------------------
# Fault injection: the acceptance test that placement matters
# ---------------------------------------------------------------------------

def test_fault_injection_placed_exact_unplaced_corrupted():
    """Decode logits are bit-identical under injected faulty-column reads
    with placement enabled, and measurably corrupted with it disabled."""
    model = get("qwen3-1.7b").make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    reqs = packing_requests(params, PUD_ATTN)
    masks = _masks(g=8, c=512, p=0.25, seed=3)
    placed_plan = plan_placement(masks, reqs, avoid_faulty=True)
    ident_plan = plan_placement(masks, reqs, avoid_faulty=False)

    packed_placed, _ = pack_for_serving(params, PUD_ATTN,
                                        placement=placed_plan)
    packed_ident, _ = pack_for_serving(params, PUD_ATTN,
                                       placement=ident_plan)

    def decode_logits(p):
        toks = jax.random.randint(jax.random.key(2), (2, 8), 0,
                                  model.cfg.vocab, jnp.int32)
        logits, cache = model.prefill(p, toks, max_len=12)
        out = [logits]
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for i in range(2):
            logits, cache = model.decode_step(p, cache, nxt, jnp.int32(8 + i))
            out.append(logits)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return jnp.stack(out, axis=1)

    clean = decode_logits(packed_placed)
    # sanity: identity layout is numerically identical while fault-free
    np.testing.assert_array_equal(np.asarray(decode_logits(packed_ident)),
                                  np.asarray(clean))

    hurt_placed = decode_logits(inject_read_faults(packed_placed,
                                                   placed_plan))
    hurt_ident = decode_logits(inject_read_faults(packed_ident, ident_plan))
    # placement dodges every corrupted column: bit-identical logits
    np.testing.assert_array_equal(np.asarray(hurt_placed), np.asarray(clean))
    # the logical layout computes on faulty columns: logits break
    delta = float(jnp.abs(hurt_ident - clean).max())
    assert delta > 0.1, delta


def test_inject_requires_matching_placement():
    model = get("qwen3-1.7b").make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    reqs = packing_requests(params, PUD_ATTN)
    plan = plan_placement(_masks(g=8, c=512, seed=3), reqs)
    packed, _ = pack_for_serving(params, PUD_ATTN, placement=plan)
    empty = dataclasses.replace(plan, entries={})
    with pytest.raises(KeyError, match="no placement entry"):
        inject_read_faults(packed, empty)


# ---------------------------------------------------------------------------
# Persistence + perf model
# ---------------------------------------------------------------------------

def test_placement_cache_round_trip(tmp_path):
    from repro.core.fleet import FleetConfig
    from repro.pud.physics import PhysicsParams
    from repro.runtime.calib_cache import CalibrationTableCache
    cfg = FleetConfig(n_channels=1, n_banks=1, n_subarrays=4, n_cols=128)
    phys = PhysicsParams()
    cache = CalibrationTableCache(tmp_path)
    masks = _masks(g=4, c=128, seed=9)
    levels = np.zeros((4, 128), np.int32)
    plan = plan_for_grid(masks, [PlacementRequest("unembed/w", 96, 0),
                                 PlacementRequest("l/mixer/wi", 32, 2)],
                         cfg.grid_shape)
    # placement cannot be saved before its table exists
    with pytest.raises(FileNotFoundError):
        cache.save_placement("d1", cfg, phys, "m0", plan)
    cache.save("d1", cfg, phys, levels, masks=masks)
    cache.save_placement("d1", cfg, phys, "m0", plan)
    assert cache.placements("d1", cfg, phys) == ["m0"]
    got = cache.load_placement("d1", cfg, phys, "m0")
    assert got is not None
    assert got.grid_shape == cfg.grid_shape
    assert sorted(got.entries) == sorted(plan.entries)
    for name in plan.entries:
        np.testing.assert_array_equal(got.entries[name].phys_cols,
                                      plan.entries[name].phys_cols)
        np.testing.assert_array_equal(got.entries[name].faulty,
                                      plan.entries[name].faulty)
    assert got.capacity_report() == plan.capacity_report()
    # unknown name and corrupt payload read as misses
    assert cache.load_placement("d1", cfg, phys, "other") is None
    path = next((tmp_path / "d1").glob("*/placements/m0.npz"))
    path.write_bytes(path.read_bytes()[:32])
    assert cache.load_placement("d1", cfg, phys, "m0") is None


def test_fleet_perf_model_from_placement():
    masks = _masks(g=4, c=128, p=0.2, seed=2)
    plan = plan_placement(masks, [PlacementRequest("t", 150, 0)])
    m = FleetPerfModel.from_placement(plan, n_fracs=3)
    used = np.asarray(plan.used_per_subarray, float)
    occ = used[used > 0] / plan.n_cols_per_subarray
    assert len(m.error_free_fracs) == occ.size
    np.testing.assert_allclose(sorted(m.error_free_fracs), sorted(occ))
    # occupancy-derived rate is bounded by the all-error-free rate
    full = FleetPerfModel(error_free_fracs=(1.0,), n_fracs=3)
    assert m.macs_per_second < full.macs_per_second
    with pytest.raises(ValueError):
        FleetPerfModel.from_placement(
            dataclasses.replace(
                plan, used_per_subarray=np.zeros(4, np.int32)))


def test_placement_is_a_pytree():
    plan = plan_placement(_masks(seed=4), [PlacementRequest("t", 32, 0)])
    leaves = jax.tree_util.tree_leaves(plan)
    assert any(l is plan.entries["t"].phys_cols for l in leaves)
    mapped = jax.tree_util.tree_map(lambda x: x, plan)
    assert isinstance(mapped, Placement)
    np.testing.assert_array_equal(mapped.entries["t"].phys_cols,
                                  plan.entries["t"].phys_cols)
