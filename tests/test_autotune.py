"""Kernel autotuner: the degenerate-tile fix, candidate generation and
contract pruning, tile-plan pre-flight (window-stride rule), and
bit-exactness of tuned plans vs the heuristic across every backend on
logical and placed layouts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import check_tile_plan
from repro.analysis.errors import ContractViolation
from repro.kernels.autotune import (TunedTile, candidate_plans, median_time,
                                    plan_for_entry, tune_kernel, tuning_key,
                                    valid_candidates)
from repro.kernels.backends import backend_names, get_backend
from repro.kernels.bitplane_gemv import bitplane_gemv
from repro.kernels.ops import (DEGENERATE_TILE_FLOOR, K_BLOCK, N_BLOCK,
                               heuristic_block, largest_divisor, pud_matmul)
from repro.kernels.ref import bitplane_gemv_ref, pack_bitplanes

WB = 4


def _fixture(k=64, n=96, b=1, key=0):
    w = jax.random.randint(jax.random.key(key), (k, n), -8, 8, jnp.int32)
    planes = pack_bitplanes(w, WB)
    x = jax.random.randint(jax.random.key(key + 1), (b, k), -127, 128,
                           jnp.int32).astype(jnp.int8)
    return x, planes


PWB = 16            # pack window stride of the placed fixtures


def _placed_fixture(k=64, n=96, b=1, key=0, block_cols=12):
    """Block-aligned placed layout: n_blocks windows of PWB physical
    columns, ``block_cols`` logical columns packed at the head of each
    (the layout ``plan_placement`` emits)."""
    x, planes = _fixture(k, n, b, key)
    n_blocks = n // block_cols
    w_len = n_blocks * PWB
    cols = jnp.arange(n)
    col_ids = ((cols // block_cols) * PWB + cols % block_cols) \
        .astype(jnp.int32)
    window = jnp.zeros((WB, k, w_len), jnp.int8).at[:, :, col_ids] \
        .set(planes)
    return x, window, col_ids


# ---------------------------------------------------------------------------
# Degenerate-tile fix (prime N or K used to select 1-wide tiles)
# ---------------------------------------------------------------------------

def test_largest_divisor_degenerates_on_primes():
    assert largest_divisor(509, K_BLOCK) == 1
    assert largest_divisor(127, 64) == 1


def test_heuristic_block_pads_degenerate_dims():
    """Primes fall back to the padded power-of-two block instead of 1."""
    assert heuristic_block(509, K_BLOCK) == K_BLOCK      # pow2 capped
    assert heuristic_block(127, 64) == 64
    # dims with a real divisor keep the exact-divisor tiling
    assert heuristic_block(300, K_BLOCK) == 150
    assert heuristic_block(172, N_BLOCK) == 172
    assert heuristic_block(2048, K_BLOCK) == K_BLOCK
    # tiny dims are their own (whole) block, never padded
    assert heuristic_block(6, K_BLOCK) == 6
    assert heuristic_block(DEGENERATE_TILE_FLOOR, K_BLOCK) == \
        DEGENERATE_TILE_FLOOR


@pytest.mark.parametrize("k,n", [(509, 127), (127, 509)])
@pytest.mark.parametrize("mode", ["planes", "folded"])
def test_prime_shape_bit_exact(k, n, mode):
    """The shapes from the bug report: prime K and N run on padded blocks
    (zero pads are inert in the integer dot products) and stay bit-exact
    against the einsum oracle."""
    x, planes = _fixture(k=k, n=n, b=1, key=3)
    got = bitplane_gemv(x, planes, mode=mode)
    want = bitplane_gemv_ref(x, planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.shape == (1, n)


def test_prime_shape_gemm_all_backends():
    x, planes = _fixture(k=509, n=127, b=5, key=4)
    want = np.asarray(get_backend("reference").matmul(x, planes))
    for name in backend_names():
        got = np.asarray(get_backend(name).matmul(x, planes))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{name} != reference")


# ---------------------------------------------------------------------------
# TunedTile / plan resolution / keys
# ---------------------------------------------------------------------------

def test_tuned_tile_round_trip_and_default():
    assert TunedTile().is_default()
    assert TunedTile().to_dict() == {}
    plan = TunedTile(n_block=64, k_block=32, mode="planes")
    assert not plan.is_default()
    assert TunedTile.from_dict(plan.to_dict()) == plan
    assert hash(plan) == hash(TunedTile(n_block=64, k_block=32,
                                        mode="planes"))
    with pytest.raises(ValueError, match="unknown TunedTile fields"):
        TunedTile.from_dict({"n_block": 64, "bogus": 1})


def test_plan_for_entry_resolution():
    gemv_plan = TunedTile(k_block=32)
    gemm_plan = TunedTile(b_block=4, k_block=64)
    stamp = (("gemm", gemm_plan), ("gemv", gemv_plan))
    assert plan_for_entry(None, "gemv") is None
    assert plan_for_entry(gemv_plan, "gemm") is gemv_plan  # shared stamp
    assert plan_for_entry(stamp, "gemv") is gemv_plan
    assert plan_for_entry(stamp, "gemm") is gemm_plan
    assert plan_for_entry((("gemm", gemm_plan),), "gemv") is None


def test_tuning_key_coordinates():
    key = tuning_key("gemv", 1, 64, 96, 4, "bitpack8", placed=True)
    assert key == "gemv__placed__bitpack8__1x64x96@4b"
    assert tuning_key("gemm", 8, 64, 96, 4, "dense", placed=False) == \
        "gemm__logical__dense__8x64x96@4b"


# ---------------------------------------------------------------------------
# Candidate generation + contract pruning
# ---------------------------------------------------------------------------

def test_candidates_heuristic_first_and_unique():
    plans = candidate_plans("gemm", 8, 2048, 2048)
    assert plans[0].is_default()
    assert len(set(plans)) == len(plans)
    assert sum(1 for p in plans if p.is_default()) == 1


def test_valid_candidates_all_pass_contracts():
    x, planes = _fixture(k=64, n=96, b=8)
    plans = candidate_plans("gemm", 8, 64, 96)
    valid = valid_candidates(plans, "gemm", x.shape, planes.shape)
    assert valid and valid[0].is_default()
    for plan in valid:                        # re-check: none may raise
        check_tile_plan(plan, "gemm", x.shape, planes.shape)


def test_over_budget_tuned_tile_is_pruned():
    """A tuned tile that would blow the 4 MiB VMEM gate never reaches the
    timer — the same adversarial fixture the static gate carries."""
    huge = TunedTile(b_block=128, n_block=4096, k_block=4096)
    with pytest.raises(ContractViolation, match="vmem-budget"):
        check_tile_plan(huge, "gemm", (128, 4096), (4, 4096, 4096))
    valid = valid_candidates([TunedTile(), huge], "gemm", (128, 4096),
                             (4, 4096, 4096))
    assert huge not in valid and valid[0].is_default()


def test_window_stride_rule():
    """Tuned window_block must be c x pack stride with c dividing the
    block count; anything else gathers the wrong physical columns."""
    x, window, col_ids = _placed_fixture()
    shapes = dict(layout="dense", col_ids=col_ids, window_block=PWB)
    # 8 blocks of 16: c=2 and c=4 group cleanly ...
    check_tile_plan(TunedTile(window_block=2 * PWB), "gemv", x.shape,
                    window.shape, **shapes)
    check_tile_plan(TunedTile(window_block=4 * PWB), "gemv", x.shape,
                    window.shape, **shapes)
    # ... non-multiples and non-dividing multipliers do not
    for bad in (24, 48, 15, -16):
        with pytest.raises(ContractViolation, match="window-stride"):
            check_tile_plan(TunedTile(window_block=bad), "gemv", x.shape,
                            window.shape, **shapes)
    # a window_block override on a logical (non-placed) call is meaningless
    with pytest.raises(ContractViolation, match="tile-plan"):
        check_tile_plan(TunedTile(window_block=2 * PWB), "gemv", x.shape,
                        window.shape)


def test_gemv_rejects_b_block_and_bitpack8_word_rule():
    x, planes = _fixture()
    with pytest.raises(ContractViolation, match="tile-plan"):
        check_tile_plan(TunedTile(b_block=8), "gemv", x.shape, planes.shape)
    from repro.kernels.ref import pack_plane_words
    words = pack_plane_words(planes)
    with pytest.raises(ContractViolation, match="tile-plan"):
        check_tile_plan(TunedTile(k_block=12), "gemv", x.shape, words.shape,
                        layout="bitpack8", logical_k=64)


# ---------------------------------------------------------------------------
# tune_kernel: search, winner, bit-exactness guarantees
# ---------------------------------------------------------------------------

def test_tune_kernel_returns_valid_winner():
    x, planes = _fixture(k=64, n=96)
    res = tune_kernel("gemv", x, planes, reps=1, max_candidates=6)
    assert res.key == tuning_key("gemv", 1, 64, 96, WB, "dense", False)
    assert res.heuristic_s > 0 and res.tuned_s > 0
    assert res.tuned_s <= res.heuristic_s          # heuristic is candidate #0
    assert res.speedup >= 1.0
    assert 1 <= res.n_candidates <= 6
    stats = res.to_stats()
    assert set(stats) == {"tuned_s", "heuristic_s", "speedup",
                          "n_candidates"}


def test_tune_kernel_rejects_unknown_entry():
    x, planes = _fixture()
    with pytest.raises(ContractViolation, match="entry"):
        tune_kernel("conv", x, planes)


@pytest.mark.parametrize("b,entry", [(1, "gemv"), (6, "gemm")])
def test_tuned_plans_bit_exact_all_backends_logical(b, entry):
    """Every valid candidate plan — not just the winner — computes the
    identical integer result on every registered backend."""
    x, planes = _fixture(k=64, n=96, b=b, key=7)
    plans = valid_candidates(candidate_plans(entry, b, 64, 96), entry,
                             x.shape, planes.shape)[:5]
    assert len(plans) >= 2                    # heuristic + a real override
    want = np.asarray(pud_matmul(x.astype(jnp.float32), planes, 1.0))
    for name in backend_names():
        for plan in plans:
            got = np.asarray(pud_matmul(x.astype(jnp.float32), planes, 1.0,
                                        backend=name, tile_plan=plan))
            np.testing.assert_array_equal(
                got, want, err_msg=f"{name} with {plan.to_dict()}")


@pytest.mark.parametrize("b,entry", [(1, "gemv"), (6, "gemm")])
def test_tuned_plans_bit_exact_all_backends_placed(b, entry):
    x, window, col_ids = _placed_fixture(b=b, key=9)
    w_len = int(window.shape[-1])
    plans = valid_candidates(
        candidate_plans(entry, b, 64, 96, placed_window=w_len,
                        pack_window_block=PWB),
        entry, x.shape, window.shape, col_ids=col_ids,
        window_block=PWB)[:6]
    assert any(p.window_block for p in plans)  # stride grouping searched
    want = np.asarray(pud_matmul(x.astype(jnp.float32), window, 1.0,
                                 col_ids=col_ids, window_block=PWB))
    for name in backend_names():
        for plan in plans:
            got = np.asarray(pud_matmul(
                x.astype(jnp.float32), window, 1.0, col_ids=col_ids,
                window_block=PWB, backend=name, tile_plan=plan))
            np.testing.assert_array_equal(
                got, want, err_msg=f"{name} with {plan.to_dict()}")


def test_tune_kernel_bitpack8_placed_search():
    """The full-fat coordinate: bit-packed words + placed window, searched
    end to end (this is the serving hot path's tuning problem)."""
    from repro.kernels.ref import pack_plane_words
    x, window, col_ids = _placed_fixture(k=64, n=96, key=11)
    words = pack_plane_words(window)
    res = tune_kernel("gemv", x, words, col_ids=col_ids, window_block=PWB,
                      layout="bitpack8", logical_k=64, reps=1,
                      max_candidates=5)
    assert res.key == tuning_key("gemv", 1, 64, 96, WB, "bitpack8", True)
    assert res.speedup >= 1.0


def test_median_time_returns_output():
    t, out = median_time(lambda: jnp.arange(4), warmup=1, reps=3)
    assert t >= 0
    np.testing.assert_array_equal(np.asarray(out), np.arange(4))
