"""Chunked prefill + prefix cache + SLO admission: the scheduler
extensions must be invisible in the outputs — tokens AND logits
bit-identical to the whole-request engine — across backends and layouts,
with bounded compile counts, correct prefix reuse/invalidation, and
deterministic SLO shedding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CalibrationConfig, FleetConfig, PrefixCache,
                       PUDGemvConfig, PUDSession, Request, ServingEngine,
                       SLOConfig, backend_names)
from repro.models.transformer import TransformerLM
from repro.models.params import init_params
from repro.configs import get
from repro.runtime.engine import FleetServingEngine

MAX_LEN = 32
GEN = 4


@pytest.fixture(scope="module")
def smoke():
    spec = get("qwen3-1.7b")
    model = spec.make_smoke()
    params = init_params(model.param_defs(), jax.random.key(0))
    return model, params


def _prompts(model, lens, key=1):
    k = jax.random.key(key)
    return [np.asarray(jax.random.randint(
        jax.random.fold_in(k, i), (s,), 0, model.cfg.vocab, jnp.int32))
        for i, s in enumerate(lens)]


def _requests(prompts, gen=GEN):
    return [Request(request_id=i, tokens=p, max_new_tokens=gen)
            for i, p in enumerate(prompts)]


def _assert_same(comps_a, comps_b):
    assert [c.request_id for c in comps_a] == [c.request_id for c in comps_b]
    for a, b in zip(comps_a, comps_b):
        assert a.tokens == b.tokens, a.request_id
        if a.logits is not None and b.logits is not None:
            np.testing.assert_array_equal(a.logits, b.logits,
                                          err_msg=str(a.request_id))


def _session(backend="pallas", calibrate=True):
    s = PUDSession.open(
        "qwen3-1.7b",
        grid=FleetConfig(n_channels=1, n_banks=1, n_subarrays=8,
                         n_cols=1024),
        calib=CalibrationConfig(n_iterations=4, n_samples=64),
        key=7, n_trials_ecr=128, backend=backend)
    if calibrate:
        s.calibrate()
    return s


# ---------------------------------------------------------------------------
# Bit-exactness: chunked + cached == whole-request, raw / placed / logical
# ---------------------------------------------------------------------------

def test_chunked_cached_equals_whole_raw(smoke):
    """Ragged prompts through the chunked+prefix engine produce the same
    tokens and logits as the whole-request engine, bit for bit."""
    model, params = smoke
    prompts = _prompts(model, [5, 8, 11, 4, 16, 9, 3])
    whole = ServingEngine(model, params, max_len=MAX_LEN, batch_size=3,
                          collect_logits=True)
    chunked = ServingEngine(model, params, max_len=MAX_LEN, batch_size=3,
                            collect_logits=True, chunk_prefill=4,
                            prefix_cache=True)
    _assert_same(whole.run(_requests(prompts)),
                 chunked.run(_requests(prompts)))
    rep = chunked.scheduler_report()
    assert rep["prefill_chunks"] > 0          # the chunk path actually ran
    assert rep["prefix_cache"]["inserts"] > 0


@pytest.mark.parametrize("backend", sorted(backend_names()))
def test_chunked_cached_equals_whole_placed(smoke, backend):
    """Placed physical layout, every backend: the scheduling mode must not
    change a single bit of the PUD decode."""
    model, params = smoke
    session = _session(backend=backend)
    packed = session.pack(params, PUDGemvConfig(weight_bits=4),
                          name=f"chunk-{backend}")
    assert packed.placed
    prompts = _prompts(model, [4, 9, 6])
    whole = ServingEngine(model, packed.params, session=session,
                          max_len=MAX_LEN, batch_size=2, collect_logits=True)
    chunked = ServingEngine(model, packed.params, session=session,
                            max_len=MAX_LEN, batch_size=2,
                            collect_logits=True, chunk_prefill=4,
                            prefix_cache=True)
    _assert_same(whole.run(_requests(prompts)),
                 chunked.run(_requests(prompts)))


def test_chunked_cached_equals_whole_logical(smoke):
    model, params = smoke
    session = _session(calibrate=False)
    packed = session.pack(params, PUDGemvConfig(weight_bits=4))
    assert not packed.placed
    prompts = _prompts(model, [7, 12, 5])
    whole = ServingEngine(model, packed.params, session=session,
                          max_len=MAX_LEN, batch_size=2)
    chunked = ServingEngine(model, packed.params, session=session,
                            max_len=MAX_LEN, batch_size=2, chunk_prefill=8,
                            prefix_cache=True)
    _assert_same(whole.run(_requests(prompts)),
                 chunked.run(_requests(prompts)))


def test_chunked_mla_dense(smoke):
    """The MLA chunk path (latent cache re-expansion) is bit-exact too.
    No registry arch is dense MLA, so strip the MoE off the deepseek
    smoke config (MoE itself is sequence-global and stays un-chunked)."""
    cfg = dataclasses.replace(get("deepseek-v2-lite-16b").make_smoke().cfg,
                              n_experts=0)
    model = TransformerLM(cfg)
    assert model.supports_chunked_prefill
    params = init_params(model.param_defs(), jax.random.key(2))
    prompts = _prompts(model, [5, 11, 8], key=3)
    whole = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2,
                          collect_logits=True)
    chunked = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2,
                            collect_logits=True, chunk_prefill=4,
                            prefix_cache=True)
    _assert_same(whole.run(_requests(prompts)),
                 chunked.run(_requests(prompts)))


def test_moe_rejects_chunk_prefill(smoke):
    moe = get("deepseek-v2-lite-16b").make_smoke()
    assert not moe.supports_chunked_prefill
    params = init_params(moe.param_defs(), jax.random.key(0))
    with pytest.raises(ValueError, match="sequence-global"):
        ServingEngine(moe, params, max_len=MAX_LEN, chunk_prefill=4)


# ---------------------------------------------------------------------------
# Compile-count satellite: ragged prompts share pow2 buckets
# ---------------------------------------------------------------------------

def test_bounded_prefill_compiles_across_ragged_prompts(smoke):
    """20 ragged prompt lengths compile O(log max_len) prefill variants,
    not one per length (the static-s recompilation blowup)."""
    model, params = smoke
    rng = np.random.default_rng(0)
    lens = [int(x) for x in rng.integers(2, MAX_LEN - GEN, size=20)]
    assert len(set(lens)) > 6                 # genuinely ragged
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=4)
    eng.run(_requests(_prompts(model, lens)))
    # buckets <= {2,4,8,16,32}: one whole-prefill trace per bucket
    assert eng.prefill_trace_count <= 5, eng.scheduler_report()

    chunked = ServingEngine(model, params, max_len=MAX_LEN, batch_size=4,
                            chunk_prefill=8)
    chunked.run(_requests(_prompts(model, lens)))
    # chunk traces: one per (chunk, bucket) pair actually exercised
    assert chunked.prefill_trace_count <= 6, chunked.scheduler_report()
    _assert_same(sorted(eng._completions, key=lambda c: c.request_id),
                 sorted(chunked._completions, key=lambda c: c.request_id))


# ---------------------------------------------------------------------------
# Prefix cache: full hit, partial hit, boundary + invalidation cases
# ---------------------------------------------------------------------------

def test_prefix_full_hit_bit_exact(smoke):
    model, params = smoke
    [p] = _prompts(model, [9])
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                        collect_logits=True, chunk_prefill=4,
                        prefix_cache=True)
    a = eng.run([Request(0, p, GEN)])
    chunks_before = eng.scheduler_report()["prefill_chunks"]
    b = eng.run([Request(1, p, GEN)])
    st = eng.scheduler_report()
    assert st["prefix_cache"]["hits"] >= 1
    # the repeat ran zero prefill chunks: the stored cache+logits replaced it
    assert st["prefill_chunks"] == chunks_before
    assert a[0].tokens == b[0].tokens
    np.testing.assert_array_equal(a[0].logits, b[0].logits)


def test_prefix_partial_hit_resumes_bit_exact(smoke):
    """A shared system prompt hits a chunk-aligned stored prefix; the
    resumed suffix must finish bit-identically to a cold engine."""
    model, params = smoke
    rng = np.random.default_rng(5)
    sysp = rng.integers(0, model.cfg.vocab, size=12).astype(np.int32)
    pa = np.concatenate([sysp, rng.integers(0, model.cfg.vocab,
                                            size=7).astype(np.int32)])
    pb = np.concatenate([sysp, rng.integers(0, model.cfg.vocab,
                                            size=5).astype(np.int32)])
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                        collect_logits=True, chunk_prefill=4,
                        prefix_cache=True)
    eng.run([Request(0, pa, GEN)])
    hits0 = eng.scheduler_report()["prefix_cache"]["hits"]
    got = [c for c in eng.run([Request(1, pb, GEN)]) if c.request_id == 1]
    assert eng.scheduler_report()["prefix_cache"]["hits"] > hits0
    cold = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                         collect_logits=True, chunk_prefill=4)
    ref = cold.run([Request(1, pb, GEN)])
    _assert_same(ref, got)


def test_prefix_longer_than_prompt_not_misused(smoke):
    """Caching a 12-token prompt must not poison a 6-token prompt that is
    its prefix: only stored entries *shorter or equal* to the query can be
    reused (the chunk-aligned sub-prefix), never the longer cache with
    extra live rows."""
    model, params = smoke
    [long_p] = _prompts(model, [12], key=9)
    short_p = long_p[:6]
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                        collect_logits=True, chunk_prefill=4,
                        prefix_cache=True)
    eng.run([Request(0, long_p, GEN)])
    got = [c for c in eng.run([Request(1, short_p, GEN)])
           if c.request_id == 1]
    assert eng.scheduler_report()["prefix_cache"]["hits"] >= 1
    cold = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                         collect_logits=True)
    _assert_same(cold.run([Request(1, short_p, GEN)]), got)


def test_stage_params_invalidates_prefix_cache(smoke):
    model, params = smoke
    [p] = _prompts(model, [8])
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                        chunk_prefill=4, prefix_cache=True)
    eng.run([Request(0, p, GEN)])
    assert eng.scheduler_report()["prefix_cache"]["entries"] > 0
    eng.stage_params(params)                  # hot swap (same tree is fine)
    eng.run([Request(1, p, GEN)])
    st = eng.scheduler_report()["prefix_cache"]
    assert st["invalidations"] == 1
    assert st["invalidated_entries"] > 0
    assert st["hits"] == 0                    # post-swap lookups all missed


def test_prefix_cache_lru_eviction_while_serving(smoke):
    """A capacity-2 LRU keeps serving correctly while evicting: entries
    rotate out under pressure yet every completion stays bit-exact."""
    model, params = smoke
    prompts = _prompts(model, [6, 9, 12, 7], key=11)
    pc = PrefixCache(capacity=2)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2,
                        collect_logits=True, chunk_prefill=4,
                        prefix_cache=pc)
    got = eng.run(_requests(prompts))
    st = eng.scheduler_report()["prefix_cache"]
    assert st["evictions"] > 0 and st["entries"] <= 2
    whole = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2,
                          collect_logits=True)
    _assert_same(whole.run(_requests(prompts)), got)


# ---------------------------------------------------------------------------
# Scheduler edge cases: shed mid-prefill, zero budget, degenerate chunks
# ---------------------------------------------------------------------------

def test_shed_while_prefilling(smoke):
    """Evicting a slot in the *prefilling* phase discards its private
    chunk cache without corrupting the neighbours' decode."""
    model, params = smoke
    prompts = _prompts(model, [16, 6], key=13)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2,
                        collect_logits=True, chunk_prefill=4,
                        prefix_cache=True)
    eng.submit_all(_requests(prompts))
    eng.step()                                # both admitted, one chunk in
    assert any(s is not None and s.phase == "prefill" for s in eng._slots)
    assert eng.shed_request(0)                # still mid-prefill
    comps = eng.run()
    shed = [c for c in comps if c.request_id == 0][0]
    assert shed.shed and shed.slo_met is False and shed.tokens == []
    survivor = [c for c in comps if c.request_id == 1][0]
    cold = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                         collect_logits=True)
    _assert_same(cold.run([Request(1, prompts[1], GEN)]), [survivor])


def test_shed_queued_request(smoke):
    model, params = smoke
    prompts = _prompts(model, [6, 6], key=14)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                        chunk_prefill=4)
    eng.submit_all(_requests(prompts))
    assert eng.shed_request(1)                # never admitted
    assert not eng.shed_request(99)
    comps = eng.run()
    assert [c.request_id for c in comps] == [0, 1]
    assert comps[1].shed and comps[1].tokens == []
    assert len(comps[0].tokens) == GEN


def test_zero_budget_holds_then_completes(smoke):
    """prefill_budget=0 parks prefilling slots with zero progress (and
    run() refuses to spin forever); restoring the budget completes the
    held request bit-exactly."""
    model, params = smoke
    [p] = _prompts(model, [10], key=15)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                        collect_logits=True, chunk_prefill=4,
                        prefill_budget=0)
    eng.submit(Request(0, p, GEN))
    eng.step()
    st = eng._slots[0]
    assert st is not None and st.phase == "prefill" and st.pf.pos == 0
    with pytest.raises(RuntimeError, match="stalled"):
        eng.run()
    eng.prefill_budget = None                 # lift the hold
    got = eng.run()
    cold = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                         collect_logits=True)
    _assert_same(cold.run([Request(0, p, GEN)]), got)


def test_chunk_larger_than_prompt_degenerates_to_whole(smoke):
    """chunk >= bucket: exactly one chunk per prompt, still bit-exact."""
    model, params = smoke
    prompts = _prompts(model, [3, 6], key=16)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2,
                        collect_logits=True, chunk_prefill=MAX_LEN)
    got = eng.run(_requests(prompts))
    assert eng.scheduler_report()["prefill_chunks"] == 2
    whole = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2,
                          collect_logits=True)
    _assert_same(whole.run(_requests(prompts)), got)


# ---------------------------------------------------------------------------
# SLO-aware admission
# ---------------------------------------------------------------------------

def test_slo_shed_on_admit_and_met(smoke):
    model, params = smoke
    prompts = _prompts(model, [6, 8], key=17)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=2,
                        slo=SLOConfig(step_time_ms=10.0))
    eng.submit(Request(0, prompts[0], GEN, deadline_ms=1000.0))
    eng.submit(Request(1, prompts[1], GEN, deadline_ms=0.5))  # hopeless
    comps = eng.run()
    assert comps[1].shed and comps[1].slo_met is False
    assert comps[1].tokens == []              # shed before any compute
    assert comps[0].slo_met is True and len(comps[0].tokens) == GEN
    slo = eng.scheduler_report()["slo"]
    assert slo["shed_on_admit"] == 1 and slo["met"] == 1
    assert slo["step_ms"] == 10.0


def test_slo_sheds_admitted_request_mid_decode(smoke):
    """With admission-time shedding off, a hopeless deadline is admitted
    anyway and then shed mid-flight by the virtual-clock expiry check."""
    model, params = smoke
    [p] = _prompts(model, [6], key=18)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                        slo=SLOConfig(step_time_ms=10.0,
                                      shed_on_admit=False))
    eng.submit(Request(0, p, 8, deadline_ms=15.0))   # ~2 steps of budget
    comps = eng.run()
    assert comps[0].shed and comps[0].slo_met is False
    assert 0 < len(comps[0].tokens) < 8       # partial progress kept
    assert eng.scheduler_report()["slo"]["shed_admitted"] == 1


def test_slo_edf_admission_order(smoke):
    """Tight deadlines jump the queue: EDF admits the later-submitted but
    tighter request first when only one slot is free."""
    model, params = smoke
    prompts = _prompts(model, [4, 4, 4], key=19)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1,
                        slo=SLOConfig(step_time_ms=1.0))
    eng.submit(Request(0, prompts[0], GEN, deadline_ms=10_000.0))
    eng.submit(Request(1, prompts[1], GEN, deadline_ms=10_000.0))
    eng.submit(Request(2, prompts[2], GEN, deadline_ms=50.0))
    comps = eng.run()
    by_id = {c.request_id: c for c in comps}
    assert by_id[2].admitted_step <= by_id[1].admitted_step
    assert all(not c.shed for c in comps)


def test_no_deadline_means_slo_met_none(smoke):
    model, params = smoke
    [p] = _prompts(model, [6], key=20)
    eng = ServingEngine(model, params, max_len=MAX_LEN, batch_size=1)
    comps = eng.run([Request(0, p, GEN)])
    assert comps[0].slo_met is None and not comps[0].shed


# ---------------------------------------------------------------------------
# Fleet: per-lane caches + affinity routing (no mesh required)
# ---------------------------------------------------------------------------

def test_fleet_prefix_affinity_routes_to_warm_lane(smoke):
    model, params = smoke
    [p, q] = _prompts(model, [10, 7], key=21)
    fleet = FleetServingEngine(model, [params, params], max_len=MAX_LEN,
                               batch_size=2, chunk_prefill=4,
                               prefix_cache=True)
    lane_a = fleet.submit(Request(0, p, GEN))
    fleet.run()
    lane_b = fleet.submit(Request(1, p, GEN))     # repeat -> warm lane
    lane_c = fleet.submit(Request(2, q, GEN))     # cold -> round-robin
    comps = fleet.run()
    assert lane_a == lane_b
    assert comps[0].tokens == comps[1].tokens
    rep = fleet.scheduler_report()
    assert rep["prefix_cache"]["hits"] >= 1
    assert len(rep["lanes"]) == 2
    assert lane_c in (0, 1)


def test_fleet_rejects_shared_prefix_cache_instance(smoke):
    model, params = smoke
    with pytest.raises(ValueError, match="per-lane"):
        FleetServingEngine(model, [params, params], max_len=MAX_LEN,
                           prefix_cache=PrefixCache())
