"""Validate the trip-count-aware HLO analyzer against known-flop graphs."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, parse_computations


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((512, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    c = analyze(_hlo(lambda a, b: a @ b, x, w))
    want = 2 * 512 * 256 * 128
    assert abs(c.flops - want) / want < 0.01, (c.flops, want)


def test_scan_multiplies_by_trip_count():
    """THE bug this module exists for: XLA cost_analysis counts a scanned
    body once; the analyzer must multiply by the known trip count."""
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def scanned(a, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, a, None, length=9)
        return y

    c = analyze(_hlo(scanned, x, x))
    want = 9 * 2 * 256 ** 3
    assert abs(c.flops - want) / want < 0.05, (c.flops, want)

    # built-in cost_analysis undercounts (sanity check of the premise)
    builtin = jax.jit(scanned).lower(x, x).compile().cost_analysis()
    if isinstance(builtin, (list, tuple)):
        builtin = builtin[0]
    assert builtin.get("flops", 0) < want / 4


def test_nested_scan():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def nested(a, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None

        y, _ = jax.lax.scan(outer, a, None, length=5)
        return y

    c = analyze(_hlo(nested, x, x))
    want = 15 * 2 * 128 ** 3
    assert abs(c.flops - want) / want < 0.05, (c.flops, want)


def test_batched_dot_flops():
    x = jax.ShapeDtypeStruct((8, 64, 32), jnp.float32)
    y = jax.ShapeDtypeStruct((8, 32, 16), jnp.float32)
    c = analyze(_hlo(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), x, y))
    want = 2 * 8 * 64 * 32 * 16
    assert abs(c.flops - want) / want < 0.01


def test_bytes_nonzero_and_scale():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = analyze(_hlo(lambda a: jnp.tanh(a) + 1.0, x))
    nbytes = 1024 * 1024 * 4
    assert nbytes <= c.bytes <= 6 * nbytes


def test_parse_computations_finds_entry():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    hlo = _hlo(lambda a: a + 1, x)
    comps = parse_computations(hlo)
    assert len(comps) >= 1
